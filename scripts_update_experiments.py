"""Regenerate the §Roofline table inside EXPERIMENTS.md from artifacts."""
import sys
sys.path.insert(0, "src")
import glob, json
from benchmarks.roofline import table

cells = [json.load(open(f)) for f in sorted(glob.glob(
    "dryrun_artifacts/*.json")) if "__opt" not in f]
lines = ["", "### Single-pod (16×16 = 256 chips) baseline", "", "```"]
lines += table(cells, "single")
lines += ["```", "", "### Multi-pod (2×16×16 = 512 chips) baseline", "", "```"]
lines += table(cells, "multi")
lines += ["```", ""]
block = "\n".join(lines)

src = open("EXPERIMENTS.md").read()
marker = "<!-- ROOFLINE_TABLE -->"
assert marker in src
pre, rest = src.split(marker, 1)
# drop any previously generated table (up to the next ### Reading heading)
tail_key = "### Reading of the baseline table"
tail = rest[rest.index(tail_key):] if tail_key in rest else rest
open("EXPERIMENTS.md", "w").write(pre + marker + "\n" + block + "\n" + tail)
print("table updated:", len(cells), "artifacts")

#!/usr/bin/env python
"""Docs gate: keep README.md and docs/*.md from rotting.

Two checks, run by ``scripts/check.sh`` (and CI):

1. **Internal links resolve** — every markdown link target that is not an
   external URL or pure anchor must exist on disk, relative to the file
   containing it (anchors on internal links are stripped).
2. **Fenced ``python`` blocks execute** — each one is smoke-run in a
   subprocess with ``PYTHONPATH=src`` from the repo root, so the quickstart
   can never drift from the real API.  Blocks fenced as anything else
   (``console``, ``text``, …) are documentation-only and skipped.

Exit status: 0 when the gate passes, 1 when anything failed (every
failure is printed to stderr).
"""
from __future__ import annotations

import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: [text](target) — target captured; images (![...]) match too, same rules
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"^```(\w*)\s*$")

#: seconds before a runaway quickstart block fails the gate
BLOCK_TIMEOUT = 300


def doc_files() -> list:
    docs = [os.path.join(ROOT, "README.md")]
    docs_dir = os.path.join(ROOT, "docs")
    if os.path.isdir(docs_dir):
        docs += sorted(os.path.join(docs_dir, f)
                       for f in os.listdir(docs_dir) if f.endswith(".md"))
    return [d for d in docs if os.path.exists(d)]


def strip_fences(text: str) -> str:
    """Drop fenced code block bodies so code snippets containing
    ``[x](y)``-shaped text don't register as links."""
    out, in_fence = [], False
    for line in text.splitlines():
        if _FENCE.match(line) or (in_fence and line.strip() == "```"):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_links(path: str) -> list:
    failures = []
    text = strip_fences(open(path).read())
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = os.path.normpath(os.path.join(os.path.dirname(path), rel))
        if not os.path.exists(resolved):
            failures.append(f"{os.path.relpath(path, ROOT)}: broken link "
                            f"-> {target}")
    return failures


def python_blocks(path: str) -> list:
    blocks, current = [], None
    for line in open(path).read().splitlines():
        m = _FENCE.match(line)
        if current is None and m and m.group(1) == "python":
            current = []
        elif current is not None and line.strip() == "```":
            blocks.append("\n".join(current))
            current = None
        elif current is not None:
            current.append(line)
    return blocks


def run_block(path: str, i: int, code: str) -> list:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    try:
        proc = subprocess.run([sys.executable, "-"], input=code.encode(),
                              cwd=ROOT, env=env, capture_output=True,
                              timeout=BLOCK_TIMEOUT)
    except subprocess.TimeoutExpired:
        return [f"{os.path.relpath(path, ROOT)}: python block #{i} hung "
                f"(killed after {BLOCK_TIMEOUT}s)"]
    if proc.returncode != 0:
        tail = proc.stderr.decode(errors="replace").strip().splitlines()
        return [f"{os.path.relpath(path, ROOT)}: python block #{i} failed "
                f"(exit {proc.returncode}): "
                + ("; ".join(tail[-3:]) if tail else "no stderr")]
    return []


def main() -> int:
    failures = []
    n_links = n_blocks = 0
    for path in doc_files():
        link_fails = check_links(path)
        failures += link_fails
        n_links += len(_LINK.findall(strip_fences(open(path).read())))
        for i, code in enumerate(python_blocks(path)):
            n_blocks += 1
            failures += run_block(path, i, code)
    for f in failures:
        print(f"docs gate FAIL: {f}", file=sys.stderr)
    if not failures:
        print(f"docs gate OK: {len(doc_files())} files, {n_links} links, "
              f"{n_blocks} python blocks executed")
    # exit status, not a count: N*256 failures must not wrap to "success"
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Run the repo-invariant linter (repro.analysis.lint) over source trees.

Usage::

    python scripts/lint.py [PATH ...] [--strict] [--json]

Defaults to linting ``src``.  Output is machine-readable, one finding
per line (``path:line: RULE message``), followed by a suppression
summary.  ``--strict`` (the CI gate in ``scripts/check.sh``) exits
non-zero on any unsuppressed finding *or* any unused suppression, so
the baseline can only shrink.  ``--json`` dumps the full result
(findings, baselined findings, suppressions) as JSON instead.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.lint import lint_paths  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to lint (default: src)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any finding or unused suppression")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the full result as JSON")
    args = ap.parse_args(argv)

    paths = [Path(p) if Path(p).is_absolute() else ROOT / p
             for p in args.paths]
    result = lint_paths(paths, root=ROOT)

    if args.as_json:
        print(json.dumps({
            "findings": [dataclasses.asdict(f) for f in result.findings],
            "suppressed": [dataclasses.asdict(f) for f in result.suppressed],
            "suppressions": [dataclasses.asdict(s)
                             for s in result.suppressions],
        }, indent=2))
    else:
        for f in result.findings:
            print(f)
        n_sup = len(result.suppressions)
        n_used = sum(1 for s in result.suppressions if s.used)
        print(f"lint: {len(result.findings)} finding(s), "
              f"{len(result.suppressed)} baselined via {n_used}/{n_sup} "
              f"suppression(s)")
        for s in result.unused_suppressions:
            print(f"{s.path}:{s.line}: unused suppression "
                  f"(disable={','.join(s.rules)})")

    if args.strict and (result.findings or result.unused_suppressions):
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

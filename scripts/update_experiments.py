#!/usr/bin/env python
"""Regenerate the roofline tables inside ``EXPERIMENTS.md``.

Reads every dry-run artifact under ``dryrun_artifacts/*.json`` (skipping
``__opt`` variants), renders the single-pod and multi-pod roofline
tables via :func:`benchmarks.roofline.table`, and splices them into
``EXPERIMENTS.md`` after the ``<!-- ROOFLINE_TABLE -->`` marker —
replacing any previously generated block up to the "Reading of the
baseline table" heading.  Paths are resolved relative to the repo root,
so it can be run from anywhere::

    python scripts/update_experiments.py
"""
import glob
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))

from benchmarks.roofline import table  # noqa: E402

cells = [json.load(open(f)) for f in sorted(glob.glob(
    os.path.join(ROOT, "dryrun_artifacts", "*.json"))) if "__opt" not in f]
lines = ["", "### Single-pod (16×16 = 256 chips) baseline", "", "```"]
lines += table(cells, "single")
lines += ["```", "", "### Multi-pod (2×16×16 = 512 chips) baseline", "", "```"]
lines += table(cells, "multi")
lines += ["```", ""]
block = "\n".join(lines)

experiments_md = os.path.join(ROOT, "EXPERIMENTS.md")
src = open(experiments_md).read()
marker = "<!-- ROOFLINE_TABLE -->"
assert marker in src
pre, rest = src.split(marker, 1)
# drop any previously generated table (up to the next ### Reading heading)
tail_key = "### Reading of the baseline table"
tail = rest[rest.index(tail_key):] if tail_key in rest else rest
open(experiments_md, "w").write(pre + marker + "\n" + block + "\n" + tail)
print("table updated:", len(cells), "artifacts")

#!/usr/bin/env bash
# Tier-1 gate: what CI runs and what every PR must keep green.
#   1. compile-all — every module under src/ must at least parse/compile;
#   2. tier-1 tests — the ROADMAP's verify command (slow marker excluded
#      via pytest.ini).
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

#!/usr/bin/env bash
# Tier-1 gate: what CI runs and what every PR must keep green.
#   1. compile-all — every module under src/ must at least parse/compile;
#   2. tier-1 tests — the ROADMAP's verify command (slow marker excluded
#      via pytest.ini);
#   3. benchmark smoke — the tiny tensorstore sweep must run end to end and
#      emit valid perf-trajectory JSON (read_ops/write_ops/reshard/
#      contention rows), so the BENCH_<n>.json plumbing can't silently rot
#      — posix coalescing (write + reshard) must stay below per-chunk
#      counts, and the multi-writer contention scenario (N sessions x
#      disjoint leased windows) must stay conflict-free with write_ops
#      coalesced per writer;
#   4. docs gate — README.md/docs/*.md internal links resolve and the
#      fenced python quickstart blocks actually execute.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

smoke_json=$(mktemp /tmp/bench_smoke.XXXXXX.json)
trap 'rm -f "$smoke_json"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --suites tensorstore --tiny \
    --json "$smoke_json" > /dev/null
python - "$smoke_json" <<'PY'
import json, sys
d = json.load(open(sys.argv[1]))
rows = d["rows"]
assert rows, "bench smoke emitted no rows"
assert any("write_ops" in r for r in rows), "no write_ops rows"
assert any("read_ops" in r for r in rows), "no read_ops rows"
assert any("reshard_read_ops" in r for r in rows), "no reshard rows"
posix = [r for r in rows if r.get("backend") == "posix" and "write_ops" in r]
assert posix and all(r["write_ops"] < r["n_chunks"] for r in posix), \
    "posix write coalescing regressed: write_ops not below chunk count"
prs = [r for r in rows if r.get("backend") == "posix"
       and "reshard_read_ops" in r]
assert prs and all(r["reshard_read_ops"] < r["naive_read_ops"]
                   and r["reshard_write_ops"] < r["naive_write_ops"]
                   for r in prs), \
    "posix reshard coalescing regressed: ops not below naive per-chunk count"
assert any("garbage_bytes" in r for r in prs), "no garbage accounting column"
cont = [r for r in rows if r.get("contention")]
assert cont, "no multi-writer contention rows"
assert all(r["lease_conflicts"] == 0 for r in cont), \
    "disjoint leased windows raised lease conflicts"
pcont = [r for r in cont if r.get("backend") == "posix"]
assert pcont and all(r["write_ops"] <= r["writers"] for r in pcont), \
    "posix contention coalescing regressed: more store writes than writers"
print(f"bench smoke OK: {len(rows)} rows ({len(cont)} contention)")
PY

python scripts/docs_check.py

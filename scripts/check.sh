#!/usr/bin/env bash
# Tier-1 gate: what CI runs and what every PR must keep green.
#   1. compile-all — every module under src/ must at least parse/compile;
#   2. tier-1 tests — the ROADMAP's verify command (slow marker excluded
#      via pytest.ini);
#   3. benchmark smoke — the tiny tensorstore sweep must run end to end and
#      emit valid perf-trajectory JSON (read_ops/write_ops/reshard/
#      contention rows), so the BENCH_<n>.json plumbing can't silently rot
#      — posix coalescing (write + reshard) must stay below per-chunk
#      counts, and the multi-writer contention scenario (N sessions x
#      disjoint leased windows) must stay conflict-free with write_ops
#      coalesced per writer; the run also exports an I/O trace (--trace)
#      that must be valid, non-empty Chrome trace_event JSON, and the
#      phase-attributed t_queue/t_io/t_decode/t_encode columns must be
#      present and sane on the bench rows; the chaos rows (seeded fault
#      schedule healed by the retry layer) must report nonzero retries,
#      zero giveups and zero lost chunks; the tiny workflow suite (NWP
#      cycle: assimilation -> forecast -> products) must report per-stage
#      latency/throughput/lease-wait columns on all four backends and
#      pass its per-backend chaos gate — the chaos rerun byte-identical
#      to the fault-free cycle, zero lost chunks, protocol clean — plus
#      modeled per-stage bandwidth columns from each stage's op-trace
#      window; the many-reader serving rows must report cache_hit_rate/
#      open_cost_us/per-reader latency, with cache-on rereads issuing
#      ZERO backend ops;
#   4. trace smoke — a traced chunked roundtrip on all four backends must
#      record plan/io/codec spans (and record nothing with tracing off);
#   5. chaos smoke — a writer crash-killed between archive and flush
#      (InjectedCrash) must leave torn state that fdb.recover() fully
#      mops up (expired lease purged, orphan intents quarantined) so a
#      second writer completes byte-identical, protocol-clean;
#   6. cache smoke — the decoded-chunk cache + consolidated open on the
#      serving read path: opening a 3-array tree costs exactly one
#      catalogue fetch (meter-asserted against a raw per-array open),
#      and a cache-on reread is pure cache traffic — zero engine ops;
#   7. lint gate — the repo-invariant linter (repro.analysis.lint) in
#      strict mode: zero unsuppressed findings, zero unused suppressions
#      (docs/analysis.md has the rule catalogue);
#   8. docs gate — README.md/docs/*.md internal links resolve and the
#      fenced python quickstart blocks actually execute.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

smoke_json=$(mktemp /tmp/bench_smoke.XXXXXX.json)
trace_json=$(mktemp /tmp/bench_trace.XXXXXX.json)
trap 'rm -f "$smoke_json" "$trace_json"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --suites tensorstore,workflow --tiny \
    --json "$smoke_json" --trace "$trace_json" > /dev/null
python - "$smoke_json" "$trace_json" <<'PY'
import json, sys
d = json.load(open(sys.argv[1]))
rows = d["rows"]
assert rows, "bench smoke emitted no rows"
assert any("write_ops" in r for r in rows), "no write_ops rows"
assert any("read_ops" in r for r in rows), "no read_ops rows"
assert any("reshard_read_ops" in r for r in rows), "no reshard rows"
posix = [r for r in rows if r.get("backend") == "posix" and "write_ops" in r]
assert posix and all(r["write_ops"] < r["n_chunks"] for r in posix), \
    "posix write coalescing regressed: write_ops not below chunk count"
prs = [r for r in rows if r.get("backend") == "posix"
       and "reshard_read_ops" in r]
assert prs and all(r["reshard_read_ops"] < r["naive_read_ops"]
                   and r["reshard_write_ops"] < r["naive_write_ops"]
                   for r in prs), \
    "posix reshard coalescing regressed: ops not below naive per-chunk count"
assert any("garbage_bytes" in r for r in prs), "no garbage accounting column"
cont = [r for r in rows if r.get("contention")]
assert cont, "no multi-writer contention rows"
assert all(r["lease_conflicts"] == 0 for r in cont), \
    "disjoint leased windows raised lease conflicts"
pcont = [r for r in cont if r.get("backend") == "posix"]
assert pcont and all(r["write_ops"] <= r["writers"] for r in pcont), \
    "posix contention coalescing regressed: more store writes than writers"

# many-reader serving rows: the decoded-chunk cache must turn the timed
# concurrent reread into pure cache traffic (zero metered backend ops,
# nonzero hit rate) while the cache-off twin keeps paying per-window op
# trains; every row must price the consolidated cold open and carry the
# per-reader latency columns
readers = [r for r in rows if "cache_hit_rate" in r]
assert readers, "no many-reader serving rows"
assert {r["backend"] for r in readers} >= {"posix", "daos"}, \
    "reader rows missing a backend"
for r in readers:
    for col in ("open_cost_us", "open_ops", "reread_ops",
                "reader_mean_us", "reader_max_us"):
        assert col in r, f"missing reader column {col}: {r['name']}"
ron = [r for r in readers if r["cache"]]
roff = [r for r in readers if not r["cache"]]
assert ron and roff, "reader rows missing a cache mode"
assert all(r["reread_ops"] == 0 for r in ron), \
    "CACHE MISS ON REREAD: cache-on readers issued backend ops"
assert all(r["cache_hit_rate"] > 0 for r in ron), \
    "cache-on readers recorded no cache hits"
assert all(r["reread_ops"] > 0 for r in roff), \
    "cache-off readers issued no backend ops: the baseline is dead"

# chaos rows: the seeded fault schedule must have actually fired and the
# retry layer must have healed every fault -- goodput under degradation
# with zero data loss is the robustness contract (docs/robustness.md)
chaos = [r for r in rows if r.get("chaos") and r["suite"] == "tensorstore"]
assert chaos, "no chaos (seeded fault schedule) rows"
assert all(r["faults_injected"] > 0 for r in chaos), \
    "chaos rows injected no faults: the schedule is dead"
assert all(r["retries"] > 0 for r in chaos), \
    "chaos rows show zero retries: faults bypassed the retry layer"
assert all(r["giveups"] == 0 for r in chaos), \
    "chaos rows gave up retrying: transient schedule exceeded the policy"
assert all(r["lost_chunks"] == 0 for r in chaos), \
    "CHAOS DATA LOSS: chunks failed to read back byte-identical"
assert all(r["goodput_mib_s"] > 0 for r in chaos), "zero chaos goodput"

# phase-attributed latency columns (repro.obs): every tensorstore bench
# row must carry them, io time must be nonzero where I/O happened, and
# the phase sum must stay within a sane multiple of the row's wall time
# (concurrent spans sum, so the total may exceed wall -- but not absurdly)
phased = [r for r in rows if r["suite"] == "tensorstore" and "wall_us" in r]
assert phased, "no phase-attributed (t_*) bench rows"
for r in phased:
    for col in ("t_queue_us", "t_io_us", "t_decode_us", "t_encode_us"):
        assert col in r and r[col] >= 0, f"missing/negative {col}: {r['name']}"
    total = r["t_queue_us"] + r["t_io_us"] + r["t_decode_us"] + r["t_encode_us"]
    assert total <= r["wall_us"] * 64, \
        f"phase totals absurdly above wall: {r['name']} ({total} vs {r['wall_us']}us)"
writes = [r for r in phased if r["name"].endswith("/write")]
reads = [r for r in phased if r["name"].endswith("/window_read")]
assert writes and all(r["t_io_us"] > 0 for r in writes), \
    "write rows recorded no io.archive span time"
assert reads and all(r["t_io_us"] > 0 for r in reads), \
    "read rows recorded no io.fetch span time"

# workflow rows: the NWP cycle must report per-stage latency/throughput/
# lease-wait columns for all four backends, and the per-backend chaos
# gate must hold -- byte-identical products under the fault schedule,
# zero lost chunks, clean protocol window (docs/workflows.md)
wf = [r for r in rows if r["suite"] == "workflow"]
wf_backends = {"daos", "rados", "posix", "s3"}
for backend in sorted(wf_backends):
    for stage in ("assimilation", "forecast", "products"):
        srow = [r for r in wf if r.get("backend") == backend
                and r.get("stage") == stage]
        assert srow, f"no workflow {stage} row for {backend}"
        r = srow[0]
        assert r["wall_us"] > 0 and r["tasks"] > 0, \
            f"empty workflow stage row: {r['name']}"
        assert r["mib_s"] > 0, f"zero workflow throughput: {r['name']}"
        assert "lease_waits" in r and "lease_wait_us" in r, \
            f"missing lease-wait columns: {r['name']}"
        assert r.get("stage_ops", 0) > 0, \
            f"empty stage op-trace window: {r['name']}"
        for col in ("modeled_write_gib_s", "modeled_read_gib_s",
                    "modeled_dominant"):
            assert col in r, f"missing modeled bandwidth column: {r['name']}"
    arow = [r for r in wf if r.get("backend") == backend
            and r.get("stage") == "assimilation"][0]
    assert arow["lease_waits"] > 0, \
        f"{backend}: overlapping writers recorded no blocking lease waits"
wf_gate = [r for r in wf if r.get("chaos")]
assert {r["backend"] for r in wf_gate} == wf_backends, \
    "workflow chaos gate missing backends"
for r in wf_gate:
    assert r["ok"] and r["identical"], \
        f"WORKFLOW CHAOS GATE FAILED on {r['backend']}: {r['failures']}"
    assert r["lost_chunks"] == 0, \
        f"WORKFLOW CHAOS DATA LOSS on {r['backend']}"
    assert r["faults_injected"] > 0 and r["crashed_writer"] is not None, \
        f"workflow chaos schedule dead on {r['backend']}"

# exported Chrome trace: valid JSON, nonzero complete events, well-formed
t = json.load(open(sys.argv[2]))
ev = t["traceEvents"]
xs = [e for e in ev if e.get("ph") == "X"]
assert xs, "trace export contains no complete ('X') span events"
for e in xs[:64]:
    assert {"name", "ph", "ts", "dur", "pid", "tid"} <= set(e), \
        f"malformed trace event: {e}"
names = {e["name"] for e in xs}
assert "io.archive" in names or "io.fetch" in names, \
    f"trace has no io spans: {sorted(names)[:20]}"
print(f"bench smoke OK: {len(rows)} rows ({len(cont)} contention, "
      f"{len(chaos)} chaos, {len(wf)} workflow incl. {len(wf_gate)} "
      f"chaos-gate), trace OK: {len(xs)} spans")
PY

# trace smoke: a traced chunked roundtrip on all four simulated backends
# must record plan/io/codec spans, and the disabled path must record none
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import numpy as np
from repro.core import FDB, FDBConfig, reset_engines
from repro.obs.trace import Tracer
from repro.tensorstore import TensorStore

for backend in ("daos", "rados", "posix", "s3"):
    reset_engines()
    tracer = Tracer(enabled=True)
    fdb = FDB(FDBConfig(backend=backend, schema="tensor",
                        root=f"/tmp/trace-smoke-{backend}"), tracer=tracer)
    ts = TensorStore(fdb, {"store": "smoke", "array": "a", "writer": "w"})
    x = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    ts.save(x, chunks=(32, 32))
    arr = ts.open()
    np.testing.assert_array_equal(arr[:, :], x)
    names = {s.name for s in tracer.spans()}
    need = {"plan.resolve", "plan.execute", "io.archive", "io.fetch",
            "codec.encode", "codec.decode", "fdb.flush",
            f"store.{backend}.archive"}
    missing = need - names
    assert not missing, f"{backend}: missing spans {sorted(missing)}"
    pt = tracer.phase_totals()
    assert pt["io"] > 0 and pt["encode"] > 0 and pt["decode"] > 0, \
        f"{backend}: zero phase totals {pt}"
    fdb.close()

    # disabled tracer: the same roundtrip must record nothing
    reset_engines()
    off = Tracer(enabled=False)
    fdb = FDB(FDBConfig(backend=backend, schema="tensor",
                        root=f"/tmp/trace-smoke-off-{backend}"), tracer=off)
    ts = TensorStore(fdb, {"store": "smoke", "array": "a", "writer": "w"})
    ts.save(x, chunks=(32, 32))
    np.testing.assert_array_equal(ts.open()[:, :], x)
    assert not off.spans(), f"{backend}: disabled tracer recorded spans"
    fdb.close()
print("trace smoke OK: 4 backends traced, disabled path records nothing")
PY

# chaos smoke: kill a writer between archive and flush, let its lease TTL
# lapse, then fdb.recover() must purge the lease + quarantine the orphan
# intents so a second writer completes byte-identical, protocol-clean
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import time
import numpy as np
from repro.core import (FDB, FDBConfig, FaultInjector, InjectedCrash,
                        RetryPolicy, reset_engines)
from repro.obs.trace import GLOBAL_TRACER
from repro.tensorstore import TensorStore

GLOBAL_TRACER.enable()
reset_engines()
base = {"store": "smoke", "array": "crash", "writer": "w0"}
cfg = dict(backend="rados", schema="tensor", root="/tmp/chaos-smoke-rados")
x = np.random.default_rng(7).normal(size=(64, 48)).astype(np.float32)

setup = FDB(FDBConfig(**cfg))
arr = TensorStore(setup, base).create(x.shape, x.dtype, chunks=(16, 16))
setup.flush()

inj = FaultInjector().crash_on("store.flush", call=1)
fdb_a = FDB(FDBConfig(**cfg), faults=inj,
            retry=RetryPolicy(sleep=lambda _s: None, seed=0))
sa = fdb_a.session("A", lease_ttl=0.2)
aa = TensorStore(None, base, session=sa).open()
aa.write_plan((slice(0, 32), slice(None)), x[:32]).execute(flush=False)
try:
    sa.flush()
    raise SystemExit("chaos smoke: injected crash did not fire")
except InjectedCrash:
    pass
sa.abandon()                                   # the process is dead

time.sleep(0.45)                               # let the TTL lapse
fdb_b = FDB(FDBConfig(**cfg))
report = TensorStore(fdb_b, base).recover()
assert any(e["owner"] == "A" for e in report.expired), \
    "recover() missed the crashed writer's expired lease"
assert report.orphan_chunks == 6, \
    f"recover() quarantined {report.orphan_chunks} orphans, expected 6"
assert TensorStore(fdb_b, base).recover().clean, "second sweep not clean"

sb = fdb_b.session("B")
ab = TensorStore(None, base, session=sb).open()
ab.write_plan((slice(0, 32), slice(None)), x[:32]).execute(flush=False)
ab.write_plan((slice(32, 64), slice(None)), x[32:]).execute(flush=False)
sb.flush()
sb.close()
np.testing.assert_array_equal(arr.read(), x)
violations = fdb_b.check_protocol()
assert violations == [], f"chaos smoke protocol violations: {violations}"
setup.close(); fdb_a.close(); fdb_b.close()
GLOBAL_TRACER.disable(); GLOBAL_TRACER.clear()
print("chaos smoke OK: crash-killed writer recovered, rewrite "
      "byte-identical, protocol clean")
PY

# cache smoke: the serving read path's two levers, meter-asserted --
# opening a multi-array tree costs exactly ONE catalogue fetch (the
# consolidated-metadata open, priced against a raw per-array open), and
# a cache-on reread of already-decoded windows issues ZERO engine ops
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'PY'
import shutil
import numpy as np
from repro.core import FDB, FDBConfig, Meter, reset_engines
from repro.data import ChunkedFieldStore
from repro.tensorstore import TensorStore

for backend in ("daos", "posix"):
    reset_engines()
    meter = Meter()           # shared: in-memory engines are keyed per meter
    root = f"/tmp/cache-smoke-{backend}"
    shutil.rmtree(root, ignore_errors=True)
    cfg = FDBConfig(backend=backend, schema="tensor", root=root)
    fields = {name: np.random.default_rng(i).normal(
                  size=(64, 64)).astype(np.float32)
              for i, name in enumerate(("t2m", "u10", "msl"))}
    prod = ChunkedFieldStore(store="smoke", fdb_config=cfg, meter=meter,
                             cache_bytes=0)
    for name, v in fields.items():
        prod.put_field(name, v, chunks=(16, 16))
    prod.commit()
    prod.close()

    # consolidated open: the whole 3-array tree == one raw array open
    cons = ChunkedFieldStore(store="smoke", fdb_config=cfg, meter=meter)
    mark = len(meter.snapshot())
    opened = cons.open_tree()
    tree_ops = len(meter.snapshot()) - mark
    assert set(opened) == set(fields), sorted(opened)
    probe = FDB(cfg, meter=meter)
    mark = len(meter.snapshot())
    TensorStore(probe, {"store": "smoke", "array": "t2m",
                        "writer": "prod0"}).open()
    single_ops = len(meter.snapshot()) - mark
    probe.close()
    assert tree_ops == single_ops, \
        f"{backend}: tree open cost {tree_ops} ops, one array {single_ops}"

    # cache-on reread: zero engine ops, all hits
    win = (slice(0, 48), slice(8, 56))
    for name, v in fields.items():
        np.testing.assert_array_equal(cons.read_window(name, *win),
                                      v[win])
    mark = len(meter.snapshot())
    for name, v in fields.items():
        np.testing.assert_array_equal(cons.read_window(name, *win),
                                      v[win])
    reread_ops = len(meter.snapshot()) - mark
    assert reread_ops == 0, \
        f"{backend}: cache-on reread issued {reread_ops} engine ops"
    hits = cons.fdb.metrics()["cache.hits"]["value"]
    assert hits > 0, f"{backend}: no cache hits recorded"
    cons.close()
    shutil.rmtree(root, ignore_errors=True)
print("cache smoke OK: consolidated tree open == one fetch, "
      "cache-on rereads are zero-op on daos + posix")
PY

# lint gate: repo invariants, strict (prints the suppression count)
python scripts/lint.py src --strict

python scripts/docs_check.py

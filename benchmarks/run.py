"""Benchmark runner: one function per thesis table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json FILE`` additionally
dumps the rows (with their structured read_ops/write_ops/throughput fields)
to a perf-trajectory file — the repo commits one ``BENCH_<n>.json`` per perf
PR so regressions are diffable.  ``--suites a,b`` selects suites,
``--tiny`` switches suites that support it onto their CI smoke profile.

``--trace FILE`` enables the process tracer (:mod:`repro.obs`) for the
whole run and writes one combined Chrome ``trace_event`` JSON — each suite
becomes a Perfetto process row (``pid`` = suite index) so the plan
lifecycle spans (``plan.resolve`` → ``io.fetch``/``codec.decode`` → ...)
of every benchmark land on one timeline.  Open it at
https://ui.perfetto.dev.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import traceback

SUITES = [
    ("ior", "bench_ior"),                      # Figs. 4.5-4.7 / 4.19-4.20
    ("fieldio", "bench_fieldio"),              # Figs. 4.8-4.11
    ("hammer", "bench_hammer"),                # Figs. 4.12-4.13 / 4.21-4.25
    ("rados_options", "bench_rados_options"),  # Fig. 3.5
    ("small_objects", "bench_small_objects"),  # Fig. 4.26
    ("redundancy", "bench_redundancy"),        # Figs. 4.27-4.28
    ("ckpt", "bench_ckpt"),                    # §3.1.3 operational pattern
    ("tensorstore", "bench_tensorstore"),      # chunk size x parallelism
    ("workflow", "bench_workflow"),            # NWP cycle + chaos gate
    ("roofline", "roofline"),                  # §Roofline deliverable
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suites", default=None,
                    help="comma-separated suite names (default: all)")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="also dump rows as JSON to FILE")
    ap.add_argument("--tiny", action="store_true",
                    help="tiny CI profile for suites that support it")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="enable I/O tracing and write a Chrome trace_event "
                         "JSON (load in https://ui.perfetto.dev)")
    args = ap.parse_args(argv)

    wanted = None if args.suites is None else {
        s.strip() for s in args.suites.split(",") if s.strip()}
    selected = [(n, m) for n, m in SUITES if wanted is None or n in wanted]
    if wanted is not None:
        unknown = wanted - {n for n, _m in SUITES}
        if unknown:
            sys.exit(f"unknown suites: {sorted(unknown)} "
                     f"(known: {[n for n, _m in SUITES]})")

    tracer = None
    if args.trace:
        from repro.obs.trace import GLOBAL_TRACER
        tracer = GLOBAL_TRACER
        tracer.enable()

    import importlib
    print("name,us_per_call,derived")
    failures = 0
    json_rows = []
    trace_events = []
    for pid, (name, modname) in enumerate(selected):
        if tracer is not None:
            mark = tracer.mark()
        try:
            mod = importlib.import_module(f"benchmarks.{modname}")
            kwargs = {}
            if args.tiny and "tiny" in inspect.signature(
                    mod.run).parameters:
                kwargs["tiny"] = True
            for row in mod.run(**kwargs):
                print(row.line(), flush=True)
                json_rows.append({"suite": name, **row.to_json()})
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},,ERROR", flush=True)
            traceback.print_exc()
        if tracer is not None:
            trace_events.append({"name": "process_name", "ph": "M",
                                 "pid": pid, "tid": 0,
                                 "args": {"name": f"suite:{name}"}})
            trace_events.extend(tracer.chrome_events(since=mark, pid=pid))
    if tracer is not None:
        with open(args.trace, "w") as f:
            json.dump({"traceEvents": trace_events,
                       "displayTimeUnit": "ms"}, f)
        if tracer.dropped:
            print(f"[trace buffer overflow: {tracer.dropped} oldest spans "
                  f"evicted — raise repro.obs.trace.DEFAULT_CAPACITY or "
                  f"trace fewer suites]", file=sys.stderr)
        print(f"trace written to {args.trace} "
              f"(open in https://ui.perfetto.dev)", file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": [n for n, _m in selected],
                       "tiny": args.tiny, "rows": json_rows}, f, indent=1)
            f.write("\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

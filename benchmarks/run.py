"""Benchmark runner: one function per thesis table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_ckpt, bench_fieldio, bench_hammer, bench_ior,
                   bench_rados_options, bench_redundancy,
                   bench_small_objects, bench_tensorstore, roofline)
    suites = [
        ("ior", bench_ior),                     # Figs. 4.5-4.7 / 4.19-4.20
        ("fieldio", bench_fieldio),             # Figs. 4.8-4.11
        ("hammer", bench_hammer),               # Figs. 4.12-4.13 / 4.21-4.25
        ("rados_options", bench_rados_options), # Fig. 3.5
        ("small_objects", bench_small_objects), # Fig. 4.26
        ("redundancy", bench_redundancy),       # Figs. 4.27-4.28
        ("ckpt", bench_ckpt),                   # §3.1.3 operational pattern
        ("tensorstore", bench_tensorstore),     # chunk size x parallelism
        ("roofline", roofline),                 # §Roofline deliverable
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in suites:
        try:
            for row in mod.run():
                print(row.line(), flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Chunked tensorstore sweeps — the paper's object-size/concurrency axes
applied to the new subsystem: chunk size × I/O parallelism × backend.

Per cell: archive one (256, 256) float32 field as a chunked array (the
write side plans first — ``WritePlan`` batches chunks per storage unit, so
posix archives land as single buffered appends), then read back a 64-row
window (partial read: only intersecting chunks), then **reshard** the array
onto a transposed chunk grid (the paper's producer-grid → consumer-grid
re-layout, streamed through composed Read/Write plans).  Reports in-process
us/chunk, the cost-modeled at-scale bandwidth, and the planned I/O-op
counts on ALL sides — ``WritePlan.write_ops()`` next to
``ReadPlan.read_ops()``, and the reshard's coalesced read/write op totals
next to the naive one-op-per-chunk counts: on posix, adjacent chunks of one
data file coalesce into fewer store-level ops, while object stores keep one
op per chunk in flight — the paper's central trade-off, mirroring
Figs. 4.5-4.7/4.26.

A **multi-writer contention suite** rides along (writers × window size,
posix + one object backend): N ``WriterSession``\\ s lease disjoint row
bands of one array and write them concurrently through one client
executor, reporting per-writer coalesced ``write_ops`` and the
``lease_conflicts`` count (expected 0 for disjoint windows) — the
concurrency-behaviour axis the related DAOS/NWP work says object stores
win on.

A **chaos suite** closes the run: the same archive workload driven under
a *seeded fault schedule* (``FaultInjector`` — scripted transient archive
faults, a catalogue-flush failure, latency spikes) with the facade
``RetryPolicy`` healing them.  Reported per cell: ``retries`` (facade
re-attempts), ``goodput_mib_s`` (payload bytes over degraded wall time),
``faults_injected``, and ``lost_chunks`` — which must be 0: every chunk
reads back byte-identical despite the faults (asserted by the check.sh
chaos smoke).

``run(tiny=True)`` is the CI smoke profile: two backends, one cell each
(plus one contention cell and one chaos cell per backend), enough to keep
the perf-trajectory JSON (read_ops/write_ops/reshard/garbage/contention/
chaos rows) honest without a full sweep.
"""
from __future__ import annotations

import os
import shutil
import threading
import time
from typing import List

import numpy as np

from repro.core import (FDB, FDBConfig, FaultInjector, LeaseConflictError,
                        Meter, PROFILES, RetryPolicy, model_run,
                        reset_engines)
from repro.obs.trace import GLOBAL_TRACER, Tracer
from repro.tensorstore import ChunkExecutor, TensorStore
from .common import Row

BACKENDS = ("daos", "rados", "posix", "s3")
CHUNK_EDGES = (32, 64, 128)
PARALLELISM = (1, 4, 16)
#: CI smoke profile: one cell per backend family (object vs posix)
TINY_BACKENDS = ("daos", "posix")
TINY_CHUNK_EDGES = (64,)
TINY_PARALLELISM = (4,)
SERVERS = 4
SHAPE = (256, 256)
#: contention suite: posix + one object backend (the paper's comparison)
CONTENTION_BACKENDS = ("posix", "daos")
CONTENTION_WRITERS = (2, 4, 8)
CONTENTION_WINDOWS = ("full", "half")   # leased window vs half-band window
TINY_CONTENTION_WRITERS = (2,)
TINY_CONTENTION_WINDOWS = ("full",)
#: chaos suite: posix + one object backend, seeded so the schedule (and
#: therefore the retry/goodput columns) is reproducible run to run
CHAOS_BACKENDS = ("posix", "daos")
CHAOS_SEED = 1107
#: many-reader serving suite: readers × backend × decoded-chunk cache
READER_BACKENDS = ("posix", "daos")
READER_COUNTS = (2, 4, 8)
TINY_READER_COUNTS = (4,)
READER_FIELDS = ("t2m", "u10", "msl")


def _bench_tracer() -> Tracer:
    """The tracer bench cells record into: the (enabled) global tracer when
    ``run.py --trace`` switched it on — so the exported trace sees every
    cell — otherwise a private enabled one, so the phase-attributed
    ``t_*`` columns are populated either way."""
    return GLOBAL_TRACER if GLOBAL_TRACER.enabled else Tracer(enabled=True)


def _phase_extra(tracer: Tracer, mark: int, wall_s: float):
    """The phase-attributed latency columns: summed span µs per wall-time
    phase over the window since ``mark`` (concurrent spans sum, so the
    totals can exceed ``wall_us`` when the executor overlaps I/O)."""
    pt = tracer.phase_totals(since=mark)
    return {"t_queue_us": pt["queue"], "t_io_us": pt["io"],
            "t_decode_us": pt["decode"], "t_encode_us": pt["encode"],
            "wall_us": round(wall_s * 1e6, 3)}


def run(profile: str = "gcp", tiny: bool = False) -> List[Row]:
    rows: List[Row] = []
    x = np.random.default_rng(0).normal(size=SHAPE).astype(np.float32)
    backends = TINY_BACKENDS if tiny else BACKENDS
    edges = TINY_CHUNK_EDGES if tiny else CHUNK_EDGES
    parallelisms = TINY_PARALLELISM if tiny else PARALLELISM
    for backend in backends:
        for edge in edges:
            for par in parallelisms:
                meter = Meter()
                tracer = _bench_tracer()
                reset_engines()
                root = f"/tmp/fdb-bench-ts-{backend}-{edge}-{par}-{os.getpid()}"
                shutil.rmtree(root, ignore_errors=True)
                # parallelism lever: the explicitly sized executor below
                fdb = FDB(FDBConfig(backend=backend, schema="tensor",
                                    root=root), meter=meter, tracer=tracer)
                executor = ChunkExecutor(max_workers=max(par, 1),
                                         max_in_flight=4 * max(par, 1))
                ts = TensorStore(fdb, {"store": "bench", "array": "field",
                                       "writer": "p0"}, executor=executor)
                n_chunks = (-(-SHAPE[0] // edge)) * (-(-SHAPE[1] // edge))

                mk_w = tracer.mark()
                t0 = time.perf_counter()
                ts.save(x, chunks=(edge, edge))
                wall_w = time.perf_counter() - t0
                ph_w = _phase_extra(tracer, mk_w, wall_w)
                mw = model_run(meter.snapshot(), PROFILES[profile],
                               server_nodes=SERVERS)

                meter.reset()
                arr = ts.open()
                mk_r = tracer.mark()
                t0 = time.perf_counter()
                arr[96:160, :]           # 64-row window: partial read
                wall_r = time.perf_counter() - t0
                ph_r = _phase_extra(tracer, mk_r, wall_r)
                mr = model_run(meter.snapshot(), PROFILES[profile],
                               server_nodes=SERVERS)
                # planned I/O-op counts after coalescing, write and read
                # side (metadata/placement only, so compute after the
                # modeled runs to keep the meter clean)
                wplan = arr.write_plan((slice(None), slice(None)), x)
                window = arr.read_plan((slice(96, 160), slice(None)))
                full = arr.read_plan((slice(None), slice(None)))

                tag = f"tensorstore/{backend}/c{edge}/p{par}"
                rows.append(Row(
                    f"{tag}/write", wall_w / n_chunks * 1e6,
                    f"modeled={mw.write_bw / 2**30:.2f}GiB/s "
                    f"dominant={mw.dominant} "
                    f"write_ops={wplan.write_ops()}/{wplan.n_chunks}chunks "
                    f"t_queue={ph_w['t_queue_us']:.0f}us "
                    f"t_io={ph_w['t_io_us']:.0f}us "
                    f"t_encode={ph_w['t_encode_us']:.0f}us",
                    extra={"backend": backend, "chunk_edge": edge,
                           "parallelism": par,
                           "write_ops": wplan.write_ops(),
                           "n_chunks": wplan.n_chunks,
                           "modeled_write_gib_s": round(mw.write_bw / 2**30,
                                                        4), **ph_w}))
                rows.append(Row(
                    f"{tag}/window_read", wall_r * 1e6,
                    f"modeled={mr.read_bw / 2**30:.2f}GiB/s "
                    f"dominant={mr.dominant} "
                    f"ops={window.read_ops()}/{window.n_chunks}chunks "
                    f"full_ops={full.read_ops()}/{full.n_chunks}chunks "
                    f"t_queue={ph_r['t_queue_us']:.0f}us "
                    f"t_io={ph_r['t_io_us']:.0f}us "
                    f"t_decode={ph_r['t_decode_us']:.0f}us",
                    extra={"backend": backend, "chunk_edge": edge,
                           "parallelism": par,
                           "read_ops": window.read_ops(),
                           "n_chunks": window.n_chunks,
                           "full_read_ops": full.read_ops(),
                           "full_n_chunks": full.n_chunks,
                           "modeled_read_gib_s": round(mr.read_bw / 2**30,
                                                       4), **ph_r}))

                # reshard: producer grid (edge, edge) -> consumer grid
                # (edge/2, 2*edge), streamed through composed plans; the
                # coalesced op totals ride next to the naive per-chunk
                # counts (source fetches / destination chunks)
                meter.reset()
                rplan = arr.reshard_plan((max(1, edge // 2), 2 * edge))
                naive_r, naive_w = (rplan.src_chunk_fetches(),
                                    rplan.n_dest_chunks)
                mk_rs = tracer.mark()
                t0 = time.perf_counter()
                rplan.execute()
                wall_rs = time.perf_counter() - t0
                ph_rs = _phase_extra(tracer, mk_rs, wall_rs)
                ms = model_run(meter.snapshot(), PROFILES[profile],
                               server_nodes=SERVERS)
                # retained-garbage accounting (catalogue walk only) runs
                # after the modeled snapshot so the meter stays clean
                garbage = ts.garbage_report()
                rows.append(Row(
                    f"{tag}/reshard", wall_rs / max(1, naive_w) * 1e6,
                    f"modeled={ms.write_bw / 2**30:.2f}GiB/s "
                    f"dominant={ms.dominant} "
                    f"read_ops={rplan.read_ops_executed}/{naive_r}naive "
                    f"write_ops={rplan.write_ops_executed}/{naive_w}naive "
                    f"batches={rplan.n_batches} "
                    f"garbage={garbage.garbage_bytes}B",
                    extra={"backend": backend, "chunk_edge": edge,
                           "parallelism": par,
                           "reshard_read_ops": rplan.read_ops_executed,
                           "reshard_write_ops": rplan.write_ops_executed,
                           "naive_read_ops": naive_r,
                           "naive_write_ops": naive_w,
                           "reshard_batches": rplan.n_batches,
                           "peak_staged_bytes": rplan.peak_staged_bytes,
                           "garbage_chunks": garbage.garbage_chunks,
                           "garbage_bytes": garbage.garbage_bytes,
                           **ph_rs}))
                executor.shutdown()
                fdb.close()
                shutil.rmtree(root, ignore_errors=True)
    rows.extend(contention_rows(profile, tiny))
    rows.extend(reader_rows(profile, tiny))
    rows.extend(fault_rows(profile, tiny))
    return rows


def reader_rows(profile: str = "gcp", tiny: bool = False) -> List[Row]:
    """Many-reader serving contention: N readers re-read overlapping row
    bands of a 3-field tree through ONE shared ``ChunkedFieldStore``
    client, with the decoded-chunk cache on vs off.  The cold open goes
    through ``open_tree()`` — the consolidated-metadata fetch — so the
    ``open_cost_us`` / ``open_ops`` columns price opening the whole tree
    at one catalogue round-trip.  A single warm pass populates the cache,
    then the timed concurrent re-read reports ``cache_hit_rate``,
    per-reader latency and the metered backend ``reread_ops`` — 0 with
    the cache on (hit chunks never reach the backend; asserted by the
    check.sh cache smoke), one op train per window with it off."""
    rows: List[Row] = []
    from repro.data.pipeline import ChunkedFieldStore
    shape, chunk, band = (256, 256), 32, 96
    rng = np.random.default_rng(3)
    fields = {name: rng.normal(size=shape).astype(np.float32)
              for name in READER_FIELDS}
    reader_axis = TINY_READER_COUNTS if tiny else READER_COUNTS

    def window(i: int):
        lo = (i * chunk) % (shape[0] - band)
        return (slice(lo, lo + band), slice(None))

    for backend in READER_BACKENDS:
        for n_readers in reader_axis:
            for cache_on in (False, True):
                meter = Meter()
                tracer = _bench_tracer()
                reset_engines()
                root = (f"/tmp/fdb-bench-ts-read-{backend}-{n_readers}-"
                        f"{int(cache_on)}-{os.getpid()}")
                shutil.rmtree(root, ignore_errors=True)
                cfg = FDBConfig(backend=backend, schema="tensor", root=root)
                # the simulated in-memory clusters are keyed per meter, so
                # producer and consumer must share one to share the engine
                prod = ChunkedFieldStore(store="bench", fdb_config=cfg,
                                         meter=meter, cache_bytes=0)
                for name, values in fields.items():
                    prod.put_field(name, values, chunks=(chunk, chunk))
                prod.commit()
                prod.close()

                cons = ChunkedFieldStore(
                    store="bench", fdb_config=cfg, meter=meter,
                    tracer=tracer,
                    cache_bytes=(64 * 2 ** 20 if cache_on else 0))
                ops0 = len(meter.snapshot())
                t0 = time.perf_counter()
                opened = cons.open_tree()
                open_cost_us = (time.perf_counter() - t0) * 1e6
                open_ops = len(meter.snapshot()) - ops0
                assert set(opened) == set(READER_FIELDS)
                # warm pass: one sweep of every reader's windows primes
                # the shared cache (and the cache-off baseline's page
                # layout) before the timed contention phase
                for i in range(n_readers):
                    for name in READER_FIELDS:
                        cons.read_window(name, *window(i))

                lat = [[] for _ in range(n_readers)]
                errors: List[BaseException] = []

                def reader(i: int) -> None:
                    try:
                        for name in READER_FIELDS:
                            t1 = time.perf_counter()
                            cons.read_window(name, *window(i))
                            lat[i].append(time.perf_counter() - t1)
                    except BaseException as e:  # noqa: BLE001
                        errors.append(e)

                mk = tracer.mark()
                ops1 = len(meter.snapshot())
                t0 = time.perf_counter()
                threads = [threading.Thread(target=reader, args=(i,))
                           for i in range(n_readers)]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                wall = time.perf_counter() - t0
                if errors:
                    raise errors[0]
                reread_ops = len(meter.snapshot()) - ops1
                ph = _phase_extra(tracer, mk, wall)
                snap = cons.fdb.metrics()
                hits = snap.get("cache.hits", {}).get("value", 0)
                misses = snap.get("cache.misses", {}).get("value", 0)
                hit_rate = hits / (hits + misses) if hits + misses else 0.0
                per_read = [u for per in lat for u in per]
                mean_us = sum(per_read) / max(1, len(per_read)) * 1e6
                max_us = max(per_read, default=0.0) * 1e6
                m = model_run(meter.snapshot(), PROFILES[profile],
                              server_nodes=SERVERS)
                mode = "cache" if cache_on else "nocache"
                rows.append(Row(
                    f"tensorstore/{backend}/readers/r{n_readers}/{mode}",
                    mean_us,
                    f"hit_rate={hit_rate:.2f} open={open_cost_us:.0f}us/"
                    f"{open_ops}ops reread_ops={reread_ops} "
                    f"reader_max={max_us:.0f}us "
                    f"modeled={m.read_bw / 2**30:.2f}GiB/s",
                    extra={"backend": backend, "readers": n_readers,
                           "cache": cache_on,
                           "cache_hit_rate": round(hit_rate, 4),
                           "open_cost_us": round(open_cost_us, 3),
                           "open_ops": open_ops,
                           "reread_ops": reread_ops,
                           "reads": len(per_read),
                           "reader_mean_us": round(mean_us, 3),
                           "reader_max_us": round(max_us, 3),
                           "modeled_read_gib_s": round(m.read_bw / 2**30,
                                                       4), **ph}))
                cons.close()
                shutil.rmtree(root, ignore_errors=True)
    return rows


def contention_rows(profile: str = "gcp", tiny: bool = False) -> List[Row]:
    """Multi-writer contention scenario: N writer sessions lease disjoint
    row-band windows of ONE array and write them concurrently through one
    client executor.  Per cell: total coalesced ``write_ops`` (posix: one
    batched append per writer stage, far below chunk count; object: one op
    per chunk, the in-flight parallelism those backends want) and the
    ``lease_conflicts`` count — 0 by construction for disjoint windows,
    asserted by the check.sh smoke."""
    rows: List[Row] = []
    chunk = 32                           # (8, 8) chunk grid on SHAPE
    x = np.random.default_rng(1).normal(size=SHAPE).astype(np.float32)
    writer_axis = TINY_CONTENTION_WRITERS if tiny else CONTENTION_WRITERS
    window_axis = TINY_CONTENTION_WINDOWS if tiny else CONTENTION_WINDOWS
    for backend in CONTENTION_BACKENDS:
        for n_writers in writer_axis:
            for window in window_axis:
                band = SHAPE[0] // n_writers
                rows_per_writer = band if window == "full" else band // 2
                meter = Meter()
                tracer = _bench_tracer()
                reset_engines()
                root = (f"/tmp/fdb-bench-ts-cont-{backend}-{n_writers}-"
                        f"{window}-{os.getpid()}")
                shutil.rmtree(root, ignore_errors=True)
                fdb = FDB(FDBConfig(backend=backend, schema="tensor",
                                    root=root), meter=meter, tracer=tracer)
                base = {"store": "bench", "array": "shared", "writer": "p0"}
                TensorStore(fdb, base).create(SHAPE, np.float32,
                                              chunks=(chunk, chunk))
                fdb.flush()              # publish metadata to the sessions
                sessions = [fdb.session(f"w{i}") for i in range(n_writers)]
                plans, conflicts, errors = [], 0, []
                for i, sess in enumerate(sessions):
                    arr = TensorStore(None, base, session=sess).open()
                    lo = i * band
                    try:
                        plans.append(arr.write_plan(
                            (slice(lo, lo + rows_per_writer), slice(None)),
                            x[lo:lo + rows_per_writer]))
                    except LeaseConflictError:
                        conflicts += 1

                def execute(plan) -> None:
                    try:
                        plan.execute(flush=False)
                    except Exception as e:  # noqa: BLE001
                        errors.append(e)

                mk = tracer.mark()
                t0 = time.perf_counter()
                threads = [threading.Thread(target=execute, args=(p,))
                           for p in plans]
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                fdb.flush()              # one commit barrier for all bands
                wall = time.perf_counter() - t0
                ph = _phase_extra(tracer, mk, wall)
                if errors:
                    raise errors[0]
                m = model_run(meter.snapshot(), PROFILES[profile],
                              server_nodes=SERVERS)
                write_ops = sum(p.write_ops() for p in plans)
                n_chunks = sum(p.n_chunks for p in plans)
                for sess in sessions:
                    sess.close()
                rows.append(Row(
                    f"tensorstore/{backend}/contention/w{n_writers}/"
                    f"{window}",
                    wall / max(1, n_chunks) * 1e6,
                    f"modeled={m.write_bw / 2**30:.2f}GiB/s "
                    f"dominant={m.dominant} writers={n_writers} "
                    f"write_ops={write_ops}/{n_chunks}chunks "
                    f"conflicts={conflicts}",
                    extra={"backend": backend, "contention": True,
                           "writers": n_writers,
                           "window_rows": rows_per_writer,
                           "write_ops": write_ops, "n_chunks": n_chunks,
                           "lease_conflicts": conflicts,
                           "modeled_write_gib_s": round(
                               m.write_bw / 2**30, 4), **ph}))
                fdb.close()
                shutil.rmtree(root, ignore_errors=True)
    return rows


def fault_rows(profile: str = "gcp", tiny: bool = False) -> List[Row]:
    """Goodput under a seeded fault schedule: archive + read back one
    array while the injector drops transient errors into the data path
    (scripted ``first=N`` floor so every run retries, plus a
    probabilistic tail and latency spikes) and the facade
    ``RetryPolicy`` heals them.  The contract column is ``lost_chunks``:
    after the faulted write, every chunk must read back byte-identical
    to the source — 0 by construction, asserted by the check.sh chaos
    smoke alongside ``retries > 0``."""
    rows: List[Row] = []
    edge = 64
    x = np.random.default_rng(2).normal(size=SHAPE).astype(np.float32)
    for backend in CHAOS_BACKENDS:
        meter = Meter()
        tracer = _bench_tracer()
        reset_engines()
        root = f"/tmp/fdb-bench-ts-chaos-{backend}-{os.getpid()}"
        shutil.rmtree(root, ignore_errors=True)
        inj = (FaultInjector(seed=CHAOS_SEED)
               .fail("store.archive", rate=0.08, first=2)
               .fail("store.retrieve", first=1)
               .fail("catalogue.flush", first=1)
               .delay("store.archive", 0.0005, rate=0.2))
        retry = RetryPolicy(seed=CHAOS_SEED, base_delay=0.0005,
                            max_delay=0.005)
        fdb = FDB(FDBConfig(backend=backend, schema="tensor", root=root),
                  meter=meter, tracer=tracer, retry=retry, faults=inj)
        ts = TensorStore(fdb, {"store": "bench", "array": "chaos",
                               "writer": "p0"})
        mk = tracer.mark()
        t0 = time.perf_counter()
        ts.save(x, chunks=(edge, edge))
        wall = time.perf_counter() - t0
        ph = _phase_extra(tracer, mk, wall)
        n_chunks = (-(-SHAPE[0] // edge)) * (-(-SHAPE[1] // edge))

        # zero-loss audit: every chunk window must read back byte-equal
        arr = ts.open()
        lost = 0
        for i in range(-(-SHAPE[0] // edge)):
            for j in range(-(-SHAPE[1] // edge)):
                sl = (slice(i * edge, min(SHAPE[0], (i + 1) * edge)),
                      slice(j * edge, min(SHAPE[1], (j + 1) * edge)))
                try:
                    if not np.array_equal(arr[sl], x[sl]):
                        lost += 1
                except Exception:  # noqa: BLE001 — a lost chunk, not a bug
                    lost += 1

        snap = fdb.metrics()
        retries = snap.get("retry.attempts", {}).get("value", 0)
        giveups = snap.get("retry.giveups", {}).get("value", 0)
        goodput = x.nbytes / wall / 2**20
        rows.append(Row(
            f"tensorstore/{backend}/chaos", wall / n_chunks * 1e6,
            f"goodput={goodput:.1f}MiB/s retries={retries} "
            f"faults={inj.injected} lost_chunks={lost} giveups={giveups}",
            extra={"backend": backend, "chaos": True, "seed": CHAOS_SEED,
                   "retries": retries, "giveups": giveups,
                   "goodput_mib_s": round(goodput, 3),
                   "faults_injected": inj.injected,
                   "lost_chunks": lost, "n_chunks": n_chunks, **ph}))
        fdb.close()
        shutil.rmtree(root, ignore_errors=True)
    return rows

"""Framework-level checkpoint benchmark (the operational pattern of §3.1.3
applied to training state): shard archive throughput per backend, async
overlap, and field-codec compression ratio/effect."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core import FDBConfig, GLOBAL_METER, Meter, PROFILES, model_run, \
    reset_engines
from repro.models import lm
from repro.configs import get_smoke_config
from repro.train.checkpoint import FDBCheckpointer
from .common import Row


def run(profile: str = "gcp") -> List[Row]:
    rows: List[Row] = []
    cfg = get_smoke_config("tinyllama-1.1b").scaled(
        d_model=256, d_ff=704, n_layers=4, vocab_size=4096)
    params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    nbytes = sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(params))
    n_tensors = len(jax.tree.leaves(params))

    for backend in ("daos", "rados", "posix", "s3"):
        reset_engines()
        meter = Meter()
        root = f"/tmp/ckpt-bench-{backend}"
        import shutil
        shutil.rmtree(root, ignore_errors=True)
        ck = FDBCheckpointer(
            "bench", FDBConfig(backend=backend, root=root), n_shards=2)
        ck.fdb.meter = meter
        ck.fdb.store, ck.fdb.catalogue = ck.fdb._build_backends()
        t0 = time.perf_counter()
        ck.save(1, params)
        wall = time.perf_counter() - t0
        m = model_run(meter.snapshot(), PROFILES[profile], server_nodes=4)
        rows.append(Row(
            f"ckpt/{backend}/save", wall / n_tensors * 1e6,
            f"payload={nbytes/2**20:.1f}MiB"
            f" modeled={m.write_bw/2**30:.2f}GiB/s"))
        t0 = time.perf_counter()
        restored = ck.restore(1, params)
        wall_r = time.perf_counter() - t0
        del restored
        rows.append(Row(f"ckpt/{backend}/restore",
                        wall_r / n_tensors * 1e6, "ok"))

    # async overlap: archive from background thread while "training"
    reset_engines()
    ck = FDBCheckpointer("bench-async", FDBConfig(backend="daos"),
                         asynchronous=True)
    t0 = time.perf_counter()
    ck.save(1, params)
    foreground = time.perf_counter() - t0       # returns ~immediately
    ck.wait()
    total = time.perf_counter() - t0
    rows.append(Row("ckpt/daos/async_save_foreground", foreground * 1e6,
                    f"total={total*1e3:.1f}ms overlap="
                    f"{(1 - foreground/max(total,1e-9))*100:.0f}%"))

    # compression
    reset_engines()
    meter = Meter()
    ck = FDBCheckpointer("bench-comp", FDBConfig(backend="daos"),
                         compress=True)
    ck.fdb.meter = meter
    ck.fdb.store, ck.fdb.catalogue = ck.fdb._build_backends()
    ck.save(1, params)
    stored = sum(op.nbytes for op in meter.snapshot()
                 if op.kind == "array_write")
    rows.append(Row("ckpt/daos/compressed_save", 0.0,
                    f"ratio={nbytes/max(stored,1):.2f}x"
                    f" ({nbytes/2**20:.1f}->{stored/2**20:.1f}MiB)"))
    return rows

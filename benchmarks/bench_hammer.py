"""fdb-hammer scaling benchmark — thesis Figs. 4.12–4.13 (NEXTGenIO) and
4.21–4.22 (GCP): write/read bandwidth vs deployment size, with and without
write+read contention, for DAOS-like / Ceph-like / Lustre-POSIX backends."""
from __future__ import annotations

import threading
from typing import List

from repro.core import Meter, PROFILES, model_run
from .common import MiB, Row, fresh_fdb, hammer_read, hammer_write

#: scaled-down in-process run; the cost model extrapolates steady state.
STEPS, PARAMS, FIELD = 4, 8, 1 * MiB
BACKENDS = ("daos", "rados", "posix")
SCALE_POINTS = ((4, 2), (8, 4), (16, 8), (32, 16))   # (client nodes, servers)
PROCS = 4


def run(profile: str = "gcp") -> List[Row]:
    rows: List[Row] = []
    for backend in BACKENDS:
        for clients, servers in SCALE_POINTS:
            # -- no-contention: write phase, then read phase ----------------
            meter = Meter()
            fdb = fresh_fdb(backend, meter, f"h-{backend}-{clients}")
            wall_w, nbytes = hammer_write(fdb, clients, PROCS, STEPS, PARAMS,
                                          FIELD)
            mw = model_run(meter.snapshot(), PROFILES[profile],
                           server_nodes=servers)
            meter.reset()
            # reuse the same engines for the read phase (no engine reset)
            from repro.core import FDB, FDBConfig
            reader = FDB(FDBConfig(
                backend=backend,
                schema="nwp-posix" if backend == "posix" else "nwp-object",
                root=fdb.config.root), meter=meter)
            wall_r, rbytes = hammer_read(reader, clients, PROCS, STEPS,
                                         PARAMS, FIELD, verify=True)
            mr = model_run(meter.snapshot(), PROFILES[profile],
                           server_nodes=servers)
            calls = clients * PROCS * STEPS * PARAMS
            rows.append(Row(
                f"hammer/{backend}/c{clients}s{servers}/write",
                wall_w / calls * 1e6,
                f"modeled={mw.write_bw/2**30:.2f}GiB/s"
                f" dominant={mw.dominant}"))
            rows.append(Row(
                f"hammer/{backend}/c{clients}s{servers}/read",
                wall_r / calls * 1e6,
                f"modeled={mr.read_bw/2**30:.2f}GiB/s"
                f" dominant={mr.dominant}"))
    # -- contention runs (write+read concurrent), mid scale point ------------
    for backend in BACKENDS:
        clients, servers = 8, 4
        meter = Meter()
        fdb = fresh_fdb(backend, meter, f"hc-{backend}")
        hammer_write(fdb, clients, PROCS, STEPS, PARAMS, FIELD)  # seed data
        from repro.core import FDB, FDBConfig
        meter.reset()
        schema = "nwp-posix" if backend == "posix" else "nwp-object"
        writer = FDB(FDBConfig(backend=backend, schema=schema,
                               root=fdb.config.root), meter=meter)
        reader = FDB(FDBConfig(backend=backend, schema=schema,
                               root=fdb.config.root), meter=meter)
        errs: List[BaseException] = []

        def w():
            try:
                hammer_write(writer, clients, PROCS, STEPS, PARAMS, FIELD)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        def r():
            try:
                hammer_read(reader, clients, PROCS, STEPS, PARAMS, FIELD,
                            verify=True)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        t1, t2 = threading.Thread(target=w), threading.Thread(target=r)
        import time
        t0 = time.perf_counter()
        t1.start(); t2.start(); t1.join(); t2.join()
        wall = time.perf_counter() - t0
        assert not errs, errs
        m = model_run(meter.snapshot(), PROFILES[profile],
                      server_nodes=servers)
        calls = 2 * clients * PROCS * STEPS * PARAMS
        rows.append(Row(
            f"hammer/{backend}/c{clients}s{servers}/contended",
            wall / calls * 1e6,
            f"modeled_w={m.write_bw/2**30:.2f}GiB/s"
            f"+r={m.read_bw/2**30:.2f}GiB/s dominant={m.dominant}"))
    return rows

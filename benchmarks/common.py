"""Shared benchmark harness: virtual-client workload generators + reporting.

Every benchmark does two things, mirroring the thesis methodology (§4.1):
  1. measures the *in-process* throughput of the real backend implementation
     (us_per_call — functional cost of the software layer), and
  2. feeds the op trace through the calibrated cluster cost model to report
     *modeled at-scale bandwidth* on the thesis's hardware profiles
     (GiB/s — the numbers comparable to the thesis figures).
"""
from __future__ import annotations

import os
import time
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core import (FDB, FDBConfig, Meter, PROFILES, client_context,
                        model_run, reset_engines)

MiB = 1024 * 1024

NWP_DIMS = {"class": "od", "expver": "0001", "stream": "oper",
            "date": "20240101", "time": "0000", "type": "fc",
            "levtype": "sfc"}


def ident(node: int, proc: int, step: int, param: int) -> Dict[str, str]:
    return {**NWP_DIMS, "number": str(node), "levelist": str(proc),
            "step": str(step), "param": f"p{param}"}


def hammer_write(fdb: FDB, n_nodes: int, procs_per_node: int, n_steps: int,
                 n_params: int, field_bytes: int) -> Tuple[float, int]:
    """fdb-hammer write phase (§2.7.2): returns (seconds, payload bytes)."""
    data = os.urandom(field_bytes)
    t0 = time.perf_counter()
    for node in range(n_nodes):
        for proc in range(procs_per_node):
            with client_context(f"proc{proc}@node{node}"):
                for step in range(n_steps):
                    for param in range(n_params):
                        fdb.archive(ident(node, proc, step, param), data)
                    fdb.flush()
    fdb.close()
    dt = time.perf_counter() - t0
    return dt, n_nodes * procs_per_node * n_steps * n_params * field_bytes


def hammer_read(fdb: FDB, n_nodes: int, procs_per_node: int, n_steps: int,
                n_params: int, field_bytes: int,
                verify: bool = False) -> Tuple[float, int]:
    """fdb-hammer read phase: every reader retrieves its writer's fields."""
    t0 = time.perf_counter()
    total = 0
    for node in range(n_nodes):
        for proc in range(procs_per_node):
            with client_context(f"rproc{proc}@rnode{node}"):
                ids = [ident(node, proc, s, p) for s in range(n_steps)
                       for p in range(n_params)]
                handle = fdb.retrieve(ids)
                blob = handle.read()
                total += len(blob)
                if verify:
                    assert len(blob) == n_steps * n_params * field_bytes, \
                        "fdb-hammer consistency check failed"
    dt = time.perf_counter() - t0
    return dt, total


def fresh_fdb(backend: str, meter: Meter, tmp_tag: str, **kw) -> FDB:
    reset_engines()
    schema = kw.pop("schema", "nwp-posix" if backend == "posix"
                    else "nwp-object")
    root = f"/tmp/fdb-bench-{tmp_tag}-{os.getpid()}"
    import shutil
    shutil.rmtree(root, ignore_errors=True)
    return FDB(FDBConfig(backend=backend, schema=schema, root=root, **kw),
               meter=meter)


class Row:
    """One benchmark result row: name,us_per_call,derived (CSV), plus an
    optional ``extra`` dict of structured fields (read_ops / write_ops /
    modeled throughput ...) that rides along into ``run.py --json``
    perf-trajectory dumps but stays out of the CSV line."""

    def __init__(self, name: str, us_per_call: float, derived: str,
                 extra: Optional[Dict[str, object]] = None):
        self.name = name
        self.us_per_call = us_per_call
        self.derived = derived
        self.extra = dict(extra or {})

    def line(self) -> str:
        return f"{self.name},{self.us_per_call:.2f},{self.derived}"

    def to_json(self) -> Dict[str, object]:
        return {"name": self.name, "us_per_call": round(self.us_per_call, 3),
                "derived": self.derived, **self.extra}


def modeled_bw(meter: Meter, profile: str, servers: int) -> Dict[str, float]:
    r = model_run(meter.snapshot(), PROFILES[profile], server_nodes=servers)
    return {"write_gib": r.write_bw / 2**30, "read_gib": r.read_bw / 2**30,
            "dominant": r.dominant, "wall": r.wall_time}

"""Roofline report: aggregates the dry-run artifacts into the per-(arch ×
shape × mesh) table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ARTIFACT_DIR = os.environ.get("DRYRUN_ARTIFACTS",
                              os.path.join(os.path.dirname(__file__), "..",
                                           "dryrun_artifacts"))


def load_cells(artifact_dir: Optional[str] = None,
               include_opt: bool = False) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(artifact_dir or ARTIFACT_DIR,
                                              "*.json"))):
        if not include_opt and "__opt" in os.path.basename(path):
            continue        # hillclimb variants live in EXPERIMENTS.md §Perf
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def table(cells: List[Dict], mesh: str = "single") -> List[str]:
    lines = ["arch,shape,mesh,status,compute_ms,memory_ms(adj),collective_ms,"
             "dominant,useful_ratio,roofline_frac,peak_GiB(est)"]
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c.get("status") != "ok":
            lines.append(f"{c['arch']},{c['shape']},{c['mesh']},"
                         f"{c['status']},,,,,,,")
            continue
        r = c["roofline_adjusted"]
        lines.append(
            f"{c['arch']},{c['shape']},{c['mesh']},ok,"
            f"{r['compute_s']*1e3:.2f},{r['memory_s']*1e3:.2f},"
            f"{r['collective_s']*1e3:.2f},{c['dominant_term_adjusted']},"
            f"{c['useful_flops_ratio']:.3f},{c['roofline_fraction']:.3f},"
            f"{c['tpu_peak_estimate']['total']/2**30:.2f}")
    return lines


def run(profile: str = "gcp"):
    from .common import Row
    rows: List[Row] = []
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    skipped = [c for c in cells if c.get("status") == "skipped"]
    errors = [c for c in cells if c.get("status") == "error"]
    rows.append(Row("roofline/cells",
                    0.0, f"ok={len(ok)} skipped={len(skipped)} "
                    f"errors={len(errors)}"))
    for c in ok:
        if c["mesh"] != "single":
            continue
        r = c["roofline_adjusted"]
        rows.append(Row(
            f"roofline/{c['arch']}/{c['shape']}",
            max(r.values()) * 1e6,
            f"dominant={c['dominant_term_adjusted']}"
            f" frac={c['roofline_fraction']:.3f}"
            f" useful={c['useful_flops_ratio']:.3f}"))
    return rows


if __name__ == "__main__":
    for line in table(load_cells(), "single"):
        print(line)
    print()
    for line in table(load_cells(), "multi"):
        print(line)

"""Ceph/RADOS backend design-option sweep — thesis Fig. 3.5:
namespace-vs-pool encapsulation, object modes (multi-field span / single
large / per-field), immediate vs on-flush persistence."""
from __future__ import annotations

from typing import List

from repro.core import Meter, PROFILES, model_run
from .common import MiB, Row, fresh_fdb, hammer_read, hammer_write

CLIENTS, SERVERS, PROCS, STEPS, PARAMS = 8, 4, 4, 4, 8
FIELD = 1 * MiB

CONFIGS = [
    ("ns+span+immediate", dict(rados_encapsulation="namespace",
                               rados_object_mode="span",
                               rados_persistence="immediate")),
    ("pool+span+immediate", dict(rados_encapsulation="pool",
                                 rados_object_mode="span",
                                 rados_persistence="immediate")),
    ("ns+single_large", dict(rados_encapsulation="namespace",
                             rados_object_mode="single_large",
                             rados_max_object_size=1 << 40)),
    ("ns+per_field+immediate", dict(rados_encapsulation="namespace",
                                    rados_object_mode="per_field",
                                    rados_persistence="immediate")),
    ("ns+per_field+large_max", dict(rados_encapsulation="namespace",
                                    rados_object_mode="per_field",
                                    rados_max_object_size=1024 * MiB)),
    ("ns+span+on_flush", dict(rados_encapsulation="namespace",
                              rados_object_mode="span",
                              rados_persistence="on_flush")),
]


def run(profile: str = "gcp") -> List[Row]:
    rows: List[Row] = []
    for name, kw in CONFIGS:
        meter = Meter()
        fdb = fresh_fdb("rados", meter, f"ro-{name}", **kw)
        wall_w, _ = hammer_write(fdb, CLIENTS, PROCS, STEPS, PARAMS, FIELD)
        mw = model_run(meter.snapshot(), PROFILES[profile],
                       server_nodes=SERVERS)
        meter.reset()
        from repro.core import FDB, FDBConfig
        reader = FDB(FDBConfig(backend="rados", schema="nwp-object",
                               **kw), meter=meter)
        wall_r, _ = hammer_read(reader, CLIENTS, PROCS, STEPS, PARAMS,
                                FIELD, verify=True)
        mr = model_run(meter.snapshot(), PROFILES[profile],
                       server_nodes=SERVERS)
        calls = CLIENTS * PROCS * STEPS * PARAMS
        rows.append(Row(f"rados_options/{name}/write",
                        wall_w / calls * 1e6,
                        f"modeled={mw.write_bw/2**30:.2f}GiB/s"))
        rows.append(Row(f"rados_options/{name}/read",
                        wall_r / calls * 1e6,
                        f"modeled={mr.read_bw/2**30:.2f}GiB/s"))
    return rows

"""Field-I/O benchmark with object sharding sweep — thesis Figs. 4.8–4.11:
DAOS array object classes (S1/S2/S4/SX striping) across field sizes.

Validates the thesis finding that *unsharded* (OC_S1) objects win for the
many-small-fields NWP pattern because parallelism comes from spreading many
arrays across targets, not from striping each array."""
from __future__ import annotations

from typing import List

from repro.core import Meter, PROFILES, model_run
from .common import MiB, Row, fresh_fdb, hammer_read, hammer_write

CLIENTS, SERVERS, PROCS, STEPS, PARAMS = 8, 4, 4, 2, 8


def run(profile: str = "gcp") -> List[Row]:
    rows: List[Row] = []
    for field_mib in (1, 8):
        for oclass in ("OC_S1", "OC_S2", "OC_S4", "OC_SX"):
            meter = Meter()
            fdb = fresh_fdb("daos", meter, f"fio-{oclass}-{field_mib}",
                            daos_oclass=oclass)
            wall_w, _ = hammer_write(fdb, CLIENTS, PROCS, STEPS, PARAMS,
                                     field_mib * MiB)
            mw = model_run(meter.snapshot(), PROFILES[profile],
                           server_nodes=SERVERS)
            calls = CLIENTS * PROCS * STEPS * PARAMS
            rows.append(Row(
                f"fieldio/daos/{oclass}/{field_mib}MiB/write",
                wall_w / calls * 1e6,
                f"modeled={mw.write_bw/2**30:.2f}GiB/s"
                f" dominant={mw.dominant}"))
    return rows

"""Operational NWP workflow benchmark: one seeded cycle per backend.

Drives :class:`repro.workflows.NWPCycle` — concurrent leased assimilation
writers, a strict-read forecast with sharded checkpoints, and a fan-out
product-reader pool — on each simulated backend, and reports one row per
stage: wall latency per task, payload throughput, the lease-contention
column (blocking acquires + total time queued on other writers' leases,
from the ``lease.wait_us`` histogram), and the **modeled at-scale
bandwidth** columns — every client of the cycle shares one engine-op
``Meter``, each stage's op-trace window feeds the calibrated cluster
cost model (``model_run``), and the resulting write/read GiB/s +
dominant-resource verdict ride next to the in-process numbers, the same
methodology split the tensorstore bench uses (thesis §4.1).

A final ``chaos_gate`` row per backend reruns the *identical* seeded
cycle under a fault schedule plus a mid-cycle writer crash
(:func:`repro.workflows.run_chaos_gate`) and reports the byte-identity /
zero-loss / clean-protocol verdict — the robustness gate ``check.sh``
asserts on.
"""
from __future__ import annotations

import os
import shutil
from typing import List

from repro.core import Meter, PROFILES, model_run, reset_engines
from repro.workflows import ChaosSchedule, NWPCycle, WorkflowConfig, \
    run_chaos_gate

from .common import Row

BACKENDS = ["daos", "rados", "posix", "s3"]
CHAOS_SEED = 1107
SERVERS = 4

#: full profile: a 96x96 grid, 6 overlapping writers, 3 leads, 8 readers
FULL = dict(shape=(96, 96), chunks=(16, 16), n_writers=6, halo=6,
            leads=3, n_shards=2, n_readers=8, reads_per_reader=8)
#: CI smoke profile — same shape of workload, tiny sizes
TINY = dict(shape=(32, 32), chunks=(8, 8), n_writers=3, halo=3,
            leads=2, n_shards=2, n_readers=4, reads_per_reader=4)


def _config(backend: str, tag: str, tiny: bool) -> WorkflowConfig:
    root = f"/tmp/fdb-bench-wf-{backend}-{tag}-{os.getpid()}"
    shutil.rmtree(root, ignore_errors=True)
    return WorkflowConfig(backend=backend, root=root, seed=CHAOS_SEED,
                          **(TINY if tiny else FULL))


def run(tiny: bool = False, profile: str = "gcp") -> List[Row]:
    rows: List[Row] = []
    for backend in BACKENDS:
        reset_engines()
        meter = Meter()
        cycle = NWPCycle(_config(backend, "clean", tiny), meter=meter)
        report = cycle.run()
        for stage, stats in report.stages.items():
            # the stage's own op-trace window through the cluster model:
            # what this stage's I/O would sustain on the profile hardware
            m = model_run(cycle.stage_ops.get(stage, []),
                          PROFILES[profile], server_nodes=SERVERS)
            rows.append(Row(
                f"workflow/{backend}/{stage}",
                stats.wall_s / max(1, stats.tasks) * 1e6,
                f"{stats.mib_s:.1f}MiB/s tasks={stats.tasks} "
                f"lease_waits={stats.lease_waits} "
                f"lease_wait={stats.lease_wait_us / 1e3:.1f}ms "
                f"modeled_w={m.write_bw / 2**30:.2f}GiB/s "
                f"modeled_r={m.read_bw / 2**30:.2f}GiB/s "
                f"dominant={m.dominant}",
                extra={"backend": backend, "stage": stage,
                       "wall_us": round(stats.wall_s * 1e6, 1),
                       "mib_s": round(stats.mib_s, 3),
                       "nbytes": stats.nbytes, "tasks": stats.tasks,
                       "lease_waits": stats.lease_waits,
                       "lease_wait_us": round(stats.lease_wait_us, 1),
                       "stage_ops": len(cycle.stage_ops.get(stage, [])),
                       "modeled_write_gib_s": round(m.write_bw / 2**30, 4),
                       "modeled_read_gib_s": round(m.read_bw / 2**30, 4),
                       "modeled_dominant": m.dominant}))
        assert report.clean, (backend, report.protocol_violations)
        assert report.lost_chunks == 0, (backend, report.lost_chunks)

        reset_engines()
        gate = run_chaos_gate(_config(backend, "chaos", tiny),
                              ChaosSchedule(seed=CHAOS_SEED))
        identical = gate.clean.digests == gate.chaos.digests
        rows.append(Row(
            f"workflow/{backend}/chaos_gate",
            gate.chaos.wall_s * 1e6,
            f"identical={identical} lost={gate.chaos.lost_chunks} "
            f"protocol_clean={not gate.chaos.protocol_violations} "
            f"orphans={gate.chaos.recovery['orphan_chunks']} "
            f"faults={gate.chaos.faults_injected} "
            f"retries={gate.chaos.retries} ok={gate.ok}",
            extra={"backend": backend, "chaos": True, "seed": CHAOS_SEED,
                   "identical": identical, "ok": gate.ok,
                   "lost_chunks": gate.chaos.lost_chunks,
                   "faults_injected": gate.chaos.faults_injected,
                   "retries": gate.chaos.retries,
                   "crashed_writer": gate.chaos.crashed_writer,
                   "failures": gate.failures}))
        assert gate.ok, (backend, gate.failures)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(tiny=True):
        print(row.line())

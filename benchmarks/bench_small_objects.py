"""Small-object (1 KiB) performance — thesis Fig. 4.26: DAOS sustains high
op rates; Ceph and Lustre collapse to latency/op-rate bounds."""
from __future__ import annotations

from typing import List

from repro.core import Meter, PROFILES, model_run
from .common import Row, fresh_fdb, hammer_read, hammer_write

CLIENTS, SERVERS, PROCS, STEPS, PARAMS = 8, 4, 4, 4, 16
FIELD = 1024   # 1 KiB


def run(profile: str = "gcp") -> List[Row]:
    rows: List[Row] = []
    for backend in ("daos", "rados", "posix"):
        meter = Meter()
        fdb = fresh_fdb(backend, meter, f"so-{backend}")
        wall_w, _ = hammer_write(fdb, CLIENTS, PROCS, STEPS, PARAMS, FIELD)
        mw = model_run(meter.snapshot(), PROFILES[profile],
                       server_nodes=SERVERS)
        meter.reset()
        from repro.core import FDB, FDBConfig
        schema = "nwp-posix" if backend == "posix" else "nwp-object"
        reader = FDB(FDBConfig(backend=backend, schema=schema,
                               root=fdb.config.root), meter=meter)
        wall_r, _ = hammer_read(reader, CLIENTS, PROCS, STEPS, PARAMS, FIELD,
                                verify=True)
        mr = model_run(meter.snapshot(), PROFILES[profile],
                       server_nodes=SERVERS)
        calls = CLIENTS * PROCS * STEPS * PARAMS
        wkops = calls / max(mw.wall_time, 1e-9) / 1e3
        rkops = calls / max(mr.wall_time, 1e-9) / 1e3
        rows.append(Row(f"small_objects/{backend}/write",
                        wall_w / calls * 1e6,
                        f"modeled={wkops:.1f}kops/s dominant={mw.dominant}"))
        rows.append(Row(f"small_objects/{backend}/read",
                        wall_r / calls * 1e6,
                        f"modeled={rkops:.1f}kops/s dominant={mr.dominant}"))
    return rows

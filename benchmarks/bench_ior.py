"""IOR-analogue raw engine benchmark — thesis Figs. 4.5–4.7 / 4.19–4.20:
per-process independent object streams (no index), write and read bandwidth
vs deployment size.  Probes the storage engines below the FDB layer."""
from __future__ import annotations

import os
import time
from typing import List

from repro.core import Meter, PROFILES, client_context, model_run, \
    reset_engines
from repro.core.engine.daos import DaosEngine
from repro.core.engine.rados import RadosEngine
from .common import MiB, Row

N_OPS = 64
FIELD = 1 * MiB
SCALE = ((4, 2), (8, 4), (16, 8))
PROCS = 4


def _daos_stream(engine, meter, clients):
    engine.pool_create("ior")
    engine.cont_create_with_label("ior", "c")
    data = os.urandom(FIELD)
    oid = engine.cont_alloc_oids("ior", "c", clients * PROCS * N_OPS)
    t0 = time.perf_counter()
    for node in range(clients):
        for proc in range(PROCS):
            with client_context(f"p{proc}@n{node}"):
                for i in range(N_OPS):
                    engine.array_write("ior", "c", oid, 0, data)
                    oid += 1
    return time.perf_counter() - t0


def _rados_stream(engine, meter, clients):
    engine.pool_create("ior", pg_count=512)
    data = os.urandom(FIELD)
    t0 = time.perf_counter()
    for node in range(clients):
        for proc in range(PROCS):
            with client_context(f"p{proc}@n{node}"):
                for i in range(N_OPS):
                    engine.write_full("ior", "ns", f"o{node}.{proc}.{i}",
                                      data)
    return time.perf_counter() - t0


def _posix_stream(meter, clients, root):
    from repro.core.backends.posix import LustreSim
    sim = LustreSim(root, meter=meter)
    data = os.urandom(FIELD)
    t0 = time.perf_counter()
    for node in range(clients):
        for proc in range(PROCS):
            with client_context(f"p{proc}@n{node}"):
                path = os.path.join(root, f"f{node}.{proc}")
                with open(path, "wb") as f:
                    for i in range(N_OPS):
                        f.write(data)
                sim.data_io(path, N_OPS * FIELD, "write")
                sim.fsync(path)
                sim.meta(2)
    return time.perf_counter() - t0


def run(profile: str = "gcp") -> List[Row]:
    rows: List[Row] = []
    for clients, servers in SCALE:
        for backend in ("daos", "rados", "posix"):
            reset_engines()
            meter = Meter()
            if backend == "daos":
                wall = _daos_stream(DaosEngine(meter=meter), meter, clients)
            elif backend == "rados":
                wall = _rados_stream(RadosEngine(meter=meter), meter, clients)
            else:
                root = f"/tmp/ior-{os.getpid()}-{clients}"
                import shutil
                shutil.rmtree(root, ignore_errors=True)
                wall = _posix_stream(meter, clients, root)
            m = model_run(meter.snapshot(), PROFILES[profile],
                          server_nodes=servers)
            calls = clients * PROCS * N_OPS
            rows.append(Row(
                f"ior/{backend}/c{clients}s{servers}/write",
                wall / calls * 1e6,
                f"modeled={m.write_bw/2**30:.2f}GiB/s dominant={m.dominant}"))
    return rows

"""Data-redundancy cost — thesis Figs. 4.27–4.28: replication 2× and 2+1
erasure coding on the DAOS-like and Ceph-like backends."""
from __future__ import annotations

from typing import List

from repro.core import Meter, PROFILES, model_run
from .common import MiB, Row, fresh_fdb, hammer_write

CLIENTS, SERVERS, PROCS, STEPS, PARAMS = 8, 4, 4, 4, 8
FIELD = 1 * MiB

VARIANTS = [
    ("daos/plain", "daos", {}),
    ("daos/rp2", "daos", {"daos_oclass": "OC_RP_2G1"}),
    ("daos/ec2p1", "daos", {"daos_oclass": "OC_EC_2P1G1"}),
    ("rados/plain", "rados", {}),
    ("rados/rp2", "rados", {"rados_replication": 2}),
    ("rados/ec2p1", "rados", {"rados_ec": (2, 1)}),
]


def run(profile: str = "gcp") -> List[Row]:
    rows: List[Row] = []
    for name, backend, kw in VARIANTS:
        meter = Meter()
        fdb = fresh_fdb(backend, meter, f"red-{name.replace('/', '-')}", **kw)
        wall, _ = hammer_write(fdb, CLIENTS, PROCS, STEPS, PARAMS, FIELD)
        m = model_run(meter.snapshot(), PROFILES[profile],
                      server_nodes=SERVERS)
        calls = CLIENTS * PROCS * STEPS * PARAMS
        rows.append(Row(f"redundancy/{name}/write", wall / calls * 1e6,
                        f"modeled={m.write_bw/2**30:.2f}GiB/s"
                        f" dominant={m.dominant}"))
    return rows

"""End-to-end behaviour: the ECMWF operational NWP I/O pattern (§2.7.2 /
§3.1.3) run against the framework — parallel I/O-server writers archiving
weather fields per step, flush barriers, and PGEN-style post-processing
readers listing+retrieving under write+read contention."""
import os
import threading

import numpy as np
import pytest

from repro.core import FDB, FDBConfig, client_context

N_WRITERS = 4
N_STEPS = 5
N_PARAMS = 6
FIELD = 8 * 1024


def _ident(writer, step, param):
    return {"class": "od", "expver": "0001", "stream": "oper",
            "date": "20240101", "time": "0000", "type": "fc",
            "levtype": "sfc", "number": str(writer), "levelist": "1",
            "step": str(step), "param": f"p{param}"}


@pytest.mark.parametrize("backend", ["daos", "rados", "posix"])
def test_operational_nwp_pattern(backend, tmp_path):
    schema = "nwp-posix" if backend == "posix" else "nwp-object"
    cfg = FDBConfig(backend=backend, schema=schema,
                    root=str(tmp_path / "fdb"))
    fields = {(w, s, p): os.urandom(FIELD)
              for w in range(N_WRITERS) for s in range(N_STEPS)
              for p in range(N_PARAMS)}
    barrier_counts = [threading.Semaphore(0) for _ in range(N_STEPS)]
    pgen_results = {}
    errors = []

    def io_server(w):
        fdb = FDB(cfg)
        try:
            with client_context(f"proc{w}@node{w % 2}"):
                for s in range(N_STEPS):
                    for p in range(N_PARAMS):
                        fdb.archive(_ident(w, s, p), fields[(w, s, p)])
                    fdb.flush()           # step barrier (visibility rule 3)
                    barrier_counts[s].release()
            fdb.close()
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    def pgen(s):
        # wait for all writers to flush step s (workflow-manager signal)
        for _ in range(N_WRITERS):
            barrier_counts[s].acquire()
        fdb = FDB(cfg)
        try:
            listed = list(fdb.list({"class": "od", "date": "20240101",
                                    "step": str(s)}))
            assert len(listed) == N_WRITERS * N_PARAMS, \
                f"step {s}: {len(listed)} fields listed"
            total = bytearray()
            for w in range(N_WRITERS):
                handle = fdb.retrieve([_ident(w, s, p)
                                       for p in range(N_PARAMS)])
                data = handle.read_parts()
                for p, blob in enumerate(data):
                    assert blob == fields[(w, s, p)], (w, s, p)
                    total += blob
            pgen_results[s] = len(total)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    writers = [threading.Thread(target=io_server, args=(w,))
               for w in range(N_WRITERS)]
    pgens = [threading.Thread(target=pgen, args=(s,))
             for s in range(N_STEPS)]
    for t in writers + pgens:
        t.start()
    for t in writers + pgens:
        t.join()
    assert not errors, errors[:2]
    assert all(pgen_results[s] == N_WRITERS * N_PARAMS * FIELD
               for s in range(N_STEPS))


def test_framework_end_to_end_train_ckpt_serve():
    """Train a reduced model a few steps, checkpoint through the FDB,
    restore into a fresh process-alike, and serve from it."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data import SyntheticTokens
    from repro.models import lm
    from repro.serve import Request, ServeEngine
    from repro.train.checkpoint import FDBCheckpointer
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer

    cfg = get_smoke_config("tinyllama-1.1b")
    data = SyntheticTokens(cfg.vocab_size, 16, seed=9)
    ck = FDBCheckpointer("e2e", FDBConfig(backend="daos"))
    tr = Trainer(cfg, None, AdamWConfig(lr=1e-3), checkpointer=ck,
                 ckpt_every=5, batch_fn=lambda s: data.batch(s, 2))
    tr.fit(5, log_every=100)
    step, params = ck.restore_latest(
        lm.init_params(cfg, jax.random.PRNGKey(0)))
    assert step == 5
    eng = ServeEngine(cfg, params, batch_slots=2, max_len=24)
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=3))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 3

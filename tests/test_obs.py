"""repro.obs: spans, trace-context propagation, metrics, exporters.

Covers the observability acceptance criteria: parentage surviving the
executor's thread hand-off, reshard nesting, the disabled fast path
recording nothing, Chrome trace_event export validity, phase-attributed
totals, the bounded Meter.ops cap, and tracing changing no stored bytes.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import FDB, FDBConfig, LeaseConflictError, Meter
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry, Tracer,
                       TraceBuffer)
from repro.obs.trace import _NOOP, PHASE_SPANS, current_span, span
from repro.tensorstore import TensorStore

BACKENDS = ["daos", "rados", "posix", "s3"]


def make_store(backend, tmp_path, tracer=None, array="a", **kw):
    fdb = FDB(FDBConfig(backend=backend, schema="tensor",
                        root=str(tmp_path / "fdb"), **kw), tracer=tracer)
    return fdb, TensorStore(fdb, {"store": "s", "array": array,
                                  "writer": "w0"})


def span_index(spans):
    return {s.span_id: s for s in spans}


def ancestry(s, by_id):
    names = []
    while s is not None:
        names.append(s.name)
        s = by_id.get(s.parent_id)
    return names


# ---------------------------------------------------------------------------
# tracer primitives
# ---------------------------------------------------------------------------

def test_span_nesting_builds_parent_chain():
    tr = Tracer(enabled=True)
    with tr.span("outer", k=1) as a:
        assert current_span() is a
        with tr.span("inner") as b:
            assert b.parent_id == a.span_id
        with tr.span("inner2") as c:
            assert c.parent_id == a.span_id
    assert current_span() is None
    spans = tr.spans()
    assert [s.name for s in spans] == ["inner", "inner2", "outer"]
    assert spans[-1].parent_id is None
    assert spans[-1].t1_ns >= spans[-1].t0_ns
    assert spans[-1].attrs == {"k": 1}


def test_span_records_error_attr():
    tr = Tracer(enabled=True)
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    (s,) = tr.spans()
    assert s.attrs["error"] == "ValueError"


def test_disabled_tracer_is_noop_fast_path():
    tr = Tracer(enabled=False)
    cm = tr.span("anything", k=1)
    assert cm is _NOOP                      # shared object, no allocation
    with cm as s:
        assert s is None
        assert current_span() is None
    assert tr.spans() == []
    assert tr.record_complete("x", 0, 10) is None
    assert tr.spans() == []
    # the ambient helper is also a no-op outside any traced span
    assert span("ambient") is _NOOP


def test_ambient_span_joins_active_tracer():
    tr = Tracer(enabled=True)
    with tr.span("outer") as a:
        with span("ambient", nbytes=3) as b:
            assert b.tracer is tr and b.parent_id == a.span_id
    assert [s.name for s in tr.spans()] == ["ambient", "outer"]


def test_foreign_tracer_parent_treated_as_root():
    tr1, tr2 = Tracer(enabled=True), Tracer(enabled=True)
    with tr1.span("outer"):
        with tr2.span("other") as b:
            assert b.parent_id is None      # tr1's span would dangle in tr2


def test_trace_buffer_bounded_and_windowed():
    tr = Tracer(enabled=True, capacity=8)
    for i in range(20):
        with tr.span(f"s{i}"):
            pass
    assert tr.buffer.total == 20 and tr.dropped == 12
    assert [s.name for s in tr.spans()] == [f"s{i}" for i in range(12, 20)]
    # a mark inside the evicted region clamps to the oldest retained span
    assert [s.name for s in tr.spans(since=5)][0] == "s12"
    mark = tr.mark()
    with tr.span("fresh"):
        pass
    assert [s.name for s in tr.spans(since=mark)] == ["fresh"]
    tr.clear()
    assert tr.spans() == [] and tr.buffer.total == 0


def test_record_complete_interval():
    tr = Tracer(enabled=True)
    with tr.span("parent") as p:
        s = tr.record_complete("queue.wait", 1000, 5000, parent=p, depth=2)
    assert s.parent_id == p.span_id
    assert s.duration_us == 4.0 and s.attrs == {"depth": 2}


def test_chrome_trace_export_shape():
    tr = Tracer(enabled=True)
    with tr.span("a", nbytes=3, arr=np.int64(7)):
        with tr.span("b"):
            pass
    doc = tr.chrome_trace(process_name="test")
    blob = json.dumps(doc)                  # must be JSON-serialisable
    doc = json.loads(blob)
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert meta[0]["args"]["name"] == "test"
    assert {e["name"] for e in xs} == {"a", "b"}
    for e in xs:
        assert {"name", "ph", "ts", "dur", "pid", "tid"} <= set(e)
        assert e["dur"] >= 0
    # non-JSON attr values are stringified, not dropped
    a = next(e for e in xs if e["name"] == "a")
    assert a["args"]["nbytes"] == 3 and a["args"]["arr"] == "7"


def test_phase_totals_counts_exact_leaf_names_only():
    tr = Tracer(enabled=True)
    with tr.span("plan.execute"):           # wrapper: must not count
        with tr.span("io.fetch"):
            pass
        with tr.span("codec.decode"):
            pass
        with tr.span("io.archive"):
            pass
    pt = tr.phase_totals()
    assert pt["io"] > 0 and pt["decode"] > 0 and pt["encode"] == 0
    total = sum(pt.values())
    wrapper = next(s for s in tr.spans() if s.name == "plan.execute")
    assert total < wrapper.duration_us      # nested leaves < wrapper alone
    # every phase name set is exact (no prefixes), so wrappers never leak in
    for names in PHASE_SPANS.values():
        assert "plan.execute" not in names


def test_rollup_table_and_store_latency_histograms():
    tr = Tracer(enabled=True)
    with tr.span("store.daos.archive"):
        pass
    with tr.span("store.daos.archive"):
        pass
    text = tr.rollup()
    assert "store.daos.archive" in text and "count" in text
    h = tr.metrics.get("store.daos.archive_us")
    assert isinstance(h, Histogram) and h.count == 2


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("ops").inc()
    reg.counter("ops").inc(4)
    assert reg.counter("ops").value == 5
    g = reg.gauge("depth")
    g.set(3)
    g.add(2)
    assert g.value == 5 and g.max == 5
    g.set(1)
    assert g.value == 1 and g.max == 5      # high-water mark sticks
    h = reg.histogram("lat_us", buckets=(10, 100))
    for v in (5, 50, 500):
        h.observe(v)
    assert h.count == 3 and h.sum == 555
    assert h.mean == pytest.approx(185.0)
    assert h.percentile(50) <= 100
    snap = reg.snapshot()
    assert snap["ops"]["value"] == 5
    assert snap["lat_us"]["count"] == 3
    assert snap["lat_us"]["buckets"]["gt_100"] == 1
    with pytest.raises(TypeError):
        reg.counter("depth")                # name already bound to a Gauge
    reg.clear()
    assert reg.counter("ops").value == 0


def test_metrics_thread_safety_smoke():
    reg = MetricsRegistry()

    def work():
        for _ in range(1000):
            reg.counter("c").inc()
            reg.histogram("h").observe(1.0)

    ts = [threading.Thread(target=work) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.counter("c").value == 4000
    assert reg.histogram("h").count == 4000


# ---------------------------------------------------------------------------
# instrumentation: context propagation through the I/O stack
# ---------------------------------------------------------------------------

def test_executor_thread_spans_parent_under_plan(tmp_path):
    """io.fetch / codec.decode run on pool threads, but their ancestry
    chains reach the plan.execute span of the submitting thread — the
    contextvars hand-off across the ChunkExecutor."""
    tracer = Tracer(enabled=True)
    fdb, ts = make_store("daos", tmp_path, tracer=tracer)
    x = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    mark = tracer.mark()
    np.testing.assert_array_equal(arr[8:40, :], x[8:40, :])
    spans = tracer.spans(since=mark)
    by_id = span_index(spans)
    main = threading.get_ident()
    fetches = [s for s in spans if s.name == "io.fetch"]
    assert fetches
    assert any(s.thread_id != main for s in fetches)    # really off-thread
    for s in fetches:
        assert "plan.execute" in ancestry(s, by_id)
    # queue-wait intervals also attach under the plan
    queued = [s for s in spans if s.name == "executor.queue"]
    assert queued
    for s in queued:
        assert "plan.execute" in ancestry(s, by_id)
    assert tracer.metrics.histogram("executor.queue_us").count >= len(queued)
    fdb.close()


def test_reshard_spans_nest_inner_plans(tmp_path):
    tracer = Tracer(enabled=True)
    fdb, ts = make_store("posix", tmp_path, tracer=tracer)
    x = np.random.default_rng(1).normal(size=(64, 64)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    mark = tracer.mark()
    arr.reshard((32, 64))
    spans = tracer.spans(since=mark)
    by_id = span_index(spans)
    roots = [s for s in spans if s.name == "plan.reshard"]
    assert len(roots) == 1
    inner = [s for s in spans if s.name == "plan.execute"]
    assert inner
    for s in inner:
        chain = ancestry(s, by_id)
        assert "reshard.batch" in chain and "plan.reshard" in chain
    fdb.close()


def test_disabled_tracing_records_nothing_through_the_stack(tmp_path):
    fdb, ts = make_store("daos", tmp_path)      # default: GLOBAL_TRACER off
    x = np.arange(256, dtype=np.float32).reshape(16, 16)
    ts.save(x, chunks=(8, 8))
    np.testing.assert_array_equal(ts.open()[:, :], x)
    assert fdb.trace() == []
    # spans are gated off; coarse counters still count (exact, cheap)
    assert fdb.metrics().get("codec.bytes_encoded", {}).get("value", 0) > 0
    fdb.close()


def test_fdb_trace_and_metrics_accessors(tmp_path):
    tracer = Tracer(enabled=True)
    fdb, ts = make_store("rados", tmp_path, tracer=tracer)
    x = np.ones((8, 8), np.float32)
    ts.save(x, chunks=(4, 4))
    mark = tracer.mark()
    ts.open().read()
    names = {s.name for s in fdb.trace(since=mark)}
    assert "io.fetch" in names and "codec.decode" in names
    m = fdb.metrics()
    assert m["codec.bytes_decoded"]["value"] >= x.nbytes
    assert "store.rados.archive_us" in m
    fdb.close()


def test_lease_conflict_and_session_metrics(tmp_path):
    tracer = Tracer(enabled=True)
    fdb, ts = make_store("daos", tmp_path, tracer=tracer)
    ts.create((32, 32), np.float32, chunks=(8, 8))
    fdb.flush()
    s1, s2 = fdb.session("w1"), fdb.session("w2")
    a1 = TensorStore(None, {"store": "s", "array": "a", "writer": "w0"},
                     session=s1).open()
    a2 = TensorStore(None, {"store": "s", "array": "a", "writer": "w0"},
                     session=s2).open()
    a1.write_plan((slice(0, 16), slice(None)),
                  np.zeros((16, 32), np.float32)).execute(flush=False)
    with pytest.raises(LeaseConflictError):
        a2.write_plan((slice(8, 24), slice(None)),
                      np.ones((16, 32), np.float32))
    assert tracer.metrics.counter("lease.conflicts").value == 1
    assert tracer.metrics.counter("lease.acquired").value >= 1
    s1.close()
    s2.close()
    names = {s.name for s in tracer.spans()}
    assert {"lease.acquire", "session.close"} <= names
    fdb.close()


# ---------------------------------------------------------------------------
# tracing must not change what is stored
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["posix", "daos"])
def test_tracing_changes_no_stored_bytes(backend, tmp_path):
    """Byte-identical archives with tracing on vs off: observability is
    read-only with respect to the data path."""
    x = np.random.default_rng(7).normal(size=(37, 53)).astype(np.float32)

    def stored_bytes(sub, tracer):
        from repro.core import reset_engines
        reset_engines()
        fdb = FDB(FDBConfig(backend=backend, schema="tensor",
                            root=str(tmp_path / sub)), tracer=tracer)
        ts = TensorStore(fdb, {"store": "s", "array": "a", "writer": "w0"})
        ts.save(x, chunks=(16, 16))
        arr = ts.open()
        arr[0:10, 0:10] = 2.5               # exercise RMW too
        blobs = {}
        for ident, _loc in fdb.list({"store": "s", "array": "a"}):
            key = tuple(sorted(ident.items()))
            blobs[key] = fdb.retrieve(ident).read()
        fdb.close()
        return blobs

    off = stored_bytes("off", Tracer(enabled=False))
    on = stored_bytes("on", Tracer(enabled=True))
    assert off.keys() == on.keys()
    for k in off:
        assert off[k] == on[k], f"stored bytes differ under tracing: {k}"


# ---------------------------------------------------------------------------
# Meter.ops cap (bounded trace, exact counters)
# ---------------------------------------------------------------------------

def test_meter_ops_bounded_with_exact_rollup():
    from repro.core import client_context
    m = Meter(max_ops=10)
    for i in range(15):
        with client_context(f"c{i % 2}@n0"):
            m.record("target:0", "write", nbytes=100)
    assert len(m.snapshot()) == 10          # trace truncated at the cap
    assert m.dropped_ops == 5
    s = m.summary()
    # counters stay exact past the cap — and the truncation is reported
    assert s["total_ops"] == 15
    assert s["ops_by_kind"]["write"] == 15
    assert s["bytes_by_kind"]["write"] == 1500
    assert s["clients"] == 2
    assert s["dropped_ops"] == 5 and s["trace_truncated"] is True
    m.reset()
    assert m.dropped_ops == 0 and len(m.snapshot()) == 0
    m.record("target:0", "read", nbytes=1)
    assert m.summary()["total_ops"] == 1
    assert "trace_truncated" not in m.summary()


def test_meter_windowing_below_cap_unchanged():
    m = Meter()
    m.record("target:0", "write", nbytes=1)
    before = m.snapshot()
    m.record("target:0", "read", nbytes=2)
    new = m.snapshot()[len(before):]
    assert len(new) == 1 and new[0].kind == "read"

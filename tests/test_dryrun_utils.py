"""Unit tests for the dry-run harness internals (pure functions — the
512-device lowering itself is exercised by launch/dryrun.py runs)."""
import numpy as np
import pytest

pytest.importorskip("jax")


def test_parse_collectives_counts_and_bytes():
    from repro.launch import dryrun
    hlo = """
  %ag = bf16[16,4096,128]{2,1,0} all-gather(bf16[1,4096,128]{2,1,0} %p), dims={0}
  %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %x), to_apply=%sum
  %ag.s = (bf16[8]{0}) all-gather-start(bf16[8]{0} %y)
  %ag.d = bf16[8]{0} all-gather-done((bf16[8]{0}) %ag.s)
  %rs = f32[64]{0} reduce-scatter(f32[1024]{0} %z), dims={0}
  %noise = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
"""
    out = dryrun.parse_collectives(hlo)
    assert out["all-gather"]["count"] == 2          # start counted, done not
    assert out["all-gather"]["operand_bytes"] == 4096 * 128 * 2 + 16
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["operand_bytes"] == 4096
    assert out["reduce-scatter"]["operand_bytes"] == 4096


def test_loop_correction_zero_for_decode_and_unrolled():
    from repro.launch.dryrun import loop_flop_correction
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from types import SimpleNamespace
    plan = SimpleNamespace(dp_size=16, tp_size=16, sp=True)
    # dense arch: no sequence loops at all
    assert loop_flop_correction(get_config("tinyllama-1.1b"),
                                SHAPES["train_4k"], plan) == 0.0
    # jamba train_4k: 16 chunks > unroll limit (8) → scan, correction > 0
    assert loop_flop_correction(get_config("jamba-v0.1-52b"),
                                SHAPES["train_4k"], plan) > 0.0
    # jamba prefill_32k: 128 chunks → scan mode, correction > 0
    assert loop_flop_correction(get_config("jamba-v0.1-52b"),
                                SHAPES["prefill_32k"], plan) > 0.0
    # decode never has sequence loops
    assert loop_flop_correction(get_config("jamba-v0.1-52b"),
                                SHAPES["long_500k"], plan) == 0.0
    # xlstm always has the sLSTM scan
    assert loop_flop_correction(get_config("xlstm-1.3b"),
                                SHAPES["train_4k"], plan) > 0.0


def test_model_flops_formula():
    from repro.launch.dryrun import model_flops_global
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    cfg = get_config("tinyllama-1.1b")
    n = cfg.active_param_count()
    assert model_flops_global(cfg, SHAPES["train_4k"]) == \
        pytest.approx(6.0 * n * 256 * 4096)
    assert model_flops_global(cfg, SHAPES["decode_32k"]) == \
        pytest.approx(2.0 * n * 128)
    moe = get_config("deepseek-moe-16b")
    assert model_flops_global(moe, SHAPES["train_4k"]) < \
        6.0 * moe.param_count() * 256 * 4096 * 0.25   # active ≪ total


def test_eligible_cells_count():
    from repro.configs import ARCH_NAMES, get_config, eligible_shapes
    total = sum(len(eligible_shapes(get_config(a))) for a in ARCH_NAMES)
    assert total == 32          # 10×3 + xlstm/jamba long_500k


def test_sharding_ctx_levers_trace():
    """The hillclimb levers must trace cleanly (1×1 mesh: constraints are
    trivial, the code path is what we check)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import lm
    from repro.sharding import context as shctx
    from repro.sharding.partition import MeshPlan

    cfg = get_smoke_config("deepseek-moe-16b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    mesh = make_local_mesh()
    ctx = shctx.ShardingCtx(mesh=mesh, dp_axes=("data",),
                            ffn="gather_weights", moe_gather_seq=True)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    with shctx.use(ctx):
        logits = lm.forward(cfg, params, toks, mamba_chunk=8)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

"""Sharding rules: TP divisibility fallback, FSDP, cache layouts."""
from types import SimpleNamespace

import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models.lm import ParamDef
from repro.sharding.partition import MeshPlan, _spec_for, _cache_leaf_spec


def fake_plan(fsdp=False, data=16, model=16, pod=None):
    shape = {"data": data, "model": model}
    if pod:
        shape = {"pod": pod, **shape}
    mesh = SimpleNamespace(shape=shape)
    dp_axes = tuple(a for a in ("pod", "data") if a in shape)
    return MeshPlan(mesh=mesh, dp_axes=dp_axes, fsdp=fsdp)


def test_tp_on_divisible_heads():
    d = ParamDef((2048, 32, 64), ("embed", "heads", None))
    assert _spec_for(d, fake_plan()) == P(None, "model", None)


def test_replicate_non_divisible_heads():
    """deepseek-coder 56 heads: TP falls back to replication (context
    parallelism takes over via activation sharding)."""
    d = ParamDef((7168, 56, 128), ("embed", "heads", None))
    assert _spec_for(d, fake_plan()) == P(None, None, None)


def test_replicate_small_kv_heads():
    d = ParamDef((2048, 2, 128), ("embed", "kv_heads", None))
    assert _spec_for(d, fake_plan()) == P(None, None, None)


def test_fsdp_shards_embed_dim():
    d = ParamDef((2048, 32, 64), ("embed", "heads", None))
    assert _spec_for(d, fake_plan(fsdp=True)) == P("data", "model", None)


def test_fsdp_skipped_when_not_divisible():
    d = ParamDef((100, 32, 64), ("embed", "heads", None))
    assert _spec_for(d, fake_plan(fsdp=True)) == P(None, "model", None)


def test_expert_dim_sharded():
    d = ParamDef((64, 2048, 1408), ("expert", "embed", None))
    assert _spec_for(d, fake_plan(fsdp=True)) == P("model", "data", None)


def test_one_mesh_axis_used_once():
    d = ParamDef((2048, 2048), ("embed", "embed2"))
    spec = _spec_for(d, fake_plan(fsdp=True))
    axes = [a for a in spec if a is not None]
    assert len(axes) == len(set(axes))


def test_cache_attn_kv_seq_over_model():
    plan = fake_plan()
    spec = _cache_leaf_spec((128, 32768, 8, 128), plan, "attn_kv")
    assert spec == P(("data",), "model", None, None)


def test_cache_batch_replicated_when_indivisible():
    plan = fake_plan()
    spec = _cache_leaf_spec((1, 524288, 8, 128), plan, "attn_kv")
    assert spec == P(None, "model", None, None)


def test_cache_state_shards_largest_divisible_dim():
    plan = fake_plan()
    spec = _cache_leaf_spec((128, 8192, 16), plan, "state")
    assert spec == P(("data",), "model", None)


def test_multipod_dp_axes():
    plan = fake_plan(pod=2)
    assert plan.dp_axes == ("pod", "data")
    assert plan.dp_size == 32


def test_plan_defaults():
    from repro.launch.mesh import make_local_mesh
    cfg = get_config("deepseek-coder-33b")
    from repro.sharding.partition import make_plan
    mesh = make_local_mesh()
    plan = make_plan(cfg, mesh, "train")
    assert plan.fsdp            # 33B ⇒ FSDP on
    assert not plan.sp          # model axis size 1 locally ⇒ no SP

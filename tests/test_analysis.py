"""repro.analysis: seeded-violation tests for the protocol checker and
unit tests for the repo-invariant linter.

Each protocol rule is proven to *fire* on a synthetic trace seeded with
exactly that violation, and to stay silent on the healthy variant; one
violation is driven through the real FDB/tensorstore stack
(``execute(flush=False)`` then release) and caught by
``fdb.check_protocol()``.  The linter is exercised against tiny
synthetic repos under ``tmp_path`` — one per rule — plus the live
check that ``src/`` itself is lint-clean with every suppression pinned.
"""
import textwrap

import numpy as np
import pytest

from repro.analysis.lint import Linter, lint_paths, load_span_taxonomy
from repro.analysis.protocol import (LockOrderRecorder, Violation,
                                     check_protocol, protocol_guard)
from repro.core import FDB, FDBConfig
from repro.obs.locks import NamedLock
from repro.obs.trace import GLOBAL_TRACER, Tracer
from repro.tensorstore import TensorStore
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# ---------------------------------------------------------------------------
# protocol checker: seeded traces, rule by rule
# ---------------------------------------------------------------------------

S = dict(scope="ds|col", resource="g0")     # one (scope, resource) key


def tracer():
    return Tracer(enabled=True)


def test_archive_without_lease_fires():
    t = tracer()
    t.record_complete("io.archive", 10, 20, owner="w1", client="c1",
                      chunk_ids=[0, 1], **S)
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["archive-without-lease"]
    assert v[0].details["chunk_ids"] == [0, 1]
    assert "no live covering lease" in str(v[0])


def test_archive_under_live_lease_full_lifecycle_is_clean():
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("io.archive", 10, 20, owner="w1", client="c1",
                      chunk_ids=[0, 3], **S)
    t.record_complete("fdb.flush", 30, 40, client="c1")
    t.record_complete("lease.release", 50, 55, owner="w1", lo=0, hi=4,
                      exact=True, **S)
    assert check_protocol(t.spans()) == []


def test_archive_outside_leased_range_fires():
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("io.archive", 10, 20, owner="w1", client="c1",
                      chunk_ids=[3, 4], **S)         # 4 is outside [0, 4)
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["archive-without-lease"]
    assert v[0].details["chunk_ids"] == [4]


def test_epoch_regression_fires_but_idempotent_reacquire_does_not():
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=7, **S)
    t.record_complete("lease.acquire", 10, 15, owner="w1", lo=0, hi=4,
                      epoch=7, **S)                  # idempotent: same epoch
    t.record_complete("lease.release", 20, 22, owner="w1", lo=0, hi=4,
                      exact=True, **S)
    t.record_complete("lease.acquire", 30, 35, owner="w2", lo=0, hi=4,
                      epoch=3, **S)                  # regression: 3 < 7
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["epoch-regression"]
    assert v[0].details == {"scope": "ds|col", "resource": "g0", "lo": 0,
                            "hi": 4, "epoch": 3, "prev_epoch": 7}


def test_release_before_flush_fires():
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("io.archive", 10, 20, owner="w1", client="c1",
                      chunk_ids=[1, 2], **S)
    t.record_complete("lease.release", 30, 35, owner="w1", lo=0, hi=4,
                      exact=True, **S)               # dirty chunks orphaned
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["release-before-flush"]
    assert v[0].details["chunk_ids"] == [1, 2]


def test_release_after_flush_is_clean():
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("io.archive", 10, 20, owner="w1", client="c1",
                      chunk_ids=[1, 2], **S)
    t.record_complete("fdb.flush", 25, 28, client="c1")
    t.record_complete("lease.release", 30, 35, owner="w1", lo=0, hi=4,
                      exact=True, **S)
    assert check_protocol(t.spans()) == []


def test_sibling_lease_keeps_dirty_chunks_covered():
    """Exact release of one of two overlapping same-owner leases is clean
    while the sibling still covers the dirty chunk — releasing the
    sibling too (still unflushed) then fires."""
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("lease.acquire", 6, 8, owner="w1", lo=2, hi=6,
                      epoch=2, **S)
    t.record_complete("io.archive", 10, 20, owner="w1", client="c1",
                      chunk_ids=[3], **S)            # covered by both
    t.record_complete("lease.release", 30, 32, owner="w1", lo=2, hi=6,
                      exact=True, **S)               # sibling still covers 3
    assert check_protocol(t.spans()) == []
    t.record_complete("lease.release", 40, 42, owner="w1", lo=0, hi=4,
                      exact=True, **S)               # now 3 is orphaned
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["release-before-flush"]
    assert v[0].details["chunk_ids"] == [3]


def test_rmw_without_lease_check_fires():
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("rmw.fetch", 10, 20, owner="w1", client="c1", **S)
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["rmw-unvalidated"]


def test_rmw_after_fencing_check_is_clean():
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("lease.check", 8, 9, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("rmw.fetch", 10, 20, owner="w1", client="c1", **S)
    assert check_protocol(t.spans()) == []


def test_rmw_with_stale_check_fires():
    """A check that predates the owner's last lease-state change does not
    validate a later RMW fetch — it must be re-run."""
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("lease.check", 8, 9, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("lease.acquire", 12, 15, owner="w1", lo=4, hi=8,
                      epoch=2, **S)                  # state changed at t=15
    t.record_complete("rmw.fetch", 20, 30, owner="w1", client="c1", **S)
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["rmw-unvalidated"]
    assert v[0].details["last_check"] == 8
    assert v[0].details["last_change"] == 15


def test_recover_live_lease_fires_when_ttl_not_lapsed():
    """A recovery sweep that purges a lease whose TTL (per the trace's
    last extension) had not yet lapsed raced a live heartbeat."""
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, ttl=1.0, **S)         # expires at 5 + 1e9
    t.record_complete("fdb.recover", 1000, 2000, client="c2",
                      scope="ds|col",
                      expired=[{"resource": "g0", "owner": "w1", "lo": 0,
                                "hi": 4, "epoch": 1}],
                      orphans=[], stale=0)           # 1000 < 5 + 1e9
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["recover-live-lease"]
    assert v[0].details["owner"] == "w1"
    assert "raced a heartbeat" in str(v[0])


def test_recover_after_ttl_lapse_is_clean_and_clears_dirty():
    """A sweep after the TTL genuinely lapsed is clean; its quarantined
    orphans stop counting as dirty (no release-before-flush afterwards),
    and a later writer may re-lease the range at a higher epoch."""
    t = tracer()
    ttl_ns = int(0.001 * 1e9)                        # 1 ms TTL
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, ttl=0.001, **S)
    t.record_complete("io.archive", 10, 20, owner="w1", client="c1",
                      chunk_ids=[1, 2], **S)         # journaled, unflushed
    t_sweep = 5 + ttl_ns + 100                       # past expiry
    t.record_complete("fdb.recover", t_sweep, t_sweep + 10, client="c2",
                      scope="ds|col",
                      expired=[{"resource": "g0", "owner": "w1", "lo": 0,
                                "hi": 4, "epoch": 1}],
                      orphans=[{"resource": "g0", "owner": "w1",
                                "chunk_ids": [1, 2], "client": "c1"}],
                      stale=0)
    t.record_complete("lease.acquire", t_sweep + 20, t_sweep + 25,
                      owner="w2", lo=0, hi=4, epoch=2, **S)
    assert check_protocol(t.spans()) == []


def test_renew_extends_ttl_so_recover_after_it_fires():
    """A heartbeat renewal re-arms the TTL: a sweep that would have been
    legal against the acquire time races the renewed lease."""
    t = tracer()
    ttl_ns = int(0.001 * 1e9)
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, ttl=0.001, **S)
    t_renew = 5 + ttl_ns // 2
    t.record_complete("lease.renew", t_renew, t_renew + 2, owner="w1",
                      ttl=0.001, renewed=1, **S)     # re-armed at t_renew+2
    t_sweep = 5 + ttl_ns + 100                       # past the *acquire* TTL
    t.record_complete("fdb.recover", t_sweep, t_sweep + 10, client="c2",
                      scope="ds|col",
                      expired=[{"resource": "g0", "owner": "w1", "lo": 0,
                                "hi": 4, "epoch": 1}],
                      orphans=[], stale=0)
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["recover-live-lease"]
    # a renewal that extended nothing (renewed=0) does not re-arm
    t2 = tracer()
    t2.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                       epoch=1, ttl=0.001, **S)
    t2.record_complete("lease.renew", 10, 12, owner="w1", ttl=0.001,
                       renewed=0, **S)
    t2.record_complete("fdb.recover", 5 + ttl_ns + 100, 5 + ttl_ns + 110,
                       client="c2", scope="ds|col",
                       expired=[{"resource": "g0", "owner": "w1", "lo": 0,
                                 "hi": 4, "epoch": 1}],
                       orphans=[], stale=0)
    assert check_protocol(t2.spans()) == []


def test_failed_flush_is_not_a_barrier():
    """A flush span carrying an error attr (crashed or failed barrier)
    published nothing: the owner's dirty chunks stay dirty, so a release
    right after it still fires release-before-flush."""
    t = tracer()
    t.record_complete("lease.acquire", 0, 5, owner="w1", lo=0, hi=4,
                      epoch=1, **S)
    t.record_complete("io.archive", 10, 20, owner="w1", client="c1",
                      chunk_ids=[1], **S)
    t.record_complete("fdb.flush", 25, 28, client="c1",
                      error="InjectedCrash")
    t.record_complete("lease.release", 30, 35, owner="w1", lo=0, hi=4,
                      exact=True, **S)
    v = check_protocol(t.spans())
    assert [x.rule for x in v] == ["release-before-flush"]


def test_executor_over_window_fires_from_gauge_high_water():
    t = tracer()
    t.metrics.gauge("executor.in_flight").set(9)
    t.metrics.gauge("executor.in_flight").set(2)     # level drops, max stays
    v = check_protocol([], t.metrics, max_in_flight=8)
    assert [x.rule for x in v] == ["executor-over-window"]
    assert v[0].details == {"max": 9, "window": 8}
    assert check_protocol([], t.metrics, max_in_flight=16) == []
    assert check_protocol([], None, max_in_flight=8) == []       # skipped
    assert check_protocol([], t.metrics, max_in_flight=None) == []


def test_lock_cycle_recorder_flags_opposite_orders():
    a, b = NamedLock("La"), NamedLock("Lb")
    rec = LockOrderRecorder()
    with rec:
        with a:
            with b:
                pass
        with b:
            with a:                                  # opposite order
                pass
    cycles = rec.cycles()
    assert len(cycles) == 1 and set(cycles[0]) == {"La", "Lb"}
    v = rec.violations()
    assert [x.rule for x in v] == ["lock-cycle"]


def test_lock_order_consistent_is_clean():
    a, b = NamedLock("La"), NamedLock("Lb")
    with LockOrderRecorder() as rec:
        for _ in range(3):
            with a:
                with b:
                    pass
    assert rec.cycles() == [] and rec.violations() == []


def test_protocol_guard_raises_on_seeded_violation():
    t = tracer()
    with pytest.raises(AssertionError, match="archive-without-lease"):
        with protocol_guard(t, lock_order=False):
            t.record_complete("io.archive", 10, 20, owner="w1",
                              chunk_ids=[0], **S)


def test_protocol_guard_clean_block_passes_and_body_errors_propagate():
    t = tracer()
    with protocol_guard(t):
        with t.span("io.fetch"):
            pass
    with pytest.raises(ValueError, match="boom"):    # not swallowed
        with protocol_guard(t):
            raise ValueError("boom")


def test_violation_str_format():
    v = Violation("epoch-regression", "msg", 5, {"k": 1})
    assert str(v) == "[epoch-regression] msg"


# ---------------------------------------------------------------------------
# protocol checker against the real FDB/tensorstore stack
# ---------------------------------------------------------------------------

BASE = {"store": "s", "array": "a", "writer": "w0"}


def make_fdb(tmp_path):
    return FDB(FDBConfig(backend="posix", schema="tensor",
                         root=str(tmp_path / "fdb")))


def test_real_stack_release_before_flush_detected(tmp_path):
    """Drive the actual contract break through the public API: a session
    plan archives with ``flush=False`` and then abandons its leases
    without flushing — ``fdb.check_protocol()`` must catch it."""
    GLOBAL_TRACER.enable()
    fdb = make_fdb(tmp_path)
    x = np.arange(64 * 48, dtype=np.float32).reshape(64, 48)
    TensorStore(fdb, BASE).create(x.shape, x.dtype, chunks=(16, 16))
    fdb.flush()
    sa = fdb.session("A")
    arr = TensorStore(None, BASE, session=sa).open()
    plan = arr.write_plan((slice(0, 32), slice(None)), x[:32])
    plan.execute(flush=False)                        # chunks stay dirty
    plan.release_leases()                            # ...and get orphaned
    v = fdb.check_protocol()
    assert any(x.rule == "release-before-flush" for x in v)
    assert all(x.rule == "release-before-flush" for x in v)
    sa.close()
    fdb.close()


def test_real_stack_healthy_two_writer_run_is_clean(tmp_path):
    GLOBAL_TRACER.enable()
    fdb = make_fdb(tmp_path)
    x = np.arange(64 * 48, dtype=np.float32).reshape(64, 48)
    arr = TensorStore(fdb, BASE).create(x.shape, x.dtype, chunks=(16, 16))
    fdb.flush()
    sa, sb = fdb.session("A"), fdb.session("B")
    aa = TensorStore(None, BASE, session=sa).open()
    ab = TensorStore(None, BASE, session=sb).open()
    aa.write_plan((slice(0, 32), slice(None)), x[:32]).execute(flush=False)
    ab.write_plan((slice(32, 64), slice(None)), x[32:]).execute(flush=False)
    sa.flush()                                       # publishes both
    sa.close()
    sb.close()
    np.testing.assert_array_equal(arr.read(), x)
    assert fdb.check_protocol() == []
    fdb.close()


# ---------------------------------------------------------------------------
# linter: one synthetic mini-repo per rule
# ---------------------------------------------------------------------------

DOCS = textwrap.dedent("""\
    # Observability

    ## Span taxonomy

    | Span | Layer | Meaning |
    |---|---|---|
    | `io.fetch` | tensorstore | reads |
    | `plan.write` / `plan.stage` | tensorstore | stages |
    | `store.<backend>.archive[_batch]` | backends | writes |

    ## Metric names
    """)


def mkrepo(tmp_path, files):
    (tmp_path / "docs").mkdir(exist_ok=True)
    (tmp_path / "docs" / "observability.md").write_text(DOCS)
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return lint_paths([tmp_path / "src"], root=tmp_path)


def rules(result):
    return [f.rule for f in result.findings]


def test_lint_layer_violations(tmp_path):
    res = mkrepo(tmp_path, {
        "src/repro/core/x.py": "from repro.tensorstore import store\n",
        "src/repro/obs/y.py": "import numpy\n",
        "src/repro/data/ok.py": "from repro.core import FDB\n",
        "src/repro/obs/ok.py": "import json\nfrom .y import thing\n",
    })
    assert rules(res) == ["L001", "L001"]
    assert {f.path for f in res.findings} == \
        {"src/repro/core/x.py", "src/repro/obs/y.py"}
    assert "stdlib-only" in res.findings[1].message


def test_lint_byte_ops_outside_facade(tmp_path):
    body = "def f(x, b):\n    x.store.archive(b)\n    x.catalogue.flush()\n"
    res = mkrepo(tmp_path, {
        "src/repro/data/x.py": body,        # not a facade/plan module
        "src/repro/core/fdb.py": body,      # the facade itself: allowed
    })
    assert rules(res) == ["L002", "L002"]
    assert all(f.path == "src/repro/data/x.py" for f in res.findings)


def test_lint_blocking_call_under_lock(tmp_path):
    res = mkrepo(tmp_path, {
        "src/repro/core/backends/b.py": """\
            def f(self, data):
                with self._lock:
                    self.f.write(data)
                self.f.write(data)      # outside the lock: fine
            """,
        "src/repro/tensorstore/t.py": """\
            def f(self, data):
                with self._lock:
                    self.f.write(data)  # rule scoped to fdb/backends only
            """,
    })
    assert rules(res) == ["L003"]
    assert res.findings[0].path == "src/repro/core/backends/b.py"


def test_lint_span_discipline(tmp_path):
    res = mkrepo(tmp_path, {
        "src/repro/train/x.py": """\
            def f(tracer):
                cm = tracer.span("io.fetch")          # not a CM: flagged
                with tracer.span("bogus.name"):       # undocumented name
                    pass
                with tracer.span("io.fetch"):         # fine
                    pass
                with tracer.span("store.daos.archive_batch"):  # wildcard
                    pass
            """,
    })
    assert rules(res) == ["L004", "L004"]
    assert "context manager" in res.findings[0].message
    assert "bogus.name" in res.findings[1].message


def test_lint_bare_thread(tmp_path):
    res = mkrepo(tmp_path, {
        "src/repro/serve/x.py":
            "import threading\n\nt = threading.Thread(target=print)\n",
        "src/repro/tensorstore/executor.py":
            "import threading\n\nt = threading.Thread(target=print)\n",
    })
    assert rules(res) == ["L005"]
    assert res.findings[0].path == "src/repro/serve/x.py"


def test_lint_metered_lease_path(tmp_path):
    res = mkrepo(tmp_path, {
        "src/repro/core/lease.py": """\
            def acquire(self):
                self.meter.record("op", 1)
            """,
        "src/repro/core/fdb.py": """\
            def archive(self):
                self.meter.record("op", 1)  # data path: metering is fine
            def acquire_lease(self):
                GLOBAL_METER.record("op", 1)
            """,
    })
    assert set(rules(res)) == {"L006"}
    assert {f.path for f in res.findings} == \
        {"src/repro/core/lease.py", "src/repro/core/fdb.py"}


def test_lint_repo_layout(tmp_path):
    res = mkrepo(tmp_path, {
        "stray.py": "x = 1\n",
        "conftest.py": "x = 1\n",           # allow-listed
    })
    assert rules(res) == ["L007"]
    assert "stray.py" in res.findings[0].message


def test_lint_suppression_matching_and_l008(tmp_path):
    res = mkrepo(tmp_path, {
        "src/repro/serve/a.py": """\
            import threading

            # lint: disable=L005 -- deliberate single helper thread
            t = threading.Thread(target=print)
            """,
        "src/repro/serve/b.py": """\
            import threading

            t = threading.Thread(target=print)  # lint: disable=L005
            """,
        "src/repro/serve/c.py": """\
            import threading  # lint: disable=L001 -- never fires
            """,
    })
    # a.py: baselined by a comment-block pragma with rationale.
    # b.py: suppressed but the bare pragma is itself an L008 finding.
    # c.py: a suppression that matches nothing is reported unused.
    assert rules(res) == ["L008"]
    assert res.findings[0].path == "src/repro/serve/b.py"
    assert [f.path for f in res.suppressed] == ["src/repro/serve/a.py",
                                                "src/repro/serve/b.py"]
    assert [s.path for s in res.unused_suppressions] == \
        ["src/repro/serve/c.py"]


def test_lint_sleep_and_hand_rolled_retry(tmp_path):
    res = mkrepo(tmp_path, {
        "src/repro/data/x.py": """\
            import time

            def poll(self):
                time.sleep(0.1)             # bare sleep: flagged
                for _ in range(3):
                    try:
                        self.op()
                    except Exception:
                        continue            # hand-rolled retry: flagged
            """,
        "src/repro/core/retry.py": """\
            import time

            def backoff(self, s):
                time.sleep(s)               # the retry layer itself: fine
            """,
        "src/repro/core/faults.py": """\
            import time

            def spike(self, s):
                time.sleep(s)               # latency injection: fine
            """,
        "src/repro/train/ok.py": """\
            def drain(self):
                while True:
                    try:
                        self.step()
                    except Exception:
                        pass                # swallow-and-fall-through: fine
                    if self.done:
                        break
            """,
    })
    assert rules(res) == ["L009", "L009"]
    assert all(f.path == "src/repro/data/x.py" for f in res.findings)
    assert "time.sleep" in res.findings[0].message
    assert "hand-rolled retry" in res.findings[1].message


def test_load_span_taxonomy_expansion(tmp_path):
    doc = tmp_path / "observability.md"
    doc.write_text(DOCS)
    exact, patterns = load_span_taxonomy(doc)
    assert {"io.fetch", "plan.write", "plan.stage"} <= exact
    assert any(p.fullmatch("store.rados.archive") for p in patterns)
    assert any(p.fullmatch("store.rados.archive_batch") for p in patterns)
    assert not any(p.fullmatch("store.rados.retrieve") for p in patterns)


def test_repo_src_is_lint_clean():
    """The live gate: the repo's own src/ has zero unsuppressed findings
    and no stale suppressions (mirrors `scripts/lint.py src --strict`)."""
    res = lint_paths([REPO / "src"], root=REPO)
    assert res.findings == []
    assert res.unused_suppressions == []
    assert all(s.rationale for s in res.suppressions)


def test_linter_uses_real_taxonomy():
    linter = Linter(root=REPO)
    assert linter._span_name_ok("lease.release")
    assert linter._span_name_ok("store.posix.archive_batch")
    assert not linter._span_name_ok("made.up.name")

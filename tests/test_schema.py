"""Property tests for identifiers and schema key-splitting (thesis §2.7)."""
import string

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # thin deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import Identifier, NWP_OBJECT_SCHEMA, NWP_POSIX_SCHEMA, Schema

_key_text = st.text(alphabet=string.ascii_lowercase + string.digits,
                    min_size=1, max_size=8)


def _ident_strategy(schema: Schema):
    dims = schema.all_dims
    return st.fixed_dictionaries({d: _key_text for d in dims}).map(Identifier)


@settings(max_examples=50, deadline=None)
@given(_ident_strategy(NWP_POSIX_SCHEMA))
def test_split_join_roundtrip_posix(ident):
    d, c, e = NWP_POSIX_SCHEMA.split(ident)
    assert NWP_POSIX_SCHEMA.join(d, c, e) == ident
    assert set(d) == set(NWP_POSIX_SCHEMA.dataset_dims)
    assert set(c) == set(NWP_POSIX_SCHEMA.collocation_dims)
    assert set(e) == set(NWP_POSIX_SCHEMA.element_dims)


@settings(max_examples=50, deadline=None)
@given(_ident_strategy(NWP_OBJECT_SCHEMA))
def test_canonical_roundtrip(ident):
    assert Identifier.from_canonical(ident.canonical()) == ident


@settings(max_examples=50, deadline=None)
@given(_ident_strategy(NWP_OBJECT_SCHEMA), st.data())
def test_matches_partial(ident, data):
    sub_dims = data.draw(st.sets(st.sampled_from(list(ident)), max_size=4))
    partial = {k: ident[k] for k in sub_dims}
    assert ident.matches(partial)
    if sub_dims:
        k = next(iter(sub_dims))
        assert not ident.matches({**partial, k: ident[k] + "x"})


def test_identifier_order_invariance():
    a = Identifier({"a": 1, "b": 2})
    b = Identifier({"b": 2, "a": 1})
    assert a == b and hash(a) == hash(b)


def test_schema_rejects_overlap():
    with pytest.raises(ValueError):
        Schema("bad", ("a",), ("a",), ("b",))


def test_schema_rejects_missing_dims():
    with pytest.raises(KeyError):
        NWP_POSIX_SCHEMA.split(Identifier({"class": "od"}))


def test_object_schema_moves_contention_dims():
    """The thesis's C7 lever: number+levelist in the collocation key."""
    assert "number" in NWP_OBJECT_SCHEMA.collocation_dims
    assert "levelist" in NWP_OBJECT_SCHEMA.collocation_dims
    assert "number" in NWP_POSIX_SCHEMA.element_dims

"""Writer sessions + chunk-range leases: the multi-writer tensorstore.

Covers the PR acceptance criteria: two ``WriterSession``\\ s on disjoint
chunk ranges of one array produce byte-identical results to a single
sequential writer on all four backends; overlapping sessions
deterministically raise ``LeaseConflictError`` at *plan* time; a fenced
stale writer cannot commit after its lease is broken and re-acquired
(``StaleLeaseError``); plus the catalogue-level lease table contract
(cross-client visibility, epoch monotonicity), per-session dirty/flush
barriers, the ``ChunkedFieldStore.writer`` facade, the checkpointer's
``save_sharded``, and a threaded two-writer stress loop (marked slow).
"""
import threading

import numpy as np
import pytest

from repro.core import (FDB, FDBConfig, LeaseConflictError, StaleLeaseError)
from repro.tensorstore import TensorStore

BASE = {"store": "s", "array": "a", "writer": "w0"}


# ---------------------------------------------------------------------------
# catalogue-level lease table contract
# ---------------------------------------------------------------------------

def test_lease_table_contract(backend, tmp_path, make_fdb):
    """Acquire/conflict/idempotence/release/holders + epoch fencing, seen
    identically from two FDB clients of one deployment."""
    fdb, fdb2 = make_fdb(backend), make_fdb(backend)
    with fdb.session("A") as a:
        e1 = a.acquire_lease(BASE, "g0", 0, 4)
        assert a.acquire_lease(BASE, "g0", 0, 4) == e1   # idempotent
        b = fdb2.session("B")
        with pytest.raises(LeaseConflictError, match=r"\[2, 6\)"):
            b.acquire_lease(BASE, "g0", 2, 6)            # overlap, fast
        e2 = b.acquire_lease(BASE, "g0", 4, 8)           # disjoint is fine
        assert e2 > e1                                   # epochs monotonic
        holders = fdb.lease_holders(BASE, "g0")          # cross-client view
        assert [(l.owner, l.lo, l.hi) for l in holders] == \
            [("A", 0, 4), ("B", 4, 8)]
        # a third party breaks A's lease; B re-acquires; A is fenced
        fdb2.release_lease(BASE, "g0", 0, 4, owner="A")
        e3 = b.acquire_lease(BASE, "g0", 0, 4)
        assert e3 > e2
        with pytest.raises(StaleLeaseError, match="no longer current"):
            a.check_lease(BASE, "g0", 0, 4, e1)
        b.check_lease(BASE, "g0", 0, 4, e3)              # current holder ok
        b.close()
        assert fdb.lease_holders(BASE, "g0") == []       # close releases
    fdb.close()
    fdb2.close()


def test_lease_identifier_requires_dataset_and_collocation(tmp_path, make_fdb):
    fdb = make_fdb("daos")
    with pytest.raises(KeyError, match="missing dims"):
        fdb.acquire_lease({"store": "s"}, "g0", 0, 1, owner="A")
    # element dims are ignored (leases cover ranges, not keys)
    fdb.acquire_lease({**BASE, "chunk": "c0"}, "g0", 0, 1, owner="A")
    assert len(fdb.lease_holders(BASE, "g0")) == 1
    with pytest.raises(ValueError, match="half-open"):
        fdb.acquire_lease(BASE, "g0", 3, 3, owner="A")
    fdb.close()


# ---------------------------------------------------------------------------
# two writers, one array (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_two_writers_disjoint_byte_identical(backend, tmp_path, make_fdb):
    """Two sessions writing disjoint chunk ranges of one array ==
    byte-identical to a single sequential writer — per chunk object, not
    just per read."""
    fdb = make_fdb(backend)
    x = np.random.default_rng(0).normal(size=(64, 48)).astype(np.float32)
    ts = TensorStore(fdb, BASE)
    arr = ts.create(x.shape, x.dtype, chunks=(16, 16))
    fdb.flush()                          # publish the metadata (rule 3)
    sa, sb = fdb.session("A"), fdb.session("B")
    aa = TensorStore(None, BASE, session=sa).open()
    ab = TensorStore(None, BASE, session=sb).open()
    pa = aa.write_plan((slice(0, 32), slice(None)), x[:32])
    pb = ab.write_plan((slice(32, 64), slice(None)), x[32:])
    # disjoint linear chunk-id ranges were leased: rows 0-1 and 2-3 of a
    # (4, 3) chunk grid -> [0, 6) and [6, 12)
    assert [(lo, hi) for lo, hi, _e, _c in pa.leases] == [(0, 6)]
    assert [(lo, hi) for lo, hi, _e, _c in pb.leases] == [(6, 12)]
    pa.execute(flush=False)
    pb.execute(flush=False)
    sa.flush()                           # one barrier publishes both
    np.testing.assert_array_equal(arr.read(), x)
    sa.close()
    sb.close()
    # sequential single-writer reference on a second array slot
    ref_base = dict(BASE, array="ref")
    ref = TensorStore(fdb, ref_base).save(x, chunks=(16, 16))
    for idx in arr.grid.all_indices():
        multi = fdb.retrieve(arr.chunk_ident(idx)).read()
        single = fdb.retrieve(ref.chunk_ident(idx)).read()
        assert multi == single, f"chunk {idx} bytes differ"
    fdb.close()


def test_overlapping_writers_rejected_at_plan_time(tmp_path, make_fdb):
    """The second writer fails fast — before any byte moves — and the
    array is untouched by the failed plan."""
    fdb = make_fdb("daos")
    x = np.ones((32, 32), np.float32)
    arr = TensorStore(fdb, BASE).save(x, chunks=(8, 8))
    sa, sb = fdb.session("A"), fdb.session("B")
    aa = TensorStore(None, BASE, session=sa).open()
    ab = TensorStore(None, BASE, session=sb).open()
    aa.write_plan((slice(0, 16), slice(None)), 2 * x[:16])
    with pytest.raises(LeaseConflictError, match="leased by"):
        ab.write_plan((slice(8, 24), slice(None)), 3 * x[:16])
    # the failed plan holds nothing: B can still lease the disjoint rest
    pb = ab.write_plan((slice(16, 32), slice(None)), 3 * x[:16])
    pb.execute()
    np.testing.assert_array_equal(arr[0:16], x[:16])     # A never executed
    np.testing.assert_array_equal(arr[16:32], 3 * x[:16])
    sa.close()
    sb.close()
    fdb.close()


def test_partial_conflict_rolls_back_acquired_ranges(tmp_path, make_fdb):
    """A plan that conflicts on its second range must release the first —
    a failed plan leaves no leases behind."""
    fdb = make_fdb("daos")
    arr = TensorStore(fdb, BASE).save(np.zeros(64, np.float32), chunks=(8,))
    sa, sb = fdb.session("A"), fdb.session("B")
    sb.acquire_lease(BASE, "g0", 6, 7)   # B pre-holds chunk 6
    ab = TensorStore(None, BASE, session=sa).open()
    # strided write touching chunks 0,2,4,6 -> ranges [0,1),[2,3),[4,5),[6,7)
    with pytest.raises(LeaseConflictError):
        ab.write_plan((slice(None, None, 16),), np.zeros(4, np.float32))
    holders = fdb.lease_holders(BASE, "g0")
    assert [(l.owner, l.lo, l.hi) for l in holders] == [("B", 6, 7)]
    sa.close()
    sb.close()
    fdb.close()


def test_sibling_plan_release_is_exact_range(tmp_path, make_fdb):
    """A session may hold overlapping leases (two plans over intersecting
    windows); abandoning one plan must not sweep away its sibling's lease
    — holder-side release is exact-range."""
    fdb = make_fdb("daos")
    arr = TensorStore(fdb, BASE).save(np.zeros(64, np.float32), chunks=(8,))
    sa = fdb.session("A")
    aa = TensorStore(None, BASE, session=sa).open()
    p1 = aa.write_plan((slice(0, 32),), np.ones(32, np.float32))   # [0, 4)
    p1.execute(flush=False)              # archived, unflushed: lease held
    p2 = aa.write_plan((slice(16, 48),), np.ones(32, np.float32))  # [2, 6)
    assert [(lo, hi) for lo, hi, _e, _c in p2.leases] == [(2, 6)]
    p2.release_leases()                  # abandon the overlapping sibling
    # p1's lease survives: another writer still conflicts on [0, 4)
    sb = fdb.session("B")
    ab = TensorStore(None, BASE, session=sb).open()
    with pytest.raises(LeaseConflictError):
        ab.write_plan((slice(0, 8),), np.zeros(8, np.float32))
    sa.close()                           # flushes, then frees [0, 4)
    ab.write_plan((slice(0, 8),), np.zeros(8, np.float32)).execute()
    np.testing.assert_array_equal(arr[8:32], np.ones(24, np.float32))
    sb.close()
    fdb.close()


@pytest.mark.parametrize("backend", ["daos", "posix"])
def test_stale_writer_fenced_after_reacquisition(backend, tmp_path, make_fdb):
    """The acceptance scenario: a writer whose lease was broken and
    re-acquired cannot commit its planned write — and the new holder's
    data survives untouched."""
    fdb = make_fdb(backend)
    x = np.zeros((32, 32), np.float32)
    arr = TensorStore(fdb, BASE).save(x, chunks=(8, 8))
    sa, sb = fdb.session("A"), fdb.session("B")
    aa = TensorStore(None, BASE, session=sa).open()
    ab = TensorStore(None, BASE, session=sb).open()
    stale = aa.write_plan((slice(0, 16), slice(None)), x[:16] + 7)
    # coordinator presumes A dead and breaks its lease; B takes over
    fdb.release_lease(BASE, f"g{arr.meta.generation}", 0, 8, owner="A")
    pb = ab.write_plan((slice(0, 16), slice(None)), x[:16] + 9)
    pb.execute()
    with pytest.raises(StaleLeaseError, match="no longer current"):
        stale.execute()
    np.testing.assert_array_equal(arr[0:16], x[:16] + 9)  # B's data intact
    # A may re-acquire after B releases and then proceed at a fresh epoch
    sb.close()
    again = aa.write_plan((slice(0, 16), slice(None)), x[:16] + 7)
    assert again.leases[0][2] > stale.leases[0][2]        # epoch advanced
    again.execute()
    np.testing.assert_array_equal(arr[0:16], x[:16] + 7)
    sa.close()
    fdb.close()


def test_rmw_fetch_is_lease_fenced(tmp_path, make_fdb):
    """A stale writer aborts *before* its read-modify-write fetches — the
    lease gate guards the reads too, not only the archives."""
    fdb = make_fdb("posix")
    x = np.arange(64, dtype=np.float32)
    arr = TensorStore(fdb, BASE).save(x, chunks=(8,))
    sa = fdb.session("A")
    aa = TensorStore(None, BASE, session=sa).open()
    stale = aa.write_plan((slice(4, 12),), np.zeros(8, np.float32))
    assert stale.rmw_chunks == 2
    fdb.release_lease(BASE, "g0", 0, 2, owner="A")
    from repro.core.engine.meter import GLOBAL_METER
    before = len(GLOBAL_METER.snapshot())
    with pytest.raises(StaleLeaseError):
        stale.execute()
    reads = [op for op in GLOBAL_METER.snapshot()[before:]
             if op.kind == "read"]
    assert not reads                     # fenced before any fetch I/O
    np.testing.assert_array_equal(arr.read(), x)
    sa.close()
    fdb.close()


# ---------------------------------------------------------------------------
# per-session visibility (rule 3 barriers)
# ---------------------------------------------------------------------------

def test_per_session_dirty_and_flush(tmp_path, make_fdb):
    fdb = make_fdb("posix")
    arr = TensorStore(fdb, BASE).save(np.zeros(32, np.float32), chunks=(8,))
    sa, sb = fdb.session("A"), fdb.session("B")
    aa = TensorStore(None, BASE, session=sa).open()
    assert not sa.dirty and not sb.dirty
    aa.write_plan((slice(0, 8),), np.ones(8, np.float32)).execute(flush=False)
    assert sa.dirty and not sb.dirty     # dirty tracks per session
    assert fdb.dirty
    sb.flush()                           # ANY barrier publishes the client
    assert not sa.dirty and not fdb.dirty
    np.testing.assert_array_equal(arr[0:8], np.ones(8, np.float32))
    sa.close()
    sb.close()
    fdb.close()


def test_session_close_flushes_then_releases(tmp_path, make_fdb):
    """Leases must not be released over unflushed chunks: close flushes
    first, so the next holder can never RMW not-yet-visible bytes."""
    fdb = make_fdb("posix")
    arr = TensorStore(fdb, BASE).save(np.zeros(32, np.float32), chunks=(8,))
    sa = fdb.session("A")
    aa = TensorStore(None, BASE, session=sa).open()
    aa.write_plan((slice(0, 16),), np.ones(16, np.float32)).execute(
        flush=False)
    assert sa.dirty and len(sa.held_leases) == 1
    sa.close()
    assert not fdb.dirty                 # flushed on close
    assert fdb.lease_holders(BASE, "g0") == []
    np.testing.assert_array_equal(arr[0:16], np.ones(16, np.float32))
    with pytest.raises(RuntimeError, match="closed"):
        sa.archive({**BASE, "chunk": "c9"}, b"x")
    fdb.close()


def test_sessionless_store_unchanged(tmp_path, make_fdb):
    """No session, no leases: the single-writer path neither acquires nor
    checks anything (plans report empty lease lists)."""
    fdb = make_fdb("daos")
    arr = TensorStore(fdb, BASE).save(np.zeros(16, np.float32), chunks=(4,))
    plan = arr.write_plan((slice(None),), np.ones(16, np.float32))
    assert plan.session is None and plan.leases == []
    plan.execute()
    assert fdb.lease_holders(BASE, "g0") == []
    fdb.close()


def test_reshard_rejected_in_session(tmp_path, make_fdb):
    fdb = make_fdb("daos")
    TensorStore(fdb, BASE).save(np.zeros((8, 8), np.float32), chunks=(4, 4))
    with fdb.session("A") as sa:
        arr = TensorStore(None, BASE, session=sa).open()
        with pytest.raises(NotImplementedError, match="single-writer"):
            arr.reshard((2, 8))
    fdb.close()


# ---------------------------------------------------------------------------
# facades: ChunkedFieldStore.writer + FDBCheckpointer.save_sharded
# ---------------------------------------------------------------------------

def test_field_store_concurrent_writers():
    """Multi-producer write_window: two threads, disjoint windows, one
    coherent read after commit; overlap rejected; close releases."""
    from repro.data.pipeline import ChunkedFieldStore
    st = ChunkedFieldStore("nwp", FDBConfig(backend="daos"),
                           chunks=(16, 16))
    st.put_field("t2m", np.zeros((64, 64), np.float32))
    st.commit()
    wa, wb = st.writer("assimA"), st.writer("assimB")
    errs = []

    def job(w, sel, val):
        try:
            w.write_window("t2m", val, *sel)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    ta = threading.Thread(target=job,
                          args=(wa, (slice(0, 32), slice(None)), 1.0))
    tb = threading.Thread(target=job,
                          args=(wb, (slice(32, 64), slice(None)), 2.0))
    ta.start(); tb.start(); ta.join(); tb.join()
    assert not errs
    wa.commit()
    y = st.read_window("t2m", slice(None), slice(None))
    assert (y[:32] == 1.0).all() and (y[32:] == 2.0).all()
    # held windows block overlap until the writer closes
    with pytest.raises(LeaseConflictError):
        wb.write_window("t2m", 9.0, slice(16, 48), slice(None))
    wa.close()
    wb.close()
    with st.writer("late") as wl:
        wl.write_window("t2m", 9.0, slice(16, 48), slice(None))
        wl.commit()
    assert (st.read_window("t2m", slice(16, 48), slice(None)) == 9.0).all()
    st.close()


@pytest.mark.parametrize("backend", ["daos", "posix"])
def test_save_sharded_matches_sequential_save(backend, tmp_path):
    """Each simulated rank leases + writes its own shard band; restore is
    byte-identical to a sequential save of the same state."""
    from repro.train.checkpoint import FDBCheckpointer
    params = {"w": np.arange(64 * 16, dtype=np.float32).reshape(64, 16),
              "b": np.arange(16, dtype=np.float32),
              "s": np.float32(3.5)}
    opt = {"mu": np.ones((64, 16), np.float32)}
    cfg = FDBConfig(backend=backend, root=str(tmp_path / "fdb"))
    ck = FDBCheckpointer("runA", cfg, n_shards=4)
    ck.save_sharded(10, params, opt, extra={"lr": np.float32(0.1)})
    got = ck.restore(10, params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(params[k]))
    np.testing.assert_array_equal(np.asarray(ck.restore(10, opt, "opt")["mu"]),
                                  opt["mu"])
    # banded tensor chunk objects match a sequential save's, byte for byte
    seq = FDBCheckpointer("runB", cfg, n_shards=4)
    seq.save(10, params, opt)
    a = ck.open_tensor(10, "w")
    b = seq.open_tensor(10, "w")
    assert a.meta.chunks == b.meta.chunks
    for idx in a.grid.all_indices():
        assert ck.fdb.retrieve(a.chunk_ident(idx)).read() == \
            seq.fdb.retrieve(b.chunk_ident(idx)).read()
    # all rank leases were released at the end of the save
    assert ck.fdb.lease_holders(
        {**ck._dataset("params", 10), "host": ck.host, "tensor": "w"},
        "g0") == []
    ck.close()
    seq.close()


def test_save_sharded_requires_chunked(tmp_path):
    from repro.train.checkpoint import FDBCheckpointer
    ck = FDBCheckpointer("runC", FDBConfig(backend="daos"), chunked=False)
    with pytest.raises(ValueError, match="chunked"):
        ck.save_sharded(0, {"w": np.ones(4, np.float32)})
    ck.close()


# ---------------------------------------------------------------------------
# threaded stress (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("backend", ["daos", "posix"])
def test_two_thread_stress_one_array(backend, tmp_path, make_fdb):
    """Two real threads hammer disjoint halves of one array through their
    own sessions — interleaved plans, partial (RMW) windows, per-write
    commits — and the final state is exactly what a serial replay gives."""
    fdb = make_fdb(backend, io_parallelism=4)
    n, chunk = 256, 8
    x = np.zeros(n, np.float32)
    arr = TensorStore(fdb, BASE).save(x, chunks=(chunk,))
    rng = np.random.default_rng(7)
    #: per-writer scripted updates inside its own half (some chunk-aligned,
    #: some partial -> RMW), replayed serially for the reference
    scripts = []
    for half in range(2):
        lo_half = half * (n // 2)
        script = []
        for _ in range(25):
            a = int(rng.integers(0, n // 2 - 1))
            b = int(rng.integers(a + 1, n // 2))
            val = float(rng.normal())
            script.append((lo_half + a, lo_half + b, val))
        scripts.append(script)
    errs = []

    def writer(w: int) -> None:
        try:
            with fdb.session(f"W{w}") as sess:
                aw = TensorStore(None, BASE, session=sess).open()
                for lo, hi, val in scripts[w]:
                    aw.write_plan((slice(lo, hi),),
                                  np.full(hi - lo, val, np.float32)
                                  ).execute(flush=True)
        except BaseException as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    ref = x.copy()
    for script in scripts:
        for lo, hi, val in script:
            ref[lo:hi] = val
    np.testing.assert_array_equal(arr.read(), ref)
    assert fdb.lease_holders(BASE, "g0") == []
    fdb.close()

"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape + finiteness assertions; decode consistency per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import lm
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.steps import make_train_step


def _batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    out = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(key, (B, 8, cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model)) * 0.02
    return out


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    logits = lm.forward(cfg, params, b["tokens"], mamba_chunk=8,
                        encoder_frames=b.get("frames"),
                        prefix_embeds=b.get("patches"))
    S = b["tokens"].shape[1] + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S, cfg.padded_vocab())
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_decreases_loss(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, None, AdamWConfig(lr=2e-3),
                                   mamba_chunk=8))
    b = _batch(cfg, seed=1)
    losses = []
    for _ in range(4):
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses   # same batch → must overfit


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen2.5-3b",
                                  "jamba-v0.1-52b", "xlstm-1.3b",
                                  "deepseek-moe-16b"])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        # capacity dropping differs between grouped forward (Sg=8) and
        # decode (Sg=1) by design; remove drops to compare the math.
        cfg = cfg.scaled(moe_capacity_factor=8.0)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    full = lm.forward(cfg, params, toks, mamba_chunk=4)
    cache = lm.init_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        logits, cache = lm.decode_step(cfg, params, toks[:, t:t + 1], cache,
                                       t)
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "llava-next-mistral-7b"])
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                              cfg.vocab_size)
    patches = None
    if cfg.family == "vlm":
        patches = jax.random.normal(jax.random.PRNGKey(5),
                                    (B, cfg.n_patches, cfg.d_model)) * 0.02
    total = S + (cfg.n_patches if patches is not None else 0)
    full = lm.forward(cfg, params, toks, prefix_embeds=patches)
    cache = lm.init_cache(cfg, B, total + 4, jnp.float32)
    logits, cache = lm.prefill(cfg, params, toks[:, :-1], cache,
                               prefix_embeds=patches)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, -2]), atol=2e-3)
    # one decode step continues correctly
    nxt, cache = lm.decode_step(cfg, params, toks[:, -1:], cache,
                                jnp.asarray(total - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(nxt[:, 0]),
                               np.asarray(full[:, -1]), atol=2e-3)


def test_whisper_encdec_paths():
    cfg = get_smoke_config("whisper-base")
    params = lm.init_params(cfg, jax.random.PRNGKey(6))
    B, S, T = 2, 10, 8
    frames = jax.random.normal(jax.random.PRNGKey(7), (B, T, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(8), (B, S), 0,
                              cfg.vocab_size)
    full = lm.forward(cfg, params, toks, encoder_frames=frames)
    assert full.shape == (B, S, cfg.padded_vocab())
    cache = lm.init_cache(cfg, B, S, jnp.float32, src_len=T)
    logits, cache = lm.prefill(cfg, params, toks, cache,
                               encoder_frames=frames)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, -1]), atol=2e-3)


def test_full_configs_match_spec():
    """The assigned-architecture table, verbatim."""
    spec = {
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    }
    for arch, (L, D, H, KV, F, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, D, H, KV, F, V), arch
    assert get_config("deepseek-moe-16b").n_experts == 64
    assert get_config("deepseek-moe-16b").top_k == 6
    assert get_config("deepseek-moe-16b").n_shared_experts == 2
    assert get_config("olmoe-1b-7b").top_k == 8
    assert get_config("jamba-v0.1-52b").n_experts == 16
    assert get_config("jamba-v0.1-52b").block_pattern.count("attn") == 1
    assert len(get_config("jamba-v0.1-52b").block_pattern) == 8
    assert get_config("qwen2.5-3b").qkv_bias


def test_moe_active_params_less_than_total():
    cfg = get_config("deepseek-moe-16b")
    assert cfg.active_param_count() < 0.25 * cfg.param_count()

"""repro.tensorstore: chunked N-D arrays over the FDB.

Covers the acceptance criteria: roundtrip of non-chunk-aligned arrays on all
four backends, partial slice reads issuing I/O for only the intersecting
chunks (asserted via engine ``Meter`` op counts), chunk-boundary edge cases,
and codec on/off parity — plus the executor's bounded in-flight window and
the batched ``FDB.archive_many`` semantics.
"""
import time

import numpy as np
import pytest

from repro.core import FDB, FDBConfig, FieldLocation
from repro.core.engine.meter import GLOBAL_METER
from repro.tensorstore import (ChunkExecutor, ChunkGrid, TensorStore,
                               auto_chunks, get_codec)


#: engine op kinds that move object payload bytes on a read path
DATA_READ_KINDS = {"array_read", "read", "http_get"}


def _data_reads(ops):
    return [op for op in ops if op.kind in DATA_READ_KINDS]


# ---------------------------------------------------------------------------
# roundtrip + partial reads (acceptance criteria)
# ---------------------------------------------------------------------------

def test_non_aligned_roundtrip(backend, tmp_path, make_store):
    """(37, 53) on a (16, 16) grid: every edge chunk is clipped."""
    fdb, ts = make_store(backend)
    x = np.random.default_rng(0).normal(size=(37, 53)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    assert arr.shape == (37, 53) and arr.dtype == np.float32
    assert arr.n_chunks == (3, 4)
    np.testing.assert_array_equal(arr.read(), x)
    fdb.close()


def test_partial_read_touches_only_intersecting_chunks(backend, tmp_path, make_store):
    fdb, ts = make_store(backend)
    x = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    ts.save(x, chunks=(16, 16))          # 4 x 4 chunk grid, 1 KiB chunks
    arr = ts.open()
    arr[0:1, 0:1]                        # warm catalogue/axis caches

    for sel, n_expected in [
        ((slice(0, 16), slice(0, 16)), 1),     # exactly one chunk
        ((slice(10, 40), slice(0, 10)), 3),    # rows 0-2 x col 0
        ((slice(0, 64), slice(20, 28)), 4),    # full column band
    ]:
        before = GLOBAL_METER.snapshot()
        np.testing.assert_array_equal(arr[sel], x[sel])
        new_ops = GLOBAL_METER.snapshot()[len(before):]
        reads = _data_reads(new_ops)
        if backend == "posix":
            # posix stripes one chunk read over several OSTs: assert on bytes
            assert sum(op.nbytes for op in reads) == n_expected * 16 * 16 * 4
        else:
            assert len(reads) == n_expected, (sel, reads)
        assert sum(op.nbytes for op in reads) < x.nbytes
    fdb.close()


def test_full_read_moves_all_bytes(backend, tmp_path, make_store):
    fdb, ts = make_store(backend)
    x = np.random.default_rng(2).normal(size=(40, 40)).astype(np.float32)
    ts.save(x, chunks=(32, 32))
    arr = ts.open()
    before = GLOBAL_METER.snapshot()
    np.testing.assert_array_equal(arr.read(), x)
    reads = _data_reads(GLOBAL_METER.snapshot()[len(before):])
    assert sum(op.nbytes for op in reads) == x.nbytes
    fdb.close()


def test_replace_semantics_same_layout(tmp_path, make_store):
    """Re-saving with an unchanged layout transactionally replaces every
    chunk (FDB rule 5)."""
    fdb, ts = make_store("daos")
    ts.save(np.zeros((8, 8), np.float32), chunks=(4, 4))
    y = np.random.default_rng(3).normal(size=(8, 8)).astype(np.float32)
    ts.save(y, chunks=(4, 4))
    np.testing.assert_array_equal(ts.open().read(), y)
    fdb.close()


def test_layout_change_rejected_without_wipe(tmp_path, make_store):
    """A re-create with a different grid would strand old-grid chunk objects
    (no per-object delete in the FDB API) — it must be rejected."""
    from repro.tensorstore import LayoutMismatchError
    fdb, ts = make_store("daos")
    ts.save(np.zeros((8, 8), np.float32), chunks=(2, 2))
    with pytest.raises(LayoutMismatchError):
        ts.create((8, 8), np.float32, chunks=(4, 4))
    with pytest.raises(LayoutMismatchError):
        ts.create((6, 6), np.float32, chunks=(2, 2))
    # after a wipe the new layout goes through
    fdb.wipe({"store": "s", "array": "a"})
    y = np.ones((6, 6), np.float32)
    ts.save(y, chunks=(4, 4))
    np.testing.assert_array_equal(ts.open().read(), y)
    fdb.close()


def test_field_store_regrid_wipes_stale_chunks():
    """ChunkedFieldStore.put_field transparently wipes + re-creates on a
    layout change, leaving no stale old-grid entries behind."""
    from repro.data import ChunkedFieldStore
    fs = ChunkedFieldStore("regrid", FDBConfig(backend="daos"))
    fs.put_field("f", np.zeros((8, 8), np.float32), chunks=(2, 2))
    fs.commit()
    y = np.random.default_rng(11).normal(size=(8, 8)).astype(np.float32)
    fs.put_field("f", y, chunks=(4, 4))
    fs.commit()
    np.testing.assert_array_equal(fs.read_window("f"), y)
    listed = list(fs.fdb.list({"store": "regrid", "array": "f"}))
    assert len(listed) == 4 + 1          # 4 new-grid chunks + meta, no stale
    fs.close()


def test_checkpoint_legacy_resave_shadows_chunked():
    """A legacy (chunked=False) re-save of a step previously saved chunked
    must win on restore — the chunked metadata is tombstoned."""
    from repro.train.checkpoint import FDBCheckpointer
    w = np.full((64, 32), 1.0, np.float32)
    ck1 = FDBCheckpointer("shadow", FDBConfig(backend="daos"))
    ck1.save(5, {"w": w})
    ck2 = FDBCheckpointer("shadow", FDBConfig(backend="daos"), chunked=False)
    ck2.save(5, {"w": w * 2})
    restored = ck2.restore(5, {"w": w})
    np.testing.assert_array_equal(np.asarray(restored["w"]), w * 2)
    ck1.close()
    ck2.close()


def test_open_missing_array_raises(tmp_path, make_store):
    fdb, ts = make_store("daos", array="nope")
    assert not ts.exists()
    with pytest.raises(FileNotFoundError):
        ts.open()
    fdb.close()


# ---------------------------------------------------------------------------
# chunk-aligned partial writes (arr[sel] = values)
# ---------------------------------------------------------------------------

def test_partial_write_roundtrip(backend, tmp_path, make_store):
    """In-place assignment round-trips on every backend, including
    partially-covered edge chunks (read-modify-write)."""
    fdb, ts = make_store(backend)
    x = np.random.default_rng(20).normal(size=(37, 53)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    v = np.random.default_rng(21).normal(size=(20, 30)).astype(np.float32)
    arr[10:30, 17:47] = v                # cuts through 6 chunks, all partial
    x[10:30, 17:47] = v
    np.testing.assert_array_equal(arr.read(), x)
    arr[16:32, 16:32] = 0.0              # exactly one full chunk + broadcast
    x[16:32, 16:32] = 0.0
    np.testing.assert_array_equal(arr.read(), x)
    fdb.close()


def test_partial_write_full_chunks_skip_rmw(tmp_path, make_store):
    """A chunk-aligned selection needs no read-modify-write: no data-read
    ops on the write path."""
    fdb, ts = make_store("daos")
    x = np.zeros((64, 64), np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    before = GLOBAL_METER.snapshot()
    arr[16:48, 0:32] = 1.0               # 2x2 whole chunks
    assert not _data_reads(GLOBAL_METER.snapshot()[len(before):])
    x[16:48, 0:32] = 1.0
    np.testing.assert_array_equal(arr.read(), x)
    fdb.close()


def test_partial_write_into_created_empty_array(tmp_path, make_store):
    """Chunks never written read as zeros (fill-value convention), so a
    created-but-unwritten array can be populated by partial writes."""
    fdb, ts = make_store("rados")
    arr = ts.create((10, 10), np.float32, chunks=(4, 4))
    arr[2:5, 2:5] = 9.0
    want = np.zeros((10, 10), np.float32)
    want[2:5, 2:5] = 9.0
    np.testing.assert_array_equal(arr.read(), want)
    np.testing.assert_array_equal(ts.open().read(), want)
    # strict mode: consumers of dense arrays can refuse the zeros fill and
    # surface never-written chunks as corruption instead
    with pytest.raises(KeyError, match="missing chunk"):
        arr.read_plan((slice(None), slice(None)), fill_missing=False)
    full = ts.save(np.ones((10, 10), np.float32), chunks=(4, 4))
    assert full.read_plan((slice(None), slice(None)),
                          fill_missing=False).n_chunks == 9
    fdb.close()


def test_partial_write_int_index_and_broadcast(tmp_path, make_store):
    fdb, ts = make_store("posix")
    x = np.zeros((9, 7, 5), np.float32)
    ts.save(x, chunks=(4, 3, 2))
    arr = ts.open()
    arr[3] = 7.0                          # int index + scalar broadcast
    x[3] = 7.0
    row = np.arange(5, dtype=np.float32)
    arr[-1, 2] = row                      # negative + squeezed-middle axes
    x[-1, 2] = row
    arr[2:4, 6, 1:3] = np.ones((2, 2), np.float32)
    x[2:4, 6, 1:3] = 1.0
    np.testing.assert_array_equal(arr.read(), x)
    # empty selection: no tasks, no I/O, no error
    assert arr.write_at((slice(5, 5),), np.zeros((0, 7, 5))) == []
    fdb.close()


def test_partial_write_sees_own_unflushed_chunks(tmp_path, make_store):
    """RMW fetches flush first (rule 3), so an archive-without-flush
    followed by a partial write must not lose the unflushed data."""
    fdb, ts = make_store("posix")
    x = np.full((8, 8), 3.0, np.float32)
    arr = ts.create(x.shape, x.dtype, chunks=(4, 4))
    arr.write(x, flush=False)             # archived, not yet committed
    arr[1:3, 1:3] = 5.0                   # partial: needs the 3.0 background
    x[1:3, 1:3] = 5.0
    np.testing.assert_array_equal(arr.read(), x)
    fdb.close()


def test_partial_write_lossy_codec_requantises_within_bound(tmp_path, make_store):
    fdb, ts = make_store("daos")
    rng = np.random.default_rng(22)
    x = rng.normal(size=(256, 128)).astype(np.float32)
    ts.save(x, chunks=(128, 128), codec="field8")
    arr = ts.open()
    v = rng.normal(size=(64, 128)).astype(np.float32)
    arr[32:96, :] = v                     # partial chunks: RMW requantises
    x[32:96, :] = v
    got = arr.read()
    bound = (x.max() - x.min()) / 255 * 0.51 + 1e-6
    assert np.abs(got - x).max() <= 2 * bound   # patch + re-encode: 2 passes
    fdb.close()


# ---------------------------------------------------------------------------
# read planning + posix coalescing
# ---------------------------------------------------------------------------

def test_posix_adjacent_chunks_coalesce(tmp_path, make_store):
    """Acceptance: a full read of a posix array with >= 4 adjacent chunks
    per file issues fewer I/O ops than chunks fetched — one writer's chunks
    land adjacent in one data file and merge into single ranged reads."""
    fdb, ts = make_store("posix")
    v = np.arange(64, dtype=np.float32)
    ts.save(v, chunks=(8,))               # 8 adjacent chunks, one file
    arr = ts.open()
    plan = arr.read_plan((slice(None),))
    assert plan.n_chunks == 8
    assert plan.read_ops() < plan.n_chunks
    assert plan.read_ops() == 1           # fully contiguous -> one read
    np.testing.assert_array_equal(plan.execute(), v)
    # the coalesced read really moves fewer ops through the engine meter
    before = GLOBAL_METER.snapshot()
    np.testing.assert_array_equal(arr.read(), v)
    reads = _data_reads(GLOBAL_METER.snapshot()[len(before):])
    assert sum(op.nbytes for op in reads) == v.nbytes
    fdb.close()


def test_object_store_reads_stay_object_granular(tmp_path, make_store):
    """No false coalescing on object backends: one op per chunk stays in
    flight (the object-store side of the paper's trade-off)."""
    for backend in ("daos", "rados", "s3"):
        fdb, ts = make_store(backend, array=f"og-{backend}")
        x = np.zeros((64,), np.float32)
        ts.save(x, chunks=(8,))
        plan = ts.open().read_plan((slice(None),))
        assert plan.read_ops() == plan.n_chunks == 8
        fdb.close()


def test_read_plan_partial_window(tmp_path, make_store):
    fdb, ts = make_store("posix")
    x = np.random.default_rng(23).normal(size=(64, 64)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    plan = arr.read_plan((slice(0, 32), slice(0, 32)))
    assert plan.n_chunks == 4
    assert plan.read_ops() <= 4
    np.testing.assert_array_equal(plan.execute(), x[:32, :32])
    # empty selection: a plan with nothing to do
    empty = arr.read_plan((slice(5, 5), slice(None)))
    assert empty.n_chunks == 0 and empty.read_ops() == 0
    assert empty.execute().shape == (0, 64)
    fdb.close()


# ---------------------------------------------------------------------------
# write planning + posix write coalescing (the WritePlan mirror)
# ---------------------------------------------------------------------------

def test_posix_write_plan_coalesces(tmp_path, make_store):
    """Acceptance: posix write_ops for a multi-chunk write is strictly
    lower than the chunk count — one writer's chunks append into one data
    file, so the whole plan lands as a single batched store write."""
    fdb, ts = make_store("posix")
    v = np.arange(64, dtype=np.float32)
    arr = ts.create(v.shape, v.dtype, chunks=(8,))    # 8 chunks, one file
    plan = arr.write_plan((slice(None),), v)
    assert plan.n_chunks == 8
    assert plan.write_ops() < plan.n_chunks
    assert plan.write_ops() == 1          # one data file -> one append
    locs = plan.execute()
    assert len(locs) == 8
    # locations are exact and adjacent: the read side coalesces them back
    # into one ranged read (write/read op symmetry)
    offs = [loc.offset for loc in locs]
    assert offs == sorted(offs)
    rplan = arr.read_plan((slice(None),))
    assert rplan.read_ops() == 1
    np.testing.assert_array_equal(rplan.execute(), v)
    fdb.close()


def test_object_store_writes_stay_object_granular(tmp_path, make_store):
    """No false write coalescing on object backends: one archive op per
    chunk stays in flight (the other side of the paper's trade-off)."""
    for backend in ("daos", "rados", "s3"):
        fdb, ts = make_store(backend, array=f"wog-{backend}")
        arr = ts.create((64,), np.float32, chunks=(8,))
        plan = arr.write_plan((slice(None),), np.zeros(64, np.float32))
        assert plan.write_ops() == plan.n_chunks == 8
        fdb.close()


def test_write_plan_read_plan_roundtrip(backend, tmp_path, make_store):
    """write_plan -> read_plan round-trips on every backend, including
    ragged edge chunks (batched encode falls back per shape group)."""
    fdb, ts = make_store(backend)
    x = np.random.default_rng(40).normal(size=(37, 53)).astype(np.float32)
    arr = ts.create(x.shape, x.dtype, chunks=(16, 16))
    plan = arr.write_plan((slice(None), slice(None)), x)
    assert plan.n_chunks == 12 and plan.rmw_chunks == 0
    plan.execute()
    np.testing.assert_array_equal(
        arr.read_plan((slice(None), slice(None)),
                      fill_missing=False).execute(), x)
    fdb.close()


def test_write_plan_partial_window_rmw_and_ops(tmp_path, make_store):
    """A window cutting through chunks: the plan reports its RMW split and
    still coalesces every re-archive into one posix write."""
    fdb, ts = make_store("posix")
    x = np.random.default_rng(41).normal(size=(64, 64)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    v = np.random.default_rng(42).normal(size=(30, 30)).astype(np.float32)
    plan = arr.write_plan((slice(10, 40), slice(10, 40)), v)
    assert plan.n_chunks == 9
    assert plan.rmw_chunks == 8           # only the (1,1) chunk is full
    assert plan.write_ops() == 1 < plan.n_chunks
    plan.execute()
    x[10:40, 10:40] = v
    np.testing.assert_array_equal(arr.read(), x)
    fdb.close()


def test_write_window_coalesces_store_writes(tmp_path):
    """The pipeline facade's write_window goes through the same coalesced
    plan: a multi-chunk assimilation window on posix lands as one batched
    store write (observed via the store's append offsets, not just the
    plan's claim)."""
    from repro.data import ChunkedFieldStore
    fs = ChunkedFieldStore("nwp-wco", FDBConfig(backend="posix",
                                                root=str(tmp_path / "fdb")),
                           chunks=(16, 16))
    field = np.zeros((64, 64), np.float32)
    fs.put_field("t2m", field)
    fs.commit()
    arr = fs.open_field("t2m")
    plan = arr.write_plan((slice(0, 32), slice(None)), np.ones((32, 64),
                                                               np.float32))
    assert plan.write_ops() == 1 and plan.n_chunks == 8
    fs.write_window("t2m", np.ones((32, 64), np.float32),
                    slice(0, 32), slice(None))
    fs.commit()
    field[0:32, :] = 1.0
    np.testing.assert_array_equal(fs.read_window("t2m"), field)
    fs.close()


def test_write_plan_flush_barrier_preserved(tmp_path, make_store):
    """FDB rule 3 under batching: a second client sees nothing until the
    writer flushes, then sees everything — and execute(flush=True) is that
    barrier."""
    root = str(tmp_path / "fdb")
    fdb, ts = make_store("posix")
    x = np.arange(64, dtype=np.float32)
    arr = ts.create(x.shape, x.dtype, chunks=(8,))
    arr.write_plan((slice(None),), x).execute(flush=False)
    reader = FDB(FDBConfig(backend="posix", schema="tensor", root=root))
    rts = TensorStore(reader, {"store": "s", "array": "a", "writer": "w0"})
    with pytest.raises(FileNotFoundError):
        rts.open()                        # not yet visible (rule 3)
    fdb.flush()
    reader.catalogue.refresh()
    np.testing.assert_array_equal(rts.open().read(), x)
    reader.close()
    fdb.close()


def test_archive_many_coalesces_on_posix(tmp_path, nwp_identifier):
    """archive_many groups items per destination data file: many fields of
    one (dataset, collocation) land as one batched append, and locations
    still resolve exactly."""
    fdb = FDB(FDBConfig(backend="posix", schema="nwp-posix",
                        root=str(tmp_path / "fdb")))
    items = [({**nwp_identifier, "step": str(i)}, bytes([i]) * 64)
             for i in range(10)]
    unit = fdb.archive_placement(items[0][0]).unit
    assert unit is not None
    assert all(fdb.archive_placement(i).unit == unit for i, _d in items)
    locs = fdb.archive_many(items)
    fdb.flush()
    assert len({loc.unit for loc in locs}) == 1       # one data file
    assert [loc.offset for loc in locs] == sorted(loc.offset for loc in locs)
    for i, (ident, data) in enumerate(items):
        assert fdb.retrieve(ident).read() == data
    fdb.close()


def test_archive_placement_object_backends_none(tmp_path, nwp_identifier):
    for backend in ("daos", "rados", "s3"):
        fdb = FDB(FDBConfig(backend=backend, schema="nwp-object",
                            root=str(tmp_path / "fdb")))
        p = fdb.archive_placement(nwp_identifier)
        assert p.unit is None and not p.mergeable_with(p)
        fdb.close()


def test_archive_batch_rejects_multi_value(nwp_identifier):
    fdb = FDB(FDBConfig(backend="daos"))
    with pytest.raises(ValueError, match="multi-value"):
        fdb.archive_batch([({**nwp_identifier, "step": [0, 6]}, b"x")])
    with pytest.raises(ValueError, match="multi-value"):
        fdb.archive_placement({**nwp_identifier, "step": "0/6"})
    fdb.close()


# ---------------------------------------------------------------------------
# batched codec paths (encode_batch / decode_batch)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec_name", ["raw", "field8", "field16"])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_codec_batch_byte_identical_to_loop(codec_name, dtype):
    """The single-launch batched encode must produce byte-identical
    containers to the per-chunk loop — equal-shape interior chunks, ragged
    tails, and ineligible (tiny) chunks alike — so the two paths
    interoperate on one array."""
    codec = get_codec(codec_name)
    rng = np.random.default_rng(50)
    arrs = [rng.normal(size=(16, 16)).astype(dtype) for _ in range(5)]
    arrs += [rng.normal(size=(5, 131)).astype(dtype)]   # ragged f32 tail
    arrs += [rng.normal(size=(3, 3)).astype(dtype)]     # ineligible -> raw
    batched = codec.encode_batch(arrs)
    looped = [codec.encode(a) for a in arrs]
    assert batched == looped
    shapes = [a.shape for a in arrs]
    dec_b = codec.decode_batch(batched, shapes, np.dtype(dtype))
    for got, data, shape in zip(dec_b, looped, shapes):
        np.testing.assert_array_equal(
            got, codec.decode(data, shape, np.dtype(dtype)))


@pytest.mark.parametrize("bits", [8, 16])
def test_codec_batch_roundtrip_bound(bits):
    codec = get_codec(f"field{bits}")
    rng = np.random.default_rng(51)
    arrs = [rng.normal(size=(32, 128)).astype(np.float32) for _ in range(4)]
    enc = codec.encode_batch(arrs)
    dec = codec.decode_batch(enc, [a.shape for a in arrs], np.float32)
    for a, d in zip(arrs, dec):
        bound = (a.max() - a.min()) / (2 ** bits - 1) * 0.51 + 1e-6
        assert np.abs(d - a).max() <= bound


def test_codec_batch_mixed_written_paths(tmp_path, make_store):
    """Chunks written per-chunk (old data) and batched (new data) decode
    together: the containers are identical, so a batched read of a
    mixed-provenance array just works."""
    fdb, ts = make_store("posix")
    x = np.random.default_rng(52).normal(size=(64, 64)).astype(np.float32)
    ts.save(x, chunks=(16, 16), codec="field16")      # batched write
    arr = ts.open()
    # overwrite two chunks through the per-chunk encode path
    codec = get_codec("field16")
    from repro.tensorstore import chunk_key
    for idx in ((0, 0), (1, 1)):
        tile = x[arr.grid.chunk_slices(idx)]
        fdb.archive(arr.store._ident(chunk_key(idx)), codec.encode(tile))
    fdb.flush()
    got = arr.read()
    bound = (x.max() - x.min()) / 65535 * 0.51 + 1e-6
    assert np.abs(got - x).max() <= bound
    fdb.close()


# ---------------------------------------------------------------------------
# per-FDB io executor (churn fix)
# ---------------------------------------------------------------------------

def test_fdb_io_executor_cached_and_rebuilt(tmp_path):
    fdb = FDB(FDBConfig(backend="daos", io_parallelism=4))
    ex = fdb.io_executor
    assert ex is fdb.io_executor                  # cached, not per-call
    assert ex.max_workers == 4
    fdb.config.io_parallelism = 2                 # config change -> rebuild
    ex2 = fdb.io_executor
    assert ex2 is not ex and ex2.max_workers == 2
    assert ex.is_shutdown                         # old one was shut down
    fdb.close()
    assert ex2.is_shutdown                        # close() shuts it down


def test_fdb_io_executor_not_shared_across_clients():
    a = FDB(FDBConfig(backend="daos"))
    b = FDB(FDBConfig(backend="daos"))
    assert a.io_executor is not b.io_executor
    a.close()
    assert not b.io_executor.is_shutdown          # b unaffected by a.close()
    b.close()


def test_tensorstore_uses_fdb_executor(tmp_path, make_store):
    fdb, ts = make_store("daos")
    assert ts.executor is fdb.io_executor
    fdb.close()


def test_tensorstore_survives_executor_rebuild(tmp_path, make_store):
    """A store must not cache the client's executor: after an
    io_parallelism change rebuilds it, the store's next I/O must ride the
    fresh pool, not a shut-down one."""
    fdb, ts = make_store("daos")
    x = np.arange(64, dtype=np.float32)
    arr = ts.create(x.shape, x.dtype, chunks=(8,))
    arr.write(x)
    fdb.config.io_parallelism = 2         # rebuilds on next access
    assert ts.executor.max_workers == 2
    arr.write(x * 2)                      # would raise on a dead pool
    np.testing.assert_array_equal(arr.read(), x * 2)
    fdb.close()


def test_fdb_io_executor_refuses_after_close():
    """A closed client must not silently mint a fresh pool nothing will
    ever shut down."""
    fdb = FDB(FDBConfig(backend="daos"))
    fdb.close()
    with pytest.raises(RuntimeError, match="closed"):
        fdb.io_executor


def test_posix_placement_is_side_effect_free(tmp_path, nwp_identifier):
    """Resolving a placement (planning a write) must not create files or
    charge the op meter — a plan that is never executed leaves no trace,
    and the data file only appears on the first real archive."""
    import os
    fdb = FDB(FDBConfig(backend="posix", schema="nwp-posix",
                        root=str(tmp_path / "fdb")))
    before = GLOBAL_METER.snapshot()
    p = fdb.archive_placement(nwp_identifier)
    assert p.unit is not None and not os.path.exists(p.unit)
    assert fdb.archive_placement(nwp_identifier).unit == p.unit   # stable
    assert GLOBAL_METER.snapshot()[len(before):] == []    # meter untouched
    fdb.flush()                           # reserved-only entries: no-op
    assert not os.path.exists(p.unit)
    loc = fdb.archive(nwp_identifier, b"x" * 32)
    assert loc.unit == p.unit             # archives land where planned
    fdb.flush()
    assert os.path.exists(p.unit)
    assert fdb.retrieve(nwp_identifier).read() == b"x" * 32
    fdb.close()


# ---------------------------------------------------------------------------
# chunk-grid edge cases
# ---------------------------------------------------------------------------

def test_grid_math_non_divisible():
    g = ChunkGrid((37, 53), (16, 16))
    assert g.n_chunks == (3, 4)
    assert g.chunk_shape((2, 3)) == (5, 5)          # clipped corner
    hits = list(g.intersecting((slice(30, 37), slice(48, 53))))
    assert {h[0] for h in hits} == {(1, 3), (2, 3)}


def test_grid_oversize_chunks_clip():
    g = ChunkGrid((10, 10), (64, 64))
    assert g.chunks == (10, 10) and g.n_chunks == (1, 1)


def test_grid_rejects_bad_args():
    with pytest.raises(ValueError):
        ChunkGrid((4, 4), (4,))
    with pytest.raises(ValueError):
        ChunkGrid((4,), (0,))


def test_grid_empty_selection_and_negative_indices():
    g = ChunkGrid((9, 7), (4, 3))
    sel, squeeze = g.normalize_key((slice(5, 5), slice(None)))
    assert g.selection_shape(sel) == (0, 7) and squeeze == ()
    assert list(g.intersecting(sel)) == []
    # negative integer indices resolve from the end and record squeezes
    sel, squeeze = g.normalize_key((-1, -7))
    assert sel == (slice(8, 9, 1), slice(0, 1, 1)) and squeeze == (0, 1)
    with pytest.raises(IndexError):
        g.normalize_key((-10, 0))
    # reversed slices clamp to empty rather than going negative
    sel, _ = g.normalize_key((slice(6, 2), slice(None)))
    assert g.selection_shape(sel) == (0, 7)


def test_grid_zero_length_dims():
    g = ChunkGrid((0, 4), (2, 2))
    assert g.n_chunks == (0, 2) and g.chunk_count == 0
    assert list(g.all_indices()) == []
    sel, _ = g.normalize_key((slice(None), slice(None)))
    assert g.selection_shape(sel) == (0, 4)
    assert list(g.intersecting(sel)) == []


def test_grid_write_plan_full_vs_partial():
    g = ChunkGrid((37, 53), (16, 16))
    # full-array selection covers every chunk, clipped edge chunks included
    sel, _ = g.normalize_key((slice(None), slice(None)))
    plan = list(g.write_plan(sel))
    assert len(plan) == 12 and all(full for *_x, full in plan)
    # a window ending mid-chunk: aligned chunks are full, the last partial
    sel, _ = g.normalize_key((slice(16, 32), slice(16, 50)))
    by_idx = {idx: full for idx, _c, _v, full in g.write_plan(sel)}
    assert by_idx == {(1, 1): True, (1, 2): True, (1, 3): False}
    # a clipped edge chunk covered to the array boundary counts as full
    sel, _ = g.normalize_key((slice(32, 37), slice(48, 53)))
    assert list(g.write_plan(sel)) == [
        ((2, 3), (slice(0, 5, 1), slice(0, 5, 1)),
         (slice(0, 5, 1), slice(0, 5, 1)), True)]


def test_store_zero_length_dim_roundtrip(tmp_path, make_store):
    fdb, ts = make_store("daos", array="empty")
    x = np.zeros((0, 4), np.float32)
    ts.save(x, chunks=(2, 2))
    arr = ts.open()
    assert arr.read().shape == (0, 4)
    assert arr.write_at((slice(None), slice(None)), x) == []
    fdb.close()


def test_indexing_edge_cases(tmp_path, make_store):
    fdb, ts = make_store("daos")
    x = np.random.default_rng(4).normal(size=(9, 7, 5)).astype(np.float32)
    ts.save(x, chunks=(4, 3, 2))
    arr = ts.open()
    np.testing.assert_array_equal(arr[3], x[3])              # int → squeeze
    np.testing.assert_array_equal(arr[-2, 1:], x[-2, 1:])    # negative index
    np.testing.assert_array_equal(arr[:, -3:, 4], x[:, -3:, 4])
    assert arr[2:2].size == 0                                # empty selection
    np.testing.assert_array_equal(arr[::2], x[::2])          # strided reads
    np.testing.assert_array_equal(arr[1::3, :, 4], x[1::3, :, 4])
    np.testing.assert_array_equal(arr[::-1], x[::-1])        # reversed reads
    np.testing.assert_array_equal(arr[8:2:-2, ::-1], x[8:2:-2, ::-1])
    arr[::-1] = x[::-1]                     # reversed writes: roundtrip
    np.testing.assert_array_equal(arr[:, :, :], x)
    with pytest.raises(IndexError):
        arr[0, 0, 0, 0]
    fdb.close()


def test_scalar_and_1d_arrays(tmp_path, make_store):
    fdb, ts = make_store("rados", array="scalar")
    ts.save(np.float32(3.25))
    assert ts.open().read() == np.float32(3.25)
    ts2 = TensorStore(fdb, {"store": "s", "array": "vec", "writer": "w0"})
    v = np.arange(1000, dtype=np.int64)
    ts2.save(v, chunks=(64,))
    np.testing.assert_array_equal(ts2.open()[128:700], v[128:700])
    fdb.close()


def test_auto_chunks_targets_size():
    chunks = auto_chunks((4096, 4096), np.float32, target_bytes=1 << 20)
    nbytes = chunks[0] * chunks[1] * 4
    assert nbytes <= 1 << 20
    assert auto_chunks((), np.float32) == ()
    assert auto_chunks((3,), np.float32) == (3,)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["daos", "posix"])
def test_codec_parity_on_off(backend, tmp_path):
    """field8/field16 vs raw: lossy within the block-quantisation bound,
    identical shape/dtype, raw stays exact."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(300, 200)).astype(np.float32)
    fdb = FDB(FDBConfig(backend=backend, schema="tensor",
                        root=str(tmp_path / "fdb")))
    got = {}
    for codec in ("raw", "field8", "field16"):
        ts = TensorStore(fdb, {"store": "s", "array": f"a-{codec}",
                               "writer": "w0"})
        ts.save(x, chunks=(128, 128), codec=codec)
        got[codec] = ts.open().read()
        assert got[codec].shape == x.shape and got[codec].dtype == x.dtype
    np.testing.assert_array_equal(got["raw"], x)
    rng_x = x.max() - x.min()
    assert np.abs(got["field8"] - x).max() <= rng_x / 255 * 0.51 + 1e-6
    assert np.abs(got["field16"] - x).max() <= rng_x / 65535 * 0.51 + 1e-6
    assert np.abs(got["field16"] - x).max() < np.abs(got["field8"] - x).max()
    fdb.close()


def test_quant_codec_falls_back_to_raw_for_ints_and_tiny_chunks(tmp_path, make_store):
    fdb, ts = make_store("daos", array="ints")
    ints = np.arange(600, dtype=np.int32).reshape(30, 20)
    ts.save(ints, chunks=(16, 16), codec="field8")   # ineligible → raw marker
    np.testing.assert_array_equal(ts.open().read(), ints)
    fdb.close()


def test_codec_container_roundtrip_odd_tail():
    """Sizes that are not multiples of 128 carry an exact float tail."""
    codec = get_codec("field8")
    x = np.random.default_rng(6).normal(size=(5, 131)).astype(np.float32)
    y = codec.decode(codec.encode(x), x.shape, x.dtype)
    assert y.shape == x.shape
    # head quantised, tail exact
    tail = x.reshape(-1)[(x.size // 128) * 128:]
    np.testing.assert_array_equal(y.reshape(-1)[(x.size // 128) * 128:], tail)


def test_unknown_codec_rejected(tmp_path, make_store):
    fdb, ts = make_store("daos")
    with pytest.raises(ValueError):
        ts.create((4, 4), np.float32, codec="zstd")
    fdb.close()


# ---------------------------------------------------------------------------
# executor + archive_many
# ---------------------------------------------------------------------------

def test_executor_bounded_in_flight():
    ex = ChunkExecutor(max_workers=4, max_in_flight=2)

    def task(i):
        time.sleep(0.01)
        return i * i

    results = ex.map_ordered(task, range(12))
    assert results == [i * i for i in range(12)]
    assert ex.peak_in_flight <= 2
    ex.shutdown()


def test_executor_propagates_errors_in_order():
    ex = ChunkExecutor(max_workers=2)

    def task(i):
        if i == 3:
            raise RuntimeError("chunk 3 failed")
        return i

    with pytest.raises(RuntimeError, match="chunk 3"):
        ex.map_ordered(task, range(6))
    ex.shutdown()


def test_executor_propagates_client_context():
    from repro.core import client_context
    from repro.core.engine.meter import current_client
    ex = ChunkExecutor(max_workers=2)
    with client_context("proc7@node3"):
        seen = ex.map_ordered(lambda _i: current_client(), range(4))
    assert seen == ["proc7@node3"] * 4
    ex.shutdown()


def test_archive_many_returns_locations(backend, tmp_path, nwp_identifier):
    schema = "nwp-posix" if backend == "posix" else "nwp-object"
    fdb = FDB(FDBConfig(backend=backend, schema=schema,
                        root=str(tmp_path / "fdb")))
    items = [({**nwp_identifier, "step": str(i)}, bytes([i]) * 256)
             for i in range(12)]
    locs = fdb.archive_many(items)
    fdb.flush()
    assert len(locs) == 12
    assert all(isinstance(loc, FieldLocation) for loc in locs)
    # locations come back in input order and resolve to the right payloads
    for i, loc in enumerate(locs):
        assert fdb.store.retrieve(loc).read() == bytes([i]) * 256
    for i in range(12):
        assert fdb.retrieve({**nwp_identifier, "step": str(i)}).read() \
            == bytes([i]) * 256
    fdb.close()


@pytest.mark.parametrize("persistence", ["immediate", "on_flush"])
def test_parallel_archive_rados_span_mode_consistent(tmp_path, persistence,
                                                     nwp_identifier):
    """Span mode appends into shared objects: under parallel archive the
    physical append order must match the reserved offsets, or locations
    would point at other items' bytes."""
    fdb = FDB(FDBConfig(backend="rados", schema="nwp-object",
                        rados_object_mode="span",
                        rados_persistence=persistence,
                        rados_max_object_size=4096))
    items = [({**nwp_identifier, "step": str(i)},
              bytes([i % 251]) * (100 + (i % 7) * 13))
             for i in range(200)]
    locs = fdb.archive_many(items, parallelism=16)
    fdb.flush()
    for (ident, data), loc in zip(items, locs):
        assert fdb.retrieve(ident).read() == data, ident
        assert fdb.store.retrieve(loc).read() == data
    fdb.close()


def test_archive_many_serial_path_equivalent(tmp_path, nwp_identifier):
    fdb = FDB(FDBConfig(backend="daos", io_parallelism=0))
    items = [({**nwp_identifier, "step": str(i)}, b"z" * 64) for i in range(3)]
    locs = fdb.archive_many(items)
    assert len(locs) == 3
    fdb.close()


# ---------------------------------------------------------------------------
# integrations: checkpoint + data pipeline
# ---------------------------------------------------------------------------

def test_checkpoint_partial_tensor_read():
    from repro.train.checkpoint import FDBCheckpointer
    ck = FDBCheckpointer("ts-part", FDBConfig(backend="daos"), n_shards=4)
    w = np.random.default_rng(7).normal(size=(256, 64)).astype(np.float32)
    ck.save(3, {"w": w})
    arr = ck.open_tensor(3, "w")
    assert arr.n_chunks[0] == 4                   # n_shards → axis-0 bands
    np.testing.assert_array_equal(arr[100:200], w[100:200])
    ck.close()


def test_chunked_field_store_window_read(tmp_path):
    from repro.data import ChunkedFieldStore
    fs = ChunkedFieldStore("nwp", FDBConfig(backend="rados"),
                           chunks=(32, 32))
    field = np.random.default_rng(8).normal(size=(100, 90)).astype(np.float32)
    fs.put_field("t2m", field)
    fs.commit()
    np.testing.assert_array_equal(
        fs.read_window("t2m", slice(10, 60), slice(40, 80)),
        field[10:60, 40:80])
    np.testing.assert_array_equal(fs.read_window("t2m"), field)
    fs.wipe_field("t2m")
    with pytest.raises(FileNotFoundError):
        fs.open_field("t2m")
    fs.close()


def test_chunked_field_store_window_write(tmp_path):
    """The assimilation pattern: patch a window of an archived field, commit
    once, and consumers see the increment."""
    from repro.data import ChunkedFieldStore
    fs = ChunkedFieldStore("nwp-asml", FDBConfig(backend="posix",
                                                 root=str(tmp_path / "fdb")),
                           chunks=(32, 32))
    field = np.random.default_rng(30).normal(size=(100, 90)
                                             ).astype(np.float32)
    fs.put_field("t2m", field)
    fs.commit()
    inc = np.random.default_rng(31).normal(size=(50, 40)).astype(np.float32)
    fs.write_window("t2m", field[10:60, 40:80] + inc,
                    slice(10, 60), slice(40, 80))
    fs.commit()
    field[10:60, 40:80] += inc
    np.testing.assert_array_equal(fs.read_window("t2m"), field)
    fs.close()


def test_checkpoint_update_tensor_in_place():
    """Optimizer-state touch-up: patch rows of a saved tensor; only the
    intersecting chunks are re-archived and restore sees the update."""
    from repro.train.checkpoint import FDBCheckpointer
    ck = FDBCheckpointer("ts-upd", FDBConfig(backend="daos"), n_shards=4)
    mu = np.random.default_rng(32).normal(size=(256, 64)).astype(np.float32)
    ck.save(7, {"w": np.zeros((8, 8), np.float32)}, opt_state={"mu": mu})
    new_rows = np.random.default_rng(33).normal(size=(50, 64)
                                                ).astype(np.float32)
    ck.update_tensor(7, "mu", slice(100, 150), new_rows, kind="opt")
    mu[100:150] = new_rows
    got = ck.restore(7, {"mu": mu}, kind="opt")
    np.testing.assert_array_equal(np.asarray(got["mu"]), mu)
    ck.close()


def test_checkpoint_restore_refuses_partial_chunked_tensor():
    """Restore reads strictly: a chunked checkpoint tensor with a missing
    chunk (lost data) raises instead of silently zero-filling."""
    from repro.train.checkpoint import FDBCheckpointer
    ck = FDBCheckpointer("ts-strict", FDBConfig(backend="daos"))
    w = np.ones((64, 32), np.float32)
    ck.save(1, {"w": w})
    # simulate lost chunks: wipe the step, then re-create metadata only
    ck.fdb.wipe({"run": "ts-strict", "kind": "params", "step": "1"})
    ck._tensor_store("params", 1, "w").create(w.shape, w.dtype,
                                              chunks=(16, 32))
    ck.fdb.flush()
    with pytest.raises(KeyError, match="missing chunk"):
        ck.restore(1, {"w": w})
    ck.close()


# ---------------------------------------------------------------------------
# FDB facade regressions (bugfix sweep)
# ---------------------------------------------------------------------------

def test_fdb_non_string_identifier_values(nwp_identifier):
    """Identifier values may be ints/floats everywhere, and sequence values
    are multi-value request expressions — normalised in one shared place."""
    fdb = FDB(FDBConfig(backend="daos"))
    base = {**nwp_identifier}
    del base["step"]
    for step in (0, 6, 12):
        fdb.archive({**base, "step": step}, bytes([step]) * 16)
    fdb.flush()
    assert fdb.retrieve({**base, "step": 0}).read() == bytes(16)
    # a sequence value expands like the "0/12" request expression
    assert fdb.retrieve({**base, "step": [0, 12]}).length() == 32
    assert fdb.retrieve({**base, "step": "0/12"}).length() == 32
    # unordered sets sort, so the concatenated payload order is stable
    assert fdb.retrieve({**base, "step": {12, 0}}).read() \
        == bytes(16) + bytes([12]) * 16
    assert fdb.axes({**base, "step": 0}, "step") == {"0", "6", "12"}
    # archive must be fully specified: an expression value would catalogue
    # the object under a key no retrieve can expand back to
    with pytest.raises(ValueError, match="multi-value"):
        fdb.archive({**base, "step": [0, 6]}, b"x")
    with pytest.raises(ValueError, match="multi-value"):
        fdb.archive({**base, "step": "0/6"}, b"x")
    fdb.close()


def test_lustre_sim_keyed_on_stripe_geometry(tmp_path):
    """Two FDBs sharing a root but differing in OST/stripe geometry must not
    share a LustreSim, or geometry sweeps measure the first config forever."""
    root = str(tmp_path / "fdb")
    a = FDB(FDBConfig(backend="posix", schema="tensor", root=root,
                      lustre_stripe_count=1))
    b = FDB(FDBConfig(backend="posix", schema="tensor", root=root,
                      lustre_stripe_count=8))
    c = FDB(FDBConfig(backend="posix", schema="tensor", root=root,
                      lustre_stripe_count=1))
    assert a.store.sim is not b.store.sim
    assert a.store.sim is c.store.sim     # same geometry still shares
    assert a.store.sim.stripe_count == 1 and b.store.sim.stripe_count == 8
    a.close(), b.close(), c.close()


# ---------------------------------------------------------------------------
# strided selections (read + write paths)
# ---------------------------------------------------------------------------

def test_strided_read_roundtrip(backend, tmp_path, make_store):
    """Positive-step selections match numpy on every backend, including
    steps larger than the chunk and offset starts."""
    fdb, ts = make_store(backend)
    x = np.random.default_rng(60).normal(size=(37, 53)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    for sel in [(slice(None, None, 2),),
                (slice(1, 30, 3), slice(0, None, 4)),
                (slice(None, None, 17), slice(5, None, 23)),
                (slice(0, 37, 16), slice(52, 53, 7)),
                (2, slice(1, None, 5))]:
        np.testing.assert_array_equal(arr[sel], x[sel], err_msg=str(sel))
    fdb.close()


def test_strided_write_roundtrip(backend, tmp_path, make_store):
    """Strided assignment preserves the stride gaps (RMW) on every
    backend."""
    fdb, ts = make_store(backend)
    x = np.random.default_rng(61).normal(size=(37, 53)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    v = np.random.default_rng(62).normal(
        size=x[2::5, 1::7].shape).astype(np.float32)
    arr[2::5, 1::7] = v
    x[2::5, 1::7] = v
    np.testing.assert_array_equal(arr.read(), x)
    arr[::2] = 0.0                       # broadcast over a strided selection
    x[::2] = 0.0
    np.testing.assert_array_equal(arr.read(), x)
    fdb.close()


def test_strided_read_skips_strided_over_chunks(tmp_path, make_store):
    """A step larger than the chunk touches only the chunks holding a
    selected point — observed via planned chunk count AND the meter."""
    fdb, ts = make_store("daos")
    x = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    ts.save(x, chunks=(16, 16))          # 4 x 4 chunk grid
    arr = ts.open()
    plan = arr.read_plan((slice(None, None, 32), slice(None, None, 32)))
    assert plan.n_chunks == 4            # rows 0/32 x cols 0/32 -> 4 chunks
    before = GLOBAL_METER.snapshot()
    np.testing.assert_array_equal(plan.execute(), x[::32, ::32])
    reads = _data_reads(GLOBAL_METER.snapshot()[len(before):])
    assert len(reads) == 4
    # strided writes classify as RMW (stride gaps must be preserved)
    wplan = arr.write_plan((slice(None, None, 2), slice(None)),
                           np.zeros((32, 64), np.float32))
    assert wplan.n_chunks == 16 and wplan.rmw_chunks == 16
    fdb.close()


def test_negative_step_read_roundtrip(backend, tmp_path, make_store):
    """Reversed reads on every backend: normalised to a positive-step plan
    plus one client-side flip, so results match numpy exactly."""
    fdb, ts = make_store(backend)
    x = np.random.default_rng(11).normal(size=(37, 53)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    for sel in [
        (slice(None, None, -1), slice(None)),
        (slice(None, None, -1), slice(None, None, -1)),
        (slice(30, 4, -3), slice(50, None, -7)),
        (slice(None, None, -16),),           # step larger than the chunk
        (5, slice(None, None, -2)),          # int squeeze + reversed
        (slice(2, 2, -1), slice(None)),      # empty reversed slice
    ]:
        np.testing.assert_array_equal(arr[sel], x[sel], err_msg=str(sel))
    # the plan only touches chunks holding selected points, same as the
    # forward equivalent
    plan = arr.read_plan((slice(None, None, -16), slice(None, None, -16)))
    fwd = arr.read_plan((slice(36, None, -16), slice(52, None, -16)))
    assert plan.n_chunks == fwd.n_chunks
    # only reshards keep rejecting reversed selections (a re-layout has no
    # meaning for a descending source order)
    with pytest.raises(NotImplementedError, match="positive step"):
        arr.reshard_plan((8, 53), sel=(slice(None, None, -1), slice(None)))
    fdb.close()


def test_negative_step_write_roundtrip(backend, tmp_path, make_store):
    """Reversed assignment on every backend: the values flip client-side
    against the positive-step mirror plan, so results match numpy's
    reversed-assignment semantics exactly."""
    fdb, ts = make_store(backend)
    rng = np.random.default_rng(63)
    x = rng.normal(size=(37, 53)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    for sel in [
        (slice(None, None, -1), slice(None)),        # full reverse
        (slice(30, 4, -3), slice(None)),             # strided reverse
        (slice(None, None, -2), slice(50, 3, -7)),   # both axes reversed
        (slice(None, None, -16),),                   # step > chunk
        (5, slice(None, None, -2)),                  # int squeeze + reverse
    ]:
        v = rng.normal(size=x[sel].shape).astype(np.float32)
        arr[sel] = v
        x[sel] = v
        np.testing.assert_array_equal(arr.read(), x, err_msg=str(sel))
    # broadcast onto a reversed selection (scalar and row)
    arr[::-1, ::2] = 3.5
    x[::-1, ::2] = 3.5
    np.testing.assert_array_equal(arr.read(), x)
    row = rng.normal(size=(53,)).astype(np.float32)
    arr[10:2:-4] = row
    x[10:2:-4] = row
    np.testing.assert_array_equal(arr.read(), x)
    # empty reversed selection: clean no-op
    arr[2:2:-1] = 99.0
    np.testing.assert_array_equal(arr.read(), x)
    fdb.close()


@pytest.mark.parametrize("backend", ["daos", "posix"])
def test_zero_length_selections(backend, tmp_path, make_store):
    """Empty selections are clean no-ops on read, write and reshard:
    empty arrays out, empty values in, zero planned I/O ops."""
    fdb, ts = make_store(backend)
    x = np.arange(36, dtype=np.float32).reshape(6, 6)
    ts.save(x, chunks=(2, 2))
    arr = ts.open()
    # reads
    assert arr[3:3].shape == (0, 6)
    assert arr[5:5:3, 1:4].shape == (0, 3)      # empty strided window
    assert arr[2:2, 4:4].size == 0
    rp = arr.read_plan((slice(3, 3), slice(None)))
    assert rp.n_chunks == 0 and rp.read_ops() == 0
    # writes: empty value arrays are accepted, nothing is archived
    wp = arr.write_plan((slice(3, 3), slice(None)),
                        np.zeros((0, 6), np.float32))
    assert wp.n_chunks == 0 and wp.write_ops() == 0 and wp.leases == []
    assert wp.execute() == []
    arr[4:4] = 7.0                               # broadcast onto empty: noop
    arr[0:0, 0:0] = np.zeros((0, 0), np.float32)
    np.testing.assert_array_equal(arr.read(), x)
    # reshard of an empty sub-selection: a valid empty array, no data I/O
    arr.reshard((2, 2), sel=(slice(3, 3), slice(None)))
    assert arr.shape == (0, 6)
    assert arr.read().shape == (0, 6)
    fdb.close()


@pytest.mark.parametrize("backend", ["daos", "posix"])
def test_garbage_report_after_reshard_and_recreate(backend, tmp_path, make_store):
    """garbage_report counts retained old-generation chunk bytes — the
    versioned-retain cost of reshards and on_mismatch='retain' re-creates
    (and only that: a fresh array reports zero garbage)."""
    from repro.tensorstore import GarbageReport
    fdb, ts = make_store(backend)
    x = np.random.default_rng(5).normal(size=(32, 32)).astype(np.float32)
    arr = ts.save(x, chunks=(8, 8))              # 16 chunks x 256 B
    rep = ts.garbage_report()
    assert isinstance(rep, GarbageReport)
    assert rep.live_generation == 0 and rep.live_chunks == 16
    assert rep.live_bytes == x.nbytes and rep.garbage_bytes == 0
    arr.reshard((16, 32))                        # gen 0 -> versioned garbage
    rep = ts.garbage_report()
    assert rep.live_generation == 1 and rep.live_chunks == 2
    assert rep.garbage_chunks == 16 and rep.garbage_bytes == x.nbytes
    assert rep.garbage_generations == (0,)
    # a retain re-create strands generation 1's chunks as well
    ts.create((32, 32), np.float32, chunks=(4, 4), on_mismatch="retain")
    fdb.flush()
    rep = ts.garbage_report()
    assert rep.live_generation == 2 and rep.live_chunks == 0
    assert rep.garbage_chunks == 18 and rep.garbage_generations == (0, 1)
    assert rep.garbage_bytes == 2 * x.nbytes
    fdb.close()


def test_grid_linear_id_and_merge_ranges():
    from repro.tensorstore import merge_id_ranges
    g = ChunkGrid((37, 53), (16, 16))            # (3, 4) chunk grid
    ids = [g.linear_id(idx) for idx in g.all_indices()]
    assert ids == list(range(12))                # row-major, dense
    assert g.linear_id((2, 3)) == 11
    with pytest.raises(IndexError):
        g.linear_id((3, 0))
    assert merge_id_ranges([0, 1, 2, 7, 8]) == [(0, 3), (7, 9)]
    assert merge_id_ranges([3, 1, 1, 2]) == [(1, 4)]     # dups + unsorted
    assert merge_id_ranges([]) == []
    # a row band of chunks leases as ONE contiguous range; a column band
    # fragments into one range per chunk row
    row_band = [g.linear_id(idx) for idx, _c, _o in g.intersecting(
        g.normalize_key((slice(0, 16), slice(None)))[0])]
    assert merge_id_ranges(row_band) == [(0, 4)]
    col_band = [g.linear_id(idx) for idx, _c, _o in g.intersecting(
        g.normalize_key((slice(None), slice(0, 16)))[0])]
    assert merge_id_ranges(col_band) == [(0, 1), (4, 5), (8, 9)]


def test_grid_strided_math():
    g = ChunkGrid((37, 53), (16, 16))
    sel, squeeze = g.normalize_key((slice(None, None, 5), slice(1, 50, 9)))
    assert squeeze == ()
    assert sel[0] == slice(0, 36, 5)     # stop normalised to last + 1
    assert sel[1] == slice(1, 47, 9)
    assert g.selection_shape(sel) == (8, 6)
    hits = list(g.intersecting(sel))
    # every selected point lands in exactly one (chunk, out) slot
    seen = np.zeros((8, 6), bool)
    for idx, chunk_sel, out_sel in hits:
        block = np.zeros(g.chunk_shape(idx), bool)
        block[chunk_sel] = True
        assert block.sum() == (out_sel[0].stop - out_sel[0].start) * \
            (out_sel[1].stop - out_sel[1].start)
        assert not seen[out_sel].any()
        seen[out_sel] = True
    assert seen.all()
    # a chunk the stride steps over entirely is not visited
    g2 = ChunkGrid((64,), (8,))
    idxs = [idx for idx, _c, _o in g2.intersecting(
        g2.normalize_key((slice(0, None, 24),))[0])]
    assert idxs == [(0,), (3,), (6,)]    # points 0, 24, 48
    # full coverage requires step 1 unless the chunk dim is size 1
    sel, _ = g2.normalize_key((slice(None, None, 2),))
    assert all(not full for *_x, full in g2.write_plan(sel))
    g3 = ChunkGrid((4, 1), (2, 1))
    sel, _ = g3.normalize_key((slice(None), slice(None, None, 3)))
    assert all(full for *_x, full in g3.write_plan(sel))
    # write/reshard normalisation still rejects negative steps; the read
    # path serves them via normalize_read_key (positive plan + flip)
    with pytest.raises(NotImplementedError, match="positive step"):
        g.normalize_key((slice(None, None, -1),))
    sel, squeeze, flips = g.normalize_read_key(
        (slice(None, None, -5), slice(49, None, -9)))
    assert squeeze == () and flips == (0, 1)
    assert sel[0] == slice(1, 37, 5)     # 36, 31, ... 1 ascending
    assert sel[1] == slice(4, 50, 9)     # 49, 40, ... 4 ascending
    sel, _sq, flips = g.normalize_read_key((slice(2, 2, -1), slice(None)))
    assert g.selection_shape(sel) == (0, 53)    # empty reversed slice
    assert flips == ()


# ---------------------------------------------------------------------------
# RMW fetch coalescing + window-bounded write staging
# ---------------------------------------------------------------------------

def test_rmw_fetches_coalesce_on_posix(tmp_path, make_store):
    """Partial-write RMW fetches route through a whole-chunk ReadPlan:
    adjacent posix chunks fetch as ONE ranged read, not one per chunk."""
    from repro.tensorstore import ReadPlan
    fdb, ts = make_store("posix")
    v = np.arange(64, dtype=np.float32)
    ts.save(v, chunks=(8,))              # 8 adjacent chunks, one file
    arr = ts.open()
    fetch = ReadPlan.for_chunks(arr, [(i,) for i in range(8)])
    assert fetch.read_ops() == 1         # all eight coalesce
    chunks = fetch.read_chunks()
    np.testing.assert_array_equal(np.concatenate(chunks), v)
    assert all(c.flags.writeable for c in chunks)
    # end to end: a strided write (all chunks partial) moves the fetch
    # bytes through the meter as coalesced reads
    before = GLOBAL_METER.snapshot()
    arr[::2] = -1.0
    reads = _data_reads(GLOBAL_METER.snapshot()[len(before):])
    assert sum(op.nbytes for op in reads) == v.nbytes   # fetched once
    v[::2] = -1.0
    np.testing.assert_array_equal(arr.read(), v)
    fdb.close()


def test_read_plan_for_chunks_missing_fill(tmp_path, make_store):
    from repro.tensorstore import ReadPlan
    fdb, ts = make_store("daos")
    arr = ts.create((16,), np.float32, chunks=(4,))
    arr[0:4] = 7.0                       # only chunk 0 exists
    chunks = ReadPlan.for_chunks(arr, [(0,), (2,)]).read_chunks()
    np.testing.assert_array_equal(chunks[0], np.full(4, 7.0, np.float32))
    np.testing.assert_array_equal(chunks[1], np.zeros(4, np.float32))
    with pytest.raises(KeyError, match="missing chunk"):
        ReadPlan.for_chunks(arr, [(2,)], fill_missing=False)
    with pytest.raises(TypeError, match="read_chunks"):
        ReadPlan.for_chunks(arr, [(0,)]).execute()
    fdb.close()


def test_write_plan_staged_by_executor_window(tmp_path):
    """A plan larger than the executor window stages its encodes: one
    batched posix write per stage (write_ops = ceil(chunks/window)), never
    the whole plan's tiles at once."""
    from repro.tensorstore import ChunkExecutor
    fdb = FDB(FDBConfig(backend="posix", schema="tensor",
                        root=str(tmp_path / "fdb")))
    ex = ChunkExecutor(max_workers=2, max_in_flight=2)
    ts = TensorStore(fdb, {"store": "s", "array": "a", "writer": "w0"},
                     executor=ex)
    v = np.arange(64, dtype=np.float32)
    arr = ts.create(v.shape, v.dtype, chunks=(8,))    # 8 chunks, window 2
    plan = arr.write_plan((slice(None),), v)
    assert plan.window == 2
    assert [len(s) for s in plan.stages] == [2, 2, 2, 2]
    assert plan.write_ops() == 4 < plan.n_chunks
    locs = plan.execute()
    offs = [loc.offset for loc in locs]
    assert offs == sorted(offs)          # stages append in plan order
    np.testing.assert_array_equal(arr.read(), v)
    assert arr.read_plan((slice(None),)).read_ops() == 1
    ex.shutdown()
    fdb.close()


# ---------------------------------------------------------------------------
# resharding (ReshardPlan: plan-composed re-layout)
# ---------------------------------------------------------------------------

def test_reshard_byte_equality_roundtrip(backend, tmp_path, make_store):
    """Reshard must produce byte-identical data on the new grid vs a
    client-side reference rewrite — per chunk object, not just per read."""
    from repro.tensorstore import chunk_key, get_codec
    fdb, ts = make_store(backend)
    x = np.random.default_rng(70).normal(size=(37, 53)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    arr.reshard((8, 32))
    assert arr.chunks == (8, 32) and arr.meta.generation == 1
    np.testing.assert_array_equal(arr.read(fill_missing=False), x)
    # a fresh open sees the new layout and identical data
    arr2 = ts.open()
    assert arr2.chunks == (8, 32) and arr2.meta.generation == 1
    np.testing.assert_array_equal(arr2.read(), x)
    # chunk-object bytes == the reference client-side rewrite's encodes
    codec = get_codec("raw")
    for idx in arr2.grid.all_indices():
        got = fdb.retrieve(arr2.chunk_ident(idx)).read()
        assert got == codec.encode(x[arr2.grid.chunk_slices(idx)]), idx
    fdb.close()


def test_reshard_posix_ops_below_naive(tmp_path, make_store):
    """Acceptance: reshard read/write op counts on posix stay strictly
    below the naive one-op-per-chunk rewrite, on the plan AND the meter."""
    fdb, ts = make_store("posix")
    x = np.random.default_rng(71).normal(size=(64, 64)).astype(np.float32)
    ts.save(x, chunks=(16, 16))          # 16 source chunks
    arr = ts.open()
    plan = arr.reshard_plan((8, 64))     # 8 dest chunks
    assert plan.read_ops() < plan.src_chunk_fetches()
    assert plan.write_ops() < plan.n_dest_chunks
    plan.execute()
    assert plan.read_ops_executed == plan.read_ops()
    assert plan.write_ops_executed == plan.write_ops()
    np.testing.assert_array_equal(arr.read(), x)
    fdb.close()


def test_reshard_object_backends_stay_object_granular(tmp_path, make_store):
    fdb, ts = make_store("daos")
    x = np.zeros((64,), np.float32)
    ts.save(x, chunks=(8,))
    plan = ts.open().reshard_plan((16,))
    assert plan.write_ops() == plan.n_dest_chunks == 4
    assert plan.read_ops() == plan.src_chunk_fetches() == 8
    fdb.close()


@pytest.mark.parametrize("backend", ["posix", "rados"])
def test_reshard_strided_subsample(backend, tmp_path, make_store):
    """sel= reshards a strided sub-selection — the consumer-subsampled-grid
    pattern: shape becomes the selection's shape."""
    fdb, ts = make_store(backend)
    x = np.random.default_rng(72).normal(size=(40, 60)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    arr.reshard((10, 10), sel=(slice(0, None, 2), slice(1, None, 3)))
    ref = x[::2, 1::3]
    assert arr.shape == ref.shape
    np.testing.assert_array_equal(arr.read(fill_missing=False), ref)
    np.testing.assert_array_equal(ts.open().read(), ref)
    with pytest.raises(ValueError, match="slices"):
        arr.reshard_plan((5, 5), sel=(0, slice(None)))
    fdb.close()


def test_reshard_bounded_staging(tmp_path, make_store):
    """The streaming property: a small window splits the reshard into many
    batches and peak staged bytes stay within one window of dest chunks."""
    from repro.tensorstore import chunk_rectangles
    fdb, ts = make_store("posix")
    x = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    ts.save(x, chunks=(8, 8))
    arr = ts.open()
    plan = arr.reshard_plan((16, 16), window=2)
    assert plan.n_batches == 8           # 16 dest chunks / window 2
    plan.execute()
    assert plan.peak_staged_bytes <= 2 * 16 * 16 * 4
    np.testing.assert_array_equal(arr.read(), x)
    # rectangle splitting covers every chunk exactly once
    rects = list(chunk_rectangles((3, 4, 5), 7))
    cover = np.zeros((3, 4, 5), int)
    for rect in rects:
        assert np.prod([hi - lo for lo, hi in rect]) <= 7
        cover[tuple(slice(lo, hi) for lo, hi in rect)] += 1
    assert (cover == 1).all()
    assert list(chunk_rectangles((), 4)) == [()]
    fdb.close()


def test_reshard_flush_barrier_and_crash_safety(tmp_path, make_store):
    """Rule 3 through composition: a second client sees the OLD layout
    until the resharding writer flushes — a reshard interrupted before its
    commit barrier leaves the old layout fully intact."""
    root = str(tmp_path / "fdb")
    fdb, ts = make_store("posix")
    x = np.arange(64, dtype=np.float32)
    ts.save(x, chunks=(8,))
    arr = ts.open()
    arr.reshard((16,), flush=False)      # archived, not yet committed
    reader = FDB(FDBConfig(backend="posix", schema="tensor", root=root))
    rts = TensorStore(reader, {"store": "s", "array": "a", "writer": "w0"})
    reader.catalogue.refresh()
    old = rts.open()
    assert old.chunks == (8,) and old.meta.generation == 0
    np.testing.assert_array_equal(old.read(), x)
    fdb.flush()                          # the commit barrier
    reader.catalogue.refresh()
    new = rts.open()
    assert new.chunks == (16,) and new.meta.generation == 1
    np.testing.assert_array_equal(new.read(), x)
    reader.close()
    fdb.close()


def test_reshard_noop_and_codec_change(tmp_path, make_store):
    fdb, ts = make_store("daos")
    x = np.random.default_rng(73).normal(size=(256, 128)).astype(np.float32)
    ts.save(x, chunks=(128, 128))
    arr = ts.open()
    plan = arr.reshard_plan((128, 128))  # identical layout: nothing to move
    assert plan.noop and plan.n_batches == 0
    plan.execute()
    assert arr.meta.generation == 0
    # codec change forces a real rewrite even on the same grid
    arr.reshard((128, 128), codec="field16")
    assert arr.meta.codec == "field16" and arr.meta.generation == 1
    bound = (x.max() - x.min()) / 65535 * 0.51 + 1e-6
    assert np.abs(arr.read() - x).max() <= bound
    fdb.close()


def test_create_on_mismatch_retain_bumps_generation(tmp_path, make_store):
    """The versioned-retain policy: a layout change under
    on_mismatch='retain' forks a fresh generation instead of raising, and
    old-generation chunks can never shadow the new grid."""
    from repro.tensorstore import LayoutMismatchError
    fdb, ts = make_store("daos")
    ts.save(np.full((8, 8), 3.0, np.float32), chunks=(2, 2))
    with pytest.raises(LayoutMismatchError):
        ts.create((8, 8), np.float32, chunks=(4, 4))
    arr = ts.create((8, 8), np.float32, chunks=(4, 4), on_mismatch="retain")
    assert arr.meta.generation == 1
    # the new generation starts empty — the old grid's (2,2) chunks (which
    # share unprefixed indices like c0.0) must not leak through
    np.testing.assert_array_equal(arr.read(), np.zeros((8, 8), np.float32))
    arr.write(np.ones((8, 8), np.float32))
    np.testing.assert_array_equal(ts.open().read(),
                                  np.ones((8, 8), np.float32))
    assert ts.open().meta.generation == 1
    # unchanged layout keeps the live generation (replace semantics)
    again = ts.create((8, 8), np.float32, chunks=(4, 4))
    assert again.meta.generation == 1
    with pytest.raises(ValueError, match="on_mismatch"):
        ts.create((8, 8), np.float32, chunks=(4, 4), on_mismatch="wipe")
    fdb.close()


def test_meta_generation_format_versioning():
    """Generation-0 metadata stays format v1 (readable by pre-generation
    code); resharded layouts serialise as v2."""
    import json
    from repro.tensorstore import ArrayMeta
    m0 = ArrayMeta(shape=(8,), dtype="float32", chunks=(4,))
    d0 = json.loads(m0.to_bytes().decode())
    assert d0["version"] == 1 and "generation" not in d0
    assert ArrayMeta.from_bytes(m0.to_bytes()) == m0
    m2 = ArrayMeta(shape=(8,), dtype="float32", chunks=(4,), generation=2)
    d2 = json.loads(m2.to_bytes().decode())
    assert d2["version"] == 2 and d2["generation"] == 2
    assert ArrayMeta.from_bytes(m2.to_bytes()) == m2
    assert m0.layout_matches(m2)
    with pytest.raises(ValueError, match="newer"):
        ArrayMeta.from_bytes(json.dumps({
            "shape": [8], "dtype": "float32", "chunks": [4],
            "version": 3}).encode())


# ---------------------------------------------------------------------------
# reshard through the facades (pipeline + checkpoint)
# ---------------------------------------------------------------------------

def test_field_store_reshard(tmp_path):
    """Producer grid -> consumer grid through the pipeline facade, with
    coalesced ops and immediate consumer visibility."""
    from repro.data import ChunkedFieldStore
    fs = ChunkedFieldStore("nwp-rs", FDBConfig(backend="posix",
                                               root=str(tmp_path / "fdb")),
                           chunks=(32, 32))
    field = np.random.default_rng(80).normal(size=(96, 96)
                                             ).astype(np.float32)
    fs.put_field("t2m", field)
    fs.commit()
    arr = fs.reshard("t2m", (96, 16))    # row-major -> column bands
    assert arr.chunks == (96, 16)
    np.testing.assert_array_equal(fs.read_window("t2m"), field)
    # strided subsample on the way through (every other row)
    fs.reshard("t2m", (48, 48), slice(0, None, 2))
    np.testing.assert_array_equal(fs.read_window("t2m"), field[::2])
    # strided window reads/writes through the facade
    np.testing.assert_array_equal(
        fs.read_window("t2m", slice(0, None, 3), slice(1, 90, 5)),
        field[::2][::3, 1:90:5])
    fs.write_window("t2m", 0.0, slice(0, None, 2))
    fs.commit()                          # rule 3: visibility needs the flush
    want = field[::2].copy()
    want[::2] = 0.0
    np.testing.assert_array_equal(fs.read_window("t2m"), want)
    fs.close()


def test_field_store_consumer_refresh_after_reshard(tmp_path):
    """A consumer store that cached its open keeps the old generation
    (versioned retain keeps it readable) until open_field(refresh=True)
    picks up the producer's re-layout."""
    from repro.data import ChunkedFieldStore
    cfg = FDBConfig(backend="posix", root=str(tmp_path / "fdb"))
    prod = ChunkedFieldStore("nwp-rf", cfg, chunks=(32, 32))
    field = np.random.default_rng(84).normal(size=(64, 64)).astype(np.float32)
    prod.put_field("t2m", field)
    prod.commit()
    cons = ChunkedFieldStore("nwp-rf", cfg, chunks=(32, 32))
    assert cons.open_field("t2m").chunks == (32, 32)   # cached open
    prod.reshard("t2m", (32, 16), slice(0, None, 2))   # shape halves
    cons.fdb.catalogue.refresh()
    stale = cons.open_field("t2m")
    assert stale.chunks == (32, 32)                    # still the old open
    np.testing.assert_array_equal(stale.read(), field)
    fresh = cons.open_field("t2m", refresh=True)
    assert fresh.chunks == (32, 16) and fresh.meta.generation == 1
    np.testing.assert_array_equal(cons.read_window("t2m"), field[::2])
    prod.close()
    cons.close()


def test_checkpoint_topology_change_restore():
    """Restore onto a different chunking than the checkpoint was saved
    with: a new-topology checkpointer reshards the saved tensors onto its
    own banding, then sharded partial reads line up."""
    from repro.train.checkpoint import FDBCheckpointer
    w = np.random.default_rng(81).normal(size=(256, 64)).astype(np.float32)
    mu = np.random.default_rng(82).normal(size=(128, 32)).astype(np.float32)
    ck4 = FDBCheckpointer("topo", FDBConfig(backend="daos"), n_shards=4)
    ck4.save(3, {"w": w}, opt_state={"mu": mu})
    # a 2-shard run opens the 4-band checkpoint as-is...
    ck2 = FDBCheckpointer("topo", FDBConfig(backend="daos"), n_shards=2)
    assert ck2.open_tensor(3, "w").n_chunks[0] == 4
    got = ck2.restore(3, {"w": w})       # whole-tensor restore still works
    np.testing.assert_array_equal(np.asarray(got["w"]), w)
    # ...then reshards it onto its own banding
    ck2.reshard_step(3, {"w": w})
    ck2.reshard_tensor(3, "mu", kind="opt")
    assert ck2.open_tensor(3, "w").n_chunks[0] == 2
    assert ck2.open_tensor(3, "mu", kind="opt").n_chunks[0] == 2
    np.testing.assert_array_equal(
        np.asarray(ck2.restore(3, {"w": w})["w"]), w)
    np.testing.assert_array_equal(
        np.asarray(ck2.restore(3, {"mu": mu}, kind="opt")["mu"]), mu)
    # band-aligned partial read on the new topology
    np.testing.assert_array_equal(ck2.open_tensor(3, "w")[128:256], w[128:])
    ck4.close()
    ck2.close()


def test_checkpoint_resave_new_banding_bumps_generation():
    """A re-save of a step under a different n_shards must not fail and
    must win on restore (create on_mismatch='retain')."""
    from repro.train.checkpoint import FDBCheckpointer
    w = np.random.default_rng(83).normal(size=(64, 16)).astype(np.float32)
    ck4 = FDBCheckpointer("reband", FDBConfig(backend="daos"), n_shards=4)
    ck4.save(1, {"w": w})
    ck8 = FDBCheckpointer("reband", FDBConfig(backend="daos"), n_shards=8)
    ck8.save(1, {"w": w * 2})
    arr = ck8.open_tensor(1, "w")
    assert arr.meta.generation == 1 and arr.n_chunks[0] == 8
    np.testing.assert_array_equal(
        np.asarray(ck8.restore(1, {"w": w})["w"]), w * 2)
    ck4.close()
    ck8.close()


# ---------------------------------------------------------------------------
# heavy sweep (excluded from tier-1 via the slow marker)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sweep_chunk_sizes_roundtrip(backend, tmp_path, make_store):
    rng = np.random.default_rng(9)
    x = rng.normal(size=(257, 129)).astype(np.float32)
    for cs in (8, 32, 64, 128, 512):
        fdb, ts = make_store(backend, array=f"sweep{cs}")
        ts.save(x, chunks=(cs, cs))
        np.testing.assert_array_equal(ts.open().read(), x)
        fdb.close()

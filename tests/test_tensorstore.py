"""repro.tensorstore: chunked N-D arrays over the FDB.

Covers the acceptance criteria: roundtrip of non-chunk-aligned arrays on all
four backends, partial slice reads issuing I/O for only the intersecting
chunks (asserted via engine ``Meter`` op counts), chunk-boundary edge cases,
and codec on/off parity — plus the executor's bounded in-flight window and
the batched ``FDB.archive_many`` semantics.
"""
import time

import numpy as np
import pytest

from repro.core import FDB, FDBConfig, FieldLocation
from repro.core.engine.meter import GLOBAL_METER
from repro.tensorstore import (ChunkExecutor, ChunkGrid, TensorStore,
                               auto_chunks, get_codec)

BACKENDS = ["daos", "rados", "posix", "s3"]

#: engine op kinds that move object payload bytes on a read path
DATA_READ_KINDS = {"array_read", "read", "http_get"}


def make_store(backend, tmp_path, array="a", writer="w0", **kw):
    fdb = FDB(FDBConfig(backend=backend, schema="tensor",
                        root=str(tmp_path / "fdb"), **kw))
    return fdb, TensorStore(fdb, {"store": "s", "array": array,
                                  "writer": writer})


def _data_reads(ops):
    return [op for op in ops if op.kind in DATA_READ_KINDS]


# ---------------------------------------------------------------------------
# roundtrip + partial reads (acceptance criteria)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_non_aligned_roundtrip(backend, tmp_path):
    """(37, 53) on a (16, 16) grid: every edge chunk is clipped."""
    fdb, ts = make_store(backend, tmp_path)
    x = np.random.default_rng(0).normal(size=(37, 53)).astype(np.float32)
    ts.save(x, chunks=(16, 16))
    arr = ts.open()
    assert arr.shape == (37, 53) and arr.dtype == np.float32
    assert arr.n_chunks == (3, 4)
    np.testing.assert_array_equal(arr.read(), x)
    fdb.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_partial_read_touches_only_intersecting_chunks(backend, tmp_path):
    fdb, ts = make_store(backend, tmp_path)
    x = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    ts.save(x, chunks=(16, 16))          # 4 x 4 chunk grid, 1 KiB chunks
    arr = ts.open()
    arr[0:1, 0:1]                        # warm catalogue/axis caches

    for sel, n_expected in [
        ((slice(0, 16), slice(0, 16)), 1),     # exactly one chunk
        ((slice(10, 40), slice(0, 10)), 3),    # rows 0-2 x col 0
        ((slice(0, 64), slice(20, 28)), 4),    # full column band
    ]:
        before = GLOBAL_METER.snapshot()
        np.testing.assert_array_equal(arr[sel], x[sel])
        new_ops = GLOBAL_METER.snapshot()[len(before):]
        reads = _data_reads(new_ops)
        if backend == "posix":
            # posix stripes one chunk read over several OSTs: assert on bytes
            assert sum(op.nbytes for op in reads) == n_expected * 16 * 16 * 4
        else:
            assert len(reads) == n_expected, (sel, reads)
        assert sum(op.nbytes for op in reads) < x.nbytes
    fdb.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_full_read_moves_all_bytes(backend, tmp_path):
    fdb, ts = make_store(backend, tmp_path)
    x = np.random.default_rng(2).normal(size=(40, 40)).astype(np.float32)
    ts.save(x, chunks=(32, 32))
    arr = ts.open()
    before = GLOBAL_METER.snapshot()
    np.testing.assert_array_equal(arr.read(), x)
    reads = _data_reads(GLOBAL_METER.snapshot()[len(before):])
    assert sum(op.nbytes for op in reads) == x.nbytes
    fdb.close()


def test_replace_semantics_same_layout(tmp_path):
    """Re-saving with an unchanged layout transactionally replaces every
    chunk (FDB rule 5)."""
    fdb, ts = make_store("daos", tmp_path)
    ts.save(np.zeros((8, 8), np.float32), chunks=(4, 4))
    y = np.random.default_rng(3).normal(size=(8, 8)).astype(np.float32)
    ts.save(y, chunks=(4, 4))
    np.testing.assert_array_equal(ts.open().read(), y)
    fdb.close()


def test_layout_change_rejected_without_wipe(tmp_path):
    """A re-create with a different grid would strand old-grid chunk objects
    (no per-object delete in the FDB API) — it must be rejected."""
    from repro.tensorstore import LayoutMismatchError
    fdb, ts = make_store("daos", tmp_path)
    ts.save(np.zeros((8, 8), np.float32), chunks=(2, 2))
    with pytest.raises(LayoutMismatchError):
        ts.create((8, 8), np.float32, chunks=(4, 4))
    with pytest.raises(LayoutMismatchError):
        ts.create((6, 6), np.float32, chunks=(2, 2))
    # after a wipe the new layout goes through
    fdb.wipe({"store": "s", "array": "a"})
    y = np.ones((6, 6), np.float32)
    ts.save(y, chunks=(4, 4))
    np.testing.assert_array_equal(ts.open().read(), y)
    fdb.close()


def test_field_store_regrid_wipes_stale_chunks():
    """ChunkedFieldStore.put_field transparently wipes + re-creates on a
    layout change, leaving no stale old-grid entries behind."""
    from repro.data import ChunkedFieldStore
    fs = ChunkedFieldStore("regrid", FDBConfig(backend="daos"))
    fs.put_field("f", np.zeros((8, 8), np.float32), chunks=(2, 2))
    fs.commit()
    y = np.random.default_rng(11).normal(size=(8, 8)).astype(np.float32)
    fs.put_field("f", y, chunks=(4, 4))
    fs.commit()
    np.testing.assert_array_equal(fs.read_window("f"), y)
    listed = list(fs.fdb.list({"store": "regrid", "array": "f"}))
    assert len(listed) == 4 + 1          # 4 new-grid chunks + meta, no stale
    fs.close()


def test_checkpoint_legacy_resave_shadows_chunked():
    """A legacy (chunked=False) re-save of a step previously saved chunked
    must win on restore — the chunked metadata is tombstoned."""
    from repro.train.checkpoint import FDBCheckpointer
    w = np.full((64, 32), 1.0, np.float32)
    ck1 = FDBCheckpointer("shadow", FDBConfig(backend="daos"))
    ck1.save(5, {"w": w})
    ck2 = FDBCheckpointer("shadow", FDBConfig(backend="daos"), chunked=False)
    ck2.save(5, {"w": w * 2})
    restored = ck2.restore(5, {"w": w})
    np.testing.assert_array_equal(np.asarray(restored["w"]), w * 2)
    ck1.close()
    ck2.close()


def test_open_missing_array_raises(tmp_path):
    fdb, ts = make_store("daos", tmp_path, array="nope")
    assert not ts.exists()
    with pytest.raises(FileNotFoundError):
        ts.open()
    fdb.close()


# ---------------------------------------------------------------------------
# chunk-grid edge cases
# ---------------------------------------------------------------------------

def test_grid_math_non_divisible():
    g = ChunkGrid((37, 53), (16, 16))
    assert g.n_chunks == (3, 4)
    assert g.chunk_shape((2, 3)) == (5, 5)          # clipped corner
    hits = list(g.intersecting((slice(30, 37), slice(48, 53))))
    assert {h[0] for h in hits} == {(1, 3), (2, 3)}


def test_grid_oversize_chunks_clip():
    g = ChunkGrid((10, 10), (64, 64))
    assert g.chunks == (10, 10) and g.n_chunks == (1, 1)


def test_grid_rejects_bad_args():
    with pytest.raises(ValueError):
        ChunkGrid((4, 4), (4,))
    with pytest.raises(ValueError):
        ChunkGrid((4,), (0,))


def test_indexing_edge_cases(tmp_path):
    fdb, ts = make_store("daos", tmp_path)
    x = np.random.default_rng(4).normal(size=(9, 7, 5)).astype(np.float32)
    ts.save(x, chunks=(4, 3, 2))
    arr = ts.open()
    np.testing.assert_array_equal(arr[3], x[3])              # int → squeeze
    np.testing.assert_array_equal(arr[-2, 1:], x[-2, 1:])    # negative index
    np.testing.assert_array_equal(arr[:, -3:, 4], x[:, -3:, 4])
    assert arr[2:2].size == 0                                # empty selection
    with pytest.raises(IndexError):
        arr[::2]                                             # steps unsupported
    with pytest.raises(IndexError):
        arr[0, 0, 0, 0]
    fdb.close()


def test_scalar_and_1d_arrays(tmp_path):
    fdb, ts = make_store("rados", tmp_path, array="scalar")
    ts.save(np.float32(3.25))
    assert ts.open().read() == np.float32(3.25)
    ts2 = TensorStore(fdb, {"store": "s", "array": "vec", "writer": "w0"})
    v = np.arange(1000, dtype=np.int64)
    ts2.save(v, chunks=(64,))
    np.testing.assert_array_equal(ts2.open()[128:700], v[128:700])
    fdb.close()


def test_auto_chunks_targets_size():
    chunks = auto_chunks((4096, 4096), np.float32, target_bytes=1 << 20)
    nbytes = chunks[0] * chunks[1] * 4
    assert nbytes <= 1 << 20
    assert auto_chunks((), np.float32) == ()
    assert auto_chunks((3,), np.float32) == (3,)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["daos", "posix"])
def test_codec_parity_on_off(backend, tmp_path):
    """field8/field16 vs raw: lossy within the block-quantisation bound,
    identical shape/dtype, raw stays exact."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(300, 200)).astype(np.float32)
    fdb = FDB(FDBConfig(backend=backend, schema="tensor",
                        root=str(tmp_path / "fdb")))
    got = {}
    for codec in ("raw", "field8", "field16"):
        ts = TensorStore(fdb, {"store": "s", "array": f"a-{codec}",
                               "writer": "w0"})
        ts.save(x, chunks=(128, 128), codec=codec)
        got[codec] = ts.open().read()
        assert got[codec].shape == x.shape and got[codec].dtype == x.dtype
    np.testing.assert_array_equal(got["raw"], x)
    rng_x = x.max() - x.min()
    assert np.abs(got["field8"] - x).max() <= rng_x / 255 * 0.51 + 1e-6
    assert np.abs(got["field16"] - x).max() <= rng_x / 65535 * 0.51 + 1e-6
    assert np.abs(got["field16"] - x).max() < np.abs(got["field8"] - x).max()
    fdb.close()


def test_quant_codec_falls_back_to_raw_for_ints_and_tiny_chunks(tmp_path):
    fdb, ts = make_store("daos", tmp_path, array="ints")
    ints = np.arange(600, dtype=np.int32).reshape(30, 20)
    ts.save(ints, chunks=(16, 16), codec="field8")   # ineligible → raw marker
    np.testing.assert_array_equal(ts.open().read(), ints)
    fdb.close()


def test_codec_container_roundtrip_odd_tail():
    """Sizes that are not multiples of 128 carry an exact float tail."""
    codec = get_codec("field8")
    x = np.random.default_rng(6).normal(size=(5, 131)).astype(np.float32)
    y = codec.decode(codec.encode(x), x.shape, x.dtype)
    assert y.shape == x.shape
    # head quantised, tail exact
    tail = x.reshape(-1)[(x.size // 128) * 128:]
    np.testing.assert_array_equal(y.reshape(-1)[(x.size // 128) * 128:], tail)


def test_unknown_codec_rejected(tmp_path):
    fdb, ts = make_store("daos", tmp_path)
    with pytest.raises(ValueError):
        ts.create((4, 4), np.float32, codec="zstd")
    fdb.close()


# ---------------------------------------------------------------------------
# executor + archive_many
# ---------------------------------------------------------------------------

def test_executor_bounded_in_flight():
    ex = ChunkExecutor(max_workers=4, max_in_flight=2)

    def task(i):
        time.sleep(0.01)
        return i * i

    results = ex.map_ordered(task, range(12))
    assert results == [i * i for i in range(12)]
    assert ex.peak_in_flight <= 2
    ex.shutdown()


def test_executor_propagates_errors_in_order():
    ex = ChunkExecutor(max_workers=2)

    def task(i):
        if i == 3:
            raise RuntimeError("chunk 3 failed")
        return i

    with pytest.raises(RuntimeError, match="chunk 3"):
        ex.map_ordered(task, range(6))
    ex.shutdown()


def test_executor_propagates_client_context():
    from repro.core import client_context
    from repro.core.engine.meter import current_client
    ex = ChunkExecutor(max_workers=2)
    with client_context("proc7@node3"):
        seen = ex.map_ordered(lambda _i: current_client(), range(4))
    assert seen == ["proc7@node3"] * 4
    ex.shutdown()


@pytest.mark.parametrize("backend", BACKENDS)
def test_archive_many_returns_locations(backend, tmp_path, nwp_identifier):
    schema = "nwp-posix" if backend == "posix" else "nwp-object"
    fdb = FDB(FDBConfig(backend=backend, schema=schema,
                        root=str(tmp_path / "fdb")))
    items = [({**nwp_identifier, "step": str(i)}, bytes([i]) * 256)
             for i in range(12)]
    locs = fdb.archive_many(items)
    fdb.flush()
    assert len(locs) == 12
    assert all(isinstance(loc, FieldLocation) for loc in locs)
    # locations come back in input order and resolve to the right payloads
    for i, loc in enumerate(locs):
        assert fdb.store.retrieve(loc).read() == bytes([i]) * 256
    for i in range(12):
        assert fdb.retrieve({**nwp_identifier, "step": str(i)}).read() \
            == bytes([i]) * 256
    fdb.close()


@pytest.mark.parametrize("persistence", ["immediate", "on_flush"])
def test_parallel_archive_rados_span_mode_consistent(tmp_path, persistence,
                                                     nwp_identifier):
    """Span mode appends into shared objects: under parallel archive the
    physical append order must match the reserved offsets, or locations
    would point at other items' bytes."""
    fdb = FDB(FDBConfig(backend="rados", schema="nwp-object",
                        rados_object_mode="span",
                        rados_persistence=persistence,
                        rados_max_object_size=4096))
    items = [({**nwp_identifier, "step": str(i)},
              bytes([i % 251]) * (100 + (i % 7) * 13))
             for i in range(200)]
    locs = fdb.archive_many(items, parallelism=16)
    fdb.flush()
    for (ident, data), loc in zip(items, locs):
        assert fdb.retrieve(ident).read() == data, ident
        assert fdb.store.retrieve(loc).read() == data
    fdb.close()


def test_archive_many_serial_path_equivalent(tmp_path, nwp_identifier):
    fdb = FDB(FDBConfig(backend="daos", io_parallelism=0))
    items = [({**nwp_identifier, "step": str(i)}, b"z" * 64) for i in range(3)]
    locs = fdb.archive_many(items)
    assert len(locs) == 3
    fdb.close()


# ---------------------------------------------------------------------------
# integrations: checkpoint + data pipeline
# ---------------------------------------------------------------------------

def test_checkpoint_partial_tensor_read():
    from repro.train.checkpoint import FDBCheckpointer
    ck = FDBCheckpointer("ts-part", FDBConfig(backend="daos"), n_shards=4)
    w = np.random.default_rng(7).normal(size=(256, 64)).astype(np.float32)
    ck.save(3, {"w": w})
    arr = ck.open_tensor(3, "w")
    assert arr.n_chunks[0] == 4                   # n_shards → axis-0 bands
    np.testing.assert_array_equal(arr[100:200], w[100:200])
    ck.close()


def test_chunked_field_store_window_read(tmp_path):
    from repro.data import ChunkedFieldStore
    fs = ChunkedFieldStore("nwp", FDBConfig(backend="rados"),
                           chunks=(32, 32))
    field = np.random.default_rng(8).normal(size=(100, 90)).astype(np.float32)
    fs.put_field("t2m", field)
    fs.commit()
    np.testing.assert_array_equal(
        fs.read_window("t2m", slice(10, 60), slice(40, 80)),
        field[10:60, 40:80])
    np.testing.assert_array_equal(fs.read_window("t2m"), field)
    fs.wipe_field("t2m")
    with pytest.raises(FileNotFoundError):
        fs.open_field("t2m")
    fs.close()


# ---------------------------------------------------------------------------
# heavy sweep (excluded from tier-1 via the slow marker)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_sweep_chunk_sizes_roundtrip(backend, tmp_path):
    rng = np.random.default_rng(9)
    x = rng.normal(size=(257, 129)).astype(np.float32)
    for cs in (8, 32, 64, 128, 512):
        fdb, ts = make_store(backend, tmp_path, array=f"sweep{cs}")
        ts.save(x, chunks=(cs, cs))
        np.testing.assert_array_equal(ts.open().read(), x)
        fdb.close()

"""Robustness: fault injection, retry/backoff, lease TTL expiry, crash
recovery.

Covers the PR acceptance criteria: the fault matrix (four backends ×
{transient archive, transient retrieve, catalogue flush failure, crash
between archive and flush}) heals to byte-identical results vs a
fault-free run; a writer killed at an injected crash point leaves torn
state that ``fdb.recover()`` mops up after its lease TTL lapses, a second
writer completes, and ``fdb.check_protocol()`` proves the recovery obeyed
the lease contract; plus the RetryPolicy unit surface (deadlines,
give-ups, on_retry fencing, permanent-error passthrough), blocking lease
acquisition, the heartbeat thread, the executor's failure-context
annotation, and the checkpointer's detected (no longer silent) shutdown
timeout.

These tests run on the real lease clock (no fakes): the protocol checker
orders recovery events against genuine TTL expiry, so TTLs here are
small-but-real (0.1–0.3 s) and expiry waits sleep past them.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (FDB, FDBConfig, Deadline, DeadlineExceeded,
                        FaultInjector, InjectedCrash, LeaseConflictError,
                        PermanentStorageError, RetryPolicy,
                        TransientStorageError, current_deadline,
                        deadline_scope)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import GLOBAL_TRACER
from repro.tensorstore import TensorStore
from repro.tensorstore.executor import ChunkExecutor

from conftest import TEST_SEED

BASE = {"store": "s", "array": "a", "writer": "w0"}


def fast_retry(**kw):
    """A policy that never really sleeps — unit tests run instantly.
    Jitter is pinned to the suite-wide ``REPRO_TEST_SEED`` so any
    chaos schedule replays from one knob."""
    kw.setdefault("sleep", lambda _s: None)
    kw.setdefault("seed", TEST_SEED)
    return RetryPolicy(**kw)


# ---------------------------------------------------------------------------
# RetryPolicy unit surface
# ---------------------------------------------------------------------------

def test_retry_heals_transient_and_counts_attempts():
    m = MetricsRegistry()
    calls = []

    def fn():
        calls.append(1)
        if len(calls) < 3:
            raise TransientStorageError("hiccup")
        return "ok"

    assert fast_retry(max_attempts=4).call(fn, op="t", metrics=m) == "ok"
    assert len(calls) == 3
    assert m.snapshot()["retry.attempts"]["value"] == 2
    assert "retry.giveups" not in m.snapshot()


def test_retry_gives_up_bounded_and_annotates():
    m = MetricsRegistry()

    def fn():
        raise TransientStorageError("always")

    with pytest.raises(TransientStorageError) as ei:
        fast_retry(max_attempts=2).call(fn, op="fdb.archive", metrics=m)
    rendered = " ".join(str(a) for a in ei.value.args) \
        + " ".join(getattr(ei.value, "__notes__", ()))
    assert "gave up after 2 attempt(s)" in rendered
    assert m.snapshot()["retry.giveups"]["value"] == 1
    assert m.snapshot()["retry.attempts"]["value"] == 1


def test_retry_permanent_error_propagates_immediately():
    m = MetricsRegistry()
    calls = []

    def fn():
        calls.append(1)
        raise PermanentStorageError("disk on fire")

    with pytest.raises(PermanentStorageError):
        fast_retry().call(fn, op="t", metrics=m)
    assert len(calls) == 1                  # never re-attempted
    assert "retry.attempts" not in m.snapshot()


def test_retry_injected_crash_is_uncatchable():
    calls = []

    def fn():
        calls.append(1)
        raise InjectedCrash("writer killed")

    with pytest.raises(InjectedCrash):
        fast_retry().call(fn, op="t", metrics=MetricsRegistry())
    assert len(calls) == 1


def test_retry_explicit_deadline_exceeded_chains_cause():
    def fn():
        raise TransientStorageError("slow")

    with pytest.raises(DeadlineExceeded) as ei:
        fast_retry(max_attempts=10).call(fn, op="t",
                                         metrics=MetricsRegistry(),
                                         deadline=Deadline(0.0))
    assert isinstance(ei.value.__cause__, TransientStorageError)


def test_retry_ambient_deadline_scope():
    assert current_deadline() is None
    with deadline_scope(0.0) as d:
        assert current_deadline() is d and d.expired
        with pytest.raises(DeadlineExceeded):
            fast_retry(max_attempts=10).call(
                lambda: (_ for _ in ()).throw(TransientStorageError("x")),
                op="t", metrics=MetricsRegistry())
    assert current_deadline() is None


def test_retry_on_retry_hook_aborts_the_loop():
    calls = []

    def fn():
        calls.append(1)
        raise TransientStorageError("transient")

    def fenced():
        raise RuntimeError("lease no longer current")

    with pytest.raises(RuntimeError, match="no longer current"):
        fast_retry(max_attempts=5).call(fn, op="t",
                                        metrics=MetricsRegistry(),
                                        on_retry=fenced)
    assert len(calls) == 1                  # fencing beat the re-attempt


# ---------------------------------------------------------------------------
# the fault matrix: 4 backends x transient fault shapes, byte-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("faulted_op", ["store.archive", "store.retrieve",
                                        "catalogue.flush"])
def test_fault_matrix_transients_heal_byte_identical(backend, faulted_op, tmp_path, make_fdb):
    """A scripted burst of transient faults on each data-path op class is
    healed by the facade retry: the array reads back exactly, and every
    chunk object is byte-identical to a fault-free reference write."""
    inj = FaultInjector(seed=TEST_SEED + 7)
    fdb = make_fdb(backend, faults=inj, retry=fast_retry())
    x = np.random.default_rng(3).normal(size=(48, 32)).astype(np.float32)
    if faulted_op == "store.retrieve":
        arr = TensorStore(fdb, BASE).save(x, chunks=(16, 16))
        inj.fail(faulted_op, first=2)
    else:
        inj.fail(faulted_op, first=2)
        arr = TensorStore(fdb, BASE).save(x, chunks=(16, 16))
    np.testing.assert_array_equal(arr.read(), x)
    assert inj.injected >= 2
    assert fdb.metrics()["retry.attempts"]["value"] >= 2
    assert fdb.metrics().get("retry.giveups", {"value": 0})["value"] == 0
    # per-chunk byte identity against a fault-free reference write
    ref = TensorStore(fdb, dict(BASE, array="ref")).save(x, chunks=(16, 16))
    for idx in arr.grid.all_indices():
        faulty = fdb.retrieve(arr.chunk_ident(idx)).read()
        clean = fdb.retrieve(ref.chunk_ident(idx)).read()
        assert faulty == clean, f"chunk {idx} bytes differ"
    fdb.close()


def test_permanent_fault_fails_the_write(tmp_path, make_fdb):
    """Permanent errors must surface, not burn the retry budget."""
    inj = FaultInjector().fail("store.archive", first=1,
                               error=PermanentStorageError)
    fdb = make_fdb("posix", faults=inj, retry=fast_retry())
    with pytest.raises(PermanentStorageError):
        TensorStore(fdb, BASE).save(np.zeros((8, 8), np.float32),
                                    chunks=(4, 4))
    assert fdb.metrics().get("retry.attempts", {"value": 0})["value"] == 0
    fdb.close()


# ---------------------------------------------------------------------------
# lease TTL expiry, blocking acquisition, heartbeat
# ---------------------------------------------------------------------------

def test_lease_ttl_expiry_frees_range_for_second_writer(tmp_path, make_fdb):
    fdb, fdb2 = make_fdb("daos"), make_fdb("daos")
    a = fdb.session("A", lease_ttl=0.1)
    e1 = a.acquire_lease(BASE, "g0", 0, 4)
    b = fdb2.session("B")
    with pytest.raises(LeaseConflictError):     # still live
        b.acquire_lease(BASE, "g0", 2, 6)
    time.sleep(0.25)                            # past the TTL, no heartbeat
    e2 = b.acquire_lease(BASE, "g0", 2, 6)      # expiry freed [0, 4)
    assert e2 > e1
    assert fdb2.metrics()["lease.expired"]["value"] >= 1
    b.close()
    a.abandon()                                 # its lease is long gone
    fdb.close()
    fdb2.close()


def test_blocking_acquire_times_out_then_succeeds_after_release(tmp_path, make_fdb):
    fdb = make_fdb("posix")
    fdb.acquire_lease(BASE, "g0", 0, 4, owner="A")
    t0 = time.perf_counter()
    with pytest.raises(LeaseConflictError, match="timed out"):
        fdb.acquire_lease(BASE, "g0", 0, 4, owner="B", block=True,
                          timeout=0.15)
    assert time.perf_counter() - t0 >= 0.1

    def free_it():
        time.sleep(0.1)
        fdb.release_lease(BASE, "g0", 0, 4, owner="A")

    t = threading.Thread(target=free_it)
    t.start()
    epoch = fdb.acquire_lease(BASE, "g0", 0, 4, owner="B", block=True,
                              timeout=5.0)
    t.join()
    assert epoch > 1
    fdb.close()


def test_blocking_acquire_wakes_on_blocker_ttl_expiry(tmp_path, make_fdb):
    """A blocked writer completes as soon as the holder's TTL lapses —
    no release, no coordinator intervention."""
    fdb = make_fdb("posix")
    fdb.acquire_lease(BASE, "g0", 0, 4, owner="A", ttl=0.15)
    epoch = fdb.acquire_lease(BASE, "g0", 0, 4, owner="B", block=True,
                              timeout=5.0)
    assert epoch > 1
    assert [l.owner for l in fdb.lease_holders(BASE, "g0")] == ["B"]
    fdb.close()


def test_heartbeat_keeps_lease_alive_past_ttl(tmp_path, make_fdb):
    fdb, fdb2 = make_fdb("s3"), make_fdb("s3")
    a = fdb.session("A", lease_ttl=0.12, heartbeat_interval=0.04)
    a.acquire_lease(BASE, "g0", 0, 4)
    b = fdb2.session("B")
    time.sleep(0.4)                             # > 3x TTL
    with pytest.raises(LeaseConflictError):     # heartbeat kept it live
        b.acquire_lease(BASE, "g0", 0, 4)
    a.close()                                   # stops the heartbeat too
    assert b.acquire_lease(BASE, "g0", 0, 4) > 1
    b.close()
    fdb.close()
    fdb2.close()


def test_heartbeat_requires_ttl(tmp_path, make_fdb):
    fdb = make_fdb("posix")
    with pytest.raises(ValueError, match="requires lease_ttl"):
        fdb.session("A", heartbeat_interval=0.1)
    fdb.close()


# ---------------------------------------------------------------------------
# crash recovery: the acceptance scenario, all four backends
# ---------------------------------------------------------------------------

def test_crash_killed_writer_recover_second_writer_completes(backend, tmp_path, make_fdb):
    """Writer A archives its chunks, is killed at the injected crash point
    between archive and flush, and stops heartbeating; after its TTL
    lapses, ``fdb.recover()`` purges the expired lease and quarantines the
    journaled orphan chunks; writer B then completes the write, and the
    result is byte-identical to an uninterrupted run.  The whole trace
    passes ``fdb.check_protocol()`` — including the new recovery rule."""
    GLOBAL_TRACER.enable()
    setup = make_fdb(backend)
    x = np.random.default_rng(5).normal(size=(64, 48)).astype(np.float32)
    arr = TensorStore(setup, BASE).create(x.shape, x.dtype, chunks=(16, 16))
    setup.flush()

    inj = FaultInjector().crash_on("store.flush", call=1)
    fdb_a = make_fdb(backend, faults=inj, retry=fast_retry())
    sa = fdb_a.session("A", lease_ttl=0.2)
    aa = TensorStore(None, BASE, session=sa).open()
    plan = aa.write_plan((slice(0, 32), slice(None)), x[:32])
    plan.execute(flush=False)                   # archived + journaled
    with pytest.raises(InjectedCrash):
        sa.flush()                              # killed mid-barrier
    sa.abandon()                                # the process is dead

    time.sleep(0.45)                            # let the TTL lapse
    fdb_b = make_fdb(backend)
    report = TensorStore(fdb_b, BASE).recover()
    assert any(e["owner"] == "A" for e in report.expired)
    assert sorted(c for q in report.quarantined
                  for c in q["chunk_ids"]) == list(range(6))
    assert report.stale == []
    assert not report.clean
    assert fdb_b.metrics()["recover.orphans"]["value"] == 6
    assert fdb_b.metrics()["lease.expired"]["value"] >= 1
    # a second sweep finds a healthy scope
    assert TensorStore(fdb_b, BASE).recover().clean

    sb = fdb_b.session("B")
    ab = TensorStore(None, BASE, session=sb).open()
    ab.write_plan((slice(0, 32), slice(None)), x[:32]).execute(flush=False)
    ab.write_plan((slice(32, 64), slice(None)), x[32:]).execute(flush=False)
    sb.flush()
    sb.close()
    np.testing.assert_array_equal(arr.read(), x)

    # byte identity vs an uninterrupted single-writer reference
    ref = TensorStore(setup, dict(BASE, array="ref")).save(x,
                                                           chunks=(16, 16))
    for idx in arr.grid.all_indices():
        recovered = fdb_b.retrieve(arr.chunk_ident(idx)).read()
        clean = fdb_b.retrieve(ref.chunk_ident(idx)).read()
        assert recovered == clean, f"chunk {idx} bytes differ"

    # the full window — crash, expiry, recovery, rewrite — is contract-clean
    assert fdb_b.check_protocol() == []
    setup.close()
    fdb_a.close()
    fdb_b.close()


def test_recover_reports_stale_generations(tmp_path, make_fdb):
    """Half-flipped reshard debris: chunks of a generation newer than the
    live metadata are reported (report-only quarantine)."""
    fdb = make_fdb("posix")
    TensorStore(fdb, BASE).save(np.zeros(8, np.float32), chunks=(4,))
    # a g1 chunk landed and was flushed, but the metadata flip never ran:
    # the live generation is still 0
    fdb.archive(dict(BASE, chunk="g1.0"), b"\x01\x02")
    fdb.flush()
    report = TensorStore(fdb, BASE).recover()
    assert report.stale == ["g1.0"]
    assert report.expired == [] and report.quarantined == []
    assert not report.clean
    fdb.close()


def test_recover_on_healthy_scope_is_clean(tmp_path, make_fdb):
    fdb = make_fdb("daos")
    TensorStore(fdb, BASE).save(np.zeros((8, 8), np.float32), chunks=(4, 4))
    report = TensorStore(fdb, BASE).recover()
    assert report.clean
    assert report.orphan_chunks == 0
    fdb.close()


# ---------------------------------------------------------------------------
# executor failure context; checkpointer shutdown detection
# ---------------------------------------------------------------------------

def test_map_ordered_annotates_first_failure_with_describe():
    with ChunkExecutor(max_workers=2) as ex:
        def task(i):
            if i in (1, 4):
                raise RuntimeError("boom")
            return i

        with pytest.raises(RuntimeError) as ei:
            ex.map_ordered(task, range(6), describe=lambda i: f"op=t#{i}")
    rendered = " ".join(str(a) for a in ei.value.args) \
        + " ".join(getattr(ei.value, "__notes__", ()))
    assert "first failure of 2/6" in rendered
    assert "item 1" in rendered and "op=t#1" in rendered


def test_map_ordered_broken_describer_does_not_mask_error():
    with ChunkExecutor(max_workers=2) as ex:
        def task(i):
            raise ValueError("real error")

        def bad_describe(_i):
            raise KeyError("describer is broken")

        with pytest.raises(ValueError, match="real error"):
            ex.map_ordered(task, [0], describe=bad_describe)


def test_checkpointer_shutdown_timeout_raises(tmp_path):
    from repro.train.checkpoint import FDBCheckpointer
    ck = FDBCheckpointer("run", FDBConfig(backend="posix",
                                          root=str(tmp_path / "fdb")),
                         asynchronous=True, shutdown_timeout=0.05)
    hang = threading.Event()
    stuck = threading.Thread(target=hang.wait, daemon=True)
    stuck.start()
    real = ck._worker
    ck._worker = stuck                  # simulate a wedged drain thread
    with pytest.raises(RuntimeError, match="failed to shut down"):
        ck.close()
    hang.set()
    real.join(timeout=5)                # the real worker exits cleanly
    ck.fdb.close()


def test_checkpointer_clean_async_close(tmp_path):
    from repro.train.checkpoint import FDBCheckpointer
    ck = FDBCheckpointer("run", FDBConfig(backend="posix",
                                          root=str(tmp_path / "fdb")),
                         asynchronous=True)
    ck.save(0, {"w": np.arange(4.0, dtype=np.float32)})
    ck.wait()
    ck.close()                          # joins within the timeout: no raise

"""Trainer integration: checkpoint/restart, async archival, stragglers,
elastic re-planning."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import FDBConfig
from repro.data import FDBDataPipeline, SyntheticTokens
from repro.train.checkpoint import FDBCheckpointer
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.trainer import (StragglerMonitor, Trainer, WorkerFailure,
                                 reassign_shard, run_with_restarts)


@pytest.fixture
def tiny_setup():
    cfg = get_smoke_config("tinyllama-1.1b")
    data = SyntheticTokens(cfg.vocab_size, 16, seed=3)
    return cfg, data


def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_checkpoint_roundtrip_async(tiny_setup):
    cfg, data = tiny_setup
    from repro.models import lm
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    ck = FDBCheckpointer("async-run", FDBConfig(backend="rados"),
                         asynchronous=True)
    ck.save(7, params)
    ck.wait()
    restored = ck.restore(7, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ck.close()


def test_checkpoint_compressed_roundtrip(tiny_setup):
    cfg, _ = tiny_setup
    from repro.models import lm
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    ck = FDBCheckpointer("comp-run", FDBConfig(backend="daos"),
                         compress=True)
    ck.save(1, params)
    restored = ck.restore(1, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        a, b = np.asarray(a), np.asarray(b)
        if a.size >= 1024 and a.ndim >= 2:
            rng = a.max() - a.min()
            assert np.abs(a - b).max() <= rng / 255 * 0.51 + 1e-6
        else:
            np.testing.assert_array_equal(a, b)
    ck.close()


def test_restart_resumes_from_checkpoint(tiny_setup):
    cfg, data = tiny_setup
    ck = FDBCheckpointer("restart-run", FDBConfig(backend="daos"))
    fail = {8}

    def fault(step):
        if step in fail:
            fail.discard(step)
            raise WorkerFailure("chaos")

    def make():
        return Trainer(cfg, None, AdamWConfig(lr=1e-3), checkpointer=ck,
                       ckpt_every=4, batch_fn=lambda s: data.batch(s, 2),
                       fault_hook=fault)

    tr = run_with_restarts(make, n_steps=12, max_restarts=1)
    assert tr.step == 12
    assert all(math.isfinite(m["loss"]) for m in tr.metrics)
    assert 12 in ck.available_steps()


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0)
    for _ in range(10):
        assert not mon.observe(0.1)
    assert mon.observe(0.5)
    assert mon.flagged == 1


def test_reassign_shard_deterministic_and_total():
    n = 16
    for epoch in range(3):
        targets = {reassign_shard(h, n, epoch) for h in range(n)}
        assert targets == set(range(n))     # a permutation — no data loss


def test_elastic_replan():
    import os
    if "pod" in str(jax.devices()):
        pass
    from repro.launch.elastic import reassign_data_shards
    out = reassign_data_shards(10, [0, 2, 5])
    assert sorted(s for lst in out.values() for s in lst) == list(range(10))
    assert max(len(v) for v in out.values()) \
        - min(len(v) for v in out.values()) <= 1


def test_pipeline_contended_producer_consumer(tiny_setup):
    cfg, data = tiny_setup
    import threading
    pipe = FDBDataPipeline("corpus", fdb_config=FDBConfig(backend="daos"))
    n = 8
    got = []

    def producer():
        for i in range(n):
            pipe.put_batch(0, i, data.batch(i, 2))
            pipe.commit()

    t = threading.Thread(target=producer)
    t.start()
    # poll concurrently with the producer: only ever see complete batches
    import time
    deadline = time.time() + 30
    while len(got) < n and time.time() < deadline:
        b = pipe.get_batch(0, len(got))
        if b is not None:
            got.append(b)
    t.join()
    assert len(got) == n
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], data.batch(i, 2)["tokens"])

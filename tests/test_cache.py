"""Decoded-chunk cache (PR 10): correctness of the read-side serving
layer — LRU bounds, generation-aware invalidation, coherence with the
FDB commit barrier, byte-identity with the cache off, consolidated
metadata opens, and the fancy-indexing rejection contract.

The coherence model under test: a cache entry is (scope, generation,
chunk index)-keyed decoded bytes.  ``WritePlan`` *invalidates* a chunk's
key on archive and marks it *pending* — lookups miss (re-fetching
whatever the backend serves, exactly like a cache-less client) and puts
are refused until this client's ``flush`` publishes the pending set.  So
cache-on reads are byte-identical to cache-off reads at every point in
the archive → flush lifecycle, whatever the simulated backend's
unflushed-read behaviour, which is what the equality tests pin down.
"""
import numpy as np
import pytest

from repro.core import FDB, FDBConfig
from repro.tensorstore import ChunkCache, TensorStore, TreeCatalogue
from repro.tensorstore.cache import ChunkCache as _CC


def _field(shape=(64, 64), seed=0):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


# -- the cache data structure itself ----------------------------------------

class TestChunkCacheUnit:
    def _key(self, i, gen=0):
        return ((("store", "s"),), gen, (i, 0))

    def test_put_lookup_roundtrip(self):
        c = ChunkCache(1 << 20)
        chunk = np.arange(16, dtype=np.float32).reshape(4, 4)
        _, token = c.lookup(self._key(0))
        c.put(self._key(0), chunk, token)
        got, _ = c.lookup(self._key(0))
        np.testing.assert_array_equal(got, chunk)
        assert c.stats()["hits"] == 1 and c.stats()["misses"] == 1

    def test_cached_chunks_are_immutable_copies(self):
        c = ChunkCache(1 << 20)
        chunk = np.ones((4, 4), np.float32)
        _, token = c.lookup(self._key(0))
        c.put(self._key(0), chunk, token)
        chunk[:] = -1.0                       # mutate the caller's array
        got, _ = c.lookup(self._key(0))
        np.testing.assert_array_equal(got, np.ones((4, 4), np.float32))
        with pytest.raises(ValueError):
            got[0, 0] = 5.0                   # cache entries are read-only

    def test_byte_bound_evicts_lru(self):
        one_chunk = 4 * 4 * 4                 # float32 (4,4)
        c = ChunkCache(max_bytes=3 * one_chunk)
        for i in range(4):
            _, token = c.lookup(self._key(i))
            c.put(self._key(i), np.full((4, 4), i, np.float32), token)
        assert len(c) == 3 and c.nbytes <= 3 * one_chunk
        assert self._key(0) not in c          # oldest went first
        assert c.stats()["evicted_bytes"] == one_chunk

    def test_entry_bound(self):
        c = ChunkCache(1 << 20, max_entries=2)
        for i in range(5):
            _, token = c.lookup(self._key(i))
            c.put(self._key(i), np.ones((2, 2), np.float32), token)
        assert len(c) == 2

    def test_lookup_refreshes_lru_order(self):
        one = 4 * 4 * 4
        c = ChunkCache(max_bytes=2 * one)
        for i in range(2):
            _, token = c.lookup(self._key(i))
            c.put(self._key(i), np.full((4, 4), i, np.float32), token)
        c.lookup(self._key(0))                # 0 is now most recent
        _, token = c.lookup(self._key(2))
        c.put(self._key(2), np.full((4, 4), 2, np.float32), token)
        assert self._key(0) in c and self._key(1) not in c

    def test_oversized_value_rejected(self):
        c = ChunkCache(max_bytes=8)
        _, token = c.lookup(self._key(0))
        c.put(self._key(0), np.ones((64, 64), np.float32), token)
        assert len(c) == 0

    def test_invalidate_pends_until_publish(self):
        c = ChunkCache(1 << 20)
        _, token = c.lookup(self._key(0))
        c.put(self._key(0), np.ones((4, 4), np.float32), token)
        c.invalidate(self._key(0))
        got, token = c.lookup(self._key(0))
        assert got is None
        c.put(self._key(0), np.zeros((4, 4), np.float32), token)
        assert self._key(0) not in c          # pending: put refused
        c.publish_pending()
        got, token = c.lookup(self._key(0))
        assert got is None                    # still absent, but cacheable
        c.put(self._key(0), np.zeros((4, 4), np.float32), token)
        assert self._key(0) in c

    def test_stale_token_put_refused(self):
        """The fetch-old → invalidate → publish → stale-put race: a put
        carrying a token from before an invalidation must be dropped."""
        c = ChunkCache(1 << 20)
        _, stale_token = c.lookup(self._key(0))
        c.invalidate(self._key(0))            # bumps the key's version
        c.publish_pending()
        c.put(self._key(0), np.ones((4, 4), np.float32), stale_token)
        assert self._key(0) not in c

    def test_clear_by_scope_superset_match(self):
        c = ChunkCache(1 << 20)
        for scope in (("store", "a"), ("store", "b")):
            key = ((scope,), 0, (0, 0))
            _, token = c.lookup(key)
            c.put(key, np.ones((2, 2), np.float32), token)
        c.clear({"store": "a"})
        assert ((("store", "a"),), 0, (0, 0)) not in c
        assert ((("store", "b"),), 0, (0, 0)) in c

    def test_zero_budget_rejected(self):
        with pytest.raises(ValueError):
            ChunkCache(0)
        assert _CC is ChunkCache


# -- cache-on vs cache-off byte identity, all four backends -----------------

def _run_sequence(backend, root, cache_bytes):
    """One archive → read → unflushed write → read → flush → read →
    reshard → read lifecycle; returns every probe read's bytes."""
    x = _field()
    fdb = FDB(FDBConfig(backend=backend, schema="tensor", root=root,
                        chunk_cache_bytes=cache_bytes))
    ts = TensorStore(fdb, {"store": "s", "array": "a", "writer": "w"})
    arr = ts.save(x, chunks=(16, 16))
    probes = [arr[:, :].copy(), arr[:, :].copy()]   # cold + warm
    arr.write_at((slice(0, 16), slice(0, 16)),
                 -np.ones((16, 16), np.float32), flush=False)
    probes.append(arr[:, :].copy())                  # between archive/flush
    fdb.flush()
    probes.append(arr[:, :].copy())                  # post-barrier
    arr.reshard((32, 32))
    probes.append(arr[:, :].copy())                  # post-re-layout
    probes.append(arr[::2, ::4].copy())              # strided through cache
    fdb.close()
    return probes


def test_cache_on_equals_cache_off(backend, tmp_path):
    """The coherence contract: with the decoded-chunk cache on, every
    read returns byte-identically what a cache-less client reads, at
    every point of the archive → flush → reshard lifecycle."""
    off = _run_sequence(backend, str(tmp_path / "off"), 0)
    from repro.core import reset_engines
    reset_engines()                 # fresh simulated cluster for run two
    on = _run_sequence(backend, str(tmp_path / "on"), 1 << 20)
    for i, (a, b) in enumerate(zip(off, on)):
        np.testing.assert_array_equal(a, b, err_msg=f"probe {i}")


def test_cached_reread_hits_no_backend(make_fdb):
    """The tentpole's headline: a fully cached re-read issues ZERO
    backend ops — no catalogue lookups, no store reads, no meter
    traffic — and reports its hits on the plan."""
    fdb = make_fdb("daos", chunk_cache_bytes=1 << 20)
    ts = TensorStore(fdb, {"store": "s", "array": "a", "writer": "w"})
    x = _field()
    arr = ts.save(x, chunks=(16, 16))
    np.testing.assert_array_equal(arr[:, :], x)      # warm the cache
    m0 = len(fdb.meter.snapshot())
    plan = arr.read_plan((slice(None), slice(None)))
    assert plan.read_ops() == 0
    assert plan.cache_hits == 16
    np.testing.assert_array_equal(plan.execute(), x)
    assert len(fdb.meter.snapshot()) == m0
    snap = fdb.metrics()
    assert snap["cache.hits"]["value"] >= 16


def test_read_your_writes_within_session(make_fdb):
    """A writer client's own reads see its committed writes through the
    cache: write → flush → read returns the new bytes from a re-fetch,
    and only then do they become cacheable."""
    fdb = make_fdb("rados", chunk_cache_bytes=1 << 20)
    ts = TensorStore(fdb, {"store": "s", "array": "a", "writer": "w"})
    x = _field()
    arr = ts.save(x, chunks=(16, 16))
    np.testing.assert_array_equal(arr[:, :], x)
    y = x.copy()
    y[16:32, 0:16] = 7.0
    arr[16:32, 0:16] = np.full((16, 16), 7.0, np.float32)  # commits
    np.testing.assert_array_equal(arr[:, :], y)
    # the rewritten chunk re-caches after the barrier: reread = all hits
    plan = arr.read_plan((slice(16, 32), slice(0, 16)))
    plan.execute()
    plan2 = arr.read_plan((slice(16, 32), slice(0, 16)))
    assert plan2.cache_hits == 1
    np.testing.assert_array_equal(plan2.execute(), y[16:32, 0:16])


def test_reshard_generation_invalidates(make_fdb):
    """A re-layout bumps the generation, so old cached chunks can never
    serve the new grid: post-reshard reads are correct and the new
    generation's chunks cache independently."""
    fdb = make_fdb("posix", chunk_cache_bytes=1 << 20)
    ts = TensorStore(fdb, {"store": "s", "array": "a", "writer": "w"})
    x = _field()
    arr = ts.save(x, chunks=(16, 16))
    np.testing.assert_array_equal(arr[:, :], x)      # gen-0 fully cached
    arr.reshard((32, 32))
    assert arr.meta.generation == 1
    np.testing.assert_array_equal(arr[:, :], x)
    plan = arr.read_plan((slice(None), slice(None)))
    assert plan.cache_hits == plan.n_chunks == 4     # new-gen entries
    np.testing.assert_array_equal(plan.execute(), x)


def test_cross_client_invalidation_via_flush(make_fdb):
    """Two clients on one deployment share the per-client caches only
    through the storage: client B's cached chunk goes stale when client
    A rewrites and flushes, and B sees the new bytes after re-opening
    its plan on the bumped metadata (same generation, so B must not
    serve its stale entry blindly — the write went through A, so B's
    cache was never invalidated: this pins the documented limitation
    that B's *same-generation* windows re-serve cached bytes until its
    cache ages them out, exactly like any client-side cache)."""
    fdb_a = make_fdb("daos")
    fdb_b = make_fdb("daos", chunk_cache_bytes=1 << 20)
    base = {"store": "s", "array": "a", "writer": "w"}
    x = _field()
    arr_a = TensorStore(fdb_a, base).save(x, chunks=(16, 16))
    arr_b = TensorStore(fdb_b, base).open()
    np.testing.assert_array_equal(arr_b[:, :], x)
    y = x.copy()
    y[0:16, 0:16] = -3.0
    arr_a[0:16, 0:16] = np.full((16, 16), -3.0, np.float32)
    # B's cache is a *client-side* cache: its warm window still serves
    # the old bytes (documented), while uncached windows see the new
    np.testing.assert_array_equal(arr_b[0:16, 0:16], x[0:16, 0:16])
    fdb_b.chunk_cache.clear({})          # drop everything → re-fetch
    np.testing.assert_array_equal(arr_b[:, :], y)


def test_wipe_clears_cache(make_fdb):
    fdb = make_fdb("s3", chunk_cache_bytes=1 << 20)
    ts = TensorStore(fdb, {"store": "s", "array": "a", "writer": "w"})
    x = _field()
    arr = ts.save(x, chunks=(16, 16))
    np.testing.assert_array_equal(arr[:, :], x)
    assert len(fdb.chunk_cache) > 0
    fdb.wipe({"store": "s", "array": "a"})
    assert len(fdb.chunk_cache) == 0


def test_bounded_memory_under_sweep(make_fdb):
    """Reading far more data than the budget keeps the cache within its
    byte bound and counts the evictions."""
    chunk_bytes = 16 * 16 * 4
    fdb = make_fdb("daos", chunk_cache_bytes=4 * chunk_bytes)
    ts = TensorStore(fdb, {"store": "s", "array": "a", "writer": "w"})
    x = _field((128, 128), seed=5)
    arr = ts.save(x, chunks=(16, 16))                # 64 chunks
    np.testing.assert_array_equal(arr[:, :], x)
    cache = fdb.chunk_cache
    assert cache.nbytes <= 4 * chunk_bytes
    assert len(cache) <= 4
    assert cache.stats()["evicted_bytes"] > 0
    np.testing.assert_array_equal(arr[:, :], x)      # still correct


def test_rmw_bypasses_cache(make_fdb):
    """Read-modify-write pre-fetches must come from storage, never the
    cache — a stale decoded chunk under an RMW would resurrect old
    bytes into a fresh write."""
    fdb = make_fdb("daos", chunk_cache_bytes=1 << 20)
    ts = TensorStore(fdb, {"store": "s", "array": "a", "writer": "w"})
    x = _field()
    arr = ts.save(x, chunks=(16, 16))
    np.testing.assert_array_equal(arr[:, :], x)      # cache everything
    y = x.copy()
    y[4:12, 4:12] = 9.0                              # partial chunk: RMW
    arr[4:12, 4:12] = np.full((8, 8), 9.0, np.float32)
    fdb.chunk_cache.clear({})
    np.testing.assert_array_equal(arr[:, :], y)


def test_cache_off_by_default_at_fdb(make_fdb):
    fdb = make_fdb("daos")
    assert fdb.chunk_cache is None


# -- consolidated metadata (TreeCatalogue) ----------------------------------

class TestConsolidatedOpen:
    def _mk_store(self, backend, root, **kw):
        from repro.data.pipeline import ChunkedFieldStore
        return ChunkedFieldStore(
            store="nwp", fdb_config=FDBConfig(backend=backend,
                                              schema="tensor", root=root),
            **kw)

    def test_open_tree_single_fetch(self, tmp_path):
        """Opening an N-array tree costs exactly one catalogue fetch:
        the op count of ``open_tree`` equals that of a single raw
        metadata retrieve, independent of how many fields exist."""
        from repro.core import Meter
        root = str(tmp_path / "fdb")
        meter = Meter()
        prod = self._mk_store("posix", root, meter=meter, cache_bytes=0)
        fields = {f"f{i}": _field(seed=i) for i in range(5)}
        for name, values in fields.items():
            prod.put_field(name, values, chunks=(16, 16))
        prod.commit()
        prod.close()
        cons = self._mk_store("posix", root, meter=meter, cache_bytes=0)
        m0 = len(meter.snapshot())
        opened = cons.open_tree()
        tree_ops = len(meter.snapshot()) - m0
        assert set(opened) == set(fields)
        # baseline: ONE raw per-array metadata retrieve on an equally
        # fresh client — the consolidated open must cost the same
        fresh = self._mk_store("posix", root, meter=meter, cache_bytes=0)
        m1 = len(meter.snapshot())
        fresh._ts("f0").open()
        single_ops = len(meter.snapshot()) - m1
        assert tree_ops == single_ops
        for name, values in fields.items():
            np.testing.assert_array_equal(opened[name][:, :], values)
        prod.close(), cons.close(), fresh.close()

    def test_open_field_serves_from_consolidated(self, tmp_path):
        from repro.core import Meter
        root = str(tmp_path / "fdb")
        meter = Meter()
        prod = self._mk_store("daos", root, meter=meter)
        prod.put_field("t2m", _field(), chunks=(16, 16))
        prod.put_field("u10", _field(seed=2), chunks=(16, 16))
        prod.commit()
        cons = self._mk_store("daos", root, meter=meter)
        cons.open_field("t2m")                       # loads the tree once
        m0 = len(meter.snapshot())
        cons.open_field("u10")                       # consolidated hit
        assert len(meter.snapshot()) == m0           # ZERO further ops
        prod.close(), cons.close()

    def test_stale_tree_falls_back_per_array(self, tmp_path):
        """A field the consolidated object does not know (written by a
        client that bypasses the tree) still opens via the authoritative
        per-array metadata."""
        root = str(tmp_path / "fdb")
        store = self._mk_store("rados", root)
        store.put_field("known", _field(), chunks=(16, 16))
        store.commit()
        rogue = FDB(FDBConfig(backend="rados", schema="tensor", root=root))
        TensorStore(rogue, {"store": "nwp", "array": "rogue",
                            "writer": "prod0"}).save(_field(seed=9),
                                                     chunks=(16, 16))
        rogue.close()
        cons = self._mk_store("rados", root)
        assert "rogue" not in cons.open_tree()
        arr = cons.open_field("rogue")               # per-array fallback
        assert arr.shape == (64, 64)
        store.close(), cons.close()

    def test_reshard_updates_tree(self, tmp_path):
        root = str(tmp_path / "fdb")
        prod = self._mk_store("posix", root)
        prod.put_field("t2m", _field(), chunks=(16, 16))
        prod.commit()
        prod.reshard("t2m", (32, 32))
        cons = self._mk_store("posix", root)
        arr = cons.open_tree()["t2m"]
        assert arr.meta.chunks == (32, 32)
        assert arr.meta.generation == 1
        np.testing.assert_array_equal(arr[:, :], _field())
        prod.close(), cons.close()

    def test_wipe_forgets_member_keeps_tree(self, tmp_path):
        root = str(tmp_path / "fdb")
        store = self._mk_store("daos", root)
        store.put_field("a", _field(), chunks=(16, 16))
        store.put_field("b", _field(seed=1), chunks=(16, 16))
        store.commit()
        store.wipe_field("a")
        cons = self._mk_store("daos", root)
        assert sorted(cons.open_tree()) == ["b"]
        store.close(), cons.close()

    def test_catalogue_survives_unrelated_client(self, make_fdb):
        """record() on a fresh client must merge, not clobber, members
        recorded by earlier clients (the load-before-first-record
        rule)."""
        fdb = make_fdb("daos")
        base = {"store": "s", "writer": "w"}
        t1 = TreeCatalogue(fdb, base)
        TensorStore(fdb, {**base, "array": "one"},
                    tree=t1).save(_field(), chunks=(16, 16))
        fdb.flush()
        t2 = TreeCatalogue(fdb, base)                # unloaded mirror
        TensorStore(fdb, {**base, "array": "two"},
                    tree=t2).save(_field(seed=1), chunks=(16, 16))
        fdb.flush()
        t3 = TreeCatalogue(fdb, base)
        assert t3.load() and t3.names() == ["one", "two"]


# -- fancy-selection rejection (satellite) ----------------------------------

class TestFancyIndexingRejected:
    @pytest.fixture
    def arr(self, make_store):
        fdb, ts = make_store("daos")
        return ts.save(_field(), chunks=(16, 16))

    @pytest.mark.parametrize("key", [
        ([0, 2, 4], slice(None)),
        (np.array([0, 1]), slice(None)),
        (slice(None), (1, 2, 3)),
        (np.ones(64, dtype=bool), slice(None)),
    ])
    def test_read_raises_typeerror(self, arr, key):
        with pytest.raises(TypeError, match="fancy"):
            arr[key]

    def test_write_raises_typeerror(self, arr):
        with pytest.raises(TypeError, match="integer-array"):
            arr[[0, 1], :] = np.zeros((2, 64), np.float32)

    def test_reshard_sel_raises_typeerror(self, arr):
        with pytest.raises(TypeError, match="not supported"):
            arr.reshard((8, 8), sel=([0, 1], slice(None)))

    def test_message_names_supported_forms(self, arr):
        with pytest.raises(TypeError, match="integers, slices"):
            arr[{1, 2}, :]

    def test_scalar_ndarray_index_still_works(self, arr):
        """0-d integer arrays quack like ints and stay supported."""
        x = _field()
        np.testing.assert_array_equal(arr[np.int64(3), :], x[3, :])

"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(1, 2, 128, 64), (2, 3, 256, 64),
                                   (1, 1, 128, 128), (2, 2, 512, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, dtype, causal):
    B, H, S, D = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, shape, dtype)
    k = jax.random.normal(k2, shape, dtype)
    v = jax.random.normal(k3, shape, dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_uneven_blocks():
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 384, 64))
    out = ops.flash_attention(q, q, q, causal=True, block_q=128, block_k=128)
    expect = ref.flash_attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("shape", [(256, 128), (512, 256), (1024, 128)])
@pytest.mark.parametrize("bits", [8, 16])
def test_field_codec_roundtrip_bound(shape, bits):
    x = jax.random.normal(jax.random.PRNGKey(2), shape, jnp.float32) * 100
    q, s, m = ops.field_encode(x, block=256, bits=bits)
    y = ops.field_decode(q, s, m, block=256, bits=bits)
    bound = np.asarray(ref.codec_error_bound(x, 256, bits)).max()
    err = float(jnp.max(jnp.abs(y - x)))
    assert err <= bound * 1.05 + 1e-6, (err, bound)
    # vs oracle: quantised codes may differ by 1 ULP-of-scale at rounding
    # boundaries (reduction-order wobble) — never more.
    qr, sr, mr = ref.field_encode_ref(x, block=256, bits=bits)
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)
                               - qr.astype(jnp.int32)))) <= 1
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mr), rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16])
@pytest.mark.parametrize("bits", [8, 16])
def test_field_codec_batched_matches_loop(dtype, bits):
    """A leading batch dim (one launch, grid = fields × blocks) must be
    bit-identical to a Python loop of per-field launches — blocks never
    straddle fields, so per-block (scale, min) pairs cannot differ."""
    x = jax.random.normal(jax.random.PRNGKey(6), (5, 512, 128), dtype) * 50
    qb, sb, mb = ops.field_encode(x, block=256, bits=bits)
    assert qb.shape == (5, 512, 128) and sb.shape == mb.shape == (5, 2)
    yb = ops.field_decode(qb, sb, mb, block=256, bits=bits)
    for i in range(x.shape[0]):
        q, s, m = ops.field_encode(x[i], block=256, bits=bits)
        np.testing.assert_array_equal(np.asarray(qb[i]), np.asarray(q))
        np.testing.assert_array_equal(np.asarray(sb[i]), np.asarray(s))
        np.testing.assert_array_equal(np.asarray(mb[i]), np.asarray(m))
        np.testing.assert_array_equal(
            np.asarray(yb[i]),
            np.asarray(ops.field_decode(q, s, m, block=256, bits=bits)))


def test_field_codec_batched_single_and_sub_block():
    """B=1 batches and fields smaller than the block size (block clips to
    N) stay bit-identical to the 2-D path."""
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 64, 128), jnp.float32)
    qb, sb, mb = ops.field_encode(x, block=256)       # block clips to 64
    q, s, m = ops.field_encode(x[0], block=256)
    np.testing.assert_array_equal(np.asarray(qb[0]), np.asarray(q))
    np.testing.assert_array_equal(np.asarray(sb[0]), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(mb[0]), np.asarray(m))


def test_field_codec_constant_block():
    x = jnp.ones((256, 128), jnp.float32) * 3.14
    q, s, m = ops.field_encode(x)
    y = ops.field_decode(q, s, m)
    np.testing.assert_allclose(np.asarray(y), 3.14, atol=1e-6)


def test_field_codec_compression_ratio():
    x = jax.random.normal(jax.random.PRNGKey(3), (1024, 128), jnp.float32)
    q, s, m = ops.field_encode(x, bits=8)
    packed = q.nbytes + s.nbytes + m.nbytes
    assert packed < x.nbytes / 3.9          # ~4× (byte-granular GRIB target)


@pytest.mark.parametrize("shape", [(256, 128), (512, 512), (128, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(4), shape, dtype)
    scale = (jax.random.normal(jax.random.PRNGKey(5), (shape[1],), dtype)
             * 0.1 + 1.0)
    out = ops.fused_rmsnorm(x, scale, block_rows=128)
    expect = ref.rmsnorm_ref(x, scale)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)

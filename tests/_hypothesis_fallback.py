"""Thin deterministic stand-in for ``hypothesis`` (collection-safe tier-1).

The property tests import ``given``/``settings``/``st`` from here when the
real hypothesis package is unavailable, so the suite collects and runs
everywhere.  Strategies draw deterministic pseudo-random examples from a
seeded ``random.Random``; ``given`` replays ``max_examples`` of them.  This
is *not* a property-testing engine (no shrinking, no coverage guidance) —
just enough surface for the existing tests.
"""
from __future__ import annotations

import os
import random
from typing import Any, Callable, Sequence

#: example stream seed — the suite-wide chaos knob (see tests/conftest.py)
#: so a falsifying example replays with REPRO_TEST_SEED=<printed seed>
_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any]):
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, f: Callable[[Any], Any]) -> "_Strategy":
        return _Strategy(lambda rng: f(self._draw(rng)))


class _DrawProxy:
    """The object handed to tests by ``st.data()``."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy) -> Any:
        return strategy.draw(self._rng)


class _StNamespace:
    @staticmethod
    def text(alphabet: str = "abcdefghijklmnopqrstuvwxyz", min_size: int = 0,
             max_size: int = 10) -> _Strategy:
        chars = list(alphabet)

        def draw(rng: random.Random) -> str:
            n = rng.randint(min_size, max_size)
            return "".join(rng.choice(chars) for _ in range(n))
        return _Strategy(draw)

    @staticmethod
    def integers(min_value: int = 0, max_value: int = 1 << 30) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng: random.Random) -> list:
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*strategies: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    @staticmethod
    def fixed_dictionaries(mapping: dict) -> _Strategy:
        return _Strategy(
            lambda rng: {k: s.draw(rng) for k, s in mapping.items()})

    @staticmethod
    def sampled_from(seq: Sequence) -> _Strategy:
        pool = list(seq)
        return _Strategy(lambda rng: rng.choice(pool))

    @staticmethod
    def sets(elements: _Strategy, min_size: int = 0,
             max_size: int = 10) -> _Strategy:
        def draw(rng: random.Random) -> set:
            want = rng.randint(min_size, max_size)
            out: set = set()
            for _ in range(want * 8 + 8):     # finite pools may be < want
                if len(out) >= want:
                    break
                out.add(elements.draw(rng))
            return out
        return _Strategy(draw)

    @staticmethod
    def data() -> _Strategy:
        return _Strategy(lambda rng: _DrawProxy(rng))


st = _StNamespace()


def settings(max_examples: int = 20, **_kw) -> Callable:
    def deco(fn: Callable) -> Callable:
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies: _Strategy) -> Callable:
    def deco(fn: Callable) -> Callable:
        # NB: deliberately no functools.wraps — pytest must see the wrapper's
        # (*args) signature, not the test's drawn-argument parameters, or it
        # would try to resolve them as fixtures.
        def wrapper(*args, **kw):
            rng = random.Random(_SEED)
            n = getattr(wrapper, "_fallback_max_examples", 20)
            for _ in range(n):
                fn(*args, *(s.draw(rng) for s in strategies), **kw)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._fallback_max_examples = getattr(fn, "_fallback_max_examples",
                                                 20)
        return wrapper
    return deco

"""Backend conformance: the FDB API semantics (§2.7) on every backend."""
import os
import threading

import numpy as np
import pytest

from repro.core import FDB, FDBConfig, Identifier

BACKENDS = ["daos", "rados", "s3", "posix"]


def make_fdb(backend, tmp_path, **kw):
    schema = "nwp-posix" if backend == "posix" else "nwp-object"
    return FDB(FDBConfig(backend=backend, schema=schema,
                         root=str(tmp_path / "fdb"), **kw))


@pytest.mark.parametrize("backend", BACKENDS)
def test_archive_flush_retrieve(backend, tmp_path, nwp_identifier):
    fdb = make_fdb(backend, tmp_path)
    data = os.urandom(4096)
    fdb.archive(nwp_identifier, data)
    fdb.flush()
    assert fdb.retrieve(nwp_identifier).read() == data


@pytest.mark.parametrize("backend", BACKENDS)
def test_retrieve_absent_is_not_error(backend, tmp_path, nwp_identifier):
    fdb = make_fdb(backend, tmp_path)
    handle = fdb.retrieve(nwp_identifier)
    assert handle.length() == 0 and handle.read() == b""


@pytest.mark.parametrize("backend", BACKENDS)
def test_list_and_axes(backend, tmp_path, nwp_identifier):
    fdb = make_fdb(backend, tmp_path)
    for step in ("1", "2", "3"):
        fdb.archive({**nwp_identifier, "step": step}, b"x" * 128)
    fdb.flush()
    listed = list(fdb.list({"class": "od", "date": "20231201"}))
    assert len(listed) == 3
    assert {i["step"] for i, _ in listed} == {"1", "2", "3"}
    assert fdb.axes(nwp_identifier, "step") == frozenset({"1", "2", "3"})


@pytest.mark.parametrize("backend", BACKENDS)
def test_replace_semantics(backend, tmp_path, nwp_identifier):
    """Rule 5: re-archiving an identifier transactionally replaces."""
    fdb = make_fdb(backend, tmp_path)
    fdb.archive(nwp_identifier, b"old" * 100)
    fdb.flush()
    fdb.archive(nwp_identifier, b"new" * 100)
    fdb.flush()
    assert fdb.retrieve(nwp_identifier).read() == b"new" * 100
    listed = list(fdb.list(dict(nwp_identifier)))
    assert len(listed) == 1


def test_posix_invisible_before_flush(tmp_path, nwp_identifier):
    """POSIX backend: buffered data must not be visible pre-flush (§2.7.2)."""
    writer = make_fdb("posix", tmp_path)
    writer.archive(nwp_identifier, b"z" * 1024)
    reader = make_fdb("posix", tmp_path)
    assert reader.retrieve(nwp_identifier).length() == 0
    writer.flush()
    reader2 = make_fdb("posix", tmp_path)
    assert reader2.retrieve(nwp_identifier).read() == b"z" * 1024


@pytest.mark.parametrize("backend", ["daos", "rados", "s3"])
def test_object_stores_visible_on_archive(backend, tmp_path, nwp_identifier):
    """DAOS/RADOS/S3 persist immediately (§3.1.1/§3.2/§3.3)."""
    writer = make_fdb(backend, tmp_path)
    writer.archive(nwp_identifier, b"q" * 512)
    reader = make_fdb(backend, tmp_path)
    assert reader.retrieve(nwp_identifier).read() == b"q" * 512


def test_posix_close_masks_subtocs(tmp_path, nwp_identifier):
    """After close(), readers use full indexes; data unchanged (§2.7.2)."""
    writer = make_fdb("posix", tmp_path)
    for step in ("1", "2"):
        writer.archive({**nwp_identifier, "step": step}, step.encode() * 64)
        writer.flush()
    writer.close()
    reader = make_fdb("posix", tmp_path)
    assert reader.retrieve({**nwp_identifier, "step": "2"}).read() == b"2" * 64
    assert len(list(reader.list({"class": "od"}))) == 2
    # TOC contains mask entries
    ds = [d for d in os.listdir(tmp_path / "fdb")][0]
    from repro.core.backends.posix import _read_records
    recs = _read_records(str(tmp_path / "fdb" / ds / "toc"))
    assert any(r.get("type") == "TOC_MASK" for r in recs)


@pytest.mark.parametrize("backend", BACKENDS)
def test_wipe(backend, tmp_path, nwp_identifier):
    fdb = make_fdb(backend, tmp_path)
    fdb.archive(nwp_identifier, b"a" * 64)
    fdb.flush()
    fdb.wipe({k: nwp_identifier[k]
              for k in ("class", "expver", "stream", "date", "time")})
    fresh = make_fdb(backend, tmp_path)
    assert fresh.retrieve(nwp_identifier).length() == 0


@pytest.mark.parametrize("backend", ["daos", "rados"])
def test_concurrent_writers_consistent_index(backend, tmp_path,
                                             nwp_identifier):
    """fdb-hammer consistency check: N threads archive disjoint identifier
    ranges; every archived object must be listable and retrievable."""
    fdb = make_fdb(backend, tmp_path)
    n_threads, n_fields = 4, 20
    errors = []

    def writer(tid):
        try:
            for i in range(n_fields):
                ident = {**nwp_identifier, "number": str(tid),
                         "step": str(i)}
                fdb.archive(ident, f"{tid}:{i}".encode() * 16)
            fdb.flush()
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    listed = list(fdb.list({"class": "od"}))
    assert len(listed) == n_threads * n_fields
    for tid in range(n_threads):
        for i in range(n_fields):
            got = fdb.retrieve({**nwp_identifier, "number": str(tid),
                                "step": str(i)}).read()
            assert got == f"{tid}:{i}".encode() * 16


@pytest.mark.parametrize("backend", ["daos", "rados"])
def test_write_read_contention(backend, tmp_path, nwp_identifier):
    """The operational NWP pattern: a reader concurrently retrieving while
    the writer archives; reader must only ever see complete objects."""
    fdb = make_fdb(backend, tmp_path)
    payload = {i: os.urandom(512) for i in range(30)}
    seen = {}
    stop = threading.Event()

    def reader():
        r = make_fdb(backend, tmp_path)
        while not stop.is_set():
            for i in range(30):
                h = r.retrieve({**nwp_identifier, "step": str(i)})
                if h.length():
                    data = h.read()
                    seen.setdefault(i, data)
                    assert data == payload[i], f"partial object step {i}"

    t = threading.Thread(target=reader)
    t.start()
    for i in range(30):
        fdb.archive({**nwp_identifier, "step": str(i)}, payload[i])
        fdb.flush()
    stop.set()
    t.join()
    # final read finds everything
    r = make_fdb(backend, tmp_path)
    for i in range(30):
        assert r.retrieve({**nwp_identifier, "step": str(i)}).read() \
            == payload[i]


def test_rados_object_size_limit(tmp_path, nwp_identifier):
    """RADOS rejects objects above the size limit (§2.4); span mode chains
    multiple objects instead."""
    from repro.core.engine.rados import RadosApiError
    small = FDB(FDBConfig(backend="rados", schema="nwp-object",
                          rados_max_object_size=1024))
    with pytest.raises(RadosApiError):
        small.archive(nwp_identifier, b"x" * 4096)


def test_rados_span_mode_chains_objects(tmp_path, nwp_identifier):
    fdb = FDB(FDBConfig(backend="rados", schema="nwp-object",
                        rados_object_mode="span",
                        rados_max_object_size=1024))
    units = set()
    for i in range(8):
        loc = fdb.archive({**nwp_identifier, "step": str(i)}, b"y" * 512)
        units.add(loc.unit)
    fdb.flush()
    assert len(units) >= 4      # 512B fields, 1 KiB limit → ≥4 objects
    for i in range(8):
        assert fdb.retrieve({**nwp_identifier, "step": str(i)}).read() \
            == b"y" * 512


def test_s3_store_uses_daos_catalogue(tmp_path, nwp_identifier):
    """S3 has no conforming catalogue (§3.3) — pairs with the DAOS one."""
    fdb = make_fdb("s3", tmp_path)
    assert fdb.store.scheme == "s3"
    assert fdb.catalogue.scheme == "daos"
    loc = fdb.archive(nwp_identifier, b"s3data")
    assert loc.scheme == "s3"
    assert fdb.retrieve(nwp_identifier).read() == b"s3data"

"""DataHandle merging (the POSIX read-coalescing optimisation, §2.7.1)."""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # thin deterministic fallback
    from _hypothesis_fallback import given, settings, st

import pytest

from repro.core.handle import (FileRangeHandle, MemoryHandle, MultiHandle,
                               ShortReadError, group_mergeable)


def _mem_reader(blob):
    def reader(unit, offset, length):
        return blob[offset:offset + length]
    return reader


def test_adjacent_ranges_coalesce():
    blob = bytes(range(256)) * 4
    reader = _mem_reader(blob)
    h1 = FileRangeHandle.single(reader, "f", 0, 100)
    h2 = FileRangeHandle.single(reader, "f", 100, 50)
    h3 = FileRangeHandle.single(reader, "f", 200, 24)
    assert h1.mergeable_with(h2)
    merged = h1.merged(h2).merged(h3)
    assert merged.read_ops() == 2          # [0,150) + [200,224)
    assert merged.read() == blob[0:150] + blob[200:224]


def test_different_units_do_not_merge():
    r = _mem_reader(b"x" * 64)
    h1 = FileRangeHandle.single(r, "a", 0, 8)
    h2 = FileRangeHandle.single(r, "b", 8, 8)
    assert not h1.mergeable_with(h2)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 900), st.integers(1, 100)),
                min_size=1, max_size=12))
def test_multihandle_preserves_order_and_content(ranges):
    blob = np.random.default_rng(0).integers(0, 255, 1024, np.uint8).tobytes()
    reader = _mem_reader(blob)
    handles = [FileRangeHandle.single(reader, "f", off, ln)
               for off, ln in ranges]
    mh = MultiHandle(handles)
    expect = b"".join(blob[o:o + n] for o, n in ranges)
    assert mh.read() == expect
    parts = mh.read_parts()
    assert parts == [blob[o:o + n] for o, n in ranges]
    assert mh.read_ops() <= len(ranges)    # merging never adds ops


def test_short_read_raises_instead_of_dropping_bytes():
    """A reader returning fewer bytes than a range needs (file truncated /
    data not yet flushed) must raise, never silently return short data."""
    blob = b"x" * 64                       # file is only 64 bytes long

    def reader(unit, offset, length):
        return blob[offset:offset + length]

    h = FileRangeHandle.single(reader, "f", 32, 64)   # runs past EOF
    with pytest.raises(ShortReadError):
        h.read()
    # a fully covered range on the same file still reads fine
    assert FileRangeHandle.single(reader, "f", 32, 32).read() == b"x" * 32


def test_group_mergeable_groups_by_unit_not_adjacency():
    r = _mem_reader(bytes(range(256)))
    handles = [
        FileRangeHandle.single(r, "a", 0, 8),
        MemoryHandle(b"zz"),
        FileRangeHandle.single(r, "b", 0, 8),
        FileRangeHandle.single(r, "a", 8, 8),   # same unit, not consecutive
    ]
    assert group_mergeable(handles) == [[0, 3], [1], [2]]
    assert group_mergeable([]) == []


def test_multihandle_mixed_backends():
    blob = b"0123456789" * 10
    mh = MultiHandle([
        MemoryHandle(b"AAA"),
        FileRangeHandle.single(_mem_reader(blob), "f", 0, 10),
        FileRangeHandle.single(_mem_reader(blob), "f", 10, 10),
    ])
    assert mh.read() == b"AAA" + blob[:20]
    assert mh.read_ops() == 2              # memory + one coalesced file read

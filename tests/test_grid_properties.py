"""Property tests for the chunk-grid index math.

The grid layer (`repro.tensorstore.grid`, `reshard.chunk_rectangles`) is
pure geometry, so instead of hand-picked shapes we sweep randomised
grids/selections and assert the *laws* the rest of the stack leans on:

- ``normalize_read_key`` + ``intersecting`` reassemble exactly what numpy
  fancy indexing returns — for strided, reversed, truncated and integer
  keys alike — touching every output point exactly once;
- ``normalize_key`` emits tight positive-step slices whose compact shape
  matches numpy's;
- ``linear_id`` is the row-major bijection the lease table's ``[lo, hi)``
  chunk-id ranges assume;
- ``merge_id_ranges`` produces the minimal disjoint cover of a chunk set;
- ``chunk_rectangles`` partitions a grid into ≤window-sized rectangles;
- ``write_plan``'s ``full`` flag is exact (a wrong True would skip a
  required read-modify-write and destroy bytes).

Runs under real hypothesis when installed (CI) and under the seeded
deterministic shim in ``_hypothesis_fallback`` otherwise.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                              # pragma: no cover
    from _hypothesis_fallback import given, settings, st

from repro.tensorstore.grid import ChunkGrid, merge_id_ranges
from repro.tensorstore.reshard import chunk_rectangles


def draw_grid(data, min_dim=0):
    ndim = data.draw(st.integers(min_value=1, max_value=3))
    shape = tuple(data.draw(st.integers(min_value=min_dim, max_value=9))
                  for _ in range(ndim))
    chunks = tuple(data.draw(st.integers(min_value=1, max_value=6))
                   for _ in range(ndim))
    return ChunkGrid(shape, chunks)


def draw_key(data, grid, allow_neg_step=True, allow_int=True):
    """A random ``__getitem__`` key: per-axis full/strided/reversed slices
    or integer indices, with trailing axes optionally omitted."""
    key = []
    for size in grid.shape:
        kinds = ["full", "slice", "strided"]
        if allow_int and size:
            kinds.append("int")
        kind = data.draw(st.sampled_from(kinds))
        if kind == "full":
            key.append(slice(None))
        elif kind == "int":
            key.append(data.draw(st.integers(min_value=-size,
                                             max_value=size - 1)))
        else:
            a = data.draw(st.integers(min_value=-size - 2, max_value=size + 2))
            b = data.draw(st.integers(min_value=-size - 2, max_value=size + 2))
            lo = 2 if kind == "strided" else 1
            step = data.draw(st.integers(min_value=lo, max_value=4))
            if allow_neg_step and data.draw(st.integers(min_value=0,
                                                        max_value=1)):
                step = -step
            key.append(slice(a, b, step))
    n = data.draw(st.integers(min_value=1, max_value=len(key)))
    return tuple(key[:n])


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_read_key_reassembles_numpy_exactly_once(data):
    grid = draw_grid(data)
    key = draw_key(data, grid)
    arr = np.arange(max(1, int(np.prod(grid.shape))),
                    dtype=np.int64)[:int(np.prod(grid.shape))]
    arr = arr.reshape(grid.shape)
    sel, squeeze, flips = grid.normalize_read_key(key)
    out = np.empty(grid.selection_shape(sel), dtype=arr.dtype)
    seen = np.zeros(out.shape, dtype=np.int32)
    for idx, chunk_sel, out_sel in grid.intersecting(sel):
        out[out_sel] = arr[grid.chunk_slices(idx)][chunk_sel]
        seen[out_sel] += 1
    assert (seen == 1).all()         # every output point scattered once
    for ax in flips:
        out = np.flip(out, axis=ax)
    if squeeze:
        out = out.reshape(tuple(s for ax, s in enumerate(out.shape)
                                if ax not in squeeze))
    np.testing.assert_array_equal(out, arr[key])


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_normalize_key_emits_tight_positive_slices(data):
    grid = draw_grid(data)
    key = draw_key(data, grid, allow_neg_step=False)
    sel, squeeze = grid.normalize_key(key)
    assert len(sel) == grid.ndim
    for s, size in zip(sel, grid.shape):
        assert s.step >= 1
        assert 0 <= s.start <= s.stop <= size
        pts = range(s.start, s.stop, s.step)
        if len(pts):
            # stop is normalised to last-selected-point + 1
            assert s.stop == pts[-1] + 1
        else:
            assert s.stop == s.start
    compact = tuple(n for ax, n in enumerate(grid.selection_shape(sel))
                    if ax not in squeeze)
    assert compact == np.empty(grid.shape, dtype=np.int8)[key].shape


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_linear_id_is_the_row_major_bijection(data):
    grid = draw_grid(data)
    ids = [grid.linear_id(idx) for idx in grid.all_indices()]
    # row-major iteration must enumerate ids 0..count-1 in order — the
    # contiguity that lets a row band lease as one [lo, hi) range
    assert ids == list(range(grid.chunk_count))


@settings(max_examples=80, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=40), max_size=30))
def test_merge_id_ranges_minimal_disjoint_cover(ids):
    ranges = merge_id_ranges(ids)
    union, prev_hi = set(), None
    for lo, hi in ranges:
        assert lo < hi
        if prev_hi is not None:
            assert lo > prev_hi      # sorted, disjoint AND non-adjacent
        union.update(range(lo, hi))
        prev_hi = hi
    assert union == set(ids)


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_chunk_rectangles_partition_within_window(data):
    ndim = data.draw(st.integers(min_value=1, max_value=3))
    n_chunks = tuple(data.draw(st.integers(min_value=1, max_value=5))
                     for _ in range(ndim))
    window = data.draw(st.integers(min_value=1, max_value=30))
    count = np.zeros(n_chunks, dtype=np.int32)
    for rect in chunk_rectangles(n_chunks, window):
        size = 1
        slc = []
        for (lo, hi), n in zip(rect, n_chunks):
            assert 0 <= lo < hi <= n
            size *= hi - lo
            slc.append(slice(lo, hi))
        assert size <= window        # one batch fits one reshard window
        count[tuple(slc)] += 1
    assert (count == 1).all()        # exact partition: no gap, no overlap


@settings(max_examples=60, deadline=None)
@given(st.data())
def test_write_plan_full_flag_is_exact(data):
    grid = draw_grid(data)
    key = draw_key(data, grid, allow_neg_step=False)
    sel, _ = grid.normalize_key(key)
    for idx, chunk_sel, _val_sel, full in grid.write_plan(sel):
        covered = np.zeros(grid.chunk_shape(idx), dtype=bool)
        covered[chunk_sel] = True
        # a false positive here would skip the read-modify-write and
        # destroy the chunk's unselected bytes
        assert full == bool(covered.all())

"""Operational NWP workflow scenarios (repro.workflows).

Tier-1 keeps the quick scenarios: one small clean cycle, the determinism
contract across fresh deployments, the lease-contention accounting, the
window/toy-model helpers, and one posix chaos gate.  The full
cross-backend matrix and per-backend chaos gates are ``workflow``-marked
(excluded from tier-1 by ``pytest.ini``; CI runs them as a dedicated
step with ``-m workflow``).
"""
import dataclasses
import hashlib

import numpy as np
import pytest

from repro.workflows import (ChaosSchedule, NWPCycle, WorkflowConfig,
                             analysis_truth, assimilation_windows,
                             forecast_states, run_chaos_gate, step_model)


def small_config(tmp_path, backend="posix", **kw):
    kw.setdefault("shape", (32, 32))
    kw.setdefault("chunks", (8, 8))
    kw.setdefault("n_writers", 3)
    kw.setdefault("halo", 3)
    kw.setdefault("leads", 2)
    kw.setdefault("n_shards", 2)
    kw.setdefault("n_readers", 4)
    kw.setdefault("reads_per_reader", 4)
    return WorkflowConfig(backend=backend, root=str(tmp_path / "fdb"), **kw)


# ---------------------------------------------------------------------------
# stage-model helpers (pure functions)
# ---------------------------------------------------------------------------

def test_assimilation_windows_cover_grid_with_overlap():
    cfg = WorkflowConfig(shape=(64, 64), n_writers=4, halo=4)
    windows = assimilation_windows(cfg)
    assert len(windows) == 4
    covered = np.zeros(64, dtype=np.int32)
    for lo, hi in windows:
        assert 0 <= lo < hi <= 64
        covered[lo:hi] += 1
    assert (covered >= 1).all()              # no gap
    # halo rows really are contested: neighbours share 2*halo rows
    for (lo_a, hi_a), (lo_b, hi_b) in zip(windows, windows[1:]):
        assert hi_a - lo_b == 2 * cfg.halo


def test_truth_and_forecast_states_are_seed_deterministic():
    a = WorkflowConfig(seed=7)
    b = WorkflowConfig(seed=7)
    assert np.array_equal(analysis_truth(a), analysis_truth(b))
    assert not np.array_equal(analysis_truth(a),
                              analysis_truth(WorkflowConfig(seed=8)))
    states = forecast_states(a)
    assert len(states) == a.leads + 1
    assert np.array_equal(states[1], step_model(states[0], a.dt))
    assert all(s.dtype == np.float32 for s in states)


# ---------------------------------------------------------------------------
# quick scenarios (tier-1)
# ---------------------------------------------------------------------------

def test_small_cycle_runs_clean(tmp_path):
    report = NWPCycle(small_config(tmp_path)).run()
    assert report.clean, report.protocol_violations
    assert report.lost_chunks == 0
    assert report.ckpt_roundtrip
    # every field digest matches the locally recomputed expected state
    cfg = small_config(tmp_path)
    for name, state in zip(cfg.field_names(), forecast_states(cfg)):
        assert report.digests[name] == hashlib.sha256(
            state.tobytes()).hexdigest()
    assert report.products_digest
    for stage in ("assimilation", "forecast", "products"):
        assert report.stages[stage].wall_s > 0
        assert report.stages[stage].tasks > 0
        assert report.stages[stage].nbytes > 0


def test_assimilation_contention_is_accounted(tmp_path):
    """Every writer runs with a blocking lease posture, so the
    ``lease.wait_us`` histogram records each plan-time acquire — the
    contention column the bench reports must be live."""
    report = NWPCycle(small_config(tmp_path, n_writers=4, halo=6)).run()
    assert report.clean
    stats = report.stages["assimilation"]
    assert stats.lease_waits > 0
    assert stats.lease_wait_us >= 0.0
    assert report.lease_wait.get("count", 0) >= stats.lease_waits


def test_cycle_is_deterministic_across_deployments(tmp_path):
    """The determinism contract: equal configs on two *fresh* deployments
    produce byte-identical fields and products digests, regardless of
    thread scheduling."""
    a = NWPCycle(small_config(tmp_path / "a", backend="daos", seed=42)).run()
    b = NWPCycle(small_config(tmp_path / "b", backend="daos", seed=42)).run()
    assert a.clean and b.clean
    assert a.digests == b.digests
    c = NWPCycle(small_config(tmp_path / "c", backend="daos", seed=43)).run()
    assert c.digests["analysis"] != a.digests["analysis"]


def test_chaos_gate_posix(tmp_path):
    """The headline robustness claim, tier-1 sized: the chaos run (fault
    schedule + mid-cycle writer crash + recovery) must be byte-identical
    to the fault-free run with zero lost chunks."""
    result = run_chaos_gate(small_config(tmp_path))
    assert result.ok, result.failures
    assert result.chaos.crashed_writer is not None
    assert result.chaos.faults_injected > 0
    assert result.chaos.recovery["orphan_chunks"] >= 0
    assert result.chaos.recovery["clean_after"]


# ---------------------------------------------------------------------------
# full matrix (workflow-marked; CI runs with -m workflow)
# ---------------------------------------------------------------------------

@pytest.mark.workflow
def test_cycle_all_backends(backend, tmp_path):
    report = NWPCycle(small_config(tmp_path, backend=backend,
                                   shape=(48, 48), chunks=(16, 16),
                                   n_writers=4, halo=4, leads=3,
                                   n_readers=6, reads_per_reader=6)).run()
    assert report.clean, report.protocol_violations
    assert report.lost_chunks == 0
    assert report.ckpt_roundtrip
    assert report.stages["assimilation"].lease_waits > 0


@pytest.mark.workflow
def test_cycle_rerun_same_deployment_is_identical(backend, tmp_path):
    """Same deployment, two dataset namespaces: digests must agree —
    namespace isolation plus determinism."""
    cfg = small_config(tmp_path, backend=backend)
    a = NWPCycle(dataclasses.replace(cfg, store="wf-a")).run()
    b = NWPCycle(dataclasses.replace(cfg, store="wf-b")).run()
    assert a.clean and b.clean
    assert a.digests == b.digests


@pytest.mark.workflow
def test_chaos_gate_all_backends(backend, tmp_path):
    result = run_chaos_gate(small_config(tmp_path, backend=backend),
                            ChaosSchedule(seed=3, crash_writer=1))
    assert result.ok, result.failures
    assert result.chaos.crashed_writer == 1

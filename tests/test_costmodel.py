"""Cost-model invariants: the paper's qualitative claims must hold."""
import os

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # thin deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import (FDB, FDBConfig, Meter, PROFILES, client_context,
                        model_run, reset_engines)


def _write_trace(backend, n_nodes, n_procs, n_fields, field_kb=1024,
                 shared_collocation=False, **cfg_kw):
    meter = Meter()
    reset_engines()
    schema = "nwp-posix" if shared_collocation else "nwp-object"
    fdb = FDB(FDBConfig(backend=backend, schema=schema,
                        root=f"/tmp/fdbcm-{os.getpid()}-{backend}-{n_nodes}",
                        **cfg_kw), meter=meter)
    data = os.urandom(field_kb * 1024)
    for node in range(n_nodes):
        for proc in range(n_procs):
            with client_context(f"proc{proc}@node{node}"):
                for i in range(n_fields):
                    fdb.archive({"class": "od", "expver": "1",
                                 "stream": "oper", "date": "20240101",
                                 "time": "0", "type": "fc", "levtype": "sfc",
                                 "number": str(node), "levelist": str(proc),
                                 "step": str(i), "param": "t"}, data)
                fdb.flush()
    fdb.close()
    return meter


def test_daos_write_bw_scales_with_servers():
    """Claim C1: DAOS bandwidth scales near-linearly with server nodes."""
    m = _write_trace("daos", n_nodes=8, n_procs=4, n_fields=10)
    bw = []
    for servers in (2, 4, 8):
        r = model_run(m.snapshot(), PROFILES["gcp"], server_nodes=servers)
        bw.append(r.write_bw)
    assert bw[1] > bw[0] * 1.5
    assert bw[2] > bw[1] * 1.5


def test_daos_faster_than_rados_like_for_like():
    """Claim C2: Ceph suitable but slower than DAOS on the same workload."""
    daos = _write_trace("daos", 4, 4, 10)
    rados = _write_trace("rados", 4, 4, 10)
    bd = model_run(daos.snapshot(), PROFILES["gcp"], server_nodes=4)
    br = model_run(rados.snapshot(), PROFILES["gcp"], server_nodes=4)
    assert bd.write_bw > br.write_bw


def test_small_objects_hit_op_rate():
    """Claim C6: KiB-sized objects are op-rate/latency bound, and DAOS
    sustains much higher rates than Ceph."""
    daos = _write_trace("daos", 4, 4, 40, field_kb=1)
    rados = _write_trace("rados", 4, 4, 40, field_kb=1)
    rd = model_run(daos.snapshot(), PROFILES["gcp"], server_nodes=4)
    rr = model_run(rados.snapshot(), PROFILES["gcp"], server_nodes=4)
    assert rr.dominant in ("latency", "op_rate")
    assert rd.write_bw > 2 * rr.write_bw


def test_hotspot_schema_penalty_on_daos():
    """Claim C7: sharing one collocation key across many writers serializes
    index KV commits; the object schema removes the hot spot."""
    hot = _write_trace("daos", 8, 8, 10, shared_collocation=True)
    cool = _write_trace("daos", 8, 8, 10, shared_collocation=False)
    rh = model_run(hot.snapshot(), PROFILES["gcp"], server_nodes=8)
    rc = model_run(cool.snapshot(), PROFILES["gcp"], server_nodes=8)
    assert rh.terms["hotspot"] > 4 * rc.terms["hotspot"]


def test_replication_halves_write_bandwidth():
    """Claim C5: 2× replication ≈ half the write bandwidth (server bound)."""
    plain = _write_trace("rados", 8, 8, 10)
    repl = _write_trace("rados", 8, 8, 10, rados_replication=2)
    rp = model_run(plain.snapshot(), PROFILES["gcp"], server_nodes=4)
    rr = model_run(repl.snapshot(), PROFILES["gcp"], server_nodes=4)
    assert rr.write_bw < 0.7 * rp.write_bw


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 32), st.integers(1, 8))
def test_model_run_invariants(servers, procs):
    meter = Meter()
    reset_engines()
    fdb = FDB(FDBConfig(backend="daos"), meter=meter)
    for p in range(procs):
        with client_context(f"proc{p}@node0"):
            fdb.archive({"class": "od", "expver": "1", "stream": "o",
                         "date": "1", "time": "0", "type": "fc",
                         "levtype": "sfc", "number": "0",
                         "levelist": str(p), "step": "0", "param": "t"},
                        b"x" * 1024)
    r = model_run(meter.snapshot(), PROFILES["gcp"], server_nodes=servers)
    assert r.wall_time > 0
    assert r.write_bw >= 0
    assert r.dominant in r.terms
    assert all(v >= 0 for v in r.terms.values())

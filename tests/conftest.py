import os

import pytest

from repro.core import FDB, FDBConfig, reset_engines
from repro.core.engine.meter import GLOBAL_METER
from repro.obs.trace import GLOBAL_TRACER
from repro.tensorstore import TensorStore

#: the four simulated deployments every cross-backend suite sweeps —
#: hoisted here so test modules share one parametrization (the `backend`
#: fixture) instead of each carrying its own copy
BACKENDS = ("daos", "rados", "posix", "s3")

#: one knob reproduces any chaos failure: the seed below feeds
#: FaultInjector coin flips and RetryPolicy jitter in the fault/workflow
#: suites, and is printed in the pytest header — rerun with
#: REPRO_TEST_SEED=<printed value> to replay the exact schedule
TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))


def pytest_report_header(config):
    return (f"REPRO_TEST_SEED={TEST_SEED} "
            f"(chaos jitter seed; set the env var to reproduce)")


@pytest.fixture(params=BACKENDS)
def backend(request):
    """Sweep all four simulated backends.  A test needing a subset
    overrides with ``@pytest.mark.parametrize("backend", [...])``."""
    return request.param


@pytest.fixture
def test_seed():
    """The suite-wide chaos seed (``REPRO_TEST_SEED``, default 0)."""
    return TEST_SEED


@pytest.fixture
def make_fdb(tmp_path):
    """Factory for FDB clients on this test's private deployment root.
    Config kwargs (``io_parallelism=...``) flow to :class:`FDBConfig`;
    ``faults``/``retry``/``tracer`` flow to the client."""
    def _make(backend, schema="tensor", *, faults=None, retry=None,
              tracer=None, **cfg_kw):
        cfg_kw.setdefault("root", str(tmp_path / "fdb"))
        return FDB(FDBConfig(backend=backend, schema=schema, **cfg_kw),
                   faults=faults, retry=retry, tracer=tracer)
    return _make


@pytest.fixture
def make_store(make_fdb):
    """Factory for ``(fdb, TensorStore)`` pairs on the shared test
    deployment — the tensorstore suite's idiom."""
    def _make(backend, array="a", writer="w0", **kw):
        fdb = make_fdb(backend, **kw)
        return fdb, TensorStore(fdb, {"store": "s", "array": array,
                                      "writer": writer})
    return _make


@pytest.fixture(autouse=True)
def fresh_engines():
    """Each test gets pristine in-process storage engines + meter, and a
    disabled, empty global tracer."""
    reset_engines()
    GLOBAL_METER.reset()
    GLOBAL_TRACER.disable()
    GLOBAL_TRACER.clear()
    yield
    reset_engines()
    GLOBAL_METER.reset()
    GLOBAL_TRACER.disable()
    GLOBAL_TRACER.clear()


#: modules whose tests run under the dynamic protocol sanitizer
#: (repro.analysis.protocol).  The tracer is force-enabled only for the
#: lease suite — test_obs asserts the disabled-by-default contract, so
#: there the guard still records lock order but sees no spans.
_PROTOCOL_GUARDED = {"test_leases", "test_obs"}
_TRACED = {"test_leases"}


@pytest.fixture(autouse=True)
def protocol_check(request, fresh_engines):
    """Replay every guarded test's trace window through the concurrency
    protocol checker and fail on any contract violation (archive without
    a live lease, release-before-flush, stale RMW, lock-order cycles,
    executor over window)."""
    module = request.module.__name__.rpartition(".")[2]
    if module not in _PROTOCOL_GUARDED:
        yield
        return
    from repro.analysis.protocol import protocol_guard
    if module in _TRACED:
        GLOBAL_TRACER.enable()
    with protocol_guard(GLOBAL_TRACER):
        yield


@pytest.fixture
def nwp_identifier():
    return {
        "class": "od", "expver": "0001", "stream": "oper",
        "date": "20231201", "time": "1200", "type": "ef", "levtype": "sfc",
        "step": "1", "number": "13", "levelist": "1", "param": "v",
    }

import pytest

from repro.core import reset_engines
from repro.core.engine.meter import GLOBAL_METER
from repro.obs.trace import GLOBAL_TRACER


@pytest.fixture(autouse=True)
def fresh_engines():
    """Each test gets pristine in-process storage engines + meter, and a
    disabled, empty global tracer."""
    reset_engines()
    GLOBAL_METER.reset()
    GLOBAL_TRACER.disable()
    GLOBAL_TRACER.clear()
    yield
    reset_engines()
    GLOBAL_METER.reset()
    GLOBAL_TRACER.disable()
    GLOBAL_TRACER.clear()


#: modules whose tests run under the dynamic protocol sanitizer
#: (repro.analysis.protocol).  The tracer is force-enabled only for the
#: lease suite — test_obs asserts the disabled-by-default contract, so
#: there the guard still records lock order but sees no spans.
_PROTOCOL_GUARDED = {"test_leases", "test_obs"}
_TRACED = {"test_leases"}


@pytest.fixture(autouse=True)
def protocol_check(request, fresh_engines):
    """Replay every guarded test's trace window through the concurrency
    protocol checker and fail on any contract violation (archive without
    a live lease, release-before-flush, stale RMW, lock-order cycles,
    executor over window)."""
    module = request.module.__name__.rpartition(".")[2]
    if module not in _PROTOCOL_GUARDED:
        yield
        return
    from repro.analysis.protocol import protocol_guard
    if module in _TRACED:
        GLOBAL_TRACER.enable()
    with protocol_guard(GLOBAL_TRACER):
        yield


@pytest.fixture
def nwp_identifier():
    return {
        "class": "od", "expver": "0001", "stream": "oper",
        "date": "20231201", "time": "1200", "type": "ef", "levtype": "sfc",
        "step": "1", "number": "13", "levelist": "1", "param": "v",
    }

import pytest

from repro.core import reset_engines
from repro.core.engine.meter import GLOBAL_METER
from repro.obs.trace import GLOBAL_TRACER


@pytest.fixture(autouse=True)
def fresh_engines():
    """Each test gets pristine in-process storage engines + meter, and a
    disabled, empty global tracer."""
    reset_engines()
    GLOBAL_METER.reset()
    GLOBAL_TRACER.disable()
    GLOBAL_TRACER.clear()
    yield
    reset_engines()
    GLOBAL_METER.reset()
    GLOBAL_TRACER.disable()
    GLOBAL_TRACER.clear()


@pytest.fixture
def nwp_identifier():
    return {
        "class": "od", "expver": "0001", "stream": "oper",
        "date": "20231201", "time": "1200", "type": "ef", "levtype": "sfc",
        "step": "1", "number": "13", "levelist": "1", "param": "v",
    }

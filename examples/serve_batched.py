"""Serve a small model with batched requests (continuous-batching slots),
weights restored from an FDB checkpoint.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import FDBConfig
from repro.models import lm
from repro.serve import Request, ServeEngine
from repro.train.checkpoint import FDBCheckpointer

cfg = get_smoke_config("tinyllama-1.1b")
params = lm.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

# stage weights through the FDB (as a serving fleet would)
ck = FDBCheckpointer("serve-weights", FDBConfig(backend="daos"))
ck.save(0, params)
_, params = ck.restore_latest(params)
print("weights staged + restored through FDB")

engine = ServeEngine(cfg, params, batch_slots=4, max_len=64)
rng = np.random.default_rng(7)
n_requests = 10
for rid in range(n_requests):
    plen = int(rng.integers(4, 12))
    engine.submit(Request(
        rid=rid,
        prompt=rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
        max_new_tokens=8))

t0 = time.time()
done = engine.run()
dt = time.time() - t0
tokens = sum(len(r.out_tokens) for r in done)
print(f"served {len(done)} requests / {tokens} tokens in {dt:.2f}s "
      f"({tokens/dt:.1f} tok/s on 1 CPU core)")
for r in done[:3]:
    print(f"  request {r.rid}: {r.out_tokens}")
print("engine stats:", engine.stats)

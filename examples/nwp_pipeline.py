"""End-to-end driver for the paper's own workload: a scaled operational NWP
run (thesis §2.7.2 / §3.1.3) through FDB-X.

Ensemble "model members" produce weather fields each simulation step and
archive them through I/O-server processes; at the end of every step a
PGEN-style post-processing job lists + retrieves the step's fields across
all members *while the model keeps writing* (write+read contention), applies
a derived-product computation, and reports throughput — measured in-process
and modeled on the thesis's GCP hardware profile.

    PYTHONPATH=src python examples/nwp_pipeline.py --backend daos
"""
import argparse
import os
import threading
import time

import numpy as np

from repro.core import (FDB, FDBConfig, Meter, PROFILES, client_context,
                        model_run)

p = argparse.ArgumentParser()
p.add_argument("--backend", default="daos",
               choices=["daos", "rados", "posix", "s3"])
p.add_argument("--members", type=int, default=4)
p.add_argument("--steps", type=int, default=6)
p.add_argument("--params", type=int, default=8)
p.add_argument("--field-kib", type=int, default=512)
args = p.parse_args()

schema = "nwp-posix" if args.backend == "posix" else "nwp-object"
meter = Meter()
cfg = FDBConfig(backend=args.backend, schema=schema,
                root=f"/tmp/nwp-example-{os.getpid()}")
FIELD = args.field_kib * 1024

# one deterministic "weather field" per (member, step, param)
rng = np.random.default_rng(0)
grid = rng.standard_normal(FIELD // 4).astype(np.float32)


def ident(member, step, param):
    return {"class": "od", "expver": "0001", "stream": "enfo",
            "date": "20240101", "time": "0000", "type": "pf",
            "levtype": "sfc", "number": str(member), "levelist": "0",
            "step": str(step), "param": f"p{param}"}


step_flushed = [threading.Semaphore(0) for _ in range(args.steps)]
t_start = time.perf_counter()


def io_server(member):
    fdb = FDB(cfg, meter=meter)
    with client_context(f"io{member}@node{member}"):
        for s in range(args.steps):
            for q in range(args.params):
                field = (grid * (1 + 0.01 * s) + q).tobytes()
                fdb.archive(ident(member, s, q), field)
            fdb.flush()                      # step visibility barrier
            step_flushed[s].release()
    fdb.close()


products = {}


def pgen(s):
    for _ in range(args.members):
        step_flushed[s].acquire()            # workflow-manager signal
    fdb = FDB(cfg, meter=meter)
    with client_context(f"pgen@pnode{s % 2}"):
        n = sum(1 for _ in fdb.list({"class": "od", "stream": "enfo",
                                     "step": str(s)}))
        assert n == args.members * args.params, (s, n)
        acc = np.zeros(FIELD // 4, np.float32)
        for m in range(args.members):
            handle = fdb.retrieve([ident(m, s, q)
                                   for q in range(args.params)])
            for blob in handle.read_parts():
                acc += np.frombuffer(blob, np.float32)
        products[s] = float(acc.mean())      # the "derived product"


writers = [threading.Thread(target=io_server, args=(m,))
           for m in range(args.members)]
pgens = [threading.Thread(target=pgen, args=(s,)) for s in range(args.steps)]
for t in writers + pgens:
    t.start()
for t in writers + pgens:
    t.join()
wall = time.perf_counter() - t_start

total = args.members * args.steps * args.params * FIELD
m = model_run(meter.snapshot(), PROFILES["gcp"], server_nodes=8)
print(f"backend={args.backend}: {args.members} members × {args.steps} steps "
      f"× {args.params} params, {total/2**20:.0f} MiB archived+retrieved "
      f"under contention in {wall:.2f}s (in-process)")
print(f"modeled on GCP profile (8 servers): write {m.write_bw/2**30:.2f} "
      f"GiB/s, read {m.read_bw/2**30:.2f} GiB/s, bottleneck={m.dominant}")
print(f"derived products per step: "
      f"{ {s: round(v, 3) for s, v in sorted(products.items())} }")
print("consistency: all fields listed, retrieved, and bit-exact ✓")

"""Quickstart: the FDB-X object store + a reduced model in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

import jax
import jax.numpy as jnp

from repro.core import FDB, FDBConfig
from repro.configs import get_smoke_config
from repro.models import lm

# ---------------------------------------------------------------- storage --
# The paper's technique: a domain-specific object store with a
# metadata-driven API.  Pick any backend: daos | rados | posix | s3.
fdb = FDB(FDBConfig(backend="daos", schema="nwp-object"))

ident = {"class": "od", "expver": "0001", "stream": "oper",
         "date": "20240101", "time": "0000", "type": "fc", "levtype": "sfc",
         "number": "1", "levelist": "10", "step": "6", "param": "t2m"}
field = os.urandom(1024 * 1024)          # a 1 MiB "weather field"

fdb.archive(ident, field)                # blocks until FDB owns the data
fdb.flush()                              # persistence + visibility barrier
assert fdb.retrieve(ident).read() == field
print("archived + retrieved 1 field;",
      "axes(step) =", sorted(fdb.axes(ident, "step")))

# multi-object request expression (thesis §2.7: expanded via axes)
for step in ("12", "18"):
    fdb.archive({**ident, "step": step}, field)
# §3.1.2 caveat, faithfully reproduced: a consumer that already retrieved
# from this (dataset, collocation) holds pre-loaded axis summaries and will
# not see values archived afterwards — refresh them (or use a new client).
fdb.catalogue.refresh_axes()
handle = fdb.retrieve({**ident, "step": "6/12/18"})
parts = handle.read_parts()
assert len(parts) == 3 and all(p == field for p in parts)
print("multi-retrieve:", len(parts), "fields,",
      handle.length() // 2**20, "MiB total")

print("catalogue listing:",
      sum(1 for _ in fdb.list({"class": "od", "date": "20240101"})),
      "objects indexed")

# ------------------------------------------------------------------ model --
cfg = get_smoke_config("tinyllama-1.1b")
params = lm.init_params(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                            cfg.vocab_size)
logits = lm.forward(cfg, params, tokens)
loss = lm.loss_fn(cfg, params, tokens, tokens)
print(f"model {cfg.name}: logits {logits.shape}, loss {float(loss):.3f}")

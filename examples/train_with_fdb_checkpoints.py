"""Train a reduced TinyLlama through the full framework path: synthetic data
pipeline → jitted train step → async FDB checkpoints → simulated node
failure → restart-from-checkpoint → final restore check.

Defaults are CPU-friendly (~1-2 min).  For the ~100M-parameter / few-hundred
step variant on real hardware:
    python examples/train_with_fdb_checkpoints.py --d-model 768 --layers 12 \
        --steps 300 --batch 8 --seq 512

    PYTHONPATH=src python examples/train_with_fdb_checkpoints.py
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import FDBConfig
from repro.data import SyntheticTokens
from repro.models import lm
from repro.train.checkpoint import FDBCheckpointer
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, WorkerFailure, run_with_restarts

p = argparse.ArgumentParser()
p.add_argument("--steps", type=int, default=60)
p.add_argument("--batch", type=int, default=4)
p.add_argument("--seq", type=int, default=64)
p.add_argument("--d-model", type=int, default=0, help="override width")
p.add_argument("--layers", type=int, default=0)
p.add_argument("--fail-at", type=int, default=35,
               help="inject a worker failure at this step (-1 = off)")
p.add_argument("--backend", default="daos")
args = p.parse_args()

cfg = get_smoke_config("tinyllama-1.1b")
if args.d_model:
    cfg = cfg.scaled(d_model=args.d_model,
                     d_ff=int(args.d_model * 2.75) // 64 * 64)
if args.layers:
    cfg = cfg.scaled(n_layers=args.layers)
print(f"model: {cfg.name} ({lm.count_params(cfg)/1e6:.1f}M params)")

data = SyntheticTokens(cfg.vocab_size, args.seq, seed=0)
ck = FDBCheckpointer("example-run", FDBConfig(backend=args.backend),
                     asynchronous=True)
fail = {args.fail_at} if args.fail_at >= 0 else set()


def fault(step):
    if step in fail:
        fail.discard(step)
        raise WorkerFailure(f"injected node failure at step {step}")


def make():
    return Trainer(cfg, None, AdamWConfig(lr=1e-3), checkpointer=ck,
                   ckpt_every=10, batch_fn=lambda s: data.batch(s, args.batch),
                   fault_hook=fault)


trainer = run_with_restarts(make, args.steps)
first = trainer.metrics[0]["loss"] if trainer.metrics else float("nan")
last = trainer.metrics[-1]["loss"]
print(f"finished at step {trainer.step}: loss {first:.3f} → {last:.3f}")
print(f"checkpoints in FDB: steps {ck.available_steps()}")

step, restored = ck.restore_latest(lm.init_params(cfg, jax.random.PRNGKey(0)))
same = all(bool(jnp.allclose(a, b)) for a, b in
           zip(jax.tree.leaves(restored), jax.tree.leaves(trainer.params)))
print(f"restore_latest(step={step}) bit-exact vs live params: {same}")
ck.close()

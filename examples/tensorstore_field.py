"""Chunked NWP field write + slice-read + reshard with repro.tensorstore.

A (lat, lon, level) temperature field is archived as a chunked array — every
chunk one FDB object, archives overlapping through the bounded I/O executor —
then a regional window is sliced back, retrieving only the intersecting
chunks (the partial-read workload the whole-blob archive path cannot serve),
and finally the array is resharded onto a consumer's chunk grid as a
streaming composition of the read and write plans.

    PYTHONPATH=src python examples/tensorstore_field.py
"""
import numpy as np

from repro.core import FDB, FDBConfig
from repro.core.engine.meter import GLOBAL_METER
from repro.data import ChunkedFieldStore
from repro.tensorstore import TensorStore

# ------------------------------------------------------- low-level surface --
# Pick any backend: daos | rados | posix | s3.
fdb = FDB(FDBConfig(backend="daos", schema="tensor"))
ts = TensorStore(fdb, {"store": "nwp", "array": "t850", "writer": "iosrv0"})

lat, lon, levels = 180, 360, 4
field = (np.random.default_rng(0)
         .normal(280.0, 15.0, size=(lat, lon, levels))
         .astype(np.float32))

arr = ts.save(field, chunks=(60, 90, 2))          # 3 x 4 x 2 chunk grid
print(f"archived {arr!r} as {arr.grid.chunk_count} chunk objects")

# A regional window: Europe-ish lat/lon box on one level.  Only the chunks
# intersecting the window are retrieved — count the data-read ops to prove it.
arr = ts.open()
before = len(GLOBAL_METER.snapshot())
window = arr[30:90, 0:90, 0]
reads = [op for op in GLOBAL_METER.snapshot()[before:]
         if op.kind in ("array_read", "read", "http_get")]
print(f"window {window.shape}: {len(reads)} chunk reads, "
      f"{sum(op.nbytes for op in reads)} bytes "
      f"(full field is {field.nbytes} bytes)")
fdb.close()

# ------------------------------------------- write/read plan symmetry ------
# Both data paths plan before they touch bytes.  On posix, one writer's
# chunks append into one data file, so a multi-chunk write coalesces into a
# single batched store write (WritePlan.write_ops) — and the read side
# merges the same adjacent ranges back into a single ranged read
# (ReadPlan.read_ops).  Object backends report one op per chunk on both
# sides: that is the paper's trade-off, now symmetric.
import shutil
shutil.rmtree("/tmp/fdb-ts-example", ignore_errors=True)
pfdb = FDB(FDBConfig(backend="posix", schema="tensor",
                     root="/tmp/fdb-ts-example"))
pts = TensorStore(pfdb, {"store": "nwp", "array": "t850", "writer": "io0"})
parr = pts.create(field.shape, field.dtype, chunks=(60, 90, 2))
full = (slice(None),) * 3
wplan = parr.write_plan(full, field)
print(f"posix write plan: {wplan.write_ops()} store writes for "
      f"{wplan.n_chunks} chunks (coalesced)")
wplan.execute()
rplan = parr.read_plan(full)
print(f"posix read plan:  {rplan.read_ops()} store reads for "
      f"{rplan.n_chunks} chunks (coalesced)")

# ------------------------------------------------- plan-composed reshard ---
# The producer archived (60, 90, 2) chunks; a regional consumer wants
# whole-column (lat-band) tiles.  reshard() streams the array onto the new
# grid — bounded batches, each one coalesced ReadPlan + one coalesced
# WritePlan — and flips readers over with a single metadata replace.  The
# old grid's chunks are retained under the previous layout generation.
splan = parr.reshard_plan((30, 360, 4))
print(f"posix reshard:    {splan.read_ops()} reads + {splan.write_ops()} "
      f"writes for {splan.n_dest_chunks} new chunks "
      f"(naive: {splan.src_chunk_fetches()} + {splan.n_dest_chunks})")
splan.execute()
assert parr.chunks == (30, 360, 4) and parr.meta.generation == 1
# strided selections express subsampled consumer grids directly
coarse = parr[::4, ::4, 0]
print(f"strided read {coarse.shape}: every 4th point, "
      f"{parr.read_plan((slice(None, None, 4),) * 2).n_chunks} chunks touched")
pfdb.close()

# ----------------------------------------------------- pipeline-level API --
# The same thing through the data-pipeline facade, with the Pallas field
# codec compressing each chunk (GRIB-style block quantisation on TPU).
fs = ChunkedFieldStore("nwp-compressed", FDBConfig(backend="rados"),
                       chunks=(60, 90, 2), codec="field16")
fs.put_field("t850", field)
fs.commit()
got = fs.read_window("t850", slice(30, 90), slice(0, 90))
err = np.abs(got - field[30:90, 0:90]).max()
print(f"field16 codec window read {got.shape}: max abs err {err:.5f} K")
fs.close()

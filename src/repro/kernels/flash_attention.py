"""Pallas TPU flash attention (forward) with explicit VMEM BlockSpec tiling.

Grid: (batch·heads, q_blocks, kv_blocks) — TPU grids iterate the trailing
dimension innermost and sequentially, so the f32 accumulator / running max /
running sum live in VMEM scratch and persist across the kv_block sweep
(online softmax).  Block sizes default to 128×128: MXU-aligned (multiples of
128 on both matmul dims) and small enough that q/k/v/o tiles + scratch fit
VMEM for head_dim ≤ 256.

Training uses the XLA einsum path (with remat); this kernel is the
serving/prefill hot path.  Validated against ``ref.flash_attention_ref`` in
interpret mode on CPU across shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq,)
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, :] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q,k,v: (B, H, S, D) → (B, H, S, D)."""
    B, H, S, D = q.shape
    T = k.shape[2]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    bh = B * H
    qr = q.reshape(bh, S, D)
    kr = k.reshape(bh, T, D)
    vr = v.reshape(bh, T, D)
    grid = (bh, S // block_q, T // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(D), causal=causal,
        block_q=block_q, block_k=block_k, kv_blocks=T // block_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running sum
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, S, D)

"""Pallas TPU fused RMSNorm: one VMEM pass computes the reduction and the
scaled output (XLA emits separate reduce + broadcast-multiply kernels,
costing an extra HBM round-trip on (B·S, D) activations)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y.astype(o_ref.dtype) * scale_ref[...])


@functools.partial(jax.jit, static_argnames=("block_rows", "eps", "interpret"))
def fused_rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5,
                  block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x: (N, D) row-normalised; scale: (D,)."""
    N, D = x.shape
    block_rows = min(block_rows, N)
    assert N % block_rows == 0, (N, block_rows)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(N // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=interpret,
    )(x, scale)

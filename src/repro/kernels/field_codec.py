"""Pallas TPU field codec — GRIB "simple packing" adapted to TPU (DESIGN §3).

The ECMWF I/O servers' compute hot spot is field packing: v = round((x -
min) / scale) at reduced bit width.  A mechanical port would be serial
bit-twiddling; the TPU-native rethink is *block-local byte-granular
quantisation*: each grid step owns a (block, 128·k) VMEM tile, computes the
tile min/max with VPU reductions, scales to int8 (or int16), and stores the
lane-aligned quantised tile + per-tile (scale, min) scalars.  Sub-byte
packing does not vectorise on TPU lanes and is intentionally dropped
(documented as non-transferring).

Used by the framework for (a) checkpoint-shard compression before
FDB archive() and (b) optional cross-pod gradient compression.

encode:  x (N, C) → q int8 (N, C), scale (N/block, 1), mins (N/block, 1)
decode:  inverse.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(x_ref, q_ref, scale_ref, min_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)
    mn = jnp.min(x)
    mx = jnp.max(x)
    levels = float(2 ** bits - 1)
    shift = float(2 ** (bits - 1))
    scale = (mx - mn) / levels
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.round((x - mn) / safe) - shift
    q = jnp.clip(q, -shift, shift - 1)
    q_ref[...] = q.astype(q_ref.dtype)
    scale_ref[0, 0] = scale
    min_ref[0, 0] = mn


def _decode_kernel(q_ref, scale_ref, min_ref, x_ref, *, bits: int):
    shift = float(2 ** (bits - 1))
    q = q_ref[...].astype(jnp.float32)
    x = (q + shift) * scale_ref[0, 0] + min_ref[0, 0]
    x_ref[...] = x.astype(x_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block", "bits", "interpret"))
def field_encode(x: jax.Array, block: int = 256, bits: int = 8,
                 interpret: bool = False):
    """x: (N, C), N % block == 0, C % 128 == 0 (lane alignment)."""
    N, Cdim = x.shape
    block = min(block, N)
    assert N % block == 0, (N, block)
    n_blocks = N // block
    dtype = jnp.int8 if bits == 8 else jnp.int16
    kernel = functools.partial(_encode_kernel, bits=bits)
    q, scale, mins = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block, Cdim), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block, Cdim), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, Cdim), dtype),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, scale[:, 0], mins[:, 0]


@functools.partial(jax.jit,
                   static_argnames=("block", "bits", "out_dtype", "interpret"))
def field_decode(q: jax.Array, scale: jax.Array, mins: jax.Array,
                 block: int = 256, bits: int = 8, out_dtype=jnp.float32,
                 interpret: bool = False) -> jax.Array:
    N, Cdim = q.shape
    block = min(block, N)
    n_blocks = N // block
    kernel = functools.partial(_decode_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block, Cdim), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, Cdim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Cdim), out_dtype),
        interpret=interpret,
    )(q, scale[:, None], mins[:, None])

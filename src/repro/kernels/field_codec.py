"""Pallas TPU field codec — GRIB "simple packing" adapted to TPU (DESIGN §3).

The ECMWF I/O servers' compute hot spot is field packing: v = round((x -
min) / scale) at reduced bit width.  A mechanical port would be serial
bit-twiddling; the TPU-native rethink is *block-local byte-granular
quantisation*: each grid step owns a (block, 128·k) VMEM tile, computes the
tile min/max with VPU reductions, scales to int8 (or int16), and stores the
lane-aligned quantised tile + per-tile (scale, min) scalars.  Sub-byte
packing does not vectorise on TPU lanes and is intentionally dropped
(documented as non-transferring).

Used by the framework for (a) checkpoint-shard compression before
FDB archive() and (b) optional cross-pod gradient compression.

encode:  x (N, C) → q int8 (N, C), scale (N/block,), mins (N/block,)
decode:  inverse.

Both entry points also accept a leading *batch* dimension — x (B, N, C) —
encoding B same-shape fields in ONE kernel launch.  The batch flattens onto
the block grid (grid = B · N/block, i.e. fields × blocks): because each
field's row count is a multiple of the block size, no quantisation block
ever straddles a field boundary, so the per-block (scale, min) pairs — and
therefore the quantised bytes — are bit-identical to B separate 2-D calls.
This is what lets the tensorstore write path encode a whole write plan's
chunks per launch instead of a Python loop of per-chunk launches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(x_ref, q_ref, scale_ref, min_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)
    mn = jnp.min(x)
    mx = jnp.max(x)
    levels = float(2 ** bits - 1)
    shift = float(2 ** (bits - 1))
    scale = (mx - mn) / levels
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.round((x - mn) / safe) - shift
    q = jnp.clip(q, -shift, shift - 1)
    q_ref[...] = q.astype(q_ref.dtype)
    scale_ref[0, 0] = scale
    min_ref[0, 0] = mn


def _decode_kernel(q_ref, scale_ref, min_ref, x_ref, *, bits: int):
    shift = float(2 ** (bits - 1))
    q = q_ref[...].astype(jnp.float32)
    x = (q + shift) * scale_ref[0, 0] + min_ref[0, 0]
    x_ref[...] = x.astype(x_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block", "bits", "interpret"))
def field_encode(x: jax.Array, block: int = 256, bits: int = 8,
                 interpret: bool = False):
    """x: (N, C) or (B, N, C); N % block == 0, C % 128 == 0 (lane alignment).

    With a batch dimension the outputs are q (B, N, C), scale (B, N/block),
    mins (B, N/block) from a single launch with grid B · N/block.
    """
    if x.ndim == 3:
        B, N, Cdim = x.shape
        blk = min(block, N)
        assert N % blk == 0, (N, blk)
        q, scale, mins = field_encode(x.reshape(B * N, Cdim), block=blk,
                                      bits=bits, interpret=interpret)
        nb = N // blk
        return (q.reshape(B, N, Cdim), scale.reshape(B, nb),
                mins.reshape(B, nb))
    N, Cdim = x.shape
    block = min(block, N)
    assert N % block == 0, (N, block)
    n_blocks = N // block
    dtype = jnp.int8 if bits == 8 else jnp.int16
    kernel = functools.partial(_encode_kernel, bits=bits)
    q, scale, mins = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block, Cdim), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block, Cdim), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, Cdim), dtype),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, scale[:, 0], mins[:, 0]


@functools.partial(jax.jit,
                   static_argnames=("block", "bits", "out_dtype", "interpret"))
def field_decode(q: jax.Array, scale: jax.Array, mins: jax.Array,
                 block: int = 256, bits: int = 8, out_dtype=jnp.float32,
                 interpret: bool = False) -> jax.Array:
    """Inverse of :func:`field_encode`; q (N, C) or batched (B, N, C) with
    scale/mins (B, N/block) — the batched form decodes in one launch."""
    if q.ndim == 3:
        B, N, Cdim = q.shape
        blk = min(block, N)
        out = field_decode(q.reshape(B * N, Cdim), scale.reshape(-1),
                           mins.reshape(-1), block=blk, bits=bits,
                           out_dtype=out_dtype, interpret=interpret)
        return out.reshape(B, N, Cdim)
    N, Cdim = q.shape
    block = min(block, N)
    n_blocks = N // block
    kernel = functools.partial(_decode_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block, Cdim), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, Cdim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Cdim), out_dtype),
        interpret=interpret,
    )(q, scale[:, None], mins[:, None])

from . import ops, ref
from .ops import field_decode, field_encode, flash_attention, fused_rmsnorm

__all__ = ["ops", "ref", "flash_attention", "field_encode", "field_decode",
           "fused_rmsnorm"]

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def flash_attention_ref(q: Array, k: Array, v: Array,
                        causal: bool = True) -> Array:
    """q,k,v: (B, H, S, D) → (B, H, S, D). Plain softmax attention."""
    S, T = q.shape[2], k.shape[2]
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(q.shape[-1])
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        scores = jnp.where(mask[None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", w, v)


def rmsnorm_ref(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale)


def field_encode_ref(x: Array, block: int = 256, bits: int = 8
                     ) -> Tuple[Array, Array, Array]:
    """GRIB-style simple packing, block-local (TPU adaptation: byte-granular).

    x: (N, C) with N % block == 0.  Returns (q int8/int16, scale, mins),
    scale/mins per (N/block, C)-tile row block: (N/block, C)? No —
    per-block scalars over the row-block × full lane width: (N/block,).
    """
    n_blocks = x.shape[0] // block
    xb = x.reshape(n_blocks, block, *x.shape[1:]).astype(jnp.float32)
    reduce_axes = tuple(range(1, xb.ndim))
    mins = jnp.min(xb, axis=reduce_axes)
    maxs = jnp.max(xb, axis=reduce_axes)
    levels = float(2 ** bits - 1)
    scale = (maxs - mins) / levels
    safe = jnp.where(scale > 0, scale, 1.0)
    shift = float(2 ** (bits - 1))
    qb = jnp.round((xb - mins.reshape((-1,) + (1,) * (xb.ndim - 1))) /
                   safe.reshape((-1,) + (1,) * (xb.ndim - 1))) - shift
    dtype = jnp.int8 if bits == 8 else jnp.int16
    q = jnp.clip(qb, -shift, shift - 1).astype(dtype).reshape(x.shape)
    return q, scale, mins


def field_decode_ref(q: Array, scale: Array, mins: Array, block: int = 256,
                     bits: int = 8, out_dtype=jnp.float32) -> Array:
    n_blocks = q.shape[0] // block
    qb = q.reshape(n_blocks, block, *q.shape[1:]).astype(jnp.float32)
    shift = float(2 ** (bits - 1))
    ex = (1,) * (qb.ndim - 1)
    x = (qb + shift) * scale.reshape((-1,) + ex) + mins.reshape((-1,) + ex)
    return x.reshape(q.shape).astype(out_dtype)


def codec_error_bound(x: Array, block: int = 256, bits: int = 8) -> Array:
    """Max abs error guaranteed by block quantisation: half a level step."""
    n_blocks = x.shape[0] // block
    xb = x.reshape(n_blocks, block, *x.shape[1:]).astype(jnp.float32)
    reduce_axes = tuple(range(1, xb.ndim))
    rng = jnp.max(xb, axis=reduce_axes) - jnp.min(xb, axis=reduce_axes)
    return rng / (2 ** bits - 1) * 0.5 + 1e-6

"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode automatically; on TPU
they compile natively.  ``ref.py`` holds the pure-jnp oracles used by the
per-kernel allclose sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .field_codec import field_decode as _field_decode
from .field_codec import field_encode as _field_encode
from .flash_attention import flash_attention as _flash_attention
from .rmsnorm import fused_rmsnorm as _fused_rmsnorm


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q,k,v: (B, H, S, D)."""
    return _flash_attention(q, k, v, causal=causal, block_q=block_q,
                            block_k=block_k, interpret=_interpret())


def field_encode(x, block: int = 256, bits: int = 8):
    return _field_encode(x, block=block, bits=bits, interpret=_interpret())


def field_decode(q, scale, mins, block: int = 256, bits: int = 8,
                 out_dtype=jnp.float32):
    return _field_decode(q, scale, mins, block=block, bits=bits,
                         out_dtype=out_dtype, interpret=_interpret())


def fused_rmsnorm(x, scale, eps: float = 1e-5, block_rows: int = 256):
    return _fused_rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                          interpret=_interpret())


__all__ = ["flash_attention", "field_encode", "field_decode",
           "fused_rmsnorm", "ref"]

"""internlm2-20b: 48L d=6144 48H(kv8) d_ff=16384 vocab=92544, GQA
[arXiv:2403.17297; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92544,
    rope_theta=1e6,
)

SMOKE = ArchConfig(
    name="internlm2-20b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=192, vocab_size=512,
)

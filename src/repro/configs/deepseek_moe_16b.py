"""deepseek-moe-16b: 28L d=2048 16H(kv16) d_ff=1408 vocab=102400,
2 shared + 64 routed top-6 fine-grained experts [arXiv:2401.06066; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400,
    n_experts=64, n_shared_experts=2, top_k=6, moe_every=1,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="deepseek-moe-16b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab_size=512,
    n_experts=8, n_shared_experts=2, top_k=2, moe_every=1,
)

"""llava-next-mistral-7b: mistral-7B backbone, 32L d=4096 32H(kv8)
d_ff=14336 vocab=32000; anyres vision frontend STUBBED — input_specs()
supplies patch embeddings [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    rope_theta=1e6, frontend="vision", n_patches=1152,
)

SMOKE = ArchConfig(
    name="llava-next-mistral-7b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512, frontend="vision", n_patches=8,
)

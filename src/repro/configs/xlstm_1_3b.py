"""xlstm-1.3b: 48L d=2048 4H, sLSTM + mLSTM blocks (7:1 ratio), d_ff=0
(projections live inside the blocks) vocab=50304 [arXiv:2405.04517;
unverified]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    mlstm_proj_factor=2.0,
)

SMOKE = ArchConfig(
    name="xlstm-1.3b-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=512,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
)

"""Architecture registry: the 10 assigned configs + reduced smoke variants."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ArchConfig
from .shapes import SHAPES, ShapeConfig, eligible_shapes, skip_reason

_MODULES: Dict[str, str] = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-base": "whisper_base",
    "qwen2.5-3b": "qwen2_5_3b",
    "internlm2-20b": "internlm2_20b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "xlstm-1.3b": "xlstm_1_3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}

ARCH_NAMES: List[str] = list(_MODULES.keys())


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    return _module(name).SMOKE


__all__ = ["ARCH_NAMES", "get_config", "get_smoke_config", "SHAPES",
           "ShapeConfig", "eligible_shapes", "skip_reason"]

"""olmoe-1b-7b: 16L d=2048 16H(kv16) d_ff=1024 vocab=50304,
64 routed experts top-8 [arXiv:2409.02060; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304,
    n_experts=64, n_shared_experts=0, top_k=8, moe_every=1,
    rope_theta=1e4,
)

SMOKE = ArchConfig(
    name="olmoe-1b-7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab_size=512,
    n_experts=8, n_shared_experts=0, top_k=2, moe_every=1,
)

"""jamba-v0.1-52b: 32L d=4096 32H(kv8) d_ff=14336, Mamba+attn 1:7
interleave (1 attention per 8-layer block), MoE 16e top-2 every other
layer, vocab=65536 [arXiv:2403.19887; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    n_experts=16, n_shared_experts=0, top_k=2, moe_every=2,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ssm_state_dim=16, ssm_conv_dim=4, ssm_expand=2,
)

SMOKE = ArchConfig(
    name="jamba-v0.1-52b-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    n_experts=4, n_shared_experts=0, top_k=2, moe_every=2,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ssm_state_dim=4, ssm_conv_dim=4, ssm_expand=2,
)

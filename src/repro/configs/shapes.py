"""Assigned input shapes and (arch × shape) cell eligibility."""
from __future__ import annotations

import dataclasses
from typing import List

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def eligible_shapes(cfg: ArchConfig) -> List[ShapeConfig]:
    """long_500k needs sub-quadratic decode state: SSM/hybrid only
    (skip rationale recorded in DESIGN.md §4)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        out.append(SHAPES["long_500k"])
    return out


def skip_reason(cfg: ArchConfig, shape_name: str) -> str:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("SKIP: pure full-attention architecture — a 524k dense "
                "KV cache has no sub-quadratic path (DESIGN.md §4)")
    return ""

"""deepseek-coder-33b: 62L d=7168 56H(kv8) d_ff=19200 vocab=32256,
llama-arch [arXiv:2401.14196; hf]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab_size=32256,
    rope_theta=1e5,
)

SMOKE = ArchConfig(
    name="deepseek-coder-33b-smoke", family="dense",
    n_layers=2, d_model=112, n_heads=7, n_kv_heads=1,
    d_ff=224, vocab_size=512,
)

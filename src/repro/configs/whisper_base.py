"""whisper-base: 6L enc + 6L dec, d=512 8H(kv8) d_ff=2048 vocab=51865;
conv frontend STUBBED — input_specs() supplies frame embeddings
[arXiv:2212.04356; unverified]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    encoder_layers=6, frontend="audio",
)

SMOKE = ArchConfig(
    name="whisper-base-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512,
    encoder_layers=2, frontend="audio",
)

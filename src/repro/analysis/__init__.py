"""Correctness tooling: repo-invariant linter + concurrency protocol checker.

Two halves, both offline with respect to the data path:

* :mod:`repro.analysis.lint` — an AST-based static pass enforcing the
  repo's layering and concurrency conventions (rule catalogue:
  ``docs/analysis.md``), driven by ``scripts/lint.py`` and the CI gate.
* :mod:`repro.analysis.protocol` — a dynamic sanitizer that replays a
  trace window (``repro.obs`` spans + metrics) and asserts the
  multi-writer lease/flush contract, plus a lock-order recorder over the
  named FDB/backend locks.

This package sits at the top of the layer DAG and imports only
``repro.obs`` — it *reads* traces; it never touches storage.
"""
from .lint import Finding, Linter, lint_paths
from .protocol import (LockOrderRecorder, Violation, check_protocol,
                       protocol_guard)

__all__ = [
    "Finding", "Linter", "lint_paths",
    "LockOrderRecorder", "Violation", "check_protocol", "protocol_guard",
]

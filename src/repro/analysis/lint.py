"""Repo-invariant linter: AST rules for the layering + concurrency contract.

The conventions PRs 1–6 established are enforceable statically; this
module encodes them as data and walks the AST.  Rule catalogue (full
prose + examples in ``docs/analysis.md``):

========  =============================================================
``L001``  import layering: each package imports only the packages below
          it in the layer DAG (``docs/architecture.md``); ``repro.obs``
          stays stdlib-only.
``L002``  byte-moving ``Store``/``Catalogue`` calls (``archive`` /
          ``retrieve`` / ``flush`` / ``wipe``) only inside the FDB
          facade, the backends, and the plan modules.
``L003``  no blocking I/O or executor calls inside a ``with <lock>:``
          body in ``core/fdb.py`` / ``core/backends/`` (direct calls
          only — a deliberate, documented heuristic).
``L004``  ``tracer.span(...)`` used only as a context manager, with
          literal names drawn from the documented taxonomy
          (``docs/observability.md``).
``L005``  no bare ``threading.Thread`` outside the executor and the
          checkpointer's simulated ranks.
``L006``  lease paths are control-plane: no engine ``Meter`` traffic in
          lease code.
``L007``  repo-root layout: no stray top-level ``*.py`` files.
``L008``  every suppression pragma carries a rationale
          (``-- <reason>``); a bare one is itself a finding.
``L009``  retries live in one place: no bare ``time.sleep`` and no
          hand-rolled retry loops (``except: ... continue`` inside a
          loop) outside ``core/retry.py`` / ``core/faults.py`` — go
          through ``RetryPolicy`` (bounded, jittered, deadline-aware).
========  =============================================================

Suppression syntax — trailing on the offending line, or in the comment
block immediately above it::

    something()   # lint: disable=<RULE> -- <why this one is sound>

Machine-readable findings (``path:line: RULE message``) and counted,
rationale-pinned suppressions are the contract with ``scripts/lint.py``
and the CI gate (``scripts/check.sh``).

Stdlib-only (``ast`` + ``re``); imports nothing above ``repro.obs``.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

# --------------------------------------------------------------------------
# the layer DAG, as data (mirrors the diagram in docs/architecture.md):
# package -> packages it may import.  Intra-package imports are always
# allowed; ``obs`` is importable from everywhere (observability is
# cross-cutting by design) and itself imports nothing but the stdlib.
# --------------------------------------------------------------------------
LAYER_DAG: Dict[str, Set[str]] = {
    "obs": set(),                       # bottom: stdlib-only
    "kernels": set(),                   # Pallas kernels (third-party: jax)
    "core": set(),                      # FDB facade + backends + engines
    "analysis": set(),                  # reads traces, never storage
    "tensorstore": {"core", "kernels"},
    "data": {"core", "tensorstore"},
    "configs": {"models"},
    "sharding": {"models"},
    "models": {"configs", "sharding"},
    "train": {"core", "kernels", "models", "sharding", "tensorstore"},
    "serve": {"models", "core", "data", "tensorstore"},
    "launch": {"configs", "core", "data", "models", "serve", "sharding",
               "train", "tensorstore"},
    # workflow drivers compose the storage facades end to end
    "workflows": {"core", "data", "tensorstore", "train"},
}
#: importable from every layer (cross-cutting observability)
UNIVERSAL = {"obs"}

#: Store/Catalogue byte-moving methods (L002) — lease methods are
#: control-plane and deliberately absent
BYTE_OPS = {"archive", "archive_batch", "retrieve", "flush", "wipe"}
#: receiver names a byte-op must not be called through outside the facade
BYTE_RECEIVERS = {"store", "catalogue"}
#: files allowed to move bytes through Store/Catalogue directly
BYTE_OP_FILES = ("core/fdb.py", "core/interfaces.py", "core/backends/",
                 "tensorstore/store.py", "tensorstore/reshard.py")

#: direct calls treated as blocking under a held lock (L003) — attribute
#: or bare names; a deliberate direct-call heuristic (indirect blocking
#: via helper methods is out of scope, see docs/analysis.md)
BLOCKING_CALLS = {"flush", "fsync", "write", "read", "readinto", "open",
                  "submit", "map_ordered", "shutdown", "archive",
                  "archive_batch", "archive_many", "retrieve",
                  "_append_record"}
#: files the lock-scope rule applies to
LOCK_SCOPE_FILES = ("core/fdb.py", "core/backends/")

#: files allowed to construct bare threading.Thread (L005)
THREAD_FILES = ("tensorstore/executor.py", "train/checkpoint.py")

#: files the lease-metering rule applies to (L006)
LEASE_FILES = ("core/lease.py", "core/fdb.py", "core/backends/")

#: span-taxonomy rule exemptions (L004): obs defines the machinery,
#: analysis replays it
SPAN_EXEMPT = ("obs/", "analysis/")

#: files that own sleeping/backoff (L009): the retry layer itself and the
#: fault injector's latency spikes — everywhere else, a sleep is either a
#: hand-rolled retry (use RetryPolicy) or a poll (use an Event/Condition)
RETRY_FILES = ("core/retry.py", "core/faults.py")

#: allowed repo-root python files (L007)
ROOT_PY_ALLOWED = {"conftest.py", "setup.py"}

SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*disable=([A-Za-z0-9_,\s-]+?)(?:\s+--\s*(\S.*?))?\s*$")


@dataclasses.dataclass
class Finding:
    """One rule violation at ``path:line``."""
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass
class Suppression:
    """One ``# lint: disable=`` pragma.  Covers its own line; a pragma in
    a comment-only block also covers the first code line below the block
    (``target``), so multi-line rationales stay attached."""
    path: str
    line: int
    rules: Tuple[str, ...]
    rationale: Optional[str]
    target: int = 0
    used: bool = False


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]                 # unsuppressed — these fail CI
    suppressed: List[Finding]               # baselined by a pragma
    suppressions: List[Suppression]

    @property
    def unused_suppressions(self) -> List[Suppression]:
        return [s for s in self.suppressions if not s.used]


def _find_repo_root(start: Optional[Path] = None) -> Path:
    """Walk upward until the directory holding ``docs/observability.md``
    (the span-taxonomy source of truth); fall back to the CWD."""
    p = (start or Path(__file__)).resolve()
    for cand in [p] + list(p.parents):
        if (cand / "docs" / "observability.md").is_file():
            return cand
    return Path.cwd()


def load_span_taxonomy(doc: Path) -> Tuple[Set[str], List[re.Pattern]]:
    """Parse the documented span names out of the *Span taxonomy* table of
    ``docs/observability.md``: every backticked token in the first column,
    with ``[_batch]`` expanding to both variants and ``<...>`` segments
    becoming wildcards.  Returns (exact names, wildcard patterns)."""
    exact: Set[str] = set()
    patterns: List[re.Pattern] = []
    in_table = False
    for line in doc.read_text().splitlines():
        if line.startswith("## "):
            in_table = line.strip() == "## Span taxonomy"
            continue
        if not (in_table and line.startswith("|")):
            continue
        first_cell = line.split("|")[1]
        for token in re.findall(r"`([^`]+)`", first_cell):
            variants = [token]
            if "[_batch]" in token:
                variants = [token.replace("[_batch]", ""),
                            token.replace("[_batch]", "_batch")]
            for v in variants:
                if "<" in v:
                    patterns.append(re.compile(
                        re.sub(r"<[^>]+>", r"[a-z0-9_]+", re.escape(v)
                               .replace(r"<", "<").replace(r">", ">"))))
                else:
                    exact.add(v)
    return exact, patterns


class Linter:
    """Stateful driver: one instance per run, fed file paths."""

    def __init__(self, root: Optional[Path] = None):
        self.root = Path(root) if root else _find_repo_root()
        taxonomy_doc = self.root / "docs" / "observability.md"
        if taxonomy_doc.is_file():
            self.span_names, self.span_patterns = \
                load_span_taxonomy(taxonomy_doc)
        else:                       # no doc, no name rule (CM rule stays)
            self.span_names, self.span_patterns = set(), []
        self.findings: List[Finding] = []
        self.suppressions: List[Suppression] = []

    # -- helpers -----------------------------------------------------------
    def _rel(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(self.root).as_posix()
        except ValueError:
            return path.as_posix()

    def _pkg_rel(self, rel: str) -> Optional[str]:
        """Path inside src/repro ('core/fdb.py'), or None if not there."""
        prefix = "src/repro/"
        return rel[len(prefix):] if rel.startswith(prefix) else None

    def _emit(self, rel: str, line: int, rule: str, message: str) -> None:
        self.findings.append(Finding(rel, line, rule, message))

    def _span_name_ok(self, name: str) -> bool:
        if name in self.span_names:
            return True
        return any(p.fullmatch(name) for p in self.span_patterns)

    # -- per-file ----------------------------------------------------------
    def lint_file(self, path: Path) -> None:
        rel = self._rel(path)
        sub = self._pkg_rel(rel)
        if sub is None:
            return                              # only src/repro is ruled
        text = path.read_text()
        lines = text.splitlines()
        for i, line in enumerate(lines, 1):
            m = SUPPRESS_RE.search(line)
            if m:
                rules = tuple(r.strip() for r in m.group(1).split(",")
                              if r.strip())
                rationale = m.group(2)
                # a pragma inside a comment block covers the first code
                # line below the block; a trailing pragma covers its line
                target = i
                if line.lstrip().startswith("#"):
                    j = i
                    while j < len(lines) and \
                            lines[j].lstrip().startswith("#"):
                        j += 1
                    target = j + 1
                self.suppressions.append(
                    Suppression(rel, i, rules, rationale, target))
                if not rationale:
                    self._emit(rel, i, "L008",
                               "suppression without a rationale: append "
                               "'-- <reason>' to the pragma")
        try:
            tree = ast.parse(text, filename=rel)
        except SyntaxError as e:
            self._emit(rel, e.lineno or 1, "L000",
                       f"file does not parse: {e.msg}")
            return
        package = sub.split("/", 1)[0] if "/" in sub else "__root__"
        self._rule_layering(rel, sub, package, tree)
        self._rule_byte_ops(rel, sub, tree)
        self._rule_lock_scope(rel, sub, tree)
        self._rule_spans(rel, sub, tree)
        self._rule_threads(rel, sub, tree)
        self._rule_lease_metering(rel, sub, tree)
        self._rule_sleep_retry(rel, sub, tree)

    # -- L001 --------------------------------------------------------------
    def _resolve_import(self, sub: str, node: ast.ImportFrom
                        ) -> Optional[str]:
        """Absolute dotted module a relative import resolves to."""
        parts = ("repro/" + sub[:-3]).split("/")
        if parts[-1] == "__init__":
            parts = parts[:-1]
        else:
            parts = parts[:-1] + ([] if node.level == 0 else [])
        base = parts[:len(parts) - (node.level - 1)] if node.level > 1 \
            else parts
        mod = ".".join(base + ([node.module] if node.module else []))
        return mod or None

    def _rule_layering(self, rel: str, sub: str, package: str,
                       tree: ast.AST) -> None:
        allowed = LAYER_DAG.get(package)
        for node in ast.walk(tree):
            mods: List[Tuple[str, int]] = []
            if isinstance(node, ast.Import):
                mods = [(a.name, node.lineno) for a in node.names]
            elif isinstance(node, ast.ImportFrom):
                if node.level:
                    m = self._resolve_import(sub, node)
                    mods = [(m, node.lineno)] if m else []
                elif node.module:
                    mods = [(node.module, node.lineno)]
            for mod, line in mods:
                top = mod.split(".", 1)[0]
                if top == "repro":
                    tgt = mod.split(".")[1] if "." in mod else package
                    if (allowed is not None and tgt != package
                            and tgt not in UNIVERSAL
                            and tgt not in allowed):
                        self._emit(rel, line, "L001",
                                   f"layer violation: {package!r} must not "
                                   f"import repro.{tgt} (allowed: "
                                   f"{sorted(allowed | UNIVERSAL)})")
                elif package == "obs" and top not in _stdlib():
                    self._emit(rel, line, "L001",
                               f"repro.obs must stay stdlib-only; imports "
                               f"{mod!r}")

    # -- L002 --------------------------------------------------------------
    def _rule_byte_ops(self, rel: str, sub: str, tree: ast.AST) -> None:
        if any(sub.startswith(p) or sub == p for p in BYTE_OP_FILES):
            return
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in BYTE_OPS):
                continue
            recv = node.func.value
            name = recv.attr if isinstance(recv, ast.Attribute) else (
                recv.id if isinstance(recv, ast.Name) else None)
            if name in BYTE_RECEIVERS:
                self._emit(rel, node.lineno, "L002",
                           f"direct byte-moving call "
                           f".{name}.{node.func.attr}(...) outside the FDB "
                           f"facade/plan modules — go through FDB or a "
                           f"plan")

    # -- L003 --------------------------------------------------------------
    def _rule_lock_scope(self, rel: str, sub: str, tree: ast.AST) -> None:
        if not any(sub.startswith(p) for p in LOCK_SCOPE_FILES):
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.With):
                continue
            locked = any("lock" in ast.unparse(item.context_expr).lower()
                         for item in node.items)
            if not locked:
                continue
            for inner in node.body:
                for call in ast.walk(inner):
                    if not isinstance(call, ast.Call):
                        continue
                    fn = call.func
                    cname = fn.attr if isinstance(fn, ast.Attribute) else (
                        fn.id if isinstance(fn, ast.Name) else None)
                    if cname in BLOCKING_CALLS:
                        self._emit(
                            rel, call.lineno, "L003",
                            f"blocking call {cname}(...) inside a "
                            f"'with <lock>:' body — move I/O out of the "
                            f"critical section or baseline with rationale")

    # -- L004 --------------------------------------------------------------
    def _rule_spans(self, rel: str, sub: str, tree: ast.AST) -> None:
        if any(sub.startswith(p) for p in SPAN_EXEMPT):
            return
        cm_exprs = {id(item.context_expr)
                    for node in ast.walk(tree) if isinstance(node, ast.With)
                    for item in node.items}
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            cname = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else None)
            if cname in ("span", "obs_span"):
                if id(node) not in cm_exprs:
                    self._emit(rel, node.lineno, "L004",
                               "span(...) must be used as a context "
                               "manager ('with ... span(name):')")
                self._check_span_name(rel, node)
            elif cname == "record_complete":
                self._check_span_name(rel, node)

    def _check_span_name(self, rel: str, node: ast.Call) -> None:
        if not node.args:
            return
        arg = node.args[0]
        if not (isinstance(arg, ast.Constant) and isinstance(arg.value, str)):
            self._emit(rel, node.lineno, "L004",
                       "span name must be a string literal from the "
                       "documented taxonomy (docs/observability.md)")
            return
        if (self.span_names or self.span_patterns) \
                and not self._span_name_ok(arg.value):
            self._emit(rel, node.lineno, "L004",
                       f"span name {arg.value!r} is not in the documented "
                       f"taxonomy (docs/observability.md) — document it or "
                       f"fix the name")

    # -- L005 --------------------------------------------------------------
    def _rule_threads(self, rel: str, sub: str, tree: ast.AST) -> None:
        if any(sub == p for p in THREAD_FILES):
            return
        for node in ast.walk(tree):
            if (isinstance(node, ast.Attribute) and node.attr == "Thread"
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "threading"):
                self._emit(rel, node.lineno, "L005",
                           "bare threading.Thread outside the executor/"
                           "checkpointer — use the bounded ChunkExecutor")

    # -- L006 --------------------------------------------------------------
    def _rule_lease_metering(self, rel: str, sub: str,
                             tree: ast.AST) -> None:
        if not any(sub.startswith(p) for p in LEASE_FILES):
            return
        whole_file = sub == "core/lease.py"
        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if not (whole_file or "lease" in node.name.lower()):
                continue
            for inner in ast.walk(node):
                bad = None
                if isinstance(inner, ast.Attribute) and \
                        inner.attr == "meter":
                    bad = ".meter access"
                elif isinstance(inner, ast.Name) and \
                        inner.id == "GLOBAL_METER":
                    bad = "GLOBAL_METER reference"
                elif (isinstance(inner, ast.Call)
                      and isinstance(inner.func, ast.Attribute)
                      and inner.func.attr == "record"
                      and isinstance(inner.func.value, ast.Attribute)
                      and inner.func.value.attr == "meter"):
                    bad = "meter.record(...) call"
                if bad is not None:
                    self._emit(rel, inner.lineno, "L006",
                               f"{bad} on a lease (control-plane) path — "
                               f"lease traffic must never be metered as "
                               f"data-path ops")

    # -- L009 --------------------------------------------------------------
    def _rule_sleep_retry(self, rel: str, sub: str, tree: ast.AST) -> None:
        if any(sub == p for p in RETRY_FILES):
            return
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "sleep"
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "time"):
                self._emit(rel, node.lineno, "L009",
                           "bare time.sleep(...) outside the retry layer — "
                           "route backoff through core.retry.RetryPolicy "
                           "(bounded, jittered, deadline-aware) or wait on "
                           "an Event/Condition")
        # hand-rolled retry: a loop whose try/except swallows the error
        # and continues the iteration (the shape RetryPolicy replaces)
        loop_tries: Dict[int, ast.Try] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.While, ast.For)):
                for t in ast.walk(node):
                    if isinstance(t, ast.Try):
                        loop_tries[id(t)] = t
        for t in loop_tries.values():
            for h in t.handlers:
                if any(isinstance(x, ast.Continue)
                       for b in h.body for x in ast.walk(b)):
                    self._emit(rel, h.lineno, "L009",
                               "hand-rolled retry loop ('except: ... "
                               "continue' inside a loop) — route retries "
                               "through core.retry.RetryPolicy so attempts "
                               "are bounded and metered")
                    break

    # -- L007 --------------------------------------------------------------
    def lint_repo_layout(self) -> None:
        for p in sorted(self.root.glob("*.py")):
            if p.name not in ROOT_PY_ALLOWED:
                self._emit(self._rel(p), 1, "L007",
                           f"stray top-level python file {p.name!r} — move "
                           f"it under scripts/ (or src/)")

    # -- suppression matching ---------------------------------------------
    def result(self) -> LintResult:
        by_file: Dict[str, List[Suppression]] = {}
        for s in self.suppressions:
            by_file.setdefault(s.path, []).append(s)
        live: List[Finding] = []
        baselined: List[Finding] = []
        for f in sorted(self.findings,
                        key=lambda f: (f.path, f.line, f.rule)):
            hit = None
            if f.rule != "L008":        # a bare pragma can't suppress itself
                for s in by_file.get(f.path, ()):
                    if f.rule in s.rules and f.line in (s.line, s.target):
                        hit = s
                        break
            if hit is not None:
                hit.used = True
                baselined.append(f)
            else:
                live.append(f)
        return LintResult(live, baselined, self.suppressions)


def _stdlib() -> Set[str]:
    return set(sys.stdlib_module_names)


def iter_python_files(paths: Sequence[Path]) -> Iterable[Path]:
    for p in paths:
        if p.is_dir():
            yield from sorted(x for x in p.rglob("*.py")
                              if "__pycache__" not in x.parts)
        elif p.suffix == ".py":
            yield p


def lint_paths(paths: Sequence[Path],
               root: Optional[Path] = None) -> LintResult:
    """Lint every ``*.py`` under ``paths`` (plus the repo-root layout
    rule) and return the matched result."""
    linter = Linter(root)
    for f in iter_python_files([Path(p) for p in paths]):
        linter.lint_file(f)
    linter.lint_repo_layout()
    return linter.result()


__all__ = ["Finding", "Suppression", "LintResult", "Linter", "lint_paths",
           "load_span_taxonomy", "LAYER_DAG", "BYTE_OPS", "BLOCKING_CALLS",
           "RETRY_FILES"]

"""Trace-driven concurrency protocol checker (the dynamic sanitizer).

:func:`check_protocol` replays a window of finished ``repro.obs`` spans —
the ``lease.*`` / ``fdb.flush`` / ``io.archive`` / ``rmw.fetch`` events the
facade and the plans record — and asserts the multi-writer contract of the
writer-session layer (``docs/architecture.md`` → *Invariants*):

* **archive-without-lease** — every chunk a *session-bound* plan archives
  is covered, at archive time, by a live lease of that owner for the
  array's live generation (the ``resource``);
* **epoch-regression** — epochs per exact ``(scope, resource, lo, hi)``
  range never decrease (idempotent re-acquires legitimately repeat an
  epoch; a fresh acquire after release must advance it);
* **release-before-flush** — a lease release never leaves an *unflushed*
  (dirty) chunk of its owner uncovered: close/commit must flush before
  releasing, or the next holder can read-modify-write bytes that are not
  yet visible and race the late flush;
* **rmw-unvalidated** — a read-modify-write fetch is preceded by a
  *successful* epoch-fencing check, re-run after the owner's lease state
  last changed;
* **executor-over-window** — the ``executor.in_flight`` gauge's high-water
  mark never exceeds the configured window;
* **recover-live-lease** — a recovery sweep (``fdb.recover``) never purges
  a lease whose TTL was still live at sweep time: the last extension the
  trace shows (acquire or ``lease.renew`` heartbeat, with its ``ttl``)
  must have lapsed before the sweep began, otherwise recovery raced a
  live writer's heartbeat and may quarantine chunks it is about to flush.

Events are ordered by their span timestamps (``perf_counter_ns`` is one
process-wide monotonic clock, so cross-thread ordering is meaningful):
acquires take effect when the acquire returns (``t1``), releases and
coverage checks when they begin (``t0``), flush barriers when the barrier
completes (``t1``).  The checker is a *sanitizer*, not a verifier: it
reports contract violations it can prove from the trace and stays silent
on windows it cannot order (e.g. spans evicted from a bounded
``TraceBuffer``).

The lock half: :class:`LockOrderRecorder` hooks the
:class:`repro.obs.locks.NamedLock` observer, builds the acquisition-order
graph (edge ``a -> b`` when some thread acquired ``b`` while holding
``a``), and flags cycles — the classic deadlock precondition —
as **lock-cycle** violations.

Usage: ``fdb.check_protocol()`` (per-client convenience),
:func:`protocol_guard` (the pytest-fixture body wrapping the lease/obs
concurrency tests), or :func:`check_protocol` on any span list.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.obs.locks import set_lock_observer
from repro.obs.trace import Span, Tracer

#: the rule identifiers check_protocol / LockOrderRecorder can emit
RULES = ("archive-without-lease", "epoch-regression",
         "release-before-flush", "rmw-unvalidated",
         "executor-over-window", "lock-cycle", "recover-live-lease")


@dataclasses.dataclass
class Violation:
    """One proven protocol violation: ``rule`` names the broken invariant,
    ``t_ns`` the event time (span clock), ``details`` the correlating
    attrs (owner, scope, chunk ids, ...)."""
    rule: str
    message: str
    t_ns: int = 0
    details: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __str__(self) -> str:
        return f"[{self.rule}] {self.message}"


# (scope, resource) -> {(owner, lo, hi): epoch}   -- live leases
_LiveKey = Tuple[str, str]
_Range = Tuple[str, int, int]


def _covered(ranges: Sequence[_Range], owner: str, chunk_id: int) -> bool:
    return any(o == owner and lo <= chunk_id < hi for o, lo, hi in ranges)


def check_protocol(spans: Sequence[Span], metrics=None,
                   max_in_flight: Optional[int] = None) -> List[Violation]:
    """Replay ``spans`` (any order; they are sorted by time) and return
    every provable violation of the lease/flush contract.  ``metrics`` is
    a ``MetricsRegistry`` or its ``snapshot()`` dict; together with
    ``max_in_flight`` it enables the executor-window rule (skipped when
    either is ``None``)."""
    out: List[Violation] = []
    # -- build the time-ordered event list ---------------------------------
    # kinds: acquire@t1, release@t0, check@t0, flush@t1, rmw@t0,
    #        archive coverage@t0 + archive dirty-marking@t1,
    #        renew@t1, recover@t0 (a sweep's purge decision is made against
    #        the lease table as it stood when the sweep began)
    events: List[Tuple[int, int, str, Span]] = []
    for i, s in enumerate(spans):
        a = s.attrs
        if s.name == "lease.acquire" and "error" not in a and "epoch" in a:
            events.append((s.t1_ns, i, "acquire", s))
        elif s.name == "lease.release":
            events.append((s.t0_ns, i, "release", s))
        elif s.name == "lease.check" and "error" not in a:
            events.append((s.t0_ns, i, "check", s))
        elif s.name == "fdb.flush" and "error" not in a:
            # a flush that raised (crashed writer, permanent backend
            # error) published nothing — it is not a barrier
            events.append((s.t1_ns, i, "flush", s))
        elif s.name == "rmw.fetch" and "owner" in a:
            events.append((s.t0_ns, i, "rmw", s))
        elif s.name == "io.archive" and "owner" in a:
            events.append((s.t0_ns, i, "archive", s))
            events.append((s.t1_ns, i, "dirty", s))
        elif s.name == "lease.renew" and "error" not in a:
            events.append((s.t1_ns, i, "renew", s))
        elif s.name == "fdb.recover" and "error" not in a:
            events.append((s.t0_ns, i, "recover", s))
    events.sort(key=lambda e: (e[0], e[1]))

    live: Dict[_LiveKey, Dict[_Range, int]] = {}
    #: highest epoch ever granted per exact range
    epoch_high: Dict[Tuple[str, str, int, int], int] = {}
    #: (scope, resource, owner) -> {chunk_id: client} archived, unflushed
    dirty: Dict[Tuple[str, str, str], Dict[int, Optional[str]]] = {}
    #: (owner, scope, resource) -> time of last successful fencing check /
    #: last change to the owner's lease set
    last_check: Dict[Tuple[str, str, str], int] = {}
    last_change: Dict[Tuple[str, str, str], int] = {}
    #: (scope, resource, owner) -> (t_ns, ttl_s) of the last TTL extension
    #: the trace shows (TTL'd acquire or heartbeat renewal)
    last_extend: Dict[Tuple[str, str, str], Tuple[int, float]] = {}

    for t, _i, kind, s in events:
        a = s.attrs
        scope = str(a.get("scope", ""))
        res = str(a.get("resource", ""))
        owner = str(a.get("owner", ""))
        key: _LiveKey = (scope, res)
        if kind == "acquire":
            lo, hi, epoch = int(a["lo"]), int(a["hi"]), int(a["epoch"])
            rng_key = (scope, res, lo, hi)
            high = epoch_high.get(rng_key)
            if high is not None and epoch < high:
                out.append(Violation(
                    "epoch-regression",
                    f"range [{lo}, {hi}) of {scope}/{res} granted at epoch "
                    f"{epoch} after epoch {high}: epochs must be monotonic",
                    t, {"scope": scope, "resource": res, "lo": lo, "hi": hi,
                        "epoch": epoch, "prev_epoch": high}))
            epoch_high[rng_key] = max(high or 0, epoch)
            live.setdefault(key, {})[(owner, lo, hi)] = epoch
            last_change[(owner, scope, res)] = t
            if a.get("ttl") is not None:
                last_extend[(scope, res, owner)] = (t, float(a["ttl"]))
        elif kind == "release":
            lo, hi = int(a["lo"]), int(a["hi"])
            held = live.get(key, {})
            if a.get("exact"):
                removed = held.pop((owner, lo, hi), None) is not None
            else:
                hit = [r for r in held
                       if r[0] == owner and r[1] < hi and lo < r[2]]
                removed = bool(hit)
                for r in hit:
                    held.pop(r)
            if removed:
                last_change[(owner, scope, res)] = t
            # a release must never orphan the owner's unflushed chunks:
            # every dirty chunk has to stay covered by a remaining lease
            # (sibling overlapping leases keep their chunks protected)
            d = dirty.get((scope, res, owner))
            if d:
                remaining = list(held)
                orphaned = sorted(c for c in d
                                  if not _covered(remaining, owner, c))
                if orphaned:
                    for c in orphaned:
                        d.pop(c)        # report each orphaning once
                    out.append(Violation(
                        "release-before-flush",
                        f"{owner!r} released [{lo}, {hi}) of {scope}/{res} "
                        f"leaving unflushed chunks {orphaned} uncovered: "
                        f"flush must precede release",
                        t, {"scope": scope, "resource": res, "owner": owner,
                            "chunk_ids": orphaned}))
        elif kind == "check":
            last_check[(owner, scope, res)] = t
        elif kind == "flush":
            client = a.get("client")
            for d in dirty.values():
                for c in [c for c, cl in d.items() if cl == client]:
                    d.pop(c)
        elif kind == "rmw":
            ka = (owner, scope, res)
            chk, chg = last_check.get(ka), last_change.get(ka)
            if chk is None or (chg is not None and chk < chg):
                out.append(Violation(
                    "rmw-unvalidated",
                    f"{owner!r} ran a read-modify-write fetch on "
                    f"{scope}/{res} without a successful lease check after "
                    f"its lease state last changed",
                    t, {"scope": scope, "resource": res, "owner": owner,
                        "last_check": chk, "last_change": chg}))
        elif kind == "archive":
            held = list(live.get(key, {}))
            missing = sorted(int(c) for c in a.get("chunk_ids", ())
                             if not _covered(held, owner, int(c)))
            if missing:
                out.append(Violation(
                    "archive-without-lease",
                    f"{owner!r} archived chunks {missing} of {scope}/{res} "
                    f"with no live covering lease at archive time",
                    t, {"scope": scope, "resource": res, "owner": owner,
                        "chunk_ids": missing}))
        elif kind == "dirty":
            d = dirty.setdefault((scope, res, owner), {})
            client = a.get("client")
            for c in a.get("chunk_ids", ()):
                d[int(c)] = client
        elif kind == "renew":
            # a heartbeat renewal re-arms the TTL but is NOT a lease-set
            # change: epochs are preserved, fenced archives stay valid, so
            # last_change is untouched.  renewed == 0 extends nothing.
            if a.get("renewed"):
                ka = (scope, res, owner)
                ttl = a.get("ttl")
                if ttl is None and ka in last_extend:
                    ttl = last_extend[ka][1]    # renew(ttl=None) re-arms
                if ttl is not None:             # the lease's existing TTL
                    last_extend[ka] = (t, float(ttl))
        elif kind == "recover":
            for e in a.get("expired", ()):
                r_res, r_owner = str(e["resource"]), str(e["owner"])
                ext = last_extend.get((scope, r_res, r_owner))
                if ext is not None and ext[0] + ext[1] * 1e9 > t:
                    out.append(Violation(
                        "recover-live-lease",
                        f"recovery sweep purged {r_owner!r}'s lease "
                        f"[{e['lo']}, {e['hi']}) of {scope}/{r_res} whose "
                        f"TTL ({ext[1]}s, last extended "
                        f"{(t - ext[0]) / 1e9:.3f}s before the sweep) was "
                        f"still live: recovery raced a heartbeat",
                        t, {"scope": scope, "resource": r_res,
                            "owner": r_owner, "lo": e["lo"], "hi": e["hi"],
                            "ttl": ext[1]}))
                live.get((scope, r_res), {}).pop(
                    (r_owner, int(e["lo"]), int(e["hi"])), None)
                last_change[(r_owner, scope, r_res)] = t
            for o in a.get("orphans", ()):
                # quarantined intents are accounted for: the dead client's
                # archives were never published, so they are no longer
                # chunks a later release could orphan
                d = dirty.get((scope, str(o["resource"]), str(o["owner"])))
                if d:
                    for c in o.get("chunk_ids", ()):
                        d.pop(int(c), None)

    # -- executor window (from the metrics gauge's high-water mark) --------
    if metrics is not None and max_in_flight is not None:
        snap = metrics.snapshot() if hasattr(metrics, "snapshot") else metrics
        g = snap.get("executor.in_flight")
        if g and g.get("max", 0) > max_in_flight:
            out.append(Violation(
                "executor-over-window",
                f"executor.in_flight reached {g['max']} > configured "
                f"window {max_in_flight}",
                0, {"max": g["max"], "window": max_in_flight}))
    return out


class LockOrderRecorder:
    """Acquisition-order recorder over the named locks
    (:class:`repro.obs.locks.NamedLock`).

    While installed, every acquisition attempt adds edges ``held -> about
    to acquire`` to a directed graph; :meth:`cycles` flags any cycle —
    two code paths taking the same locks in opposite orders, the deadlock
    precondition — and :meth:`violations` wraps them as ``lock-cycle``
    :class:`Violation`\\ s.  Install/uninstall nests: the previous
    observer is chained, so a recorder inside a recorder sees everything.
    """

    def __init__(self) -> None:
        self.edges: Dict[str, Set[str]] = {}
        self._mu = threading.Lock()     # plain: must not observe itself
        self._prev = None
        self._installed = False

    def _observe(self, held: Tuple[str, ...], acquiring: str) -> None:
        prev = self._prev
        if prev is not None:
            prev(held, acquiring)
        if held:
            with self._mu:
                for h in held:
                    if h != acquiring:
                        self.edges.setdefault(h, set()).add(acquiring)

    def install(self) -> "LockOrderRecorder":
        if not self._installed:
            self._prev = set_lock_observer(self._observe)
            self._installed = True
        return self

    def uninstall(self) -> None:
        if self._installed:
            set_lock_observer(self._prev)
            self._prev = None
            self._installed = False

    def __enter__(self) -> "LockOrderRecorder":
        return self.install()

    def __exit__(self, *exc) -> None:
        self.uninstall()

    def cycles(self) -> List[List[str]]:
        """Every elementary cycle reachable in the recorded graph (one
        representative per back edge found by DFS), as name paths like
        ``["a", "b", "a"]``."""
        with self._mu:
            edges = {k: sorted(v) for k, v in self.edges.items()}
        found: List[List[str]] = []
        seen_cycles: Set[Tuple[str, ...]] = set()

        def dfs(node: str, stack: List[str], on_stack: Set[str],
                done: Set[str]) -> None:
            stack.append(node)
            on_stack.add(node)
            for nxt in edges.get(node, ()):
                if nxt in on_stack:
                    cyc = stack[stack.index(nxt):] + [nxt]
                    canon = tuple(sorted(set(cyc)))
                    if canon not in seen_cycles:
                        seen_cycles.add(canon)
                        found.append(cyc)
                elif nxt not in done:
                    dfs(nxt, stack, on_stack, done)
            stack.pop()
            on_stack.discard(node)
            done.add(node)

        done: Set[str] = set()
        for start in sorted(edges):
            if start not in done:
                dfs(start, [], set(), done)
        return found

    def violations(self) -> List[Violation]:
        return [Violation("lock-cycle",
                          "lock acquisition order cycle: "
                          + " -> ".join(c), 0, {"cycle": c})
                for c in self.cycles()]


@contextlib.contextmanager
def protocol_guard(tracer: Tracer,
                   max_in_flight: Optional[int] = None,
                   lock_order: bool = True
                   ) -> Iterator[LockOrderRecorder]:
    """Wrap a block (the pytest fixture body): record a trace mark and the
    lock acquisition order, run the block, then assert the window is
    violation-free.  Exceptions from the block propagate unmasked; the
    assertion only runs on a clean exit."""
    mark = tracer.mark()
    recorder = LockOrderRecorder()
    if lock_order:
        recorder.install()
    try:
        yield recorder
    finally:
        recorder.uninstall()
    violations = check_protocol(tracer.spans(mark), tracer.metrics,
                                max_in_flight=max_in_flight)
    violations += recorder.violations()
    assert not violations, "concurrency protocol violations:\n" + "\n".join(
        f"  {v}" for v in violations)


__all__ = ["RULES", "Violation", "check_protocol", "LockOrderRecorder",
           "protocol_guard"]

"""Parameter definitions and forward passes for all assigned architectures.

Layers are applied through an *unrolled* Python loop (no scan): this keeps
``compiled.cost_analysis()`` / collective-byte parsing faithful for the
dry-run roofline (XLA counts while bodies once — measured, see EXPERIMENTS.md)
and lets heterogeneous patterns (jamba 1:7, xlstm 7:1) stay trivially
expressible.  Activation rematerialisation wraps each layer in
``jax.checkpoint`` when requested.

Every parameter carries *logical axis names* used by
``repro.sharding.partition`` to derive NamedShardings (TP/EP over ``model``,
FSDP over ``data``, replication fallback on non-divisible dims).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import components as C
from . import ssm, xlstm
from .config import ArchConfig

Array = jax.Array


class ParamDef:
    """A parameter leaf: shape + logical axes + init style (tree leaf)."""

    __slots__ = ("shape", "axes", "init", "scale")

    def __init__(self, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                 init: str = "normal", scale: float = 1.0):
        assert len(shape) == len(axes), (shape, axes)
        self.shape = tuple(shape)
        self.axes = tuple(axes)
        self.init = init
        self.scale = scale

    def __repr__(self) -> str:
        return f"ParamDef({self.shape}, {self.axes}, {self.init})"


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


# ---------------------------------------------------------------------------
# Parameter trees
# ---------------------------------------------------------------------------

def _attn_defs(cfg: ArchConfig, d_in: int) -> Dict[str, ParamDef]:
    QH, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    out = {
        "wq": ParamDef((d_in, QH, Dh), ("embed", "heads", None)),
        "wk": ParamDef((d_in, KV, Dh), ("embed", "kv_heads", None)),
        "wv": ParamDef((d_in, KV, Dh), ("embed", "kv_heads", None)),
        "wo": ParamDef((QH, Dh, d_in), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDef((QH, Dh), ("heads", None), "zeros")
        out["bk"] = ParamDef((KV, Dh), ("kv_heads", None), "zeros")
        out["bv"] = ParamDef((KV, Dh), ("kv_heads", None), "zeros")
    return out


def _mlp_defs(cfg: ArchConfig, gelu: bool = False) -> Dict[str, ParamDef]:
    D, F = cfg.d_model, cfg.d_ff
    if gelu:
        return {
            "w_up": ParamDef((D, F), ("embed", "mlp")),
            "b_up": ParamDef((F,), ("mlp",), "zeros"),
            "w_down": ParamDef((F, D), ("mlp", "embed")),
            "b_down": ParamDef((D,), (None,), "zeros"),
        }
    return {
        "w_gate": ParamDef((D, F), ("embed", "mlp")),
        "w_up": ParamDef((D, F), ("embed", "mlp")),
        "w_down": ParamDef((F, D), ("mlp", "embed")),
    }


def _moe_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    out = {
        "router": ParamDef((D, E), ("embed", None)),
        "w_gate": ParamDef((E, D, F), ("expert", "embed", None)),
        "w_up": ParamDef((E, D, F), ("expert", "embed", None)),
        "w_down": ParamDef((E, F, D), ("expert", None, "embed")),
    }
    if cfg.n_shared_experts:
        Fs = cfg.d_ff * cfg.n_shared_experts
        out.update({
            "shared_gate": ParamDef((D, Fs), ("embed", "mlp")),
            "shared_up": ParamDef((D, Fs), ("embed", "mlp")),
            "shared_down": ParamDef((Fs, D), ("mlp", "embed")),
        })
    return out


def _mamba_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    D, di, N, K, R = (cfg.d_model, cfg.d_inner, cfg.ssm_state_dim,
                      cfg.ssm_conv_dim, cfg.dt_rank)
    return {
        "in_proj": ParamDef((D, 2 * di), ("embed", "inner")),
        "conv_w": ParamDef((K, di), (None, "inner")),
        "conv_b": ParamDef((di,), ("inner",), "zeros"),
        "x_proj": ParamDef((di, R + 2 * N), ("inner", None)),
        "dt_proj": ParamDef((R, di), (None, "inner")),
        "dt_bias": ParamDef((di,), ("inner",), "zeros"),
        "A_log": ParamDef((di, N), ("inner", None), "a_log"),
        "D_skip": ParamDef((di,), ("inner",), "ones"),
        "out_proj": ParamDef((di, D), ("inner", "embed")),
    }


def _mlstm_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    D = cfg.d_model
    du = int(D * cfg.mlstm_proj_factor)
    H = cfg.n_heads
    dk = du // H
    return {
        "ln": ParamDef((D,), (None,), "ones"),
        "up_proj": ParamDef((D, 2 * du), ("embed", "inner")),
        "wq": ParamDef((H, dk, dk), ("heads", None, None)),
        "wk": ParamDef((H, dk, dk), ("heads", None, None)),
        "wv": ParamDef((H, dk, dk), ("heads", None, None)),
        "wi": ParamDef((du, H), ("inner", "heads")),
        "wf": ParamDef((du, H), ("inner", "heads")),
        "bi": ParamDef((H,), (None,), "zeros"),
        "bf": ParamDef((H,), (None,), "forget_bias"),
        "out_ln": ParamDef((du,), (None,), "ones"),
        "down_proj": ParamDef((du, D), ("inner", "embed")),
    }


def _slstm_defs(cfg: ArchConfig) -> Dict[str, ParamDef]:
    D = cfg.d_model
    H = cfg.n_heads
    dh = D // H
    F = int(math.ceil(cfg.slstm_ff_factor * D / 128) * 128)
    return {
        "ln": ParamDef((D,), (None,), "ones"),
        "w": ParamDef((D, H, dh, 4), ("embed", "heads", None, None)),
        "r": ParamDef((H, dh, dh, 4), ("heads", None, None, None)),
        "b": ParamDef((H, dh, 4), ("heads", None, None), "zeros"),
        "out_proj": ParamDef((D, D), ("embed", "embed2")),
        "ln2": ParamDef((D,), (None,), "ones"),
        "ff_gate": ParamDef((D, F), ("embed", "mlp")),
        "ff_up": ParamDef((D, F), ("embed", "mlp")),
        "ff_down": ParamDef((F, D), ("mlp", "embed")),
    }


def _layer_defs(cfg: ArchConfig, layer_idx: int, kind: str,
                decoder: bool = True) -> Dict[str, Any]:
    D = cfg.d_model
    gelu = cfg.family == "audio"
    ln = lambda: ParamDef((D,), (None,), "ones")  # noqa: E731
    if kind in ("mlstm",):
        return {"kind": kind, **_mlstm_defs(cfg)}
    if kind in ("slstm",):
        return {"kind": kind, **_slstm_defs(cfg)}
    out: Dict[str, Any] = {"kind": kind, "ln1": ln(), "ln2": ln()}
    if gelu:
        out["ln1_b"] = ParamDef((D,), (None,), "zeros")
        out["ln2_b"] = ParamDef((D,), (None,), "zeros")
    if kind == "attn":
        out["attn"] = _attn_defs(cfg, D)
    elif kind == "mamba":
        out["mamba"] = _mamba_defs(cfg)
    else:
        raise ValueError(kind)
    if decoder and cfg.is_encdec:
        out["ln_cross"] = ln()
        if gelu:
            out["ln_cross_b"] = ParamDef((D,), (None,), "zeros")
        out["cross"] = _attn_defs(cfg, D)
    if cfg.is_moe_layer(layer_idx):
        out["moe"] = _moe_defs(cfg)
    else:
        out["mlp"] = _mlp_defs(cfg, gelu=gelu)
    return out


def param_defs(cfg: ArchConfig) -> Dict[str, Any]:
    Vp, D = cfg.padded_vocab(), cfg.d_model
    tree: Dict[str, Any] = {
        "embed": ParamDef((Vp, D), ("vocab", "embed"), scale=1.0),
        "final_ln": ParamDef((D,), (None,), "ones"),
        "lm_head": ParamDef((D, Vp), ("embed", "vocab")),
        "layers": [
            _layer_defs(cfg, i, cfg.block_pattern[i % len(cfg.block_pattern)])
            for i in range(cfg.n_layers)
        ],
    }
    if cfg.family == "audio":
        tree["final_ln_b"] = ParamDef((D,), (None,), "zeros")
    if cfg.is_encdec:
        tree["enc_layers"] = [
            _layer_defs(cfg, i, "attn", decoder=False)
            for i in range(cfg.encoder_layers)
        ]
        tree["enc_final_ln"] = ParamDef((D,), (None,), "ones")
        if cfg.family == "audio":
            tree["enc_final_ln_b"] = ParamDef((D,), (None,), "zeros")
    return tree


def _strip_kind(tree):
    if isinstance(tree, dict):
        return {k: _strip_kind(v) for k, v in tree.items() if k != "kind"}
    if isinstance(tree, list):
        return [_strip_kind(v) for v in tree]
    return tree


def init_params(cfg: ArchConfig, key: Array, dtype=jnp.float32):
    defs = _strip_kind(param_defs(cfg))
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))

    def make(d: ParamDef, k):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        if d.init == "forget_bias":
            return jnp.full(d.shape, 3.0, dtype)
        if d.init == "a_log":
            n = d.shape[-1]
            a = jnp.tile(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
                         d.shape[:-1] + (1,))
            return a.astype(dtype)
        std = d.scale * 0.02
        return (jax.random.normal(k, d.shape, jnp.float32) * std).astype(dtype)

    return treedef.unflatten([make(d, k) for d, k in zip(leaves, keys)])


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    defs = _strip_kind(param_defs(cfg))
    return jax.tree.map(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
                        is_leaf=_is_def)


def param_logical_axes(cfg: ArchConfig):
    defs = _strip_kind(param_defs(cfg))
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=_is_def)


def count_params(cfg: ArchConfig, padded_vocab: Optional[int] = None,
                 active_only: bool = False) -> int:
    defs = param_defs(cfg)

    def n_of(v) -> int:
        return sum(math.prod(d.shape)
                   for d in jax.tree.leaves(_strip_kind(v), is_leaf=_is_def)
                   if _is_def(d))

    total = 0
    all_layers = list(defs["layers"]) + list(defs.get("enc_layers", []))
    for layer in all_layers:
        for k, v in layer.items():
            if k == "kind":
                continue
            n = n_of(v)
            if active_only and k == "moe":
                routed = sum(math.prod(v[key].shape)
                             for key in ("w_gate", "w_up", "w_down"))
                n = n - routed + routed * cfg.top_k // max(cfg.n_experts, 1)
            total += n
    for k, v in defs.items():
        if k in ("layers", "enc_layers"):
            continue
        total += n_of(v)
    return total


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _norm(cfg, x, scale, bias=None):
    if cfg.family == "audio":
        return C.layer_norm(x, scale, bias, cfg.norm_eps)
    return C.rms_norm(x, scale, cfg.norm_eps)


def _ffn(cfg, layer, x):
    h = _norm(cfg, x, layer["ln2"], layer.get("ln2_b"))
    if "moe" in layer:
        return x + C.moe_mlp(h, layer["moe"], cfg)
    if cfg.family == "audio":
        return x + C.gelu_mlp(h, layer["mlp"])
    return x + C.swiglu_mlp(h, layer["mlp"])


def apply_layer(cfg: ArchConfig, layer: Dict[str, Any], kind: str, x: Array,
                enc_out: Optional[Array] = None, causal: bool = True,
                mamba_chunk: int = 256, attn_impl=None) -> Array:
    if kind == "mlstm":
        return xlstm.mlstm_block(x, layer, cfg, chunk=mamba_chunk)
    if kind == "slstm":
        return xlstm.slstm_block(x, layer, cfg)
    h = _norm(cfg, x, layer["ln1"], layer.get("ln1_b"))
    if kind == "attn":
        x = x + C.attention(h, layer["attn"], cfg, causal=causal,
                            attn_impl=attn_impl)
    elif kind == "mamba":
        x = x + ssm.mamba_block(h, layer["mamba"], cfg, chunk=mamba_chunk)
    if enc_out is not None and "cross" in layer:
        hc = _norm(cfg, x, layer["ln_cross"], layer.get("ln_cross_b"))
        ek = jnp.einsum("btd,dhk->bthk", enc_out, layer["cross"]["wk"])
        ev = jnp.einsum("btd,dhk->bthk", enc_out, layer["cross"]["wv"])
        x = x + C.cross_attention(hc, layer["cross"], cfg, ek, ev)
    return _ffn(cfg, layer, x)


def encode(cfg: ArchConfig, params, frames: Array,
           attn_impl=None) -> Array:
    """Encoder stack over stub frame embeddings (B, T, D)."""
    x = frames
    for layer in params["enc_layers"]:
        x = apply_layer(cfg, layer, "attn", x, causal=False,
                        attn_impl=attn_impl)
    return _norm(cfg, x, params["enc_final_ln"], params.get("enc_final_ln_b"))


def forward(cfg: ArchConfig, params, tokens: Optional[Array] = None,
            prefix_embeds: Optional[Array] = None,
            encoder_frames: Optional[Array] = None,
            remat: bool = False, mamba_chunk: int = 256,
            constrain=None) -> Array:
    """Full-sequence forward → logits (B, S, Vp).

    ``prefix_embeds``: VLM stub patch embeddings prepended to token embeds.
    ``encoder_frames``: audio stub frame embeddings for enc-dec models.
    ``constrain``: optional fn applied to the residual stream at layer
    boundaries (sequence-parallel sharding constraints).
    """
    parts = []
    if prefix_embeds is not None:
        parts.append(prefix_embeds)
    if tokens is not None:
        parts.append(params["embed"][tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    enc_out = None
    if cfg.is_encdec:
        assert encoder_frames is not None
        enc_out = encode(cfg, params, encoder_frames)

    def run_layer(layer, kind, x, enc_out):
        return apply_layer(cfg, layer, kind, x, enc_out,
                           mamba_chunk=mamba_chunk)

    if remat:
        run_layer = jax.checkpoint(run_layer, static_argnums=(1,))
    if constrain is not None:
        x = constrain(x)
    for i, layer in enumerate(params["layers"]):
        kind = cfg.block_pattern[i % len(cfg.block_pattern)]
        x = run_layer(layer, kind, x, enc_out)
        if constrain is not None:
            x = constrain(x)
    x = _norm(cfg, x, params["final_ln"], params.get("final_ln_b"))
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def loss_fn(cfg: ArchConfig, params, tokens: Array, labels: Array,
            **fw_kwargs) -> Array:
    logits = forward(cfg, params, tokens, **fw_kwargs)
    if logits.shape[1] != labels.shape[1]:       # vlm prefix: score text tail
        logits = logits[:, -labels.shape[1]:]
    Vp = logits.shape[-1]
    # f32 math fuses into the reduction (no f32 materialisation in HBM)
    logits = logits.astype(jnp.float32)
    # mask padded vocab slots out of the softmax
    if Vp > cfg.vocab_size:
        pad_mask = jnp.arange(Vp) >= cfg.vocab_size
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# KV-cache / recurrent-state serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, src_len: int = 0) -> Dict[str, Any]:
    KV, Dh = cfg.n_kv_heads, cfg.dh
    layers: List[Any] = []
    for i in range(cfg.n_layers):
        kind = cfg.block_pattern[i % len(cfg.block_pattern)]
        if kind == "attn":
            entry: Dict[str, Any] = {
                "k": jnp.zeros((batch, max_len, KV, Dh), dtype),
                "v": jnp.zeros((batch, max_len, KV, Dh), dtype),
            }
            if cfg.is_encdec:
                entry["ek"] = jnp.zeros((batch, src_len, KV, Dh), dtype)
                entry["ev"] = jnp.zeros((batch, src_len, KV, Dh), dtype)
            layers.append(entry)
        elif kind == "mamba":
            layers.append(ssm.mamba_init_state(cfg, batch, dtype))
        elif kind == "mlstm":
            layers.append(xlstm.mlstm_init_state(cfg, batch))
        elif kind == "slstm":
            layers.append(xlstm.slstm_init_state(cfg, batch))
    return {"layers": layers}


def decode_step(cfg: ArchConfig, params, token: Array, cache: Dict[str, Any],
                pos: Array) -> Tuple[Array, Dict[str, Any]]:
    """One decode step. token: (B, 1) int32; pos: scalar int32.

    Returns (logits (B, 1, Vp), new cache)."""
    x = params["embed"][token]
    new_layers: List[Any] = []
    for i, layer in enumerate(params["layers"]):
        kind = cfg.block_pattern[i % len(cfg.block_pattern)]
        state = cache["layers"][i]
        if kind == "attn":
            h = _norm(cfg, x, layer["ln1"], layer.get("ln1_b"))
            att, ck, cv = C.attention_decode(h, layer["attn"], cfg,
                                             state["k"], state["v"], pos)
            x = x + att
            new_state = dict(state)
            new_state["k"], new_state["v"] = ck, cv
            if cfg.is_encdec and "cross" in layer:
                hc = _norm(cfg, x, layer["ln_cross"], layer.get("ln_cross_b"))
                x = x + C.cross_attention(hc, layer["cross"], cfg,
                                          state["ek"], state["ev"])
            x = _ffn(cfg, layer, x)
            new_layers.append(new_state)
        elif kind == "mamba":
            h = _norm(cfg, x, layer["ln1"], layer.get("ln1_b"))
            out, new_state = ssm.mamba_decode(h, layer["mamba"], cfg, state)
            x = _ffn(cfg, layer, x + out)
            new_layers.append(new_state)
        elif kind == "mlstm":
            x, new_state = xlstm.mlstm_block_decode(x, layer, cfg, state)
            new_layers.append(new_state)
        elif kind == "slstm":
            x, new_state = xlstm.slstm_block_decode(x, layer, cfg, state)
            new_layers.append(new_state)
    x = _norm(cfg, x, params["final_ln"], params.get("final_ln_b"))
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return logits, {"layers": new_layers}


def prefill(cfg: ArchConfig, params, tokens: Array, cache: Dict[str, Any],
            encoder_frames: Optional[Array] = None,
            prefix_embeds: Optional[Array] = None,
            mamba_chunk: int = 256,
            attn_impl=None, constrain=None) -> Tuple[Array, Dict[str, Any]]:
    """Prefill pass: full forward that also fills the KV cache and returns
    last-position logits.  (Recurrent layers refresh their state too.)"""
    parts = []
    if prefix_embeds is not None:
        parts.append(prefix_embeds)
    if tokens is not None:
        parts.append(params["embed"][tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    S = x.shape[1]
    enc_out = encode(cfg, params, encoder_frames,
                     attn_impl=attn_impl) if cfg.is_encdec else None
    if constrain is not None:
        x = constrain(x)
    new_layers: List[Any] = []
    for i, layer in enumerate(params["layers"]):
        kind = cfg.block_pattern[i % len(cfg.block_pattern)]
        state = cache["layers"][i]
        if kind == "attn":
            h = _norm(cfg, x, layer["ln1"], layer.get("ln1_b"))
            q, k, v = C.qkv_project(h, layer["attn"], cfg)
            posv = jnp.arange(S)
            cos, sin = C.rope_freqs(posv, cfg.dh, cfg.rope_theta)
            q = C.apply_rope(q, cos, sin)
            k = C.apply_rope(k, cos, sin)
            if attn_impl is not None:
                att = attn_impl(q, k, v, causal=True)
            else:
                att = C.gqa_scores_softmax_out(q, k, v, causal=True)
            x = x + jnp.einsum("bshk,hkd->bsd", att, layer["attn"]["wo"])
            new_state = dict(state)
            new_state["k"] = jax.lax.dynamic_update_slice_in_dim(
                state["k"], k.astype(state["k"].dtype), 0, axis=1)
            new_state["v"] = jax.lax.dynamic_update_slice_in_dim(
                state["v"], v.astype(state["v"].dtype), 0, axis=1)
            if cfg.is_encdec and "cross" in layer:
                ek = jnp.einsum("btd,dhk->bthk", enc_out, layer["cross"]["wk"])
                ev = jnp.einsum("btd,dhk->bthk", enc_out, layer["cross"]["wv"])
                hc = _norm(cfg, x, layer["ln_cross"], layer.get("ln_cross_b"))
                x = x + C.cross_attention(hc, layer["cross"], cfg, ek, ev)
                new_state["ek"] = ek.astype(state["ek"].dtype)
                new_state["ev"] = ev.astype(state["ev"].dtype)
            x = _ffn(cfg, layer, x)
            new_layers.append(new_state)
        elif kind == "mamba":
            h = _norm(cfg, x, layer["ln1"], layer.get("ln1_b"))
            out, new_state = ssm.mamba_block(h, layer["mamba"], cfg,
                                             chunk=min(256, S),
                                             return_state=True)
            x = _ffn(cfg, layer, x + out)
            new_layers.append(new_state)
        elif kind == "mlstm":
            x, new_state = xlstm.mlstm_block(x, layer, cfg,
                                             chunk=min(256, S),
                                             return_state=True)
            new_layers.append(new_state)
        elif kind == "slstm":
            h = _norm(cfg, x, layer["ln"], None)
            core, new_state = xlstm.slstm_core(h, layer, cfg,
                                               return_state=True)
            x = x + jnp.einsum("bsd,de->bse", core, layer["out_proj"])
            h2 = C.rms_norm(x, layer["ln2"], cfg.norm_eps)
            x = x + C.swiglu_mlp(h2, {"w_gate": layer["ff_gate"],
                                      "w_up": layer["ff_up"],
                                      "w_down": layer["ff_down"]})
            new_layers.append(new_state)
        if constrain is not None:
            x = constrain(x)
    x = _norm(cfg, x, params["final_ln"], params.get("final_ln_b"))
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], params["lm_head"])
    return logits, {"layers": new_layers}

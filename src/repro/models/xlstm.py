"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM.

mLSTM (matrix memory, no memory mixing) admits a chunkwise-parallel training
form — intra-chunk quadratic attention-like compute plus inter-chunk
recurrent carries — which is what makes the architecture sub-quadratic and
long_500k-eligible.  Chunks are iterated with an unrolled Python loop (dry-run
FLOP fidelity); the carry is the (dk×dv) matrix memory + normalizer + max
stabilizer.

sLSTM (scalar memory, block-diagonal memory mixing) has a true nonlinear
recurrence and cannot be parallelised over time; it runs as ``jax.lax.scan``
over the sequence.  Its FLOPs are corrected analytically in the roofline
harness (XLA cost analysis counts while bodies once — see EXPERIMENTS.md).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_qkv_gates(xu: Array, p: Dict[str, Array]):
    """xu: (B, C, du) → q,k,v (B,C,H,dk), log-gates (B,C,H).

    q/k/v are head-wise block-diagonal projections (the official
    ``LinearHeadwiseExpand`` / qkv_proj_blocksize trick) — full (du×du)
    matrices would triple the parameter budget of the 1.3B config."""
    H, dk = p["wq"].shape[0], p["wq"].shape[1]
    xh = xu.reshape(xu.shape[0], xu.shape[1], H, dk)
    q = jnp.einsum("bchk,hkj->bchj", xh, p["wq"])
    k = jnp.einsum("bchk,hkj->bchj", xh, p["wk"])
    v = jnp.einsum("bchk,hkj->bchj", xh, p["wv"])
    li = jnp.einsum("bcu,uh->bch", xu, p["wi"]).astype(jnp.float32) + p["bi"]
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bcu,uh->bch", xu, p["wf"]).astype(jnp.float32) + p["bf"])
    return q, k, v, li, lf


def mlstm_chunk_scan(xu: Array, p: Dict[str, Array], n_heads: int,
                     chunk: int = 256, return_state: bool = False):
    """Chunkwise mLSTM core. xu: (B, S, du) → (B, S, du)."""
    B, S, du = xu.shape
    H = n_heads
    dk = du // H
    chunk = min(chunk, S)
    n_chunks = (S + chunk - 1) // chunk
    scale = 1.0 / math.sqrt(dk)

    def one_chunk(carry, sl):
        C_prev, n_prev, m_prev = carry
        q, k, v, li, lf = _mlstm_qkv_gates(sl, p)
        Cn = sl.shape[1]
        F = jnp.cumsum(lf, axis=1)                      # (B,C,H) inclusive
        # intra-chunk log weights W[t,s] = F_t - F_s + li_s  (s <= t)
        W = F[:, :, None, :] - F[:, None, :, :] + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((Cn, Cn), bool))
        W = jnp.where(tri[None, :, :, None], W, -jnp.inf)   # (B,t,s,H)
        G = F + m_prev[:, None, :]                      # inter weight (B,C,H)
        m_intra = jnp.max(W, axis=2)                    # (B,t,H)
        m_t = jnp.maximum(m_intra, G)
        D = jnp.exp(W - m_t[:, :, None, :])             # (B,t,s,H)
        inter_w = jnp.exp(G - m_t)                      # (B,t,H)
        qf = q.astype(jnp.float32) * scale
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        scores = jnp.einsum("bthk,bshk->btsh", qf, kf) * D
        num = jnp.einsum("btsh,bshk->bthk", scores, vf) + \
            inter_w[..., None] * jnp.einsum("bthk,bhkv->bthv", qf, C_prev)
        # normalizer vector: n_t = Σ_s D_ts k_s + inter_w · n_prev
        nvec = jnp.einsum("btsh,bshk->bthk", D, kf) + \
            inter_w[..., None] * n_prev[:, None, :, :]
        den = jnp.abs(jnp.einsum("bthk,bthk->bth", qf, nvec))
        den = jnp.maximum(den, jnp.exp(-m_t))[..., None]
        h = (num / den).astype(xu.dtype)                # (B,t,H,dk)
        # carry update
        FC = F[:, -1:, :]                               # total logf (B,1,H)
        carry_w = FC - F + li                           # (B,s,H)
        m_carry = jnp.maximum(m_prev + FC[:, 0], jnp.max(carry_w, axis=1))
        cw = jnp.exp(carry_w - m_carry[:, None, :])     # (B,s,H)
        decay = jnp.exp(m_prev + FC[:, 0] - m_carry)    # (B,H)
        C_new = decay[..., None, None] * C_prev + \
            jnp.einsum("bsh,bshk,bshv->bhkv", cw, kf, vf)
        n_new = decay[..., None] * n_prev + \
            jnp.einsum("bsh,bshk->bhk", cw, kf)
        return (C_new, n_new, m_carry), h.reshape(B, Cn, du)

    carry = (jnp.zeros((B, H, dk, dk), jnp.float32),    # matrix memory
             jnp.zeros((B, H, dk), jnp.float32),        # normalizer
             jnp.full((B, H), -1e30, jnp.float32))      # max stabilizer
    if n_chunks <= 8:
        outs = []
        for c in range(n_chunks):                       # unrolled (dry-run)
            carry, h = one_chunk(carry, xu[:, c * chunk:(c + 1) * chunk])
            outs.append(h)
        out = jnp.concatenate(outs, axis=1)
    else:
        xs = xu.reshape(B, n_chunks, chunk, du).swapaxes(0, 1)
        carry, hs = jax.lax.scan(one_chunk, carry, xs)
        out = hs.swapaxes(0, 1).reshape(B, S, du)
    if return_state:
        C_prev, n_prev, m_prev = carry
        return out, {"C": C_prev, "n": n_prev, "m": m_prev}
    return out


def mlstm_block(x: Array, p: Dict[str, Array], cfg, chunk: int = 256,
                return_state: bool = False):
    """Pre-up-projection mLSTM block (proj_factor 2). x: (B,S,D)."""
    from .components import rms_norm
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", h, p["up_proj"])
    xu, gate = jnp.split(up, 2, axis=-1)
    core = mlstm_chunk_scan(xu, p, cfg.n_heads, chunk=chunk,
                            return_state=return_state)
    state = None
    if return_state:
        core, state = core
    core = rms_norm(core, p["out_ln"], cfg.norm_eps)
    y = core * jax.nn.silu(gate)
    out = x + jnp.einsum("bse,ed->bsd", y, p["down_proj"])
    if return_state:
        return out, state
    return out


def mlstm_init_state(cfg, batch: int) -> Dict[str, Array]:
    du = int(cfg.d_model * cfg.mlstm_proj_factor)
    dk = du // cfg.n_heads
    return {
        "C": jnp.zeros((batch, cfg.n_heads, dk, dk), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, dk), jnp.float32),
        "m": jnp.full((batch, cfg.n_heads), -1e30, jnp.float32),
    }


def mlstm_block_decode(x: Array, p: Dict[str, Array], cfg,
                       state: Dict[str, Array]
                       ) -> Tuple[Array, Dict[str, Array]]:
    """One-token mLSTM step with O(1) state. x: (B, 1, D)."""
    from .components import rms_norm
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", h, p["up_proj"])
    xu, gate = jnp.split(up, 2, axis=-1)
    B, _, du = xu.shape
    dk = du // cfg.n_heads
    q, k, v, li, lf = _mlstm_qkv_gates(xu, p)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                 # (B,H,dk)
    li, lf = li[:, 0], lf[:, 0]                         # (B,H)
    m_new = jnp.maximum(lf + state["m"], li)
    fw = jnp.exp(lf + state["m"] - m_new)[..., None]
    iw = jnp.exp(li - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = fw[..., None] * state["C"] + \
        iw[..., None] * jnp.einsum("bhk,bhv->bhkv", kf, vf)
    n = fw * state["n"] + iw * kf
    qf = q.astype(jnp.float32) / math.sqrt(dk)
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)),
                      jnp.exp(-m_new))[..., None]
    core = (num / den).astype(x.dtype).reshape(B, 1, du)
    core = rms_norm(core, p["out_ln"], cfg.norm_eps)
    y = core * jax.nn.silu(gate)
    out = x + jnp.einsum("bse,ed->bsd", y, p["down_proj"])
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_step(p: Dict[str, Array], cfg, carry, x_t):
    """carry: (c, n, h, m) each (B,H,dh); x_t: (B, D)."""
    c, n, h, m = carry
    # gates from input + block-diagonal recurrence  (B,H,dh,4)
    wx = jnp.einsum("bd,dhkg->bhkg", x_t, p["w"]).astype(jnp.float32)
    rh = jnp.einsum("bhk,hkjg->bhjg", h, p["r"]).astype(jnp.float32)
    g = wx + rh + p["b"]
    zt = jnp.tanh(g[..., 0])
    it = g[..., 1]                                       # log-space input gate
    ft = jax.nn.log_sigmoid(g[..., 2])                   # log forget gate
    ot = jax.nn.sigmoid(g[..., 3])
    m_new = jnp.maximum(ft + m, it)
    iw = jnp.exp(it - m_new)
    fw = jnp.exp(ft + m - m_new)
    c_new = fw * c + iw * zt
    n_new = fw * n + iw
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new.astype(jnp.float32), m_new), h_new


def slstm_core(x: Array, p: Dict[str, Array], cfg,
               return_state: bool = False):
    """x: (B, S, D) → (B, S, H*dh) via sequential scan (nonlinear recurrence)."""
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    init = (jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.full((B, H, dh), -1e30, jnp.float32))
    carry, hs = jax.lax.scan(
        lambda carry, xt: _slstm_step(p, cfg, carry, xt),
        init, jnp.swapaxes(x, 0, 1))
    out = jnp.swapaxes(hs, 0, 1).reshape(B, S, D).astype(x.dtype)
    if return_state:
        return out, carry
    return out


def slstm_block(x: Array, p: Dict[str, Array], cfg,
                return_state: bool = False):
    from .components import rms_norm, swiglu_mlp
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    core = slstm_core(h, p, cfg, return_state=return_state)
    state = None
    if return_state:
        core, state = core
    x = x + jnp.einsum("bsd,de->bse", core, p["out_proj"])
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    out = x + swiglu_mlp(h2, {"w_gate": p["ff_gate"], "w_up": p["ff_up"],
                              "w_down": p["ff_down"]})
    if return_state:
        return out, state
    return out


def slstm_init_state(cfg, batch: int) -> Tuple[Array, ...]:
    H, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return (z, z, z, jnp.full((batch, H, dh), -1e30, jnp.float32))


def slstm_block_decode(x: Array, p: Dict[str, Array], cfg, state
                       ) -> Tuple[Array, Tuple[Array, ...]]:
    from .components import rms_norm, swiglu_mlp
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    new_state, h_out = _slstm_step(p, cfg, state, h[:, 0])
    core = h_out.reshape(x.shape[0], 1, -1).astype(x.dtype)
    x = x + jnp.einsum("bsd,de->bse", core, p["out_proj"])
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    out = x + swiglu_mlp(h2, {"w_gate": p["ff_gate"], "w_up": p["ff_up"],
                              "w_down": p["ff_down"]})
    return out, new_state

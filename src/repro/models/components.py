"""Shared model components: norms, RoPE, GQA attention, MLP, MoE.

Everything is a pure function over explicit param dicts; all dims are
einsum-named so GSPMD can shard them from the NamedShardings installed by
``repro.sharding.partition``.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dtype) * scale


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dtype) * scale + bias


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(positions: Array, dh: int, theta: float) -> Tuple[Array, Array]:
    """positions: (...,) int32 → (cos, sin) of shape (..., dh/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, S, H, Dh); cos/sin: (B, S, Dh/2) or (S, Dh/2)."""
    dtype = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    if cos.ndim == 2:                   # (S, Dh/2) → (1, S, 1, Dh/2)
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    elif cos.ndim == 3:                 # (B, S, Dh/2) → (B, S, 1, Dh/2)
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) — full-sequence (train / prefill) and cached decode
# ---------------------------------------------------------------------------

def qkv_project(x: Array, p: Dict[str, Array], cfg) -> Tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def gqa_scores_softmax_out(q: Array, k: Array, v: Array, causal: bool,
                           q_offset: int = 0) -> Array:
    """q: (B,S,QH,Dh); k,v: (B,T,KV,Dh) → (B,S,QH,Dh).

    GQA grouping: QH = KV * G; scores in f32 with online-safe softmax.
    """
    B, S, QH, Dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = QH // KV
    qg = q.reshape(B, S, KV, G, Dh)
    # scores materialise in the input dtype (bf16 on TPU) — the f32 softmax
    # math below fuses into the reduction, halving the S×T working set.
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / math.sqrt(Dh)
    if causal:
        qpos = jnp.arange(S) + q_offset
        kpos = jnp.arange(T)
        mask = kpos[None, :] <= qpos[:, None]          # (S, T)
        scores = jnp.where(mask[None, None, None], scores,
                           jnp.asarray(-jnp.inf, scores.dtype))
    # softmax with exp recomputation: the only materialised S×T buffers are
    # the bf16 scores and bf16 weights (f32 chains fuse into the reductions)
    m = jnp.maximum(jnp.max(scores.astype(jnp.float32), axis=-1,
                            keepdims=True), -1e30)
    l = jnp.sum(jnp.exp(scores.astype(jnp.float32) - m), axis=-1,
                keepdims=True)
    w = (jnp.exp(scores.astype(jnp.float32) - m) / l).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, QH, Dh)


def attention(x: Array, p: Dict[str, Array], cfg, causal: bool = True,
              positions: Optional[Array] = None, attn_impl=None) -> Array:
    """Full-sequence self attention (train / prefill).

    ``attn_impl(q, k, v, causal)`` overrides the score computation (e.g. the
    shard_map sequence-parallel chunked path for 32k prefill)."""
    B, S, _ = x.shape
    q, k, v = qkv_project(x, p, cfg)
    if positions is None:
        positions = jnp.arange(S)
    cos, sin = rope_freqs(positions, cfg.dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if attn_impl is not None:
        out = attn_impl(q, k, v, causal=causal)
    else:
        out = gqa_scores_softmax_out(q, k, v, causal=causal)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode(x: Array, p: Dict[str, Array], cfg, cache_k: Array,
                     cache_v: Array, pos: Array
                     ) -> Tuple[Array, Array, Array]:
    """One-token decode against a KV cache.

    x: (B, 1, D); cache_k/v: (B, T, KV, Dh); pos: scalar int32 (current len).
    Returns (out (B,1,D), new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    q, k, v = qkv_project(x, p, cfg)
    posv = jnp.full((B, 1), pos, dtype=jnp.int32)
    cos, sin = rope_freqs(posv, cfg.dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    T = cache_k.shape[1]
    # mask out cache slots beyond pos
    valid = jnp.arange(T) <= pos                         # (T,)
    KV, G, Dh = cfg.n_kv_heads, cfg.q_rep, cfg.dh
    qg = q.reshape(B, 1, KV, G, Dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, cache_k).astype(jnp.float32)
    scores = scores / math.sqrt(Dh)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, cache_v).reshape(B, 1, KV * G * Dh)
    out = out.reshape(B, 1, KV * G, Dh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_k, cache_v


def cross_attention(x: Array, p: Dict[str, Array], cfg, enc_k: Array,
                    enc_v: Array) -> Array:
    """Decoder cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    out = gqa_scores_softmax_out(q, enc_k, enc_v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# MLP (SwiGLU for llama-family; GELU for whisper)
# ---------------------------------------------------------------------------

def _ffn_seq_constraint(t: Array) -> Array:
    """'gather_weights' mode: keep FFN intermediates sequence-sharded so
    GSPMD gathers the weight matrices (batch-independent bytes) instead of
    the (B,S,·) activations — §Perf iteration B."""
    from repro.sharding import context as shctx
    ctx = shctx.current()
    if ctx is None or ctx.ffn != "gather_weights":
        return t
    from jax.sharding import PartitionSpec as P
    tp = ctx.mesh.shape["model"]
    if t.shape[1] % tp != 0:
        return t
    return shctx.constrain(t, P(ctx.dp(t.shape[0]), "model", None))


def swiglu_mlp(x: Array, p: Dict[str, Array]) -> Array:
    g = _ffn_seq_constraint(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    u = _ffn_seq_constraint(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    out = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"])
    return _ffn_seq_constraint(out)


def gelu_mlp(x: Array, p: Dict[str, Array]) -> Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w_up"]) + p["b_up"])
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"]) + p["b_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts — grouped one-hot dispatch (GShard-style) baseline.
#
# Tokens are grouped along the sequence axis; per group, top-k experts are
# selected and tokens are placed into per-expert capacity slots via one-hot
# dispatch/combine einsums.  E is sharded over the `model` mesh axis, so the
# expert FFN einsums are expert-parallel; the combine einsum contracts E and
# GSPMD inserts the all-reduce.  (The sort-based dispatch that removes the
# one-hot FLOPs is a hillclimb variant in repro.sharding.moe_opt.)
# ---------------------------------------------------------------------------

def moe_dispatch_combine(probs: Array, k: int, capacity: int
                         ) -> Tuple[Array, Array]:
    """probs: (B, G, Sg, E) router probabilities.

    Returns (dispatch (B,G,Sg,E,C) bool-ish, combine (B,G,Sg,E,C) weights).
    """
    E = probs.shape[-1]
    gate, idx = jax.lax.top_k(probs, k)                  # (B,G,Sg,k)
    gate = gate / (jnp.sum(gate, axis=-1, keepdims=True) + 1e-9)
    sel = jax.nn.one_hot(idx, E, dtype=probs.dtype)      # (B,G,Sg,k,E)
    # Priority: earlier tokens (and lower k-slot) win capacity.
    B, G, Sg, _, _ = sel.shape
    flat = sel.reshape(B, G, Sg * k, E)
    pos = jnp.cumsum(flat, axis=2) - flat                # slots before me
    keep = (pos < capacity) * flat
    slot = jax.nn.one_hot(pos.astype(jnp.int32), capacity,
                          dtype=probs.dtype)             # (B,G,Sg*k,E,C)
    disp_flat = keep[..., None] * slot
    dispatch = disp_flat.reshape(B, G, Sg, k, E, capacity).sum(axis=3)
    combine = dispatch * gate.sum(axis=-1)[..., None, None] if k == 1 else None
    if combine is None:
        gate_e = jnp.einsum("bgsk,bgske->bgse", gate,
                            keep.reshape(B, G, Sg, k, E))
        combine = dispatch * gate_e[..., None]
    return dispatch, combine


def moe_mlp(x: Array, p: Dict[str, Array], cfg) -> Array:
    """x: (B, S, D) → (B, S, D) through routed experts (+ shared experts)."""
    from repro.sharding import context as shctx
    from jax.sharding import PartitionSpec as P
    B, S, D = x.shape
    ctx = shctx.current()
    gather_seq = ctx is not None and ctx.moe_gather_seq
    if gather_seq:
        # §Perf iteration A: gather the sequence once around the MoE block —
        # dispatch runs purely expert-parallel, no S↔E resharding storm.
        x = shctx.constrain(x, P(ctx.dp(B), None, None))
    E, kk = cfg.n_experts, cfg.top_k
    Sg = min(cfg.moe_group_size, S)
    G = S // Sg
    capacity = max(1, int(math.ceil(Sg * kk / E * cfg.moe_capacity_factor)))
    xg = x.reshape(B, G, Sg, D)
    router = jnp.einsum("bgsd,de->bgse", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(router, axis=-1)
    dispatch, combine = moe_dispatch_combine(probs, kk, capacity)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)
    expert_in = jnp.einsum("bgsec,bgsd->ebgcd", dispatch, xg)
    if gather_seq:
        expert_in = shctx.constrain(
            expert_in, P("model", ctx.dp(B), None, None, None))
    g = jnp.einsum("ebgcd,edf->ebgcf", expert_in, p["w_gate"])
    u = jnp.einsum("ebgcd,edf->ebgcf", expert_in, p["w_up"])
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("ebgcf,efd->ebgcd", h, p["w_down"])
    y = jnp.einsum("bgsec,ebgcd->bgsd", combine, expert_out)
    y = y.reshape(B, S, D)
    if gather_seq and S % ctx.mesh.shape["model"] == 0:
        # hand the result back sequence-sharded (reduce-scatter, not
        # all-reduce, closes the expert-contraction)
        y = shctx.constrain(y, P(ctx.dp(B), "model", None))
    if cfg.n_shared_experts:
        y = y + swiglu_mlp(x, {"w_gate": p["shared_gate"],
                               "w_up": p["shared_up"],
                               "w_down": p["shared_down"]})
    return y

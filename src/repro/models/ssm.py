"""Mamba selective-SSM block (for jamba) — TPU-adapted.

Training/prefill uses a *chunked associative scan*: the sequence is split
into chunks; within a chunk the linear recurrence h_t = a_t·h_{t-1} + b_t is
evaluated with ``jax.lax.associative_scan`` (log-depth, MXU/VPU friendly,
correct FLOP accounting because the tree unrolls in HLO), and chunk carries
propagate through a Python-level loop (unrolled — no while op, so the dry-run
cost analysis sees every chunk).  Decode is the closed-form one-step update.

Memory note: the naive parallel scan materialises (B,S,d_inner,N) which is
~16 GiB/device for jamba train_4k; chunking bounds the transient to
(B,chunk,d_inner,N).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _ssm_inputs(x: Array, p: Dict[str, Array], cfg):
    """Shared projections for scan/decode. x: (B, S, D)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])          # (B,S,2*di)
    x_in, z = jnp.split(xz, 2, axis=-1)
    return x_in, z


def _causal_conv(x_in: Array, conv_w: Array, conv_b: Array,
                 state: Array = None) -> Tuple[Array, Array]:
    """Depthwise causal conv over seq. x_in: (B,S,di); conv_w: (K,di).

    Returns (convolved (B,S,di), final window state (B,K-1,di))."""
    K = conv_w.shape[0]
    if state is None:
        state = jnp.zeros((x_in.shape[0], K - 1, x_in.shape[2]), x_in.dtype)
    padded = jnp.concatenate([state, x_in], axis=1)
    out = sum(padded[:, i:i + x_in.shape[1], :] * conv_w[i]
              for i in range(K))
    out = out + conv_b
    new_state = padded[:, -(K - 1):, :] if K > 1 else state
    return out, new_state


def _ssm_params_t(xc: Array, p: Dict[str, Array], cfg):
    """Per-timestep SSM parameters. xc: (..., di)."""
    dbc = jnp.einsum("...i,ij->...j", xc, p["x_proj"])
    dt_r, Bs, Cs = jnp.split(
        dbc, [cfg.dt_rank, cfg.dt_rank + cfg.ssm_state_dim], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dt_r, p["dt_proj"]) + p["dt_bias"])
    return dt, Bs, Cs                                      # (...,di),(...,N),(...,N)


def mamba_block(x: Array, p: Dict[str, Array], cfg,
                chunk: int = 256, return_state: bool = False):
    """Full-sequence mamba block. x: (B, S, D) → (B, S, D) [, final state]."""
    B, S, D = x.shape
    di, N = cfg.d_inner, cfg.ssm_state_dim
    x_in, z = _ssm_inputs(x, p, cfg)
    xc, conv_state = _causal_conv(x_in, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (di, N)

    chunk = min(chunk, S)
    n_chunks = (S + chunk - 1) // chunk

    def one_chunk(h, sl):
        """h: (B,di,N) f32; sl: (B,C,di) → (h', y (B,C,di))."""
        dt, Bs, Cs = _ssm_params_t(sl, p, cfg)
        dt32 = dt.astype(jnp.float32)
        a = jnp.exp(dt32[..., None] * A)                   # (B,C,di,N)
        b = (dt32 * sl.astype(jnp.float32))[..., None] * \
            Bs.astype(jnp.float32)[..., None, :]           # (B,C,di,N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_acc, b_acc = jax.lax.associative_scan(combine, (a, b), axis=1)
        hs = a_acc * h[:, None] + b_acc                    # (B,C,di,N)
        y = jnp.einsum("bcin,bcn->bci", hs, Cs.astype(jnp.float32))
        return hs[:, -1], y.astype(x.dtype) + sl * p["D_skip"]

    h = jnp.zeros((B, di, N), jnp.float32)
    if n_chunks <= 8:
        ys = []
        for c in range(n_chunks):                          # unrolled (dry-run)
            h, y = one_chunk(h, xc[:, c * chunk:(c + 1) * chunk])
            ys.append(y)
        y = jnp.concatenate(ys, axis=1)
    else:
        # long sequences: while-loop over chunks (HLO stays O(1) in S; the
        # roofline harness corrects FLOPs by trip count — EXPERIMENTS.md)
        xs = xc.reshape(B, n_chunks, chunk, di).swapaxes(0, 1)
        h, ys = jax.lax.scan(one_chunk, h, xs)
        y = ys.swapaxes(0, 1).reshape(B, S, di)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    if return_state:
        return out, {"h": h, "conv": conv_state}
    return out


def mamba_init_state(cfg, batch: int, dtype) -> Dict[str, Array]:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, cfg.d_inner), dtype),
    }


def mamba_decode(x: Array, p: Dict[str, Array], cfg,
                 state: Dict[str, Array]) -> Tuple[Array, Dict[str, Array]]:
    """One-token mamba step. x: (B, 1, D); O(1) state (the long_500k payoff)."""
    x_in, z = _ssm_inputs(x, p, cfg)
    xc, conv_state = _causal_conv(x_in, p["conv_w"], p["conv_b"],
                                  state["conv"])
    xc = jax.nn.silu(xc)                                   # (B,1,di)
    dt, Bs, Cs = _ssm_params_t(xc[:, 0], p, cfg)           # (B,di),(B,N),(B,N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt32 = dt.astype(jnp.float32)
    a = jnp.exp(dt32[..., None] * A)                       # (B,di,N)
    b = (dt32 * xc[:, 0].astype(jnp.float32))[..., None] * \
        Bs.astype(jnp.float32)[:, None, :]
    h = a * state["h"] + b
    y = jnp.einsum("bin,bn->bi", h, Cs.astype(jnp.float32)).astype(x.dtype)
    y = (y + xc[:, 0] * p["D_skip"]) * jax.nn.silu(z[:, 0])
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None, :]
    return out, {"h": h, "conv": conv_state}

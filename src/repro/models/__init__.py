from .config import ArchConfig
from .registry import build_model, Model

__all__ = ["ArchConfig", "build_model", "Model"]

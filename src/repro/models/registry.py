"""Model wrapper + registry: a thin OO facade over the functional core."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import lm
from .config import ArchConfig


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # params ------------------------------------------------------------------
    def init(self, key, dtype=jnp.float32):
        return lm.init_params(self.cfg, key, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return lm.abstract_params(self.cfg, dtype)

    def logical_axes(self):
        return lm.param_logical_axes(self.cfg)

    def param_count(self) -> int:
        return lm.count_params(self.cfg)

    def active_param_count(self) -> int:
        return lm.count_params(self.cfg, active_only=True)

    # compute ------------------------------------------------------------------
    def forward(self, params, tokens=None, **kw):
        return lm.forward(self.cfg, params, tokens, **kw)

    def loss(self, params, tokens, labels, **kw):
        return lm.loss_fn(self.cfg, params, tokens, labels, **kw)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   src_len: int = 0):
        return lm.init_cache(self.cfg, batch, max_len, dtype, src_len)

    def decode_step(self, params, token, cache, pos):
        return lm.decode_step(self.cfg, params, token, cache, pos)

    def prefill(self, params, tokens, cache, **kw):
        return lm.prefill(self.cfg, params, tokens, cache, **kw)


@functools.lru_cache(maxsize=None)
def build_model(name: str) -> Model:
    from repro.configs import get_config
    return Model(get_config(name))

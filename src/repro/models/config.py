"""Unified architecture configuration for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE replaces the MLP every k-th layer
    moe_capacity_factor: float = 2.0
    moe_group_size: int = 256   # dispatch grouping along sequence

    # --- block pattern (scan group) -----------------------------------------
    #: layer kinds within one scanned group, e.g. ("attn",) for dense,
    #: ("attn",) + ("mamba",)*7 for jamba, ("mlstm",)*7+("slstm",) for xlstm.
    block_pattern: Tuple[str, ...] = ("attn",)

    # --- attention ----------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 1e4
    head_dim: int = 0           # 0 => d_model // n_heads

    # --- encoder-decoder (whisper) -------------------------------------------
    encoder_layers: int = 0     # >0 => enc-dec model with cross attention

    # --- SSM (mamba) -----------------------------------------------------------
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0        # 0 => ceil(d_model/16)

    # --- xLSTM -------------------------------------------------------------------
    mlstm_proj_factor: float = 2.0
    slstm_ff_factor: float = 4.0 / 3.0

    # --- frontend stubs -------------------------------------------------------
    frontend: str = "none"      # none | audio | vision
    n_patches: int = 0          # vision stub: patch-embedding count

    # --- numerics / training --------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # ------------------------------------------------------------------------
    def __post_init__(self) -> None:
        if self.n_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not a multiple of "
                f"block pattern length {len(self.block_pattern)}")

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_rep(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """True when decode state is O(1)-ish in sequence length (SSM /
        xLSTM / hybrid) — the long_500k eligibility rule."""
        return self.family in ("ssm", "hybrid")

    def padded_vocab(self, multiple: int = 128) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    def layer_kind(self, group_idx: int, j: int) -> str:
        return self.block_pattern[j]

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.n_experts == 0:
            return False
        return (layer_idx % self.moe_every) == (self.moe_every - 1)

    # FLOP accounting (MODEL_FLOPS = 6·N_active·D for roofline §g) -----------
    def param_count(self, padded_vocab: Optional[int] = None) -> int:
        from . import lm  # avoid cycle
        return lm.count_params(self, padded_vocab)

    def active_param_count(self, padded_vocab: Optional[int] = None) -> int:
        from . import lm
        return lm.count_params(self, padded_vocab, active_only=True)

    def scaled(self, **overrides) -> "ArchConfig":
        return dataclasses.replace(self, **overrides)

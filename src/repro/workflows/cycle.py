"""Deterministic, seedable NWP-cycle driver over the storage facades.

One :class:`NWPCycle` run is one operational cycle on one simulated
deployment:

* **assimilation** — ``n_writers`` concurrent
  :meth:`~repro.data.pipeline.ChunkedFieldStore.writer` sessions patch
  overlapping analysis windows (row bands + halo rows) of one shared
  field.  Overlap is *waited out*, not errored: every session runs with
  ``lease_block=True``, so plan-time acquires queue on a neighbour's
  chunk ranges until its holder flushes and releases (or its TTL lapses)
  — the time spent queueing lands in the ``lease.wait_us`` histogram.
* **forecast** — a strict ``fill_missing=False`` read of the assimilated
  state, ``leads`` steps of a toy advection–diffusion model, each lead
  archived as a field and checkpointed via
  :meth:`~repro.train.checkpoint.FDBCheckpointer.save_sharded`
  (``n_shards`` concurrent rank sessions on the same deployment).
* **products** — a fan-out pool of ``n_readers`` readers, each issuing
  many small strided :meth:`read_window` calls against the forecast
  fields (the million-user proxy), digesting every byte they see.

**Determinism contract** (the chaos gate in :mod:`.chaos` depends on
it): with a fixed :class:`WorkflowConfig`, the bytes of every field and
the products digest are independent of thread scheduling.  Overlapping
assimilation windows write *identical* values in their overlap (each
writer writes rows of one global truth field), and lease serialisation
makes every read-modify-write of a shared chunk see its previous
holder's flushed rows — so any acquisition order converges to the same
truth bytes.  Product selections are derived from per-reader seeded RNG
streams, and per-reader digests combine in pool-order.  The full
argument is written out in ``docs/workflows.md``.

Span taxonomy added by this module (``docs/observability.md``):
``workflow.cycle``, ``workflow.assimilation``, ``workflow.forecast``,
``workflow.products``, ``workflow.recovery``, ``workflow.task``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import InjectedCrash, FDBConfig
from repro.data.pipeline import ChunkedFieldStore
from repro.obs.trace import Tracer
from repro.tensorstore import TensorStore
from repro.tensorstore.executor import ChunkExecutor
from repro.train.checkpoint import FDBCheckpointer


@dataclasses.dataclass(frozen=True)
class WorkflowConfig:
    """One cycle's full parameterisation — everything the determinism
    contract ranges over.  Two runs with equal configs (on any thread
    schedule, with or without a healed fault schedule) must produce
    byte-identical fields and products digests."""

    backend: str = "posix"
    root: str = "/tmp/fdb-workflow"
    store: str = "wf"                   # dataset namespace on the deployment
    shape: Tuple[int, int] = (64, 64)   # analysis grid (rows, cols)
    chunks: Tuple[int, int] = (16, 16)
    codec: str = "raw"
    seed: int = 0
    # assimilation
    n_writers: int = 4
    halo: int = 4                       # rows of overlap with each neighbour
    lease_timeout: float = 30.0         # blocking-acquire bound (seconds)
    # forecast
    leads: int = 2
    dt: float = 0.1
    n_shards: int = 2                   # checkpoint rank sessions
    # products
    n_readers: int = 6
    reads_per_reader: int = 8
    # chaos (used when a crash writer is armed)
    crash_ttl: float = 0.25             # dead writer's lease TTL (seconds)

    def fdb_config(self) -> FDBConfig:
        return FDBConfig(backend=self.backend, schema="tensor",
                         root=self.root)

    def field_names(self) -> List[str]:
        return ["analysis"] + [f"fcst{lead:02d}"
                               for lead in range(1, self.leads + 1)]


def analysis_truth(cfg: WorkflowConfig) -> np.ndarray:
    """The global analysis field every assimilation writer patches rows
    of — seeded, so overlapping windows agree byte-for-byte."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 11]))
    return rng.normal(size=cfg.shape).astype(np.float32)


def background(cfg: WorkflowConfig) -> np.ndarray:
    """Deterministic first-guess field the cycle starts from; fully
    overwritten by the assimilation bands, but its presence makes every
    halo write a genuine read-modify-write of committed chunks."""
    r = np.arange(cfg.shape[0], dtype=np.float32)[:, None]
    c = np.arange(cfg.shape[1], dtype=np.float32)[None, :]
    return np.sin(r / 7.0) * np.cos(c / 5.0)


def step_model(x: np.ndarray, dt: float = 0.1) -> np.ndarray:
    """One toy forecast step: periodic diffusion + zonal advection.
    float32 ndarray ops on one thread — bit-deterministic."""
    lap = (np.roll(x, 1, 0) + np.roll(x, -1, 0) +
           np.roll(x, 1, 1) + np.roll(x, -1, 1) - 4.0 * x)
    adv = 0.5 * (np.roll(x, 1, 1) - np.roll(x, -1, 1))
    return (x + dt * lap + 0.5 * dt * adv).astype(np.float32)


def forecast_states(cfg: WorkflowConfig) -> List[np.ndarray]:
    """The expected state at each lead time (index 0 = the analysis) —
    what the audit compares stored fields against."""
    states = [analysis_truth(cfg)]
    for _lead in range(cfg.leads):
        states.append(step_model(states[-1], cfg.dt))
    return states


def assimilation_windows(cfg: WorkflowConfig) -> List[Tuple[int, int]]:
    """Row windows ``[lo, hi)`` per writer: contiguous bands plus
    ``halo`` rows of deliberate overlap with each neighbour."""
    rows = cfg.shape[0]
    band = -(-rows // cfg.n_writers)
    out = []
    for i in range(cfg.n_writers):
        lo = max(0, i * band - cfg.halo)
        hi = min(rows, (i + 1) * band + cfg.halo)
        if lo < hi:
            out.append((lo, hi))
    return out


@dataclasses.dataclass
class StageStats:
    """Per-stage roll-up the bench columns are built from."""
    wall_s: float = 0.0
    tasks: int = 0
    nbytes: int = 0                 # payload bytes written/read by the stage
    lease_waits: int = 0            # blocking acquires during the stage
    lease_wait_us: float = 0.0      # total time queued on others' leases

    @property
    def mib_s(self) -> float:
        return (self.nbytes / (1 << 20)) / self.wall_s if self.wall_s else 0.0


@dataclasses.dataclass
class CycleReport:
    """Everything one cycle run asserts on: per-stage stats, the
    determinism digests, the loss audit, and the protocol verdict."""
    backend: str
    store: str
    seed: int
    wall_s: float = 0.0
    stages: Dict[str, StageStats] = dataclasses.field(default_factory=dict)
    #: sha256 per field plus the combined ``products`` digest
    digests: Dict[str, str] = dataclasses.field(default_factory=dict)
    #: chunks that read back missing or different from the expected state
    lost_chunks: int = 0
    ckpt_roundtrip: bool = False
    crashed_writer: Optional[int] = None
    recovery: Optional[Dict[str, object]] = None
    faults_injected: int = 0
    retries: int = 0
    giveups: int = 0
    lease_wait: Dict[str, float] = dataclasses.field(default_factory=dict)
    protocol_violations: List[object] = dataclasses.field(
        default_factory=list)

    @property
    def products_digest(self) -> str:
        return self.digests.get("products", "")

    @property
    def clean(self) -> bool:
        return self.lost_chunks == 0 and not self.protocol_violations


def _lease_wait_totals(metrics) -> Tuple[int, float]:
    h = metrics.get("lease.wait_us")
    return (0, 0.0) if h is None else (h.count, h.sum)


class NWPCycle:
    """Drive one assimilation → forecast → products cycle on one shared
    deployment (see the module docstring for the stage model).

    ``faults``/``retry`` apply to every client the cycle opens (producer,
    consumer pool, checkpointer) — the chaos schedule's hook.  Arming
    ``crash_writer`` routes that assimilation task through a dedicated
    client wearing ``crash_faults`` (default: die on its first flush),
    abandons it mid-cycle, waits the dead lease out via a blocking
    re-drive writer, then runs :meth:`~repro.core.FDB.recover` — the
    recovery path of ``docs/robustness.md`` exercised inside a live
    workflow.  All clients share one tracer, so
    ``fdb.check_protocol()`` at the end of :meth:`run` sees the whole
    cycle."""

    def __init__(self, config: WorkflowConfig, tracer: Optional[Tracer] = None,
                 faults=None, retry=None, crash_writer: Optional[int] = None,
                 crash_faults=None, meter=None):
        self.cfg = config
        self.tracer = tracer if tracer is not None else Tracer(enabled=True)
        self.faults = faults
        self.retry = retry
        #: optional engine-op meter shared by every client the cycle opens
        #: (one meter ⇒ one simulated cluster); when set, each stage's op
        #: trace window lands in :attr:`stage_ops` for the bench layer to
        #: feed through the cluster cost model
        self.meter = meter
        self.stage_ops: Dict[str, list] = {}
        self.crash_writer = crash_writer
        self.crash_faults = crash_faults
        self.report = CycleReport(backend=config.backend, store=config.store,
                                  seed=config.seed)
        self._truth = analysis_truth(config)
        self._states = forecast_states(config)
        self._windows = assimilation_windows(config)
        self._crash_store: Optional[ChunkedFieldStore] = None

    # -- clients -------------------------------------------------------------
    def _open_clients(self) -> None:
        cfg = self.cfg
        self.producer = ChunkedFieldStore(
            store=cfg.store, fdb_config=cfg.fdb_config(), codec=cfg.codec,
            chunks=cfg.chunks, tracer=self.tracer, faults=self.faults,
            retry=self.retry, meter=self.meter)
        self.consumer = ChunkedFieldStore(
            store=cfg.store, fdb_config=cfg.fdb_config(), codec=cfg.codec,
            chunks=cfg.chunks, tracer=self.tracer, faults=self.faults,
            retry=self.retry, meter=self.meter)
        self.ckpt = FDBCheckpointer(
            run=f"{cfg.store}-fc", fdb_config=cfg.fdb_config(),
            n_shards=cfg.n_shards, chunked=True, tracer=self.tracer,
            faults=self.faults, retry=self.retry, meter=self.meter)
        if self.crash_writer is not None:
            # the doomed writer gets its own client: a crashed *process*
            # takes its whole connection with it, and its unflushed state
            # must never ride another writer's commit barrier
            self._crash_store = ChunkedFieldStore(
                store=cfg.store, fdb_config=cfg.fdb_config(),
                codec=cfg.codec, chunks=cfg.chunks, tracer=self.tracer,
                faults=self.crash_faults, retry=self.retry,
                meter=self.meter)

    def _close_clients(self) -> None:
        for client in ("producer", "consumer", "ckpt"):
            store = getattr(self, client, None)
            if store is not None:
                store.close()
        # the crash client was abandoned mid-cycle (never flushed); if the
        # crash did not fire (e.g. no flush happened), close it normally
        if self._crash_store is not None \
                and not self._crash_store.fdb._closed:
            self._crash_store.close()

    # -- stages --------------------------------------------------------------
    def _stage(self, name: str) -> StageStats:
        return self.report.stages.setdefault(name, StageStats())

    def _op_mark(self) -> int:
        """Start of a stage's engine-op window (no-op without a meter)."""
        return len(self.meter.snapshot()) if self.meter is not None else 0

    def _record_ops(self, stage: str, mark: int) -> None:
        """Close a stage's op window: the slice of the shared meter's
        trace this stage issued, the cost model's per-stage input."""
        if self.meter is not None:
            self.stage_ops[stage] = self.meter.snapshot()[mark:]

    def _assimilate_one(self, i: int) -> Dict[str, object]:
        cfg = self.cfg
        lo, hi = self._windows[i]
        crashing = (i == self.crash_writer and self._crash_store is not None)
        store = self._crash_store if crashing else self.producer
        writer = store.writer(
            f"assim{i:02d}",
            lease_ttl=cfg.crash_ttl if crashing else None,
            lease_block=True, lease_timeout=cfg.lease_timeout)
        values = self._truth[lo:hi]
        with self.tracer.span("workflow.task", stage="assimilation",
                              worker=i, rows=hi - lo):
            try:
                writer.write_window("analysis", values,
                                    slice(lo, hi), slice(None))
                writer.commit()
                writer.close()
            except InjectedCrash:
                # the simulated process is gone: no flush, no release —
                # its lease lapses by TTL, its dirty intents wait for
                # recover()
                writer.session.abandon()
                store.fdb.abandon()
                return {"writer": i, "crashed": True, "nbytes": 0}
        return {"writer": i, "crashed": False, "nbytes": values.nbytes}

    def _redrive(self, i: int) -> None:
        """Re-drive a crashed writer's window with a fresh blocking
        session.  The plan-time ``block=True`` acquire doubles as the
        TTL-expiry barrier: it wakes exactly when the dead writer's lease
        lapses (no polling, real lease clock), after which the rewrite
        proceeds and :meth:`~repro.tensorstore.TensorStore.recover`
        quarantines the dead session's orphaned intents."""
        cfg = self.cfg
        lo, hi = self._windows[i]
        with self.tracer.span("workflow.recovery", worker=i):
            writer = self.producer.writer(
                f"assim{i:02d}r", lease_block=True,
                lease_timeout=cfg.lease_timeout + 4 * cfg.crash_ttl)
            writer.write_window("analysis", self._truth[lo:hi],
                                slice(lo, hi), slice(None))
            writer.commit()
            writer.close()
            base = {"store": cfg.store, "array": "analysis",
                    "writer": self.producer.writer_key}
            sweep = TensorStore(self.producer.fdb, base).recover()
            again = TensorStore(self.producer.fdb, base).recover()
            self.report.recovery = {
                "expired": len(sweep.expired),
                "orphan_chunks": sweep.orphan_chunks,
                "stale": len(sweep.stale),
                "clean_after": again.clean,
            }

    def _assimilation(self) -> None:
        cfg = self.cfg
        stats = self._stage("assimilation")
        metrics = self.tracer.metrics
        self.producer.put_field("analysis", background(cfg))
        self.producer.commit()
        op0 = self._op_mark()
        w0, t0 = _lease_wait_totals(metrics), time.perf_counter()
        with self.tracer.span("workflow.assimilation",
                              writers=cfg.n_writers):
            with ChunkExecutor(max_workers=cfg.n_writers) as pool:
                results = pool.map_ordered(
                    self._assimilate_one, range(len(self._windows)),
                    describe=lambda i: f"assim{i:02d}")
            crashed = [r["writer"] for r in results if r["crashed"]]
            for i in crashed:
                self.report.crashed_writer = i
                self._redrive(i)
        stats.wall_s = time.perf_counter() - t0
        self._record_ops("assimilation", op0)
        stats.tasks = len(results) + len(crashed)
        stats.nbytes = sum(r["nbytes"] for r in results) + sum(
            self._truth[lo:hi].nbytes
            for i in crashed for lo, hi in [self._windows[i]])
        w1 = _lease_wait_totals(metrics)
        stats.lease_waits = w1[0] - w0[0]
        stats.lease_wait_us = w1[1] - w0[1]

    def _forecast(self) -> None:
        cfg = self.cfg
        stats = self._stage("forecast")
        op0 = self._op_mark()
        t0 = time.perf_counter()
        with self.tracer.span("workflow.forecast", leads=cfg.leads):
            state = self.consumer.read_window(
                "analysis", slice(None), slice(None), fill_missing=False)
            for lead in range(1, cfg.leads + 1):
                state = step_model(state, cfg.dt)
                self.producer.put_field(f"fcst{lead:02d}", state)
                self.ckpt.save_sharded(lead, {"state": state})
                stats.nbytes += 2 * state.nbytes
            self.producer.commit()
            restored = self.ckpt.restore(
                cfg.leads, {"state": np.zeros(cfg.shape, np.float32)})
            self.report.ckpt_roundtrip = bool(
                np.array_equal(np.asarray(restored["state"]), state))
        stats.wall_s = time.perf_counter() - t0
        self._record_ops("forecast", op0)
        stats.tasks = cfg.leads

    def _produce_one(self, j: int) -> Dict[str, object]:
        cfg = self.cfg
        rows, cols = cfg.shape
        fields = cfg.field_names()
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, 23, j]))
        digest = hashlib.sha256()
        nbytes = 0
        with self.tracer.span("workflow.task", stage="products", worker=j):
            for _k in range(cfg.reads_per_reader):
                name = fields[int(rng.integers(0, len(fields)))]
                r0 = int(rng.integers(0, rows - 2))
                r1 = int(rng.integers(r0 + 1, rows)) + 1
                c0 = int(rng.integers(0, cols - 2))
                c1 = int(rng.integers(c0 + 1, cols)) + 1
                sel = (slice(r0, r1, int(rng.integers(1, 4))),
                       slice(c0, c1, int(rng.integers(1, 4))))
                window = self.consumer.read_window(name, *sel,
                                                   fill_missing=False)
                digest.update(f"{name}:{sel!r}".encode())
                digest.update(window.tobytes())
                nbytes += window.nbytes
        return {"reader": j, "digest": digest.hexdigest(), "nbytes": nbytes}

    def _products(self) -> None:
        cfg = self.cfg
        stats = self._stage("products")
        for name in cfg.field_names():    # warm the open cache serially so
            self.consumer.open_field(name)  # pool tasks share one metadata
        op0 = self._op_mark()
        t0 = time.perf_counter()
        with self.tracer.span("workflow.products", readers=cfg.n_readers):
            with ChunkExecutor(
                    max_workers=min(cfg.n_readers, 16)) as pool:
                results = pool.map_ordered(
                    self._produce_one, range(cfg.n_readers),
                    describe=lambda j: f"reader{j}")
        stats.wall_s = time.perf_counter() - t0
        self._record_ops("products", op0)
        stats.tasks = cfg.n_readers
        stats.nbytes = sum(r["nbytes"] for r in results)
        combined = hashlib.sha256(
            "|".join(r["digest"] for r in results).encode())
        self.report.digests["products"] = combined.hexdigest()

    # -- audit ---------------------------------------------------------------
    def _audit(self) -> None:
        """Read every field back chunk-by-chunk (strict) and compare with
        the locally recomputed expected state: a missing or different
        chunk is a *lost chunk* — the zero-loss gate of the chaos run."""
        cfg = self.cfg
        rows, cols = cfg.shape
        ch, cw = cfg.chunks
        expected = dict(zip(cfg.field_names(), self._states))
        for name, exp in expected.items():
            got = np.zeros_like(exp)
            lost = 0
            for r0 in range(0, rows, ch):
                for c0 in range(0, cols, cw):
                    sel = (slice(r0, min(r0 + ch, rows)),
                           slice(c0, min(c0 + cw, cols)))
                    try:
                        block = self.consumer.read_window(
                            name, *sel, fill_missing=False)
                    except KeyError:  # lint: disable=L009 -- not a retry: the missing chunk is counted as lost, never re-read
                        lost += 1
                        continue
                    got[sel] = block
                    if not np.array_equal(block, exp[sel]):
                        lost += 1
            self.report.lost_chunks += lost
            self.report.digests[name] = hashlib.sha256(
                got.tobytes()).hexdigest()

    # -- driver --------------------------------------------------------------
    def run(self) -> CycleReport:
        cfg = self.cfg
        t0 = time.perf_counter()
        self._open_clients()
        try:
            with self.tracer.span("workflow.cycle", backend=cfg.backend,
                                  store=cfg.store, seed=cfg.seed):
                self._assimilation()
                self._forecast()
                self._products()
            self._audit()
            snap = self.tracer.metrics.snapshot()
            self.report.retries = snap.get("retry.attempts",
                                           {}).get("value", 0)
            self.report.giveups = snap.get("retry.giveups",
                                           {}).get("value", 0)
            for inj in (self.faults, self.crash_faults):
                if inj is not None:
                    self.report.faults_injected += inj.injected
            waits = snap.get("lease.wait_us")
            if waits:
                self.report.lease_wait = {
                    "count": waits["count"], "sum_us": waits["sum"],
                    "max_us": waits["max"] or 0.0}
            self.report.protocol_violations = \
                self.producer.fdb.check_protocol()
        finally:
            self.report.wall_s = time.perf_counter() - t0
            self._close_clients()
        return self.report


__all__ = ["CycleReport", "NWPCycle", "StageStats", "WorkflowConfig",
           "analysis_truth", "assimilation_windows", "background",
           "forecast_states", "step_model"]

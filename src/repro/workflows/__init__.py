"""Operational NWP workflow scenarios over the storage facades.

The paper's headline claims (DAOS/Ceph vs Lustre) are about *workflows*,
not single ops.  This package drives a deterministic, seedable
assimilation → forecast → products cycle — N concurrent leased writers
patching overlapping analysis windows, a strict-read forecast step with
sharded checkpoints, and a fan-out pool of product readers (the
million-user proxy) — all racing on one shared simulated deployment per
backend, with per-stage ``workflow.*`` spans and the ``lease.wait_us``
contention histogram.  ``repro.workflows.chaos`` reruns the identical
seeded cycle under a fault schedule plus a mid-cycle writer crash and
gates on byte-identical products.  See ``docs/workflows.md``.
"""
from .chaos import ChaosGateResult, ChaosSchedule, run_chaos_gate
from .cycle import (CycleReport, NWPCycle, StageStats, WorkflowConfig,
                    analysis_truth, assimilation_windows, forecast_states,
                    step_model)

__all__ = [
    "ChaosGateResult", "ChaosSchedule", "CycleReport", "NWPCycle",
    "StageStats", "WorkflowConfig", "analysis_truth",
    "assimilation_windows", "forecast_states", "run_chaos_gate",
    "step_model",
]

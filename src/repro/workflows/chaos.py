"""Chaos variant of the NWP cycle: same seed, same bytes — under fire.

:func:`run_chaos_gate` runs the *identical* seeded cycle twice on one
deployment: once fault-free, once under a seeded
:class:`~repro.core.FaultInjector` schedule (transient archive/fetch
faults healed by a fast :class:`~repro.core.RetryPolicy`) plus one
injected mid-cycle writer crash — the designated assimilation writer
dies on its commit barrier (``InjectedCrash`` on ``store.flush``),
its client is abandoned unflushed, its lease lapses by TTL, and the
cycle re-drives the window and runs ``recover()``.

The gate is the repo's strongest end-to-end robustness claim
(``docs/workflows.md``): the chaos run's final fields and products
digest must be **byte-identical** to the fault-free run's, with **zero
lost chunks** and a **clean protocol window** — degradation may cost
latency, never bytes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core import FaultInjector, RetryPolicy
from repro.obs.trace import Tracer

from .cycle import CycleReport, NWPCycle, WorkflowConfig


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """One seeded fault schedule for a chaos cycle.  ``seed`` pins the
    injector's coin flips and the retry jitter; the ``first`` knobs make
    the schedule *guaranteed live* (a rate alone could fire zero faults
    on a tiny run, making the gate vacuous)."""

    seed: int = 0
    archive_fail_first: int = 2
    archive_fail_rate: float = 0.03
    fetch_fail_first: int = 2
    fetch_fail_rate: float = 0.03
    crash_writer: int = 0          # which assimilation task dies mid-cycle
    max_attempts: int = 8

    def injector(self) -> FaultInjector:
        """Transient-fault schedule for the cycle's live clients."""
        return (FaultInjector(seed=self.seed)
                .fail("store.archive", rate=self.archive_fail_rate,
                      first=self.archive_fail_first)
                .fail("store.fetch", rate=self.fetch_fail_rate,
                      first=self.fetch_fail_first))

    def crash_injector(self) -> FaultInjector:
        """The doomed writer's client dies on its first commit barrier —
        after archiving its window, before publishing it."""
        return FaultInjector(seed=self.seed).crash_on("store.flush", call=1)

    def retry_policy(self) -> RetryPolicy:
        """Seeded jitter, injected no-op sleep: chaos runs heal at full
        speed and reproduce from the seed."""
        return RetryPolicy(max_attempts=self.max_attempts, seed=self.seed,
                           sleep=lambda _s: None)


@dataclasses.dataclass
class ChaosGateResult:
    """Verdict of one chaos-gate run: the two reports plus every
    violated invariant (empty ``failures`` == gate passed)."""
    clean: CycleReport
    chaos: CycleReport
    failures: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures


def run_chaos_gate(config: WorkflowConfig,
                   schedule: Optional[ChaosSchedule] = None,
                   ) -> ChaosGateResult:
    """Run the fault-free and chaos variants of one seeded cycle (each
    under its own dataset namespace and tracer, on one shared
    deployment) and check every gate invariant."""
    schedule = schedule or ChaosSchedule(seed=config.seed)
    clean = NWPCycle(
        dataclasses.replace(config, store=f"{config.store}-clean"),
        tracer=Tracer(enabled=True)).run()
    chaos = NWPCycle(
        dataclasses.replace(config, store=f"{config.store}-chaos"),
        tracer=Tracer(enabled=True),
        faults=schedule.injector(), retry=schedule.retry_policy(),
        crash_writer=schedule.crash_writer,
        crash_faults=schedule.crash_injector()).run()

    result = ChaosGateResult(clean=clean, chaos=chaos)
    fail = result.failures.append
    for name, digest in clean.digests.items():
        if chaos.digests.get(name) != digest:
            fail(f"digest mismatch on {name!r}: chaos run is not "
                 f"byte-identical to the fault-free run")
    if clean.lost_chunks:
        fail(f"fault-free run lost {clean.lost_chunks} chunks")
    if chaos.lost_chunks:
        fail(f"chaos run lost {chaos.lost_chunks} chunks")
    if clean.protocol_violations:
        fail(f"fault-free protocol violations: {clean.protocol_violations}")
    if chaos.protocol_violations:
        fail(f"chaos protocol violations: {chaos.protocol_violations}")
    if chaos.faults_injected == 0:
        fail("fault schedule injected nothing: the gate ran vacuously")
    if chaos.giveups:
        fail(f"retry layer gave up {chaos.giveups} time(s)")
    if chaos.crashed_writer is None:
        fail("injected writer crash never fired")
    rec = chaos.recovery or {}
    if not rec.get("clean_after", False):
        fail(f"recovery sweep did not converge: {rec}")
    if not (clean.ckpt_roundtrip and chaos.ckpt_roundtrip):
        fail("sharded checkpoint restore was not byte-identical")
    return result


__all__ = ["ChaosGateResult", "ChaosSchedule", "run_chaos_gate"]

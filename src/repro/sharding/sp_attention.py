"""Sequence-parallel chunked prefill attention (shard_map).

For 32k-token prefill, a monolithic scores tensor is (B,H,S,T) — hundreds of
GiB.  This module computes attention under ``shard_map`` with the query
sequence sharded over the ``model`` axis (context parallelism — works for
*any* head count, including the 56-head/2-kv configs that defeat head-TP):

  * K/V are all-gathered along ``model`` (the visible collective cost),
  * each device loops over its local query chunks (unrolled — dry-run FLOP
    fidelity), online-softmax style but with full-T rows per chunk, scores
    materialised in bf16.

Used by the prefill path when seq_len exceeds ``SP_ATTN_THRESHOLD``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

SP_ATTN_THRESHOLD = 8192
Q_CHUNK = 256


def _local_chunked_attention(q, k, v, *, q_offset, causal: bool,
                             q_chunk: int):
    """q: (B, Sl, KV, G, Dh) local; k/v: (B, T, KV, Dh) full (gathered)."""
    B, Sl, KV, G, Dh = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    outs = []
    n_chunks = max(Sl // q_chunk, 1)
    cq = Sl // n_chunks
    for c in range(n_chunks):
        qc = q[:, c * cq:(c + 1) * cq]
        scores = jnp.einsum("bskgd,btkd->bkgst", qc, k) * scale
        if causal:
            qpos = q_offset + c * cq + jnp.arange(cq)
            mask = jnp.arange(T)[None, :] <= qpos[:, None]
            scores = jnp.where(mask[None, None, None], scores,
                               jnp.asarray(-jnp.inf, scores.dtype))
        m = jnp.maximum(jnp.max(scores.astype(jnp.float32), axis=-1,
                                keepdims=True), -1e30)
        l = jnp.sum(jnp.exp(scores.astype(jnp.float32) - m), axis=-1,
                    keepdims=True)
        w = (jnp.exp(scores.astype(jnp.float32) - m) / l).astype(v.dtype)
        outs.append(jnp.einsum("bkgst,btkd->bskgd", w, v))
    return jnp.concatenate(outs, axis=1)          # (B, Sl, KV, G, Dh)


def sp_prefill_attention(q, k, v, mesh: Mesh, causal: bool = True,
                         dp_axes=("data",), q_chunk: int = Q_CHUNK):
    """q: (B, S, QH, Dh); k/v: (B, S, KV, Dh) → (B, S, QH, Dh).

    Sequence sharded over "model"; batch over dp axes when divisible.
    """
    B, S, QH, Dh = q.shape
    KV = k.shape[2]
    G = QH // KV
    tp = mesh.shape["model"]
    assert S % tp == 0, (S, tp)
    dp_size = math.prod(mesh.shape[a] for a in dp_axes)
    dp = tuple(dp_axes) if B % dp_size == 0 else None
    qg = q.reshape(B, S, KV, G, Dh)

    spec_q = P(dp, "model", None, None, None)
    spec_kv = P(dp, "model", None, None)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec_q, spec_kv, spec_kv),
        out_specs=spec_q,
        check_vma=False)
    def inner(q_l, k_l, v_l):
        k_full = jax.lax.all_gather(k_l, "model", axis=1, tiled=True)
        v_full = jax.lax.all_gather(v_l, "model", axis=1, tiled=True)
        Sl = q_l.shape[1]
        off = jax.lax.axis_index("model") * Sl
        return _local_chunked_attention(q_l, k_full, v_full, q_offset=off,
                                        causal=causal, q_chunk=q_chunk)

    out = inner(qg, k, v)
    return out.reshape(B, S, QH, Dh)


def tp_chunked_prefill_attention(q, k, v, mesh: Mesh, causal: bool = True,
                                 dp_axes=("data",), q_chunk: int = 2048):
    """Heads-TP prefill attention with unrolled query chunks (§Perf C).

    Avoids the seq↔heads resharding of the shard_map path: q and the
    G-expanded k/v stay sharded on the (divisible) head dim; the only
    collective is one k/v gather per layer.  Memory is bounded by one
    (B, H/tp, q_chunk, T) score block.
    """
    B, S, QH, Dh = q.shape
    KV = k.shape[2]
    G = QH // KV
    tp = mesh.shape["model"]
    assert QH % tp == 0, (QH, tp)
    dp_size = math.prod(mesh.shape[a] for a in dp_axes)
    dp = tuple(dp_axes) if B % dp_size == 0 else None

    def cst(t, spec):
        return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

    q = cst(q, P(dp, None, "model", None))
    k_rep = jnp.repeat(k, G, axis=2)           # (B, T, QH, Dh)
    v_rep = jnp.repeat(v, G, axis=2)
    k_rep = cst(k_rep, P(dp, None, "model", None))
    v_rep = cst(v_rep, P(dp, None, "model", None))
    scale = 1.0 / math.sqrt(Dh)
    n_chunks = max(S // q_chunk, 1)
    cq = S // n_chunks
    outs = []
    for c in range(n_chunks):                  # unrolled (≤16 blocks)
        qc = q[:, c * cq:(c + 1) * cq]
        scores = jnp.einsum("bshd,bthd->bhst", qc, k_rep) * scale
        if causal:
            qpos = c * cq + jnp.arange(cq)
            mask = jnp.arange(S)[None, :] <= qpos[:, None]
            scores = jnp.where(mask[None, None], scores,
                               jnp.asarray(-jnp.inf, scores.dtype))
        m = jnp.maximum(jnp.max(scores.astype(jnp.float32), axis=-1,
                                keepdims=True), -1e30)
        l = jnp.sum(jnp.exp(scores.astype(jnp.float32) - m), axis=-1,
                    keepdims=True)
        w = (jnp.exp(scores.astype(jnp.float32) - m) / l).astype(v.dtype)
        outs.append(jnp.einsum("bhst,bthd->bshd", w, v_rep))
    return cst(jnp.concatenate(outs, axis=1), P(dp, None, "model", None))

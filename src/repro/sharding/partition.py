"""Sharding rules: logical param axes → mesh PartitionSpecs.

Strategy (DESIGN.md §5):

* **TP/EP** over the ``model`` axis for vocab / q-heads / ffn / experts /
  ssm-inner dims — applied only when the dim is divisible by the axis size,
  otherwise the dim stays replicated (e.g. kv=2 GQA heads, 56-head attention)
  and the compute falls back to sequence/context parallelism via the
  activation constraints below.
* **FSDP** over the ``data`` axis on the ``embed`` (d_model) dim of every
  weight when enabled (params + optimizer state; per-layer all-gathers are
  the visible FSDP cost in the collective roofline term).
* **SP**: residual activations constrained to P(dp, "model", None) between
  layers for large models — bounds remat-saved bytes and gives context
  parallelism to archs whose head counts don't divide the TP axis.
* Caches: attention KV caches shard batch over dp and *sequence* over
  ``model`` (distributed flash-decoding layout); recurrent states shard
  their inner dim over ``model``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.lm import ParamDef, param_defs, _strip_kind, _is_def

#: logical axis → candidate mesh axis for tensor/expert parallelism
TP_RULES: Dict[str, str] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "inner": "model",
}
FSDP_AXES = ("embed", "embed2")


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Per-(arch × mesh) distribution plan."""
    mesh: Mesh
    dp_axes: Tuple[str, ...]            # ("data",) or ("pod", "data")
    fsdp: bool = False                  # shard params over data on embed dim
    sp: bool = False                    # sequence-parallel residuals
    remat: bool = True
    grad_compress_pod: bool = False     # field-codec gradient compression
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.dp_axes]))

    @property
    def tp_size(self) -> int:
        return self.mesh.shape["model"]


def make_plan(cfg: ArchConfig, mesh: Mesh, kind: str = "train") -> MeshPlan:
    """Default plan: SP for every training run (bounds the remat-saved
    residuals AND the attention-score working set); FSDP for ≥5B params."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    big = cfg.param_count() >= 5e9
    return MeshPlan(
        mesh=mesh, dp_axes=dp_axes,
        fsdp=big,
        sp=kind == "train" and mesh.shape["model"] > 1,
        remat=kind == "train",
    )


def _spec_for(defn: ParamDef, plan: MeshPlan) -> P:
    spec: list = [None] * len(defn.shape)
    used = set()
    # 1) TP/EP on the first divisible candidate axis
    for i, (dim, ax) in enumerate(zip(defn.shape, defn.axes)):
        rule = TP_RULES.get(ax)
        if rule and rule not in used and dim % plan.mesh.shape[rule] == 0:
            spec[i] = rule
            used.add(rule)
            break
    # 2) FSDP over data on the embed dim
    if plan.fsdp and "data" not in used:
        for i, (dim, ax) in enumerate(zip(defn.shape, defn.axes)):
            if spec[i] is None and ax in FSDP_AXES \
                    and dim % plan.mesh.shape["data"] == 0:
                spec[i] = "data"
                used.add("data")
                break
    return P(*spec)


def make_param_shardings(cfg: ArchConfig, plan: MeshPlan):
    """Pytree of NamedShardings matching ``lm.abstract_params`` structure."""
    defs = _strip_kind(param_defs(cfg))
    return jax.tree.map(
        lambda d: NamedSharding(plan.mesh, _spec_for(d, plan)),
        defs, is_leaf=_is_def)


def opt_state_shardings(param_shardings):
    """Adam m/v mirror the param shardings."""
    return jax.tree.map(lambda s: s, param_shardings)


def shard_batch_spec(plan: MeshPlan, batch: int, rank: int = 2) -> P:
    """Spec for (B, S) token batches — batch over dp when divisible."""
    dp = plan.dp_axes if batch % plan.dp_size == 0 else ()
    lead = dp if dp else None
    return P(lead, *([None] * (rank - 1)))


def constrain_activations(x, plan: MeshPlan, batch_divisible: bool = True):
    """SP residual-stream constraint: P(dp, "model", None)."""
    if not plan.sp:
        return x
    dp = plan.dp_axes if batch_divisible else None
    seq_ax = "model" if x.shape[1] % plan.tp_size == 0 else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, P(dp, seq_ax, None)))


# ---------------------------------------------------------------------------
# Cache sharding (serving)
# ---------------------------------------------------------------------------

def _cache_leaf_spec(path_leaf_shape: Tuple[int, ...], plan: MeshPlan,
                     kind: str) -> P:
    m = plan.mesh.shape["model"]
    dp = plan.dp_axes
    B = path_leaf_shape[0]
    b_ax = dp if B % plan.dp_size == 0 else None
    if kind == "attn_kv":                       # (B, T, KV, Dh): seq → model
        t_ax = "model" if path_leaf_shape[1] % m == 0 else None
        return P(b_ax, t_ax, None, None)
    # recurrent states: shard the largest trailing dim divisible by model
    spec = [b_ax] + [None] * (len(path_leaf_shape) - 1)
    order = sorted(range(1, len(path_leaf_shape)),
                   key=lambda i: -path_leaf_shape[i])
    for i in order:
        if path_leaf_shape[i] % m == 0 and path_leaf_shape[i] >= m:
            spec[i] = "model"
            break
    return P(*spec)


def shard_cache(cfg: ArchConfig, plan: MeshPlan, cache_abstract):
    """NamedShardings for an ``lm.init_cache`` pytree (ShapeDtypeStructs)."""
    def leaf_spec(leaf):
        shape = leaf.shape
        kind = "attn_kv" if len(shape) == 4 and shape[2] == cfg.n_kv_heads \
            and shape[3] == cfg.dh else "state"
        return NamedSharding(plan.mesh, _cache_leaf_spec(shape, plan, kind))
    return jax.tree.map(leaf_spec, cache_abstract)

"""Layer-interior sharding strategy context (§Perf hillclimb levers).

The baseline lets GSPMD pick every interior resharding.  The hillclimb
iterations steer it with targeted constraints, selected per-run through this
contextvar so model code stays pure-functional:

* ``ffn="gather_weights"`` — constrain FFN intermediates to stay
  sequence-sharded so XLA all-gathers the (batch-independent) weight
  matrices instead of the (B,S,D) activations (Megatron-SP inversion; wins
  when B·S·D ≳ layer params, which holds for all train_4k cells).
* ``moe_gather_seq=True`` — gather the sequence once around the MoE block
  and run dispatch purely expert-parallel (kills the S↔E resharding storm).
* ``attn="tp_chunked"`` — prefill attention with heads-TP + unrolled query
  chunks instead of the seq-resharding shard_map path (divisible heads only).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Iterator, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    dp_axes: Tuple[str, ...]
    ffn: Optional[str] = None          # None | "gather_weights"
    moe_gather_seq: bool = False
    attn: Optional[str] = None         # None | "tp_chunked"
    attn_q_chunk: int = 2048

    def dp(self, size: int) -> Optional[Tuple[str, ...]]:
        import math
        dp_size = math.prod(self.mesh.shape[a] for a in self.dp_axes)
        return self.dp_axes if size % dp_size == 0 else None


_ctx: contextvars.ContextVar[Optional[ShardingCtx]] = contextvars.ContextVar(
    "repro_sharding_ctx", default=None)


def current() -> Optional[ShardingCtx]:
    return _ctx.get()


@contextlib.contextmanager
def use(ctx: Optional[ShardingCtx]) -> Iterator[None]:
    tok = _ctx.set(ctx)
    try:
        yield
    finally:
        _ctx.reset(tok)


def constrain(x, spec: P):
    ctx = current()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))

from .partition import (MeshPlan, make_param_shardings, make_plan,
                        shard_batch_spec, shard_cache, constrain_activations)

__all__ = ["MeshPlan", "make_param_shardings", "make_plan",
           "shard_batch_spec", "shard_cache", "constrain_activations"]

"""FDB-backed training-data pipeline (the paper's producer/consumer pattern).

Producers (tokenizer jobs / NWP field generators) archive sample shards;
training readers retrieve per-step batches while producers may still be
writing — the thesis's operational write+read contention pattern, running on
whichever FDB backend is configured.  A background prefetch thread overlaps
retrieval with compute (I/O-forwarding analogue).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core import FDB, FDBConfig, Identifier
from repro.core.schema import DATA_SCHEMA


class SyntheticTokens:
    """Deterministic synthetic LM data (no external corpora in-container)."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + step)
        toks = rng.integers(0, self.vocab_size,
                            (batch_size, self.seq_len + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class FDBDataPipeline:
    def __init__(self, corpus: str, split: str = "train",
                 fdb_config: Optional[FDBConfig] = None,
                 producer: str = "prod0", prefetch: int = 2):
        cfg = fdb_config or FDBConfig(backend="daos")
        if cfg.resolved_schema().name != "data":
            import dataclasses
            cfg = dataclasses.replace(cfg, schema=DATA_SCHEMA)
        self.fdb = FDB(cfg)
        self.corpus = corpus
        self.split = split
        self.producer = producer
        self.prefetch = prefetch

    # -- producer side -----------------------------------------------------
    def put_batch(self, shard: int, batch_idx: int,
                  batch: Dict[str, np.ndarray]) -> None:
        packed = np.concatenate(
            [batch["tokens"].reshape(-1), batch["labels"].reshape(-1)])
        meta = np.array(batch["tokens"].shape, np.int64)
        payload = meta.tobytes() + packed.astype(np.int32).tobytes()
        self.fdb.archive(self._ident(shard, batch_idx), payload)

    def commit(self) -> None:
        self.fdb.flush()

    # -- consumer side ---------------------------------------------------------
    def _ident(self, shard: int, batch_idx: int) -> Identifier:
        return Identifier({"corpus": self.corpus, "split": self.split,
                           "producer": self.producer, "shard": str(shard),
                           "batch": str(batch_idx)})

    def get_batch(self, shard: int, batch_idx: int
                  ) -> Optional[Dict[str, np.ndarray]]:
        h = self.fdb.retrieve(self._ident(shard, batch_idx))
        if h.length() == 0:
            return None
        raw = h.read()
        meta = np.frombuffer(raw[:16], np.int64)
        B, S = int(meta[0]), int(meta[1])
        flat = np.frombuffer(raw[16:], np.int32)
        return {"tokens": flat[:B * S].reshape(B, S).copy(),
                "labels": flat[B * S:].reshape(B, S).copy()}

    def available_batches(self, shard: int) -> int:
        return sum(1 for _ in self.fdb.list(
            {"corpus": self.corpus, "split": self.split,
             "shard": str(shard)}))

    def iter_batches(self, shard: int, start: int = 0
                     ) -> Iterator[Dict[str, np.ndarray]]:
        """Prefetching iterator: retrieval overlaps consumer compute."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)

        def fill() -> None:
            i = start
            while True:
                b = self.get_batch(shard, i)
                q.put(b)
                if b is None:
                    return
                i += 1

        t = threading.Thread(target=fill, daemon=True)
        t.start()
        while True:
            b = q.get()
            if b is None:
                return
            yield b

    def close(self) -> None:
        self.fdb.close()

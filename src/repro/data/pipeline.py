"""FDB-backed training-data pipeline (the paper's producer/consumer pattern).

Producers (tokenizer jobs / NWP field generators) archive sample shards;
training readers retrieve per-step batches while producers may still be
writing — the thesis's operational write+read contention pattern, running on
whichever FDB backend is configured.  A background prefetch thread overlaps
retrieval with compute (I/O-forwarding analogue).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core import FDB, FDBConfig, Identifier, WriterSession
from repro.core.schema import DATA_SCHEMA, TENSOR_SCHEMA
from repro.tensorstore import (ChunkedArray, LayoutMismatchError,
                               TensorStore, TreeCatalogue)


class SyntheticTokens:
    """Deterministic synthetic LM data (no external corpora in-container)."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed + step)
        toks = rng.integers(0, self.vocab_size,
                            (batch_size, self.seq_len + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ChunkedFieldStore:
    """Chunked N-D weather-field access over ``repro.tensorstore``.

    Producers archive whole fields (lat × lon × level grids) as chunked
    arrays; consumers slice windows — ``read_window("t2m", slice(0, 120),
    slice(300, 420))`` retrieves only the intersecting chunks, the partial-
    read NWP workload (regional post-processing / PGEN extraction) the
    whole-blob archive path cannot serve.
    """

    def __init__(self, store: str = "nwp",
                 fdb_config: Optional[FDBConfig] = None,
                 writer: str = "prod0", codec: str = "raw",
                 chunks: Optional[tuple] = None,
                 tracer=None, faults=None, retry=None, meter=None,
                 cache_bytes: int = 64 * 2 ** 20):
        cfg = fdb_config or FDBConfig(backend="daos")
        import dataclasses
        if cfg.resolved_schema().name != "tensor":
            cfg = dataclasses.replace(cfg, schema=TENSOR_SCHEMA)
        # the serving facade defaults the decoded-chunk cache ON (the raw
        # FDB/TensorStore layers leave it off so op accounting stays
        # exact); cache_bytes=0 opts out, and an explicit
        # FDBConfig.chunk_cache_bytes wins
        if cfg.chunk_cache_bytes == 0 and cache_bytes > 0:
            cfg = dataclasses.replace(cfg, chunk_cache_bytes=cache_bytes)
        # tracer/faults/retry/meter pass straight through to the FDB
        # client, so workflow drivers can observe, chaos-test and
        # cost-model the field path without reaching around the facade
        self.fdb = FDB(cfg, meter=meter, tracer=tracer, faults=faults,
                       retry=retry)
        self.store = store
        #: collocation key all producers share (the schema "writer" dim) —
        #: named writer_key so the :meth:`writer` session factory can keep
        #: the ISSUE-facing name
        self.writer_key = writer
        self.codec = codec
        self.chunks = chunks
        # metadata only changes on wipe/re-put/reshard, so opened arrays
        # cache; those mutators update or drop this store's own cache, but
        # a *different* consumer store must re-open after a producer
        # reshard (open_field(refresh=True)) — see reshard()
        self._opened: Dict[str, ChunkedArray] = {}
        #: consolidated metadata for this store's dataset tree (the Zarr
        #: ``.zmetadata`` idiom): creates/reshards through this facade keep
        #: it fresh, and :meth:`open_tree` opens every field with ONE fetch
        self.tree = TreeCatalogue(
            self.fdb, {"store": store, "writer": writer},
            member_dim="array")

    def _ts(self, name: str) -> TensorStore:
        return TensorStore(self.fdb, {"store": self.store, "array": name,
                                      "writer": self.writer_key},
                           tree=self.tree)

    # -- producer side -----------------------------------------------------
    def put_field(self, name: str, values: np.ndarray,
                  chunks: Optional[tuple] = None,
                  codec: Optional[str] = None) -> ChunkedArray:
        ts = self._ts(name)
        values = np.asarray(values)
        with self.fdb.tracer.span("field.put", field=name,
                                  nbytes=values.nbytes):
            try:
                arr = ts.create(values.shape, values.dtype,
                                chunks=chunks or self.chunks,
                                codec=codec or self.codec)
            except LayoutMismatchError:
                # layout changed: the array's dataset is exactly (store,
                # array), so a wipe removes every stale chunk before
                # re-creating
                self.wipe_field(name)
                arr = ts.create(values.shape, values.dtype,
                                chunks=chunks or self.chunks,
                                codec=codec or self.codec)
            # commit() is the visibility barrier — don't flush per field
            arr.write(values, flush=False)
        self._opened[name] = arr
        return arr

    def commit(self) -> None:
        self.fdb.flush()

    # -- consumer side -----------------------------------------------------
    def open_field(self, name: str, refresh: bool = False) -> ChunkedArray:
        """Open (and cache) a field's chunked array.  The first open on a
        fresh consumer loads the **consolidated metadata** once (one
        fetch) and serves every subsequent field open from it — per-array
        metadata fetches happen only for fields the consolidated object
        does not know (written by code that does not maintain it, or by a
        concurrent producer since the load).

        ``refresh=True`` drops the cached open and re-reads the
        authoritative per-array metadata — required for a consumer to pick
        up another client's re-layout (``reshard``), since versioned
        retain keeps the old generation's chunks readable and a stale
        cached open would keep returning them; the consolidated mirror is
        reloaded too."""
        if refresh:
            self._opened.pop(name, None)
            arr = self._opened[name] = self._ts(name).open()
            self.tree.load()    # resync the consolidated mirror as well
            return arr
        arr = self._opened.get(name)
        if arr is None:
            if not self.tree.loaded:
                self.tree.load()
            meta = self.tree.get(name)
            if meta is not None:        # consolidated hit: no fetch
                arr = ChunkedArray(self._ts(name), meta)
            else:                       # fall back to per-array metadata
                arr = self._ts(name).open()
            self._opened[name] = arr
        return arr

    def open_tree(self, refresh: bool = False) -> Dict[str, ChunkedArray]:
        """Open every field of this store's dataset tree with a **single**
        consolidated-metadata fetch (the Zarr consolidated-open idiom) —
        the serving cold-start path: N arrays, one round-trip.  Returns
        ``{name: ChunkedArray}`` and primes the per-field open cache.
        Fields written by clients that do not maintain the consolidated
        object are absent — open them via :meth:`open_field`, which falls
        back to the authoritative per-array metadata."""
        if refresh or not self.tree.loaded:
            self.tree.load()
        out: Dict[str, ChunkedArray] = {}
        for name in self.tree.names():
            if name.startswith("."):
                continue
            meta = self.tree.get(name)
            arr = self._opened.get(name)
            if arr is None or arr.meta != meta:
                arr = self._opened[name] = ChunkedArray(self._ts(name),
                                                        meta)
            out[name] = arr
        return out

    def read_window(self, name: str, *selection,
                    fill_missing: bool = True) -> np.ndarray:
        """Read a window of a field; I/O is issued for only the chunks the
        window intersects — in parallel, and coalesced into single ranged
        reads where chunks are adjacent in one file (posix backend).
        Windows may be strided (``slice(0, 720, 4)`` — every 4th latitude):
        chunks the stride steps over are not touched at all.

        ``fill_missing=False`` raises ``KeyError`` on never-written chunks
        instead of zero-filling — for consumers of dense fields where a
        missing chunk means lost or not-yet-committed data.
        """
        arr = self.open_field(name)
        with self.fdb.tracer.span("field.read_window", field=name):
            return arr.read_plan(tuple(selection),
                                 fill_missing=fill_missing).execute()

    def write_window(self, name: str, values, *selection) -> ChunkedArray:
        """Chunk-aligned in-place update of a field window — the
        assimilation pattern: ``write_window("t2m", increment, slice(0,
        120), slice(300, 420))`` re-archives only the chunks the window
        touches (partially covered edge chunks read-modify-write), through
        a coalesced :class:`~repro.tensorstore.WritePlan` — chunks landing
        in one posix data file archive as a single batched store write, and
        same-shape chunks encode in one codec kernel launch.

        Visibility of the *new* chunk versions waits for :meth:`commit`.
        Windows may be strided (a subsampled analysis grid writing every
        k-th row): stride gaps are preserved via read-modify-write of the
        touched chunks.  Caveat for chunk-*aligned* batching only: a window
        that partially covers a chunk needs read-modify-write, and the RMW
        pre-flush (FDB rule 3, see :meth:`ChunkedArray.write_at`) publishes
        whatever this producer archived earlier in the batch.  Producers
        that need a strict single commit barrier must keep their windows
        chunk-aligned.
        """
        arr = self.open_field(name)
        # normalize_key pads a short/empty key with full slices
        with self.fdb.tracer.span("field.write_window", field=name):
            arr.write_plan(tuple(selection), values).execute(flush=False)
        return arr

    def reshard(self, name: str, new_chunks, *selection,
                codec: Optional[str] = None) -> ChunkedArray:
        """Re-lay-out a field onto a new chunk grid — the producer-grid vs
        consumer-grid mismatch the paper's workflows revolve around: a
        model archives level-major chunks, regional post-processing wants
        lat/lon tiles, so the pipeline reshards between the stages instead
        of punishing every consumer read.

        Streams through bounded batches (one coalesced read plan + one
        coalesced write plan each — see
        :class:`repro.tensorstore.ReshardPlan`); the whole field is never
        materialised client-side, and the re-layout is committed (flushed)
        before returning: this store's cached open is updated in place and
        consumers *opening* the field afterwards see the new grid.  A
        consumer store that already cached its open keeps reading the
        retained old generation until it re-opens —
        ``open_field(name, refresh=True)`` — because versioned retain
        deliberately keeps the old chunks readable.  A trailing
        ``*selection`` of slices (possibly strided) subsamples on the way
        through — e.g. every other level for a coarse consumer.

        Old-grid chunks are retained versioned (unreachable, never read as
        wrong data) because the FDB has no per-object delete; to *reclaim*
        their space instead, use :meth:`wipe_field` + :meth:`put_field`,
        which costs a full client-side roundtrip.
        """
        arr = self.open_field(name)
        sel = tuple(selection) if selection else None
        arr.reshard(new_chunks, codec=codec, sel=sel, flush=True)
        return arr

    def wipe_field(self, name: str) -> None:
        self._opened.pop(name, None)
        self.fdb.wipe({"store": self.store, "array": name})
        # the tree index lives in its own (store, array=".tree") dataset,
        # so the wipe above never touches it — drop the member explicitly
        # (loading first so an unloaded mirror can't leave a stale entry)
        if not self.tree.loaded:
            self.tree.load()
        self.tree.forget(name)

    # -- multi-producer side ------------------------------------------------
    def writer(self, writer_id: str, lease_ttl: Optional[float] = None,
               heartbeat_interval: Optional[float] = None,
               lease_block: bool = False,
               lease_timeout: Optional[float] = None) -> "FieldWriter":
        """Open a :class:`FieldWriter` — one producer task's session on
        this store, the multi-writer counterpart of :meth:`write_window`.

        Several writers (e.g. parallel assimilation tasks, ensemble
        members) may update *one* field concurrently: each writer's window
        acquires the covering chunk-range leases at plan time, so disjoint
        windows proceed in parallel — through one FDB client and one
        bounded executor — while overlapping windows fail fast with
        ``LeaseConflictError`` instead of racing to a silent last-flush
        merge.  All writers share this store's collocation key (the
        ``writer`` schema dim), so consumers read one coherent array; the
        *session* identity exists for leases and per-session flush
        barriers, not for placement.

        ``lease_block=True`` flips the overlap posture from fail-fast to
        wait: plan-time acquires queue (up to ``lease_timeout`` seconds)
        on conflicting windows until their holder releases or its
        ``lease_ttl`` lapses — how workflow assimilation stages serialise
        overlapping analysis windows instead of erroring
        (``docs/workflows.md``).

        Use as a context manager; :meth:`FieldWriter.commit` is the
        visibility barrier, and closing flushes (if dirty) then releases
        every lease the writer still holds.
        """
        return FieldWriter(self, self.fdb.session(
            writer_id, lease_ttl=lease_ttl,
            heartbeat_interval=heartbeat_interval,
            lease_block=lease_block, lease_timeout=lease_timeout))

    def close(self) -> None:
        self.fdb.close()


class FieldWriter:
    """One producer task writing windows of shared fields under chunk-range
    leases — returned by :meth:`ChunkedFieldStore.writer`."""

    def __init__(self, store: ChunkedFieldStore, session: WriterSession):
        self._store = store
        self.session = session
        #: session-bound opens are cached per field (metadata re-reads are
        #: pure overhead; layout changes mid-session are not supported)
        self._opened: Dict[str, ChunkedArray] = {}

    @property
    def writer_id(self) -> str:
        return self.session.writer_id

    def _open(self, name: str) -> ChunkedArray:
        arr = self._opened.get(name)
        if arr is None:
            ts = TensorStore(None, {"store": self._store.store,
                                    "array": name,
                                    "writer": self._store.writer_key},
                             session=self.session)
            arr = self._opened[name] = ts.open()
        return arr

    def write_window(self, name: str, values, *selection) -> ChunkedArray:
        """Chunk-aligned in-place update of a field window under this
        writer's leases: the covering chunk ranges are acquired at plan
        time (``LeaseConflictError`` if another writer holds any of them,
        before any byte moves) and stay held until :meth:`close` — a
        :meth:`commit` publishes the data but deliberately keeps the
        windows owned, so a producer retains them across commits.  The new
        chunk versions become visible at :meth:`commit`, exactly like
        :meth:`ChunkedFieldStore.write_window`.  RMW fetches for partially
        covered chunks are lease-protected, and this session's earlier
        unflushed archives pre-flush per *session*, not per client."""
        arr = self._open(name)
        tracer = self.session.fdb.tracer
        with tracer.span("field.write_window", field=name,
                         writer=self.writer_id):
            arr.write_plan(tuple(selection), values).execute(flush=False)
        return arr

    def commit(self) -> None:
        """The visibility barrier for everything this writer archived
        (client-level flush: FDB rule 3).  Held leases stay held — a
        writer keeps its windows across commits until it closes."""
        self.session.flush()

    def close(self) -> None:
        """Flush if dirty, then release every lease this writer holds."""
        self.session.close()

    def __enter__(self) -> "FieldWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class FDBDataPipeline:
    def __init__(self, corpus: str, split: str = "train",
                 fdb_config: Optional[FDBConfig] = None,
                 producer: str = "prod0", prefetch: int = 2):
        cfg = fdb_config or FDBConfig(backend="daos")
        if cfg.resolved_schema().name != "data":
            import dataclasses
            cfg = dataclasses.replace(cfg, schema=DATA_SCHEMA)
        self.fdb = FDB(cfg)
        self.corpus = corpus
        self.split = split
        self.producer = producer
        self.prefetch = prefetch

    # -- producer side -----------------------------------------------------
    def put_batch(self, shard: int, batch_idx: int,
                  batch: Dict[str, np.ndarray]) -> None:
        packed = np.concatenate(
            [batch["tokens"].reshape(-1), batch["labels"].reshape(-1)])
        meta = np.array(batch["tokens"].shape, np.int64)
        payload = meta.tobytes() + packed.astype(np.int32).tobytes()
        with self.fdb.tracer.span("data.put_batch", shard=shard,
                                  batch=batch_idx, nbytes=len(payload)):
            self.fdb.archive(self._ident(shard, batch_idx), payload)

    def commit(self) -> None:
        self.fdb.flush()

    # -- consumer side ---------------------------------------------------------
    def _ident(self, shard: int, batch_idx: int) -> Identifier:
        return Identifier({"corpus": self.corpus, "split": self.split,
                           "producer": self.producer, "shard": str(shard),
                           "batch": str(batch_idx)})

    def get_batch(self, shard: int, batch_idx: int
                  ) -> Optional[Dict[str, np.ndarray]]:
        with self.fdb.tracer.span("data.get_batch", shard=shard,
                                  batch=batch_idx):
            h = self.fdb.retrieve(self._ident(shard, batch_idx))
            if h.length() == 0:
                return None
            raw = h.read()
        meta = np.frombuffer(raw[:16], np.int64)
        B, S = int(meta[0]), int(meta[1])
        flat = np.frombuffer(raw[16:], np.int32)
        return {"tokens": flat[:B * S].reshape(B, S).copy(),
                "labels": flat[B * S:].reshape(B, S).copy()}

    def available_batches(self, shard: int) -> int:
        return sum(1 for _ in self.fdb.list(
            {"corpus": self.corpus, "split": self.split,
             "shard": str(shard)}))

    def iter_batches(self, shard: int, start: int = 0
                     ) -> Iterator[Dict[str, np.ndarray]]:
        """Prefetching iterator: retrieval overlaps consumer compute."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)

        def fill() -> None:
            i = start
            while True:
                b = self.get_batch(shard, i)
                q.put(b)
                if b is None:
                    return
                i += 1

        # lint: disable=L005 -- single daemon prefetch thread feeding a
        # bounded queue; not chunk I/O, so ChunkExecutor doesn't fit
        t = threading.Thread(target=fill, daemon=True)
        t.start()
        while True:
            b = q.get()
            if b is None:
                return
            yield b

    def close(self) -> None:
        self.fdb.close()

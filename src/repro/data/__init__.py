from .pipeline import FDBDataPipeline, SyntheticTokens

__all__ = ["FDBDataPipeline", "SyntheticTokens"]

from .pipeline import ChunkedFieldStore, FDBDataPipeline, SyntheticTokens

__all__ = ["ChunkedFieldStore", "FDBDataPipeline", "SyntheticTokens"]

"""Decoded-chunk LRU cache — the read-serving hot path.

One :class:`ChunkCache` is shared by every reader of one FDB client
(``fdb.chunk_cache``, built lazily when ``FDBConfig.chunk_cache_bytes``
is nonzero): many concurrent consumers hammering the same forecast
fields re-decode each chunk once, not per read.  Entries are **decoded**
ndarrays keyed by ``(scope, generation, chunk_idx)`` where ``scope`` is
the array's full base identifier — a reshard's generation flip simply
stops producing the old keys, so stale layouts age out of the LRU
without any cross-client coordination.

Coherence contract (mirrors the ``ChunkedFieldStore`` metadata cache):

* **own writes** — a :class:`~repro.tensorstore.store.WritePlan` that
  archives a chunk *invalidates* its key and marks it **pending**: until
  the client's next clean flush the key refuses ``put``s, so a read
  between archive and flush re-fetches the still-visible old bytes
  every time (FDB rule 3: archive-without-flush is not readable) and
  never pins them past the barrier.  ``FDB.flush`` publishes the
  pending set on its clean path.
* **stale puts** — ``lookup`` hands out a per-key version *token*;
  ``put`` is a no-op when the key was invalidated after the token was
  issued.  This closes the fetch → concurrent overwrite → late-put race
  without holding the cache lock across I/O.
* **cross-client overwrites** under an unchanged layout are *not*
  observed (same documented staleness window as the field store's
  metadata cache); generation-bumping operations (``reshard``,
  ``on_mismatch="retain"``) invalidate naturally via new keys, and
  ``FDB.wipe`` drops every entry whose scope matches the wiped dataset.

The cache is bytes- **and** entry-bounded (strict LRU on lookup-hit and
put), stores non-writeable copies (readers copy on scatter, so a cached
chunk can never be mutated through a returned window), and counts
``cache.hits`` / ``cache.misses`` / ``cache.evicted_bytes`` into the
client's :class:`~repro.obs.metrics.MetricsRegistry`.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional, Set, Tuple

import numpy as np

#: cache key: (array scope — sorted base-identifier items, layout
#: generation, chunk grid index)
CacheKey = Tuple[Tuple[Tuple[str, str], ...], int, Tuple[int, ...]]


class ChunkCache:
    """Bytes- and entry-bounded LRU of decoded chunks.

    Thread-safe; the lock is held only for dict surgery (never across
    I/O or decode).  ``metrics`` is a
    :class:`~repro.obs.metrics.MetricsRegistry` (optional — omitting it
    keeps the cache fully functional with local stats only).
    """

    def __init__(self, max_bytes: int, max_entries: int = 1024,
                 metrics=None) -> None:
        if max_bytes <= 0:
            raise ValueError("ChunkCache needs max_bytes > 0; gate "
                             "construction on the config instead")
        self.max_bytes = int(max_bytes)
        self.max_entries = int(max_entries)
        self._data: "OrderedDict[CacheKey, np.ndarray]" = OrderedDict()
        self._nbytes = 0
        #: per-key invalidation counter — lookup tokens; persists across
        #: eviction so a late put after an invalidate is always rejected
        self._versions: Dict[CacheKey, int] = {}
        #: keys archived-but-unflushed by this client (FDB rule 3):
        #: refuse puts until the next clean flush publishes them
        self._pending: Set[CacheKey] = set()
        self._lock = threading.Lock()
        self._metrics = metrics
        self.hits = 0
        self.misses = 0
        self.evicted_bytes = 0

    @staticmethod
    def scope(base: Dict[str, str]) -> Tuple[Tuple[str, str], ...]:
        """Canonical scope component of a key for one array's ``base``."""
        return tuple(sorted(base.items()))

    # -- read side -----------------------------------------------------------
    def lookup(self, key: CacheKey):
        """``(chunk_or_None, token)``; pass the token back to :meth:`put`."""
        with self._lock:
            chunk = self._data.get(key)
            if chunk is not None:
                self._data.move_to_end(key)
                self.hits += 1
            else:
                self.misses += 1
            token = self._versions.get(key, 0)
        if self._metrics is not None:
            name = "cache.hits" if chunk is not None else "cache.misses"
            self._metrics.counter(name).inc()
        return chunk, token

    def put(self, key: CacheKey, chunk: np.ndarray, token: int) -> bool:
        """Insert a decoded chunk fetched under ``token``.  Rejected (and
        returns False) when the key is pending this client's flush or was
        invalidated after the token was issued — the fetched bytes may
        predate an overwrite."""
        value = np.ascontiguousarray(chunk)
        if value.nbytes > self.max_bytes:
            return False
        if value is chunk or value.base is not None:
            value = value.copy()
        value.setflags(write=False)
        evicted = 0
        with self._lock:
            if key in self._pending or self._versions.get(key, 0) != token:
                return False
            old = self._data.pop(key, None)
            if old is not None:
                self._nbytes -= old.nbytes
            self._data[key] = value
            self._nbytes += value.nbytes
            while self._data and (self._nbytes > self.max_bytes
                                  or len(self._data) > self.max_entries):
                _k, victim = self._data.popitem(last=False)
                self._nbytes -= victim.nbytes
                evicted += victim.nbytes
        if evicted:
            self.evicted_bytes += evicted
            if self._metrics is not None:
                self._metrics.counter("cache.evicted_bytes").inc(evicted)
        return True

    # -- write-side coherence ------------------------------------------------
    def invalidate(self, key: CacheKey) -> None:
        """An overwrite of ``key`` was archived (not yet flushed): drop
        the entry, fence stale puts, and pend the key until the client's
        next clean flush."""
        with self._lock:
            old = self._data.pop(key, None)
            if old is not None:
                self._nbytes -= old.nbytes
            self._versions[key] = self._versions.get(key, 0) + 1
            self._pending.add(key)

    def publish_pending(self) -> None:
        """The client's flush barrier committed: pending keys may be
        cached again (their next fetch sees the new bytes)."""
        with self._lock:
            self._pending.clear()

    def clear(self, match: Optional[Dict[str, str]] = None) -> None:
        """Drop every entry (``match=None``) or every entry whose scope
        carries all of ``match``'s key/value pairs — the ``FDB.wipe``
        hook (wipes are dataset-granular, e.g. ``{"store":…,"array":…}``)."""
        with self._lock:
            if match is None:
                self._data.clear()
                self._nbytes = 0
                self._pending.clear()
                return
            want = set(match.items())
            for key in [k for k in self._data
                        if want <= set(k[0])]:
                self._nbytes -= self._data.pop(key).nbytes
            self._pending -= {k for k in self._pending if want <= set(k[0])}

    # -- introspection -------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return self._nbytes

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key: CacheKey) -> bool:
        with self._lock:
            return key in self._data

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> Dict[str, object]:
        with self._lock:
            return {"entries": len(self._data), "nbytes": self._nbytes,
                    "hits": self.hits, "misses": self.misses,
                    "hit_rate": self.hit_rate,
                    "evicted_bytes": self.evicted_bytes,
                    "pending": len(self._pending)}


__all__ = ["CacheKey", "ChunkCache"]

"""Per-chunk codecs: raw passthrough + the Pallas field codec.

``field8``/``field16`` reuse the TPU field-packing kernels
(:mod:`repro.kernels.field_codec`): the chunk is flattened, the lane-aligned
head (a multiple of 128 elements) is block-quantised to int8/int16 with
per-block (scale, min) pairs, and the sub-lane tail rides along as float32.
Chunks that cannot profit (non-float dtypes, tiny chunks) fall back to raw
bytes — the one-byte container header makes every chunk self-describing, so
edge chunks of any shape roundtrip exactly through either path.

Container layout (little-endian):
  [0]   marker: 0 = raw ndarray bytes, 1 = quantised
  quantised payload:
  [1:9] rows:u32, block:u32
  [9:]  q (rows*128 int8|int16) | scale (rows/block f32) | mins (f32) | tail f32
"""
from __future__ import annotations

import struct
from typing import Dict, Tuple

import numpy as np

_LANES = 128
_RAW, _QUANT = 0, 1
_BLOCK_CANDIDATES = (256, 128, 64, 32, 16, 8, 4, 2, 1)


class Codec:
    name: str = "?"

    def encode(self, arr: np.ndarray) -> bytes:
        raise NotImplementedError

    def decode(self, data: bytes, shape: Tuple[int, ...],
               dtype: np.dtype) -> np.ndarray:
        raise NotImplementedError


class RawCodec(Codec):
    name = "raw"

    def encode(self, arr: np.ndarray) -> bytes:
        return np.ascontiguousarray(arr).tobytes()

    def decode(self, data: bytes, shape: Tuple[int, ...],
               dtype: np.dtype) -> np.ndarray:
        return np.frombuffer(data, dtype=dtype).reshape(shape).copy()


class FieldQuantCodec(Codec):
    """Lossy block quantisation via the Pallas field codec kernels."""

    def __init__(self, bits: int = 8):
        assert bits in (8, 16)
        self.bits = bits
        self.name = f"field{bits}"
        self._qdtype = np.int8 if bits == 8 else np.int16

    def _eligible(self, arr: np.ndarray) -> bool:
        return (arr.dtype in (np.float32, np.float16, np.float64)
                and arr.size >= 2 * _LANES)

    def encode(self, arr: np.ndarray) -> bytes:
        arr = np.ascontiguousarray(arr)
        if not self._eligible(arr):
            return bytes([_RAW]) + arr.tobytes()
        from repro.kernels import ops
        flat = arr.reshape(-1).astype(np.float32)
        n = (flat.size // _LANES) * _LANES
        rows = n // _LANES
        block = next(b for b in _BLOCK_CANDIDATES if rows % b == 0)
        q, scale, mins = ops.field_encode(flat[:n].reshape(rows, _LANES),
                                          block=block, bits=self.bits)
        return b"".join([
            bytes([_QUANT]), struct.pack("<II", rows, block),
            np.asarray(q, self._qdtype).tobytes(),
            np.asarray(scale, np.float32).tobytes(),
            np.asarray(mins, np.float32).tobytes(),
            flat[n:].tobytes(),
        ])

    def decode(self, data: bytes, shape: Tuple[int, ...],
               dtype: np.dtype) -> np.ndarray:
        marker = data[0]
        if marker == _RAW:
            return np.frombuffer(data, dtype=dtype, offset=1
                                 ).reshape(shape).copy()
        from repro.kernels import ops
        rows, block = struct.unpack_from("<II", data, 1)
        nb = rows // block
        off = 9
        qlen = rows * _LANES * np.dtype(self._qdtype).itemsize
        q = np.frombuffer(data, self._qdtype, rows * _LANES, off
                          ).reshape(rows, _LANES)
        off += qlen
        scale = np.frombuffer(data, np.float32, nb, off)
        off += 4 * nb
        mins = np.frombuffer(data, np.float32, nb, off)
        off += 4 * nb
        tail = np.frombuffer(data, np.float32, offset=off)
        head = np.asarray(ops.field_decode(q, scale, mins, block=block,
                                           bits=self.bits))
        return np.concatenate([head.reshape(-1), tail]).astype(
            dtype, copy=False).reshape(shape)


CODECS: Dict[str, Codec] = {
    c.name: c for c in (RawCodec(), FieldQuantCodec(8), FieldQuantCodec(16))
}


def get_codec(name: str) -> Codec:
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(f"unknown tensorstore codec {name!r}; "
                         f"known: {sorted(CODECS)}") from None

"""Per-chunk codecs: raw passthrough + the Pallas field codec.

``field8``/``field16`` reuse the TPU field-packing kernels
(:mod:`repro.kernels.field_codec`): the chunk is flattened, the lane-aligned
head (a multiple of 128 elements) is block-quantised to int8/int16 with
per-block (scale, min) pairs, and the sub-lane tail rides along as float32.
Chunks that cannot profit (non-float dtypes, tiny chunks) fall back to raw
bytes — the one-byte container header makes every chunk self-describing, so
edge chunks of any shape roundtrip exactly through either path.

The batch entry points (:meth:`Codec.encode_batch` /
:meth:`Codec.decode_batch`) are the write/read plans' hook into kernel
vectorisation: equal-shape chunks are stacked onto the kernels' leading
batch dimension and encoded (decoded) in ONE Pallas launch — grid over
chunks × blocks — while ragged edge chunks fall back to the per-chunk path.
Batched output is byte-identical to per-chunk encodes (blocks never
straddle chunks), so the two paths interoperate freely.

Container layout (little-endian):
  [0]   marker: 0 = raw ndarray bytes, 1 = quantised
  quantised payload:
  [1:9] rows:u32, block:u32
  [9:]  q (rows*128 int8|int16) | scale (rows/block f32) | mins (f32) | tail f32
"""
from __future__ import annotations

import struct
from typing import Dict, List, Sequence, Tuple

import numpy as np

_LANES = 128
_RAW, _QUANT = 0, 1
_BLOCK_CANDIDATES = (256, 128, 64, 32, 16, 8, 4, 2, 1)


class Codec:
    name: str = "?"

    def encode(self, arr: np.ndarray) -> bytes:
        raise NotImplementedError

    def decode(self, data: bytes, shape: Tuple[int, ...],
               dtype: np.dtype) -> np.ndarray:
        raise NotImplementedError

    # -- batch entry points (kernel vectorisation hook) ---------------------
    def encode_batch(self, arrs: Sequence[np.ndarray]) -> List[bytes]:
        """Encode several chunks, byte-identical to per-chunk :meth:`encode`
        and in input order.  Codecs backed by kernels override this to
        launch once per equal-shape group instead of once per chunk."""
        return [self.encode(a) for a in arrs]

    def decode_batch(self, datas: Sequence[bytes],
                     shapes: Sequence[Tuple[int, ...]],
                     dtype: np.dtype) -> List[np.ndarray]:
        """Decode several chunk payloads (inverse of :meth:`encode_batch`)."""
        return [self.decode(d, s, dtype) for d, s in zip(datas, shapes)]


class RawCodec(Codec):
    name = "raw"

    def encode(self, arr: np.ndarray) -> bytes:
        return np.ascontiguousarray(arr).tobytes()

    def decode(self, data: bytes, shape: Tuple[int, ...],
               dtype: np.dtype) -> np.ndarray:
        return np.frombuffer(data, dtype=dtype).reshape(shape).copy()


class FieldQuantCodec(Codec):
    """Lossy block quantisation via the Pallas field codec kernels."""

    def __init__(self, bits: int = 8):
        assert bits in (8, 16)
        self.bits = bits
        self.name = f"field{bits}"
        self._qdtype = np.int8 if bits == 8 else np.int16

    def _eligible(self, arr: np.ndarray) -> bool:
        return (arr.dtype in (np.float32, np.float16, np.float64)
                and arr.size >= 2 * _LANES)

    @staticmethod
    def _layout(size: int) -> Tuple[int, int, int]:
        """(lane-aligned head length, quantised rows, block) for a chunk of
        ``size`` elements — shared by the loop and batched encode paths so
        both pick identical quantisation geometry."""
        n = (size // _LANES) * _LANES
        rows = n // _LANES
        block = next(b for b in _BLOCK_CANDIDATES if rows % b == 0)
        return n, rows, block

    def _container(self, rows: int, block: int, q, scale, mins,
                   tail: np.ndarray) -> bytes:
        return b"".join([
            bytes([_QUANT]), struct.pack("<II", rows, block),
            np.asarray(q, self._qdtype).tobytes(),
            np.asarray(scale, np.float32).tobytes(),
            np.asarray(mins, np.float32).tobytes(),
            tail.tobytes(),
        ])

    def encode(self, arr: np.ndarray) -> bytes:
        arr = np.ascontiguousarray(arr)
        if not self._eligible(arr):
            return bytes([_RAW]) + arr.tobytes()
        from repro.kernels import ops
        flat = arr.reshape(-1).astype(np.float32)
        n, rows, block = self._layout(flat.size)
        q, scale, mins = ops.field_encode(flat[:n].reshape(rows, _LANES),
                                          block=block, bits=self.bits)
        return self._container(rows, block, q, scale, mins, flat[n:])

    def encode_batch(self, arrs: Sequence[np.ndarray]) -> List[bytes]:
        """Stack equal-shape eligible chunks onto the kernel's batch
        dimension: one Pallas launch per distinct chunk shape (interior
        chunks of a write plan all share one), instead of one per chunk.
        Ineligible chunks take the raw fallback; output is byte-identical
        to calling :meth:`encode` per chunk."""
        out: List[bytes] = [b""] * len(arrs)
        by_shape: Dict[Tuple[int, ...], List[int]] = {}
        contig = [np.ascontiguousarray(a) for a in arrs]
        for i, a in enumerate(contig):
            if self._eligible(a):
                by_shape.setdefault(a.shape, []).append(i)
            else:
                out[i] = bytes([_RAW]) + a.tobytes()
        if by_shape:
            from repro.kernels import ops
        for shape, idxs in by_shape.items():
            flats = [contig[i].reshape(-1).astype(np.float32) for i in idxs]
            n, rows, block = self._layout(flats[0].size)
            stacked = np.stack([f[:n].reshape(rows, _LANES) for f in flats])
            q, scale, mins = ops.field_encode(stacked, block=block,
                                              bits=self.bits)
            q, scale, mins = (np.asarray(q, self._qdtype),
                              np.asarray(scale, np.float32),
                              np.asarray(mins, np.float32))
            for k, i in enumerate(idxs):
                out[i] = self._container(rows, block, q[k], scale[k],
                                         mins[k], flats[k][n:])
        return out

    def _parse(self, data: bytes):
        """Split a quantised container into its typed views (zero-copy)."""
        rows, block = struct.unpack_from("<II", data, 1)
        nb = rows // block
        off = 9
        q = np.frombuffer(data, self._qdtype, rows * _LANES, off
                          ).reshape(rows, _LANES)
        off += rows * _LANES * np.dtype(self._qdtype).itemsize
        scale = np.frombuffer(data, np.float32, nb, off)
        off += 4 * nb
        mins = np.frombuffer(data, np.float32, nb, off)
        off += 4 * nb
        tail = np.frombuffer(data, np.float32, offset=off)
        return rows, block, q, scale, mins, tail

    def decode(self, data: bytes, shape: Tuple[int, ...],
               dtype: np.dtype) -> np.ndarray:
        if data[0] == _RAW:
            return np.frombuffer(data, dtype=dtype, offset=1
                                 ).reshape(shape).copy()
        from repro.kernels import ops
        _rows, block, q, scale, mins, tail = self._parse(data)
        head = np.asarray(ops.field_decode(q, scale, mins, block=block,
                                           bits=self.bits))
        return np.concatenate([head.reshape(-1), tail]).astype(
            dtype, copy=False).reshape(shape)

    def decode_batch(self, datas: Sequence[bytes],
                     shapes: Sequence[Tuple[int, ...]],
                     dtype: np.dtype) -> List[np.ndarray]:
        """Batched inverse: equal-geometry quantised payloads (all interior
        chunks of one array) decode through one kernel launch."""
        out: List[np.ndarray] = [None] * len(datas)  # type: ignore[list-item]
        groups: Dict[Tuple, List[int]] = {}
        for i, (d, s) in enumerate(zip(datas, shapes)):
            if d[0] == _RAW:
                out[i] = np.frombuffer(d, dtype=dtype, offset=1
                                       ).reshape(s).copy()
            else:
                rows, block = struct.unpack_from("<II", d, 1)
                groups.setdefault((tuple(s), rows, block), []).append(i)
        if groups:
            from repro.kernels import ops
        for (shape, rows, block), idxs in groups.items():
            parsed = [self._parse(datas[i]) for i in idxs]
            heads = np.asarray(ops.field_decode(
                np.stack([p[2] for p in parsed]),
                np.stack([p[3] for p in parsed]),
                np.stack([p[4] for p in parsed]),
                block=block, bits=self.bits))
            for k, i in enumerate(idxs):
                out[i] = np.concatenate(
                    [heads[k].reshape(-1), parsed[k][5]]).astype(
                        dtype, copy=False).reshape(shape)
        return out


CODECS: Dict[str, Codec] = {
    c.name: c for c in (RawCodec(), FieldQuantCodec(8), FieldQuantCodec(16))
}


def get_codec(name: str) -> Codec:
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(f"unknown tensorstore codec {name!r}; "
                         f"known: {sorted(CODECS)}") from None

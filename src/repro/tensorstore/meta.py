"""Array metadata: the small self-describing object archived next to the
chunks (the ``.zarray`` analogue).  One metadata object per array, stored
under the reserved chunk key ``meta``."""
from __future__ import annotations

import dataclasses
import json
from typing import Tuple

import numpy as np

from .grid import ChunkGrid

#: reserved element-key value for the metadata object
META_CHUNK_KEY = "meta"

FORMAT_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ArrayMeta:
    shape: Tuple[int, ...]
    dtype: str                  # numpy dtype string, e.g. "float32"
    chunks: Tuple[int, ...]
    codec: str = "raw"
    version: int = FORMAT_VERSION

    def __post_init__(self) -> None:
        np.dtype(self.dtype)    # raises early on junk
        ChunkGrid(self.shape, self.chunks)   # validates rank/positivity

    @property
    def npdtype(self) -> np.dtype:
        return np.dtype(self.dtype)

    @property
    def nbytes(self) -> int:
        n = self.npdtype.itemsize
        for s in self.shape:
            n *= s
        return n

    def grid(self) -> ChunkGrid:
        return ChunkGrid(self.shape, self.chunks)

    def to_bytes(self) -> bytes:
        return json.dumps({
            "shape": list(self.shape), "dtype": self.dtype,
            "chunks": list(self.chunks), "codec": self.codec,
            "version": self.version,
        }, separators=(",", ":")).encode()

    @staticmethod
    def from_bytes(raw: bytes) -> "ArrayMeta":
        d = json.loads(raw.decode())
        if d.get("version", 1) > FORMAT_VERSION:
            raise ValueError(f"tensorstore format {d['version']} is newer "
                             f"than supported {FORMAT_VERSION}")
        return ArrayMeta(shape=tuple(d["shape"]), dtype=d["dtype"],
                         chunks=tuple(d["chunks"]), codec=d.get("codec", "raw"),
                         version=d.get("version", 1))


def auto_chunks(shape: Tuple[int, ...], dtype,
                target_bytes: int = 1 << 20) -> Tuple[int, ...]:
    """Pick a chunk shape with roughly ``target_bytes`` per chunk by halving
    the largest dimension until the tile fits (object-granular I/O wants
    chunks big enough to amortise per-op cost — thesis Fig. 4.26)."""
    chunks = [max(1, int(s)) for s in shape]
    if not chunks:
        return ()
    itemsize = np.dtype(dtype).itemsize

    def tile_bytes() -> int:
        n = itemsize
        for c in chunks:
            n *= c
        return n

    while tile_bytes() > target_bytes:
        axis = max(range(len(chunks)), key=lambda a: chunks[a])
        if chunks[axis] == 1:
            break
        chunks[axis] = -(-chunks[axis] // 2)
    return tuple(chunks)

"""Array metadata: the small self-describing object archived next to the
chunks (the ``.zarray`` analogue).  One metadata object per array, stored
under the reserved chunk key ``meta``.

Layout *generations* (format v2) are how the FDB's immutability rules and
re-chunking coexist: the FDB API has no per-object delete (wipe is
dataset-granular), so a layout change cannot remove the old grid's chunk
objects.  Instead every layout carries a ``generation`` counter and chunk
element keys are generation-prefixed (:func:`~.store.chunk_key`) — a
reshard (or a ``create(on_mismatch="retain")``) writes the new grid's
chunks under fresh ``g<N+1>.c...`` keys that can never collide with live
data, then transactionally replaces this metadata object (FDB rule 5) to
flip readers onto the new grid.  Old-generation chunks are *versioned
retained*: unreachable through the new metadata, never readable as wrong
data, reclaimed only by wiping the array's dataset.  Generation-0 metadata
serialises as format v1 (unprefixed ``c...`` keys), so arrays that never
resharded stay readable by older code.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

import numpy as np

from .grid import ChunkGrid

#: reserved element-key value for the metadata object
META_CHUNK_KEY = "meta"

#: reserved *array name* for a dataset tree's consolidated-metadata
#: catalogue (the ``.zmetadata`` analogue) — its own (store, array)
#: dataset, so wiping a real field never takes the tree index with it
TREE_ARRAY_KEY = ".tree"

#: v1: unprefixed chunk keys; v2 adds generation-prefixed chunk keys
FORMAT_VERSION = 2


@dataclasses.dataclass(frozen=True)
class ArrayMeta:
    shape: Tuple[int, ...]
    dtype: str                  # numpy dtype string, e.g. "float32"
    chunks: Tuple[int, ...]
    codec: str = "raw"
    #: layout generation: bumped on every re-layout of the same array slot,
    #: prefixing the chunk element keys so grids never collide (see module
    #: docstring); 0 = the original layout (format-v1-compatible)
    generation: int = 0

    def __post_init__(self) -> None:
        np.dtype(self.dtype)    # raises early on junk
        ChunkGrid(self.shape, self.chunks)   # validates rank/positivity
        if self.generation < 0:
            raise ValueError(f"negative generation {self.generation}")

    @property
    def npdtype(self) -> np.dtype:
        return np.dtype(self.dtype)

    @property
    def nbytes(self) -> int:
        n = self.npdtype.itemsize
        for s in self.shape:
            n *= s
        return n

    @property
    def version(self) -> int:
        """Serialisation format: generation-0 metadata stays v1 so readers
        predating generations keep working; any resharded layout needs v2
        (a v1 reader would look for unprefixed chunk keys and fill zeros)."""
        return 2 if self.generation else 1

    def grid(self) -> ChunkGrid:
        return ChunkGrid(self.shape, self.chunks)

    def layout_matches(self, other: "ArrayMeta") -> bool:
        """True when ``other`` describes the same physical layout — shape,
        dtype, chunk grid and codec; the *generation* is deliberately not
        part of the layout (it names an instance of one)."""
        return (self.shape == other.shape and self.dtype == other.dtype
                and self.chunks == other.chunks and self.codec == other.codec)

    def to_bytes(self) -> bytes:
        d = {"shape": list(self.shape), "dtype": self.dtype,
             "chunks": list(self.chunks), "codec": self.codec,
             "version": self.version}
        if self.generation:
            d["generation"] = self.generation
        return json.dumps(d, separators=(",", ":")).encode()

    @staticmethod
    def from_bytes(raw: bytes) -> "ArrayMeta":
        d = json.loads(raw.decode())
        if d.get("version", 1) > FORMAT_VERSION:
            raise ValueError(f"tensorstore format {d['version']} is newer "
                             f"than supported {FORMAT_VERSION}")
        return ArrayMeta(shape=tuple(d["shape"]), dtype=d["dtype"],
                         chunks=tuple(d["chunks"]), codec=d.get("codec", "raw"),
                         generation=d.get("generation", 0))


class TreeCatalogue:
    """Zarr-style *consolidated metadata* for one dataset tree.

    One catalogue object holds every member array's :class:`ArrayMeta`,
    so opening a whole tree (a ``ChunkedFieldStore`` with N fields) costs
    **one** fetch instead of one metadata round-trip per array.  It lives
    under the reserved member name :data:`TREE_ARRAY_KEY` — its own
    ``(store, array)`` dataset, so wiping a field's dataset never
    destroys the index.

    Writers keep it fresh: :meth:`~.store.TensorStore.create` and the
    reshard metadata flip call :meth:`record` through the same client (or
    session) that archived the per-array metadata, so the consolidated
    copy rides the identical commit barrier.  Readers treat it as a hint
    with a per-array fallback — a tree written by older code (or a
    concurrently re-created array) just misses and falls back to the
    authoritative per-array ``meta`` object.
    """

    VERSION = 1

    def __init__(self, fdb, base: Dict[str, str], member_dim: str = "array",
                 chunk_dim: Optional[str] = None) -> None:
        self.fdb = fdb
        #: every schema dim except the member (array) and chunk dims
        self.base = {str(k): str(v) for k, v in base.items()
                     if k != member_dim}
        self.member_dim = member_dim
        self.chunk_dim = chunk_dim or fdb.schema.element_dims[-1]
        self._arrays: Dict[str, ArrayMeta] = {}
        self.loaded = False

    def _ident(self) -> Dict[str, str]:
        return {**self.base, self.member_dim: TREE_ARRAY_KEY,
                self.chunk_dim: META_CHUNK_KEY}

    def _to_bytes(self) -> bytes:
        arrays = {name: json.loads(meta.to_bytes().decode())
                  for name, meta in sorted(self._arrays.items())}
        return json.dumps({"version": self.VERSION, "arrays": arrays},
                          separators=(",", ":")).encode()

    # -- read side -----------------------------------------------------------
    def load(self) -> bool:
        """Fetch the consolidated object (one retrieve).  Returns False —
        leaving the mirror empty — when it is absent or unparseable, which
        callers treat as "fall back to per-array fetches"."""
        self._arrays.clear()
        self.loaded = True
        try:
            handle = self.fdb.retrieve(self._ident())
            if handle.length() == 0:
                return False
            raw = handle.read()
        except (KeyError, FileNotFoundError):
            return False
        try:
            d = json.loads(raw.decode())
            if d.get("version", 0) > self.VERSION:
                return False
            self._arrays = {
                name: ArrayMeta.from_bytes(
                    json.dumps(md, separators=(",", ":")).encode())
                for name, md in d["arrays"].items()}
        except (ValueError, KeyError, TypeError):
            self._arrays.clear()
            return False
        return True

    def get(self, name: str) -> Optional[ArrayMeta]:
        """The mirrored metadata for member ``name`` (no I/O), or None."""
        return self._arrays.get(name)

    def names(self) -> List[str]:
        return sorted(self._arrays)

    # -- write side ----------------------------------------------------------
    def record(self, name: str, meta: ArrayMeta, client=None) -> None:
        """A member's metadata was (re)archived: mirror it and re-archive
        the consolidated object through ``client`` (a session or the fdb),
        so it rides the caller's commit barrier.  An unloaded mirror loads
        first — otherwise a fresh client's first create would clobber the
        members earlier clients recorded."""
        if not self.loaded:
            self.load()
        self._arrays[name] = meta
        (client or self.fdb).archive(self._ident(), self._to_bytes())

    def forget(self, name: str, client=None) -> None:
        """A member was wiped: drop it from the consolidated object."""
        if self._arrays.pop(name, None) is not None:
            (client or self.fdb).archive(self._ident(), self._to_bytes())


def auto_chunks(shape: Tuple[int, ...], dtype,
                target_bytes: int = 1 << 20) -> Tuple[int, ...]:
    """Pick a chunk shape with roughly ``target_bytes`` per chunk by halving
    the largest dimension until the tile fits (object-granular I/O wants
    chunks big enough to amortise per-op cost — thesis Fig. 4.26)."""
    chunks = [max(1, int(s)) for s in shape]
    if not chunks:
        return ()
    itemsize = np.dtype(dtype).itemsize

    def tile_bytes() -> int:
        n = itemsize
        for c in chunks:
            n *= c
        return n

    while tile_bytes() > target_bytes:
        axis = max(range(len(chunks)), key=lambda a: chunks[a])
        if chunks[axis] == 1:
            break
        chunks[axis] = -(-chunks[axis] // 2)
    return tuple(chunks)

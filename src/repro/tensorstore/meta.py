"""Array metadata: the small self-describing object archived next to the
chunks (the ``.zarray`` analogue).  One metadata object per array, stored
under the reserved chunk key ``meta``.

Layout *generations* (format v2) are how the FDB's immutability rules and
re-chunking coexist: the FDB API has no per-object delete (wipe is
dataset-granular), so a layout change cannot remove the old grid's chunk
objects.  Instead every layout carries a ``generation`` counter and chunk
element keys are generation-prefixed (:func:`~.store.chunk_key`) — a
reshard (or a ``create(on_mismatch="retain")``) writes the new grid's
chunks under fresh ``g<N+1>.c...`` keys that can never collide with live
data, then transactionally replaces this metadata object (FDB rule 5) to
flip readers onto the new grid.  Old-generation chunks are *versioned
retained*: unreachable through the new metadata, never readable as wrong
data, reclaimed only by wiping the array's dataset.  Generation-0 metadata
serialises as format v1 (unprefixed ``c...`` keys), so arrays that never
resharded stay readable by older code.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Tuple

import numpy as np

from .grid import ChunkGrid

#: reserved element-key value for the metadata object
META_CHUNK_KEY = "meta"

#: v1: unprefixed chunk keys; v2 adds generation-prefixed chunk keys
FORMAT_VERSION = 2


@dataclasses.dataclass(frozen=True)
class ArrayMeta:
    shape: Tuple[int, ...]
    dtype: str                  # numpy dtype string, e.g. "float32"
    chunks: Tuple[int, ...]
    codec: str = "raw"
    #: layout generation: bumped on every re-layout of the same array slot,
    #: prefixing the chunk element keys so grids never collide (see module
    #: docstring); 0 = the original layout (format-v1-compatible)
    generation: int = 0

    def __post_init__(self) -> None:
        np.dtype(self.dtype)    # raises early on junk
        ChunkGrid(self.shape, self.chunks)   # validates rank/positivity
        if self.generation < 0:
            raise ValueError(f"negative generation {self.generation}")

    @property
    def npdtype(self) -> np.dtype:
        return np.dtype(self.dtype)

    @property
    def nbytes(self) -> int:
        n = self.npdtype.itemsize
        for s in self.shape:
            n *= s
        return n

    @property
    def version(self) -> int:
        """Serialisation format: generation-0 metadata stays v1 so readers
        predating generations keep working; any resharded layout needs v2
        (a v1 reader would look for unprefixed chunk keys and fill zeros)."""
        return 2 if self.generation else 1

    def grid(self) -> ChunkGrid:
        return ChunkGrid(self.shape, self.chunks)

    def layout_matches(self, other: "ArrayMeta") -> bool:
        """True when ``other`` describes the same physical layout — shape,
        dtype, chunk grid and codec; the *generation* is deliberately not
        part of the layout (it names an instance of one)."""
        return (self.shape == other.shape and self.dtype == other.dtype
                and self.chunks == other.chunks and self.codec == other.codec)

    def to_bytes(self) -> bytes:
        d = {"shape": list(self.shape), "dtype": self.dtype,
             "chunks": list(self.chunks), "codec": self.codec,
             "version": self.version}
        if self.generation:
            d["generation"] = self.generation
        return json.dumps(d, separators=(",", ":")).encode()

    @staticmethod
    def from_bytes(raw: bytes) -> "ArrayMeta":
        d = json.loads(raw.decode())
        if d.get("version", 1) > FORMAT_VERSION:
            raise ValueError(f"tensorstore format {d['version']} is newer "
                             f"than supported {FORMAT_VERSION}")
        return ArrayMeta(shape=tuple(d["shape"]), dtype=d["dtype"],
                         chunks=tuple(d["chunks"]), codec=d.get("codec", "raw"),
                         generation=d.get("generation", 0))


def auto_chunks(shape: Tuple[int, ...], dtype,
                target_bytes: int = 1 << 20) -> Tuple[int, ...]:
    """Pick a chunk shape with roughly ``target_bytes`` per chunk by halving
    the largest dimension until the tile fits (object-granular I/O wants
    chunks big enough to amortise per-op cost — thesis Fig. 4.26)."""
    chunks = [max(1, int(s)) for s in shape]
    if not chunks:
        return ()
    itemsize = np.dtype(dtype).itemsize

    def tile_bytes() -> int:
        n = itemsize
        for c in chunks:
            n *= c
        return n

    while tile_bytes() > target_bytes:
        axis = max(range(len(chunks)), key=lambda a: chunks[a])
        if chunks[axis] == 1:
            break
        chunks[axis] = -(-chunks[axis] // 2)
    return tuple(chunks)

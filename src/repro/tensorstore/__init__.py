"""repro.tensorstore — Zarr-style chunked N-D arrays over the FDB.

>>> from repro.core import FDB, FDBConfig
>>> from repro.tensorstore import TensorStore
>>> fdb = FDB(FDBConfig(backend="daos", schema="tensor"))
>>> ts = TensorStore(fdb, {"store": "nwp", "array": "t2m", "writer": "p0"})
>>> ts.save(field)                       # chunked, parallel archive
>>> arr = ts.open()
>>> window = arr[120:240, 300:420]       # reads only intersecting chunks
>>> coarse = arr[::4, ::4]               # strided: touches 1 chunk in 16
>>> arr[120:240, 300:420] = window + dx  # chunk-aligned in-place update
>>> arr.read_plan((slice(None), slice(None))).read_ops()  # coalesced I/O ops
>>> arr.write_plan((slice(None), slice(None)), field).write_ops()  # the twin
>>> arr.reshard((30, 420))               # stream onto a consumer chunk grid
"""
from repro.core import LeaseConflictError, StaleLeaseError, WriterSession
from .cache import ChunkCache
from .codec import CODECS, Codec, FieldQuantCodec, RawCodec, get_codec
from .executor import ChunkExecutor, default_executor, sized_executor
from .grid import ChunkGrid, merge_id_ranges
from .meta import (META_CHUNK_KEY, TREE_ARRAY_KEY, ArrayMeta, TreeCatalogue,
                   auto_chunks)
from .reshard import ReshardPlan, chunk_rectangles
from .store import (ChunkedArray, GarbageReport, LayoutMismatchError,
                    ReadPlan, TensorStore, WritePlan, chunk_key)

__all__ = [
    "TensorStore", "ChunkedArray", "ReadPlan", "WritePlan", "ReshardPlan",
    "chunk_key", "chunk_rectangles",
    "LayoutMismatchError", "GarbageReport",
    "WriterSession", "LeaseConflictError", "StaleLeaseError",
    "ArrayMeta", "auto_chunks", "META_CHUNK_KEY",
    "ChunkCache", "TreeCatalogue", "TREE_ARRAY_KEY",
    "ChunkGrid", "merge_id_ranges",
    "Codec", "RawCodec", "FieldQuantCodec", "CODECS", "get_codec",
    "ChunkExecutor", "default_executor", "sized_executor",
]

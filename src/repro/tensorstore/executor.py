"""Async chunk-I/O executor: thread pool + futures with a bounded in-flight
window.

The paper's core result is that object stores win when clients keep many
independent object-granular I/Os in flight; this executor is the client-side
half of that — ``submit()`` admits at most ``max_in_flight`` outstanding
tasks (queued + running) and blocks the producer beyond that, bounding the
memory held by encoded chunks while keeping the pipe full.

Callers' :mod:`contextvars` context (the engine meter's ``client_context``
and the obs layer's active span) is propagated into worker threads so op
attribution — and span parentage — survives the hop.

This module's only ``repro`` import is the dependency-free
:mod:`repro.obs` package: :mod:`repro.core.fdb` reaches for the executor
lazily without creating an import cycle, and ``repro.obs`` imports nothing
back.

When a caller submits from inside a traced span, the time between
``submit()`` and the task starting on a worker is recorded as an
``executor.queue`` span (parented under the caller's span) plus an
``executor.queue_us`` histogram and ``executor.in_flight`` gauge — the
``t_queue`` phase of the bench columns.  Untraced submissions skip all of
it via one context-var read.
"""
from __future__ import annotations

import contextvars
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Iterable, List, Optional

from repro.obs import trace as _obs

DEFAULT_WORKERS = 8


def annotate_error(e: BaseException, note: str) -> None:
    """Attach ``note`` to an in-flight exception without re-raising a new
    one: ``add_note`` on 3.11+, an extra ``args`` element (visible in the
    rendered message) on 3.10."""
    add = getattr(e, "add_note", None)
    if add is not None:
        add(note)
    else:
        e.args = e.args + (note,)


class ChunkExecutor:
    def __init__(self, max_workers: int = DEFAULT_WORKERS,
                 max_in_flight: Optional[int] = None):
        if max_workers < 1:
            raise ValueError("max_workers must be >= 1")
        self.max_workers = max_workers
        self.max_in_flight = max_in_flight or 4 * max_workers
        if self.max_in_flight < 1:
            raise ValueError("max_in_flight must be >= 1")
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="fdbx-io")
        self._window = threading.Semaphore(self.max_in_flight)
        self._lock = threading.Lock()
        self._in_flight = 0
        self.peak_in_flight = 0

    # -- core API -------------------------------------------------------------
    def submit(self, fn: Callable[..., Any], *args: Any, **kw: Any) -> Future:
        """Schedule ``fn(*args, **kw)``; blocks while the window is full."""
        self._window.acquire()
        with self._lock:
            self._in_flight += 1
            self.peak_in_flight = max(self.peak_in_flight, self._in_flight)
            depth = self._in_flight
        ctx = contextvars.copy_context()
        parent = _obs.current_span()
        if parent is not None and parent.tracer.enabled:
            tracer = parent.tracer
            tracer.metrics.gauge("executor.in_flight").set(depth)
            t_submit = time.perf_counter_ns()

            def task(_fn=fn, _args=args, _kw=kw):
                now = time.perf_counter_ns()
                tracer.record_complete("executor.queue", t_submit, now,
                                       parent=parent)
                tracer.metrics.histogram("executor.queue_us").observe(
                    (now - t_submit) / 1_000.0)
                return _fn(*_args, **_kw)
        else:
            task = None
        try:
            if task is not None:
                fut = self._pool.submit(ctx.run, task)
            else:
                fut = self._pool.submit(ctx.run, fn, *args, **kw)
        except BaseException:
            self._leave()
            raise
        fut.add_done_callback(lambda _f: self._leave())
        return fut

    def _leave(self) -> None:
        with self._lock:
            self._in_flight -= 1
        self._window.release()

    @property
    def in_flight(self) -> int:
        with self._lock:
            return self._in_flight

    def map_ordered(self, fn: Callable[[Any], Any],
                    items: Iterable[Any],
                    describe: Optional[Callable[[Any], str]] = None
                    ) -> List[Any]:
        """Run ``fn`` over ``items`` concurrently; results in input order.

        Items may be wildly mixed-size units of work — the tensorstore write
        path mixes direct chunk encodes with read-modify-write fetches, the
        read path mixes single-chunk fetches with one-I/O multi-chunk group
        reads — the bounded window simply admits whatever comes next.

        The first raised exception propagates (after all futures settle, so
        no task outlives the call with shared state in hand) — annotated
        with which item failed (its input position, ``describe(item)`` when
        a describer is given, and how many sibling tasks also failed), so a
        retried-then-exhausted chunk op surfaces with its context instead
        of a bare backend error.
        """
        items = list(items)
        futures = [self.submit(fn, item) for item in items]
        results: List[Any] = []
        first_error, first_pos, n_failed = None, -1, 0
        for pos, fut in enumerate(futures):
            try:
                results.append(fut.result())
            except BaseException as e:  # noqa: BLE001
                n_failed += 1
                if first_error is None:
                    first_error, first_pos = e, pos
                results.append(None)
        if first_error is not None:
            label = ""
            if describe is not None:
                try:
                    label = f" ({describe(items[first_pos])})"
                except Exception:   # a broken describer must not mask
                    label = ""      # the real failure
            annotate_error(
                first_error,
                f"first failure of {n_failed}/{len(futures)} executor "
                f"task(s): item {first_pos}{label}")
            raise first_error
        return results

    def shutdown(self, wait: bool = True) -> None:
        self._shut = True
        self._pool.shutdown(wait=wait)

    @property
    def is_shutdown(self) -> bool:
        """True once :meth:`shutdown` ran — lets owners (and tests) observe
        an executor's lifecycle; submitting to a shut-down pool raises."""
        return getattr(self, "_shut", False)

    def __enter__(self) -> "ChunkExecutor":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()


#: process-global shared executors, one per requested depth (threads are
#: created lazily by the pool, so idle entries cost almost nothing)
_SHARED: dict = {}
_SHARED_LOCK = threading.Lock()


def sized_executor(max_workers: int) -> ChunkExecutor:
    """Shared executor with exactly ``max_workers`` of overlap depth."""
    with _SHARED_LOCK:
        ex = _SHARED.get(max_workers)
        if ex is None:
            ex = _SHARED[max_workers] = ChunkExecutor(max_workers=max_workers)
        return ex


def default_executor() -> ChunkExecutor:
    return sized_executor(DEFAULT_WORKERS)

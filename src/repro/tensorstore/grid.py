"""Chunk-grid geometry for chunked N-D arrays (the Zarr layer's index math).

An array of ``shape`` is split on a regular grid of ``chunks``-shaped tiles;
edge tiles are clipped.  All selection math lives here so the store itself
only deals in whole chunks: ``intersecting()`` maps an N-D selection onto the
minimal set of (chunk index, within-chunk slice, output slice) triples — the
property that makes partial reads issue I/O for only the touched chunks.
``write_plan()`` is the write-side counterpart: it additionally classifies
each touched chunk as *fully covered* (encode the new tile directly) or
*partially covered* (read-modify-write), the split that makes chunk-aligned
in-place assignment (``arr[sel] = values``) re-archive only what it must.
The store's :class:`~.store.WritePlan` consumes these triples, batching the
encodes (equal-shape chunks share one kernel launch) and coalescing chunks
bound for one storage unit into single store-level writes.
"""
from __future__ import annotations

import itertools
from typing import Iterator, List, Tuple

Index = Tuple[int, ...]
Slices = Tuple[slice, ...]


class ChunkGrid:
    def __init__(self, shape: Tuple[int, ...], chunks: Tuple[int, ...]):
        shape = tuple(int(s) for s in shape)
        chunks = tuple(int(c) for c in chunks)
        if len(shape) != len(chunks):
            raise ValueError(f"rank mismatch: shape {shape} vs chunks {chunks}")
        if any(s < 0 for s in shape):
            raise ValueError(f"negative dim in shape {shape}")
        if any(c <= 0 for c in chunks):
            raise ValueError(f"non-positive chunk dim in {chunks}")
        self.shape = shape
        # clip oversize chunk dims so n_chunks math stays trivial
        self.chunks = tuple(min(c, s) if s > 0 else 1
                            for c, s in zip(chunks, shape))

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def n_chunks(self) -> Tuple[int, ...]:
        return tuple(-(-s // c) for s, c in zip(self.shape, self.chunks))

    @property
    def chunk_count(self) -> int:
        total = 1
        for n in self.n_chunks:
            total *= n
        return total

    def all_indices(self) -> Iterator[Index]:
        return itertools.product(*(range(n) for n in self.n_chunks))

    def chunk_slices(self, idx: Index) -> Slices:
        """Array region covered by chunk ``idx`` (edge chunks clipped)."""
        self._check_index(idx)
        return tuple(slice(i * c, min((i + 1) * c, s))
                     for i, c, s in zip(idx, self.chunks, self.shape))

    def chunk_shape(self, idx: Index) -> Tuple[int, ...]:
        return tuple(sl.stop - sl.start for sl in self.chunk_slices(idx))

    def _check_index(self, idx: Index) -> None:
        if len(idx) != self.ndim:
            raise IndexError(f"chunk index {idx} has wrong rank for {self.shape}")
        for i, n in zip(idx, self.n_chunks):
            if not 0 <= i < n:
                raise IndexError(f"chunk index {idx} outside grid {self.n_chunks}")

    # -- selection handling ---------------------------------------------------
    def normalize_key(self, key) -> Tuple[Slices, Tuple[int, ...]]:
        """Normalise a ``__getitem__`` key into per-dim unit-step slices.

        Returns ``(slices, squeeze_axes)``: integer indices become length-1
        slices and their axes are recorded for squeezing.  Steps other than 1
        are rejected (resharding follow-on, see ROADMAP).
        """
        if not isinstance(key, tuple):
            key = (key,)
        if len(key) > self.ndim:
            raise IndexError(f"too many indices for {self.ndim}-d array")
        key = key + (slice(None),) * (self.ndim - len(key))
        sel: List[slice] = []
        squeeze: List[int] = []
        for axis, (k, size) in enumerate(zip(key, self.shape)):
            if isinstance(k, slice):
                start, stop, step = k.indices(size)
                if step != 1:
                    raise IndexError("tensorstore selections require step 1")
                sel.append(slice(start, max(start, stop)))
            else:
                i = int(k)
                if i < 0:
                    i += size
                if not 0 <= i < size:
                    raise IndexError(f"index {k} out of bounds for axis "
                                     f"{axis} with size {size}")
                sel.append(slice(i, i + 1))
                squeeze.append(axis)
        return tuple(sel), tuple(squeeze)

    def selection_shape(self, sel: Slices) -> Tuple[int, ...]:
        return tuple(s.stop - s.start for s in sel)

    def intersecting(self, sel: Slices
                     ) -> Iterator[Tuple[Index, Slices, Slices]]:
        """Yield ``(chunk_idx, within_chunk_slices, output_slices)`` for every
        chunk intersecting ``sel`` — and only those."""
        if any(s.stop <= s.start for s in sel):
            return
        per_dim = []
        for s, c in zip(sel, self.chunks):
            first, last = s.start // c, (s.stop - 1) // c
            per_dim.append(range(first, last + 1))
        for idx in itertools.product(*per_dim):
            chunk_sel, out_sel = [], []
            for i, s, c, size in zip(idx, sel, self.chunks, self.shape):
                c_lo, c_hi = i * c, min((i + 1) * c, size)
                lo, hi = max(s.start, c_lo), min(s.stop, c_hi)
                chunk_sel.append(slice(lo - c_lo, hi - c_lo))
                out_sel.append(slice(lo - s.start, hi - s.start))
            yield idx, tuple(chunk_sel), tuple(out_sel)

    def write_plan(self, sel: Slices
                   ) -> Iterator[Tuple[Index, Slices, Slices, bool]]:
        """Yield ``(chunk_idx, within_chunk_slices, value_slices, full)`` for
        every chunk ``sel`` touches.

        ``full=True`` means the selection covers the whole (possibly clipped
        edge) chunk, so a writer can encode the new tile outright;
        ``full=False`` chunks need read-modify-write to preserve the bytes
        outside the selection.
        """
        for idx, chunk_sel, val_sel in self.intersecting(sel):
            full = all(s.start == 0 and s.stop == n
                       for s, n in zip(chunk_sel, self.chunk_shape(idx)))
            yield idx, chunk_sel, val_sel, full

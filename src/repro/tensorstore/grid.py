"""Chunk-grid geometry for chunked N-D arrays (the Zarr layer's index math).

An array of ``shape`` is split on a regular grid of ``chunks``-shaped tiles;
edge tiles are clipped.  All selection math lives here so the store itself
only deals in whole chunks: ``intersecting()`` maps an N-D selection onto the
minimal set of (chunk index, within-chunk slice, output slice) triples — the
property that makes partial reads issue I/O for only the touched chunks.
``write_plan()`` is the write-side counterpart: it additionally classifies
each touched chunk as *fully covered* (encode the new tile directly) or
*partially covered* (read-modify-write), the split that makes chunk-aligned
in-place assignment (``arr[sel] = values``) re-archive only what it must.
The store's :class:`~.store.WritePlan` consumes these triples, batching the
encodes (equal-shape chunks share one kernel launch) and coalescing chunks
bound for one storage unit into single store-level writes.

Selections may be *strided*: any slice with a positive step is accepted on
both the read and the write path (``arr[::4]``, ``arr[10:200:3] = v``) — the
producer-grid vs consumer-grid mismatch the paper's workflows re-lay-out
data around (a consumer subsampling every k-th level/row of a producer's
field).  A strided selection touches only the chunks holding at least one
selected point — chunks the stride steps over entirely are skipped — and the
within-chunk slices keep the stride, so strided scatters/gathers stay single
numpy slice assignments.  Output (and value) slices are always unit-step:
selections address a *compact* result array.

Negative steps are served by ``normalize_read_key``: it rewrites a reversed
slice into its positive-step mirror plus a client-side flip axis (chunk
visit order stays monotone), which is how ``arr[::-1]`` works without the
I/O plan ever seeing a descending order.  Reads flip the assembled output
once at the end; writes (``ChunkedArray.write_plan``) flip the broadcast
*values* once before planning, so reversed assignment shares the same
positive-step machinery.  Only the reshard path keeps rejecting them
(``NotImplementedError`` via ``normalize_key``): a reshard re-layouts
storage, where a reversed source selection has no meaning beyond reading
reversed first.

``linear_id`` maps a chunk index to its row-major scalar id — the chunk-id
space the catalogue-level lease table (:mod:`repro.core.lease`) covers with
``[lo, hi)`` ranges; :func:`merge_id_ranges` compacts a touched-chunk set
into the minimal disjoint ranges a ``WritePlan`` leases.
"""
from __future__ import annotations

import itertools
from typing import Iterable, Iterator, List, Tuple

Index = Tuple[int, ...]
Slices = Tuple[slice, ...]


def merge_id_ranges(ids: Iterable[int]) -> List[Tuple[int, int]]:
    """Compact a set of chunk ids into minimal disjoint half-open ranges:
    ``[0, 1, 2, 7, 8] -> [(0, 3), (7, 9)]`` — the ranges a write plan
    leases (duplicates tolerated)."""
    out: List[List[int]] = []
    for i in sorted(ids):
        if out and i < out[-1][1]:
            continue
        if out and i == out[-1][1]:
            out[-1][1] = i + 1
        else:
            out.append([i, i + 1])
    return [(lo, hi) for lo, hi in out]


class ChunkGrid:
    def __init__(self, shape: Tuple[int, ...], chunks: Tuple[int, ...]):
        shape = tuple(int(s) for s in shape)
        chunks = tuple(int(c) for c in chunks)
        if len(shape) != len(chunks):
            raise ValueError(f"rank mismatch: shape {shape} vs chunks {chunks}")
        if any(s < 0 for s in shape):
            raise ValueError(f"negative dim in shape {shape}")
        if any(c <= 0 for c in chunks):
            raise ValueError(f"non-positive chunk dim in {chunks}")
        self.shape = shape
        # clip oversize chunk dims so n_chunks math stays trivial
        self.chunks = tuple(min(c, s) if s > 0 else 1
                            for c, s in zip(chunks, shape))

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def n_chunks(self) -> Tuple[int, ...]:
        return tuple(-(-s // c) for s, c in zip(self.shape, self.chunks))

    @property
    def chunk_count(self) -> int:
        total = 1
        for n in self.n_chunks:
            total *= n
        return total

    def all_indices(self) -> Iterator[Index]:
        return itertools.product(*(range(n) for n in self.n_chunks))

    def chunk_slices(self, idx: Index) -> Slices:
        """Array region covered by chunk ``idx`` (edge chunks clipped)."""
        self._check_index(idx)
        return tuple(slice(i * c, min((i + 1) * c, s))
                     for i, c, s in zip(idx, self.chunks, self.shape))

    def chunk_shape(self, idx: Index) -> Tuple[int, ...]:
        return tuple(sl.stop - sl.start for sl in self.chunk_slices(idx))

    def _check_index(self, idx: Index) -> None:
        if len(idx) != self.ndim:
            raise IndexError(f"chunk index {idx} has wrong rank for {self.shape}")
        for i, n in zip(idx, self.n_chunks):
            if not 0 <= i < n:
                raise IndexError(f"chunk index {idx} outside grid {self.n_chunks}")

    def linear_id(self, idx: Index) -> int:
        """Row-major scalar id of chunk ``idx`` — the chunk-id space lease
        ranges cover (``[lo, hi)`` over these ids; consecutive ids are
        row-major neighbours, so rectangular row bands lease as single
        ranges)."""
        self._check_index(idx)
        lid = 0
        for i, n in zip(idx, self.n_chunks):
            lid = lid * n + i
        return lid

    # -- selection handling ---------------------------------------------------
    def normalize_key(self, key) -> Tuple[Slices, Tuple[int, ...]]:
        """Normalise a ``__getitem__`` key into per-dim positive-step slices.

        Returns ``(slices, squeeze_axes)``: integer indices become length-1
        slices and their axes are recorded for squeezing.  Any positive step
        is accepted (strided selections); every returned slice has an
        explicit ``step >= 1`` and a ``stop`` normalised to *last selected
        index + 1* (``start`` when empty), so downstream chunk math can rely
        on ``stop - 1`` being a selected point.  Negative steps raise
        ``NotImplementedError``: they are served by
        :meth:`normalize_read_key` (positive-step plan + client-side flip),
        which the read and write paths use — the reshard path, which calls
        this method, does not support reversed selections.
        """
        if not isinstance(key, tuple):
            key = (key,)
        if len(key) > self.ndim:
            raise IndexError(f"too many indices for {self.ndim}-d array")
        key = key + (slice(None),) * (self.ndim - len(key))
        sel: List[slice] = []
        squeeze: List[int] = []
        for axis, (k, size) in enumerate(zip(key, self.shape)):
            if isinstance(k, slice):
                start, stop, step = k.indices(size)
                if step < 1:
                    raise NotImplementedError(
                        "tensorstore reshard selections require a "
                        f"positive step (got {step} on axis {axis}); "
                        "negative-step selections are supported on the read "
                        "and write paths, where they normalise to a "
                        "positive-step plan plus a client-side flip")
                count = len(range(start, stop, step))
                stop = start + (count - 1) * step + 1 if count else start
                sel.append(slice(start, stop, step))
            else:
                if (isinstance(k, (list, tuple, set, frozenset))
                        or getattr(k, "ndim", 0) != 0):
                    # integer-array / boolean fancy indexing — not a
                    # contiguous chunk selection, so the plan machinery
                    # (range coalescing, chunk-range leases) cannot
                    # express it; fail with the supported forms named
                    raise TypeError(
                        f"unsupported selection {k!r} on axis {axis}: "
                        "tensorstore selections are integers, slices "
                        "(strided, and negative-step on the read/write "
                        "paths), or tuples thereof — integer-array and "
                        "boolean (fancy) indexing are not supported")
                i = int(k)
                if i < 0:
                    i += size
                if not 0 <= i < size:
                    raise IndexError(f"index {k} out of bounds for axis "
                                     f"{axis} with size {size}")
                sel.append(slice(i, i + 1, 1))
                squeeze.append(axis)
        return tuple(sel), tuple(squeeze)

    def normalize_read_key(self, key
                           ) -> Tuple[Slices, Tuple[int, ...],
                                      Tuple[int, ...]]:
        """Read-path key normalisation: like :meth:`normalize_key` but
        negative-step slices are accepted, each rewritten to the
        positive-step slice selecting the *same points in ascending order*,
        with its axis recorded in ``flip_axes`` — the caller flips the
        assembled output once, client-side, so the I/O plan (chunk visit
        order, coalescing, scatter slices) never sees a descending
        selection.  Returns ``(slices, squeeze_axes, flip_axes)``."""
        if not isinstance(key, tuple):
            key = (key,)
        if len(key) > self.ndim:
            raise IndexError(f"too many indices for {self.ndim}-d array")
        key = key + (slice(None),) * (self.ndim - len(key))
        flips: List[int] = []
        rewritten: List[object] = []
        for axis, (k, size) in enumerate(zip(key, self.shape)):
            if isinstance(k, slice):
                start, stop, step = k.indices(size)
                if step < 0:
                    count = len(range(start, stop, step))
                    if count:
                        first = start + (count - 1) * step  # smallest point
                        rewritten.append(slice(first, start + 1, -step))
                        flips.append(axis)
                    else:
                        rewritten.append(slice(0, 0, 1))
                    continue
            rewritten.append(k)
        sel, squeeze = self.normalize_key(tuple(rewritten))
        return sel, squeeze, tuple(flips)

    def selection_shape(self, sel: Slices) -> Tuple[int, ...]:
        return tuple(len(range(s.start, s.stop, s.step or 1)) for s in sel)

    def intersecting(self, sel: Slices
                     ) -> Iterator[Tuple[Index, Slices, Slices]]:
        """Yield ``(chunk_idx, within_chunk_slices, output_slices)`` for every
        chunk holding at least one selected point — and only those.

        With a strided ``sel``, ``within_chunk_slices`` keep the stride
        (clamped to the chunk's first/last selected point) while
        ``output_slices`` are the compact unit-step positions of those points
        in the result — so a step larger than the chunk size simply skips
        the chunks it strides over.
        """
        if any(s.stop <= s.start for s in sel):
            return
        per_dim = []
        for s, c in zip(sel, self.chunks):
            first, last = s.start // c, (s.stop - 1) // c
            per_dim.append(range(first, last + 1))
        for idx in itertools.product(*per_dim):
            chunk_sel, out_sel = [], []
            for i, s, c, size in zip(idx, sel, self.chunks, self.shape):
                step = s.step or 1
                c_lo, c_hi = i * c, min((i + 1) * c, size)
                # k-th selected point is start + k*step; clamp to the chunk
                k0 = max(0, -(-(c_lo - s.start) // step))
                k1 = (min(s.stop, c_hi) - 1 - s.start) // step
                if k1 < k0:         # stride stepped over this chunk entirely
                    break
                a0, a1 = s.start + k0 * step, s.start + k1 * step
                chunk_sel.append(slice(a0 - c_lo, a1 - c_lo + 1, step))
                out_sel.append(slice(k0, k1 + 1, 1))
            else:
                yield idx, tuple(chunk_sel), tuple(out_sel)

    def write_plan(self, sel: Slices
                   ) -> Iterator[Tuple[Index, Slices, Slices, bool]]:
        """Yield ``(chunk_idx, within_chunk_slices, value_slices, full)`` for
        every chunk ``sel`` touches.

        ``full=True`` means the selection covers *every* element of the
        (possibly clipped edge) chunk, so a writer can encode the new tile
        outright; ``full=False`` chunks need read-modify-write to preserve
        the bytes outside the selection.  A strided selection can only fully
        cover a chunk dim of size 1 (a step > 1 always leaves gaps), so
        strided writes are RMW except on such degenerate dims.
        """
        for idx, chunk_sel, val_sel in self.intersecting(sel):
            full = all(
                s.start == 0 and s.stop == n
                and len(range(s.start, s.stop, s.step or 1)) == n
                for s, n in zip(chunk_sel, self.chunk_shape(idx)))
            yield idx, chunk_sel, val_sel, full

"""Plan-composed resharding: rewrite a chunked array onto a new chunk grid
as a streaming composition of the existing I/O plans.

The paper's central finding is that object stores let applications reshape
their I/O — many small fields or few large objects — without being punished
by POSIX locking, and ECMWF's workflows exploit that by re-laying-out data
between producer and consumer stages (a model writes level-major, a
post-processing consumer wants region-major).  :class:`ReshardPlan` is that
re-layout for ``repro.tensorstore``:

* the **destination** grid is walked in rectangular batches of at most one
  executor window of chunks (:attr:`window`), so peak staged bytes are
  bounded regardless of array size — the whole array is never materialised
  client-side;
* each batch's **source** chunks resolve through one
  :class:`~.store.ReadPlan` (coalesced posix ranges, batched decode) and
  archive through one :class:`~.store.WritePlan` (placement-grouped batched
  writes, batched encode) — reshard I/O inherits both plans' coalescing,
  so posix op counts stay far below one-per-chunk on both sides;
* the new grid's chunks live under a fresh layout **generation**
  (:mod:`.meta`): they can never collide with the source grid's keys, the
  final transactional metadata replace (FDB rule 5) flips readers over in
  one object, and the ``flush()`` commit barrier (rule 3) publishes chunks
  and metadata together.  Old-generation chunks are retained versioned —
  unreachable through the new metadata, reclaimed only by wiping the
  array's dataset (the FDB API has no per-object delete).

A reshard may also *subsample*: ``sel`` restricts (possibly strided —
``(slice(None), slice(0, None, 4))``) the source region, so a consumer grid
can take every k-th level/row of the producer's field while re-chunking; the
array's shape becomes the selection's shape.  ``codec`` re-encodes on the
way through (e.g. ``raw`` → ``field16`` to quantise an archive in place).
"""
from __future__ import annotations

import itertools
from typing import Iterator, List, Optional, Sequence, Tuple

from repro.core import deadline_scope

from .codec import get_codec
from .meta import META_CHUNK_KEY, ArrayMeta, auto_chunks

Slices = Tuple[slice, ...]
Rect = Tuple[Tuple[int, int], ...]


def chunk_rectangles(n_chunks: Sequence[int], window: int
                     ) -> Iterator[Rect]:
    """Split a chunk grid into rectangular index blocks of at most
    ``window`` chunks each: as many complete trailing dims as fit, the next
    dim split into runs, leading dims iterated one index at a time.  The
    union of each block's chunks is a rectangle — which is what lets one
    coalesced read/write plan cover a whole batch."""
    d = len(n_chunks)
    if d == 0:                  # scalar array: one one-chunk "rectangle"
        yield ()
        return
    window = max(1, window)
    suffix, cut = 1, d
    while cut > 0 and n_chunks[cut - 1] > 0 \
            and suffix * n_chunks[cut - 1] <= window:
        suffix *= n_chunks[cut - 1]
        cut -= 1
    if cut == 0:                # the whole grid fits in one window
        yield tuple((0, n) for n in n_chunks)
        return
    run = max(1, window // suffix)
    for prefix in itertools.product(*(range(n) for n in n_chunks[:cut - 1])):
        for a in range(0, n_chunks[cut - 1], run):
            b = min(n_chunks[cut - 1], a + run)
            yield (tuple((p, p + 1) for p in prefix) + ((a, b),)
                   + tuple((0, n) for n in n_chunks[cut:]))


class ReshardPlan:
    """Materialised re-layout plan for one :class:`~.store.ChunkedArray`.

    Construction is pure planning — destination grid, batch rectangles and
    the new metadata are computed, but no I/O happens and nothing is
    archived.  :meth:`read_ops` / :meth:`write_ops` resolve each batch's
    Read/Write plans (catalogue lookups and placement only) to report the
    coalesced op counts :meth:`execute` will issue — strictly below the
    naive one-op-per-chunk rewrite wherever chunks coalesce (posix), equal
    to it on object backends, which is the paper's trade-off carried
    through composition.

    :meth:`execute` streams the batches; afterwards the executed totals are
    on :attr:`read_ops_executed` / :attr:`write_ops_executed` and the
    decoded-staging high-water mark on :attr:`peak_staged_bytes` (bounded
    by ~``window`` destination chunks by construction).
    """

    def __init__(self, array, new_chunks, codec: Optional[str] = None,
                 sel=None, window: Optional[int] = None,
                 fill_missing: bool = True):
        self.array = array
        src_grid = array.grid
        key = sel if sel is not None else (slice(None),) * src_grid.ndim
        norm, squeeze = src_grid.normalize_key(key)
        if squeeze:
            raise ValueError(
                "reshard selections must be slices — an integer index would "
                "drop an axis; use slice(i, i + 1) to keep it")
        self.sel = norm
        self.fill_missing = fill_missing
        shape = src_grid.selection_shape(norm)
        codec = codec if codec is not None else array.meta.codec
        get_codec(codec)        # validate early
        if new_chunks is None:
            new_chunks = auto_chunks(shape, array.dtype)
        self.dest_meta = ArrayMeta(
            shape=shape, dtype=array.dtype.name,
            chunks=tuple(int(c) for c in new_chunks), codec=codec,
            generation=array.meta.generation + 1)
        self.dest_grid = self.dest_meta.grid()
        #: batch size in destination chunks (defaults to the executor's
        #: in-flight window) — the staged-bytes bound
        self.window = window if window is not None \
            else max(1, array.store.executor.max_in_flight)
        full_sel = all((s.step or 1) == 1 and s.start == 0 and s.stop == n
                       for s, n in zip(norm, array.shape))
        #: identical layout over the full array: nothing to move
        self.noop = full_sel and self.dest_meta.layout_matches(array.meta)
        #: destination-coordinate rectangular selections, one per batch
        self.regions: List[Slices] = [] if self.noop else [
            tuple(slice(lo * c, min(hi * c, s), 1)
                  for (lo, hi), c, s in zip(rect, self.dest_grid.chunks,
                                            self.dest_grid.shape))
            for rect in chunk_rectangles(self.dest_grid.n_chunks,
                                         self.window)]
        self.read_ops_executed: Optional[int] = None
        self.write_ops_executed: Optional[int] = None
        self.peak_staged_bytes = 0
        #: planning-time accounting caches — one catalogue/placement
        #: resolution sweep however many of the stat methods are called
        self._read_stats_cache: Optional[Tuple[int, int]] = None
        self._write_ops_cache: Optional[int] = None

    # -- planning / accounting ----------------------------------------------
    def _src_sel(self, region: Slices) -> Slices:
        """Compose a destination-coordinate rectangle with the (possibly
        strided) source selection into source coordinates."""
        out = []
        for s, r in zip(self.sel, region):
            step = s.step or 1
            if r.stop <= r.start:
                out.append(slice(s.start, s.start, step))
            else:
                out.append(slice(s.start + r.start * step,
                                 s.start + (r.stop - 1) * step + 1, step))
        return tuple(out)

    @property
    def n_batches(self) -> int:
        return len(self.regions)

    @property
    def n_dest_chunks(self) -> int:
        return 0 if self.noop else self.dest_grid.chunk_count

    def _read_plans(self):
        from .store import ReadPlan
        for region in self.regions:
            yield ReadPlan(self.array, self._src_sel(region), (),
                           fill_missing=self.fill_missing)

    def _write_plans(self):
        from .store import ChunkedArray, WritePlan
        dest = ChunkedArray(self.array.store, self.dest_meta)
        for region in self.regions:
            yield WritePlan(dest, region, None)     # values bound at execute

    def _read_stats(self) -> Tuple[int, int]:
        """(coalesced read ops, per-chunk fetches), resolved once per plan
        — the stat methods below share this sweep so calling several of
        them costs one catalogue pass, not one each."""
        if self._read_stats_cache is None:
            ops = fetches = 0
            for p in self._read_plans():
                ops += p.read_ops()
                fetches += p.n_chunks
            self._read_stats_cache = (ops, fetches)
        return self._read_stats_cache

    def read_ops(self) -> int:
        """Coalesced source read ops :meth:`execute` will issue (catalogue
        resolution only, no data I/O; cached on first call)."""
        return self._read_stats()[0]

    def write_ops(self) -> int:
        """Coalesced destination write ops :meth:`execute` will issue
        (placement resolution only, no I/O; cached on first call)."""
        if self._write_ops_cache is None:
            self._write_ops_cache = sum(p.write_ops()
                                        for p in self._write_plans())
        return self._write_ops_cache

    def src_chunk_fetches(self) -> int:
        """Source chunk fetches across all batches — the naive read-op
        count a one-op-per-chunk rewrite would issue (a source chunk
        straddling batch boundaries counts once per batch)."""
        return self._read_stats()[1]

    # -- execution -----------------------------------------------------------
    def execute(self, flush: bool = True, deadline: Optional[float] = None):
        """Stream every batch (coalesced read → coalesced write), then flip
        the metadata to the new layout and — with ``flush=True`` — commit
        (FDB rule 3: chunks and metadata publish together).  Returns the
        source array, mutated onto the new layout.  ``deadline`` (seconds)
        is the whole reshard's shared retry budget — every facade-level
        retry under any batch draws from it (ambient
        :func:`repro.core.deadline_scope`)."""
        from .store import ChunkedArray, ReadPlan, WritePlan
        arr = self.array
        store = arr.store
        fdb = store.fdb
        if self.noop:
            return arr
        tracer = fdb.tracer
        with tracer.span("plan.reshard", batches=self.n_batches,
                         dest_chunks=self.n_dest_chunks,
                         generation=self.dest_meta.generation), \
                deadline_scope(deadline):
            if fdb.dirty:
                fdb.flush()     # source chunks must be visible to our reads
            dest = ChunkedArray(store, self.dest_meta)
            read_ops = write_ops = 0
            for ri, region in enumerate(self.regions):
                # the inner Read/Write plans open their own plan.* spans,
                # which nest as children of this per-batch span
                with tracer.span("reshard.batch", batch=ri):
                    rp = ReadPlan(arr, self._src_sel(region), (),
                                  fill_missing=self.fill_missing)
                    data = rp.execute()
                    self.peak_staged_bytes = max(self.peak_staged_bytes,
                                                 data.nbytes)
                    wp = WritePlan(dest, region, data)
                    wp.execute(flush=False)
                    read_ops += rp.read_ops()
                    write_ops += wp.write_ops()
            self.read_ops_executed = read_ops
            self.write_ops_executed = write_ops
            # the flip: one transactional metadata replace (rule 5) moves
            # readers onto the new generation's chunk keys — a chunk
            # cache needs no invalidation here (new generation, new keys)
            fdb.archive(store._ident(META_CHUNK_KEY),
                        self.dest_meta.to_bytes())
            if store.tree is not None:
                store.tree.record(store.base[store.tree.member_dim],
                                  self.dest_meta, client=fdb)
            if flush:
                fdb.flush()
        arr.meta = self.dest_meta
        arr.grid = self.dest_grid
        arr._codec = get_codec(self.dest_meta.codec)
        return arr


__all__ = ["ReshardPlan", "chunk_rectangles"]

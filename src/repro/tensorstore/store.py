"""Chunked N-D arrays over the FDB: the storage layer the paper's access
pattern wants (many independent object-granular I/Os per request).

An array is split on a :class:`~.grid.ChunkGrid`; every chunk is archived as
one FDB object whose element key encodes the chunk index (``c<i>.<j>...``),
and a small :class:`~.meta.ArrayMeta` object rides under the reserved element
value ``meta``.  Slicing ``arr[10:20, :]`` retrieves only the intersecting
chunks — in parallel, through the bounded :class:`~.executor.ChunkExecutor` —
on any of the four backends (daos / rados / posix / s3).

The store is schema-agnostic: it binds to an existing :class:`repro.core.FDB`
plus a *base identifier* covering every schema dimension except the chunk
dimension.  With the dedicated ``tensor`` schema that base is
``{store, array, writer}``; with the ``ckpt`` schema the chunk index rides
the ``shard`` element dim so checkpoint tensors become chunked arrays without
a second catalogue.
"""
from __future__ import annotations

from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import FDB, FieldLocation, Identifier
from .codec import Codec, get_codec
from .executor import ChunkExecutor, sized_executor
from .grid import ChunkGrid
from .meta import META_CHUNK_KEY, ArrayMeta, auto_chunks

Index = Tuple[int, ...]


class LayoutMismatchError(ValueError):
    """Raised on re-create of an existing array with a different layout."""


def chunk_key(idx: Index) -> str:
    """Element-key value for a chunk index, e.g. ``c0.3.1``.

    ``.`` as separator: ``/`` is the FDB multi-value expression separator and
    ``,``/``=`` are taken by the canonical identifier form.
    """
    return "c" + ".".join(str(i) for i in idx)


class TensorStore:
    """A named slot for one chunked array inside an FDB."""

    def __init__(self, fdb: FDB, base: Mapping[str, object],
                 chunk_dim: Optional[str] = None,
                 executor: Optional[ChunkExecutor] = None):
        self.fdb = fdb
        schema = fdb.schema
        self.chunk_dim = chunk_dim or schema.element_dims[-1]
        if self.chunk_dim not in schema.element_dims:
            raise KeyError(f"chunk dim {self.chunk_dim!r} is not an element "
                           f"dim of schema {schema.name!r}")
        self.base = {str(k): str(v) for k, v in base.items()}
        missing = [d for d in schema.all_dims
                   if d != self.chunk_dim and d not in self.base]
        if missing:
            raise KeyError(f"tensorstore base {self.base} missing dims "
                           f"{missing} of schema {schema.name!r}")
        if executor is None:
            # honour the FDB's configured overlap depth (<= 1 serializes)
            executor = sized_executor(max(1, fdb.config.io_parallelism))
        self.executor = executor

    # -- identifiers -----------------------------------------------------------
    def _ident(self, chunk_value: str) -> Identifier:
        return Identifier({**self.base, self.chunk_dim: chunk_value})

    # -- lifecycle -------------------------------------------------------------
    def exists(self) -> bool:
        return self.fdb.retrieve(self._ident(META_CHUNK_KEY)).length() > 0

    def create(self, shape: Sequence[int], dtype,
               chunks: Optional[Sequence[int]] = None,
               codec: str = "raw") -> "ChunkedArray":
        """Archive the metadata object and return the (empty) array.

        Re-creating over an existing array is only a clean transactional
        replace (FDB rule 5) when the layout is unchanged — every new chunk
        key then overwrites its predecessor.  A different chunk grid / dtype
        / codec would leave stale old-grid chunk objects behind (there is no
        per-object delete in the FDB API), so that case is rejected: wipe
        the array's dataset first.
        """
        get_codec(codec)        # validate early
        shape = tuple(int(s) for s in shape)
        dtype = np.dtype(dtype)
        if chunks is None:
            chunks = auto_chunks(shape, dtype)
        meta = ArrayMeta(shape=shape, dtype=dtype.name,
                         chunks=tuple(int(c) for c in chunks), codec=codec)
        handle = self.fdb.retrieve(self._ident(META_CHUNK_KEY))
        if handle.length():
            old = ArrayMeta.from_bytes(handle.read())
            if old != meta:
                raise LayoutMismatchError(
                    f"array at {self.base} already exists with layout "
                    f"{old} != {meta}; wipe it before re-creating with a "
                    f"different layout")
        self.fdb.archive(self._ident(META_CHUNK_KEY), meta.to_bytes())
        return ChunkedArray(self, meta)

    def open(self) -> "ChunkedArray":
        handle = self.fdb.retrieve(self._ident(META_CHUNK_KEY))
        if handle.length() == 0:
            raise FileNotFoundError(
                f"no tensorstore array at {self.base} "
                f"(backend {self.fdb.config.backend})")
        return ChunkedArray(self, ArrayMeta.from_bytes(handle.read()))

    def save(self, values, chunks: Optional[Sequence[int]] = None,
             codec: str = "raw") -> "ChunkedArray":
        """create() + write() + flush() in one call."""
        values = np.asarray(values)
        arr = self.create(values.shape, values.dtype, chunks=chunks,
                          codec=codec)
        arr.write(values)
        return arr


class ChunkedArray:
    def __init__(self, store: TensorStore, meta: ArrayMeta):
        self.store = store
        self.meta = meta
        self.grid: ChunkGrid = meta.grid()
        self._codec: Codec = get_codec(meta.codec)

    # -- introspection ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return self.meta.shape

    @property
    def dtype(self) -> np.dtype:
        return self.meta.npdtype

    @property
    def chunks(self) -> Tuple[int, ...]:
        return self.meta.chunks

    @property
    def n_chunks(self) -> Tuple[int, ...]:
        return self.grid.n_chunks

    def __repr__(self) -> str:
        return (f"ChunkedArray(shape={self.shape}, dtype={self.dtype.name}, "
                f"chunks={self.chunks}, codec={self.meta.codec})")

    # -- write path ------------------------------------------------------------
    def write(self, values, flush: bool = True) -> List[FieldLocation]:
        """Archive every chunk: one executor task per chunk encodes *and*
        archives, so at most the executor's in-flight window of encoded
        chunks is ever alive and archives overlap encodes of later chunks.
        ``flush=True`` commits before returning (FDB visibility rule 3)."""
        values = np.asarray(values)
        if values.shape != self.shape:
            raise ValueError(f"write shape {values.shape} != array shape "
                             f"{self.shape}")
        values = values.astype(self.dtype, copy=False)
        codec, grid, store = self._codec, self.grid, self.store

        def put(idx: Index) -> FieldLocation:
            chunk = values[grid.chunk_slices(idx)]
            return store.fdb.archive(store._ident(chunk_key(idx)),
                                     codec.encode(chunk))

        locs = store.executor.map_ordered(put, list(grid.all_indices()))
        if flush:
            store.fdb.flush()
        return locs

    # -- read path -------------------------------------------------------------
    def __getitem__(self, key) -> np.ndarray:
        sel, squeeze = self.grid.normalize_key(key)
        out = np.empty(self.grid.selection_shape(sel), self.dtype)
        plan = list(self.grid.intersecting(sel))
        codec, grid, store = self._codec, self.grid, self.store

        def fetch(task) -> None:
            idx, chunk_sel, out_sel = task
            handle = store.fdb.retrieve(store._ident(chunk_key(idx)))
            if handle.length() == 0:
                raise KeyError(f"missing chunk {idx} of array at {store.base}")
            chunk = codec.decode(handle.read(), grid.chunk_shape(idx),
                                 self.dtype)
            out[out_sel] = chunk[chunk_sel]

        # disjoint output regions per task → concurrent assembly is safe
        store.executor.map_ordered(fetch, plan)
        if squeeze:
            out = out.reshape(tuple(
                s for a, s in enumerate(out.shape) if a not in squeeze))
        return out

    def read(self) -> np.ndarray:
        return self[(slice(None),) * self.grid.ndim]

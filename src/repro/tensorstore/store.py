"""Chunked N-D arrays over the FDB: the storage layer the paper's access
pattern wants (many independent object-granular I/Os per request).

An array is split on a :class:`~.grid.ChunkGrid`; every chunk is archived as
one FDB object whose element key encodes the chunk index (``c<i>.<j>...``),
and a small :class:`~.meta.ArrayMeta` object rides under the reserved element
value ``meta``.  Slicing ``arr[10:20, :]`` retrieves only the intersecting
chunks — in parallel, through the bounded :class:`~.executor.ChunkExecutor` —
on any of the four backends (daos / rados / posix / s3).

The store is schema-agnostic: it binds to an existing :class:`repro.core.FDB`
plus a *base identifier* covering every schema dimension except the chunk
dimension.  With the dedicated ``tensor`` schema that base is
``{store, array, writer}``; with the ``ckpt`` schema the chunk index rides
the ``shard`` element dim so checkpoint tensors become chunked arrays without
a second catalogue.

Both data paths plan before they touch bytes — the two halves of the paper's
object-store/POSIX trade-off:

* **Reads** build a :class:`ReadPlan`: every intersecting chunk is resolved
  to its backend handle (catalogue only, no data I/O), and handles over the
  same storage unit — posix chunks of one data file — are grouped so adjacent
  ranges coalesce into single large reads (``FileRangeHandle`` merging),
  while object-store chunks keep one op in flight each.  ``read_ops()`` on
  the plan reports the I/O-op count a read will issue.
* **Writes** (``write``, ``arr[sel] = values``, ``write_at``) build a
  :class:`WritePlan` — the mirror of the read side.  Every chunk the
  selection touches is resolved to its destination storage unit
  (``FDB.archive_placement``, placement only, no I/O) and chunks landing in
  the same unit — posix chunks appending into one writer's data file — are
  grouped into ONE batched store-level write (``FDB.archive_batch``), while
  object-store chunks keep one archive op in flight each.
  ``write_ops()`` on the plan reports the store-level write count, the twin
  of ``ReadPlan.read_ops()``.  Encoding is batched too: same-shape chunks
  encode through the codec's single-kernel-launch path
  (``Codec.encode_batch``), ragged edge chunks fall back per-chunk.  Chunks
  fully covered by the selection encode from the new values outright;
  partially covered (edge) chunks do read-modify-write through the bounded
  executor.  Chunks never written before read as zeros (the Zarr fill-value
  convention).  A ``flush()`` barrier after the archives preserves FDB
  visibility rule 3 — and partial writes flush *first* as well, so their
  RMW fetches see this writer's own earlier unflushed chunks.
"""
from __future__ import annotations

from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import (FDB, FieldLocation, Identifier, MultiHandle,
                        group_mergeable)
from .codec import Codec, get_codec
from .executor import ChunkExecutor
from .grid import ChunkGrid
from .meta import META_CHUNK_KEY, ArrayMeta, auto_chunks

Index = Tuple[int, ...]


class LayoutMismatchError(ValueError):
    """Raised on re-create of an existing array with a different layout."""


def chunk_key(idx: Index) -> str:
    """Element-key value for a chunk index, e.g. ``c0.3.1``.

    ``.`` as separator: ``/`` is the FDB multi-value expression separator and
    ``,``/``=`` are taken by the canonical identifier form.
    """
    return "c" + ".".join(str(i) for i in idx)


class TensorStore:
    """A named slot for one chunked array inside an FDB."""

    def __init__(self, fdb: FDB, base: Mapping[str, object],
                 chunk_dim: Optional[str] = None,
                 executor: Optional[ChunkExecutor] = None):
        self.fdb = fdb
        schema = fdb.schema
        self.chunk_dim = chunk_dim or schema.element_dims[-1]
        if self.chunk_dim not in schema.element_dims:
            raise KeyError(f"chunk dim {self.chunk_dim!r} is not an element "
                           f"dim of schema {schema.name!r}")
        self.base = {str(k): str(v) for k, v in base.items()}
        missing = [d for d in schema.all_dims
                   if d != self.chunk_dim and d not in self.base]
        if missing:
            raise KeyError(f"tensorstore base {self.base} missing dims "
                           f"{missing} of schema {schema.name!r}")
        #: explicit executor, or None to track the FDB client's own
        self._executor = executor

    @property
    def executor(self) -> ChunkExecutor:
        """This store's bounded I/O executor.  When none was passed in, the
        FDB client's own (``FDB.io_executor``) is resolved *per use*, not
        cached: the client rebuilds it on an ``io_parallelism`` config
        change, and a reference taken at construction would go stale (a
        shut-down pool)."""
        if self._executor is not None:
            return self._executor
        return self.fdb.io_executor

    # -- identifiers -----------------------------------------------------------
    def _ident(self, chunk_value: str) -> Identifier:
        return Identifier({**self.base, self.chunk_dim: chunk_value})

    # -- lifecycle -------------------------------------------------------------
    def exists(self) -> bool:
        return self.fdb.retrieve(self._ident(META_CHUNK_KEY)).length() > 0

    def create(self, shape: Sequence[int], dtype,
               chunks: Optional[Sequence[int]] = None,
               codec: str = "raw") -> "ChunkedArray":
        """Archive the metadata object and return the (empty) array.

        Re-creating over an existing array is only a clean transactional
        replace (FDB rule 5) when the layout is unchanged — every new chunk
        key then overwrites its predecessor.  A different chunk grid / dtype
        / codec would leave stale old-grid chunk objects behind (there is no
        per-object delete in the FDB API), so that case is rejected: wipe
        the array's dataset first.
        """
        get_codec(codec)        # validate early
        shape = tuple(int(s) for s in shape)
        dtype = np.dtype(dtype)
        if chunks is None:
            chunks = auto_chunks(shape, dtype)
        meta = ArrayMeta(shape=shape, dtype=dtype.name,
                         chunks=tuple(int(c) for c in chunks), codec=codec)
        handle = self.fdb.retrieve(self._ident(META_CHUNK_KEY))
        if handle.length():
            old = ArrayMeta.from_bytes(handle.read())
            if old != meta:
                raise LayoutMismatchError(
                    f"array at {self.base} already exists with layout "
                    f"{old} != {meta}; wipe it before re-creating with a "
                    f"different layout")
        self.fdb.archive(self._ident(META_CHUNK_KEY), meta.to_bytes())
        return ChunkedArray(self, meta)

    def open(self) -> "ChunkedArray":
        handle = self.fdb.retrieve(self._ident(META_CHUNK_KEY))
        if handle.length() == 0:
            raise FileNotFoundError(
                f"no tensorstore array at {self.base} "
                f"(backend {self.fdb.config.backend})")
        return ChunkedArray(self, ArrayMeta.from_bytes(handle.read()))

    def save(self, values, chunks: Optional[Sequence[int]] = None,
             codec: str = "raw") -> "ChunkedArray":
        """create() + write() + flush() in one call."""
        values = np.asarray(values)
        arr = self.create(values.shape, values.dtype, chunks=chunks,
                          codec=codec)
        arr.write(values)
        return arr


class ChunkedArray:
    def __init__(self, store: TensorStore, meta: ArrayMeta):
        self.store = store
        self.meta = meta
        self.grid: ChunkGrid = meta.grid()
        self._codec: Codec = get_codec(meta.codec)

    # -- introspection ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return self.meta.shape

    @property
    def dtype(self) -> np.dtype:
        return self.meta.npdtype

    @property
    def chunks(self) -> Tuple[int, ...]:
        return self.meta.chunks

    @property
    def n_chunks(self) -> Tuple[int, ...]:
        return self.grid.n_chunks

    def __repr__(self) -> str:
        return (f"ChunkedArray(shape={self.shape}, dtype={self.dtype.name}, "
                f"chunks={self.chunks}, codec={self.meta.codec})")

    # -- write path ------------------------------------------------------------
    def write_plan(self, key, values) -> "WritePlan":
        """Plan a write without moving data — the mirror of
        :meth:`read_plan`: every chunk the selection touches is resolved to
        its destination storage unit and coalescible chunks are grouped
        into single batched store writes.  Use :meth:`WritePlan.write_ops`
        to see the store-level write count before (or without) executing.

        ``values`` broadcasts against the selection shape (so
        ``arr[10:20, :] = 0.0`` works).
        """
        sel, squeeze = self.grid.normalize_key(key)
        sel_shape = self.grid.selection_shape(sel)
        values = np.asarray(values)
        if squeeze and values.ndim == len(sel_shape) - len(squeeze):
            # integer-indexed axes were dropped by the caller: re-insert them
            values = np.expand_dims(values, tuple(squeeze))
        values = np.broadcast_to(values.astype(self.dtype, copy=False),
                                 sel_shape)
        return WritePlan(self, sel, values)

    def write(self, values, flush: bool = True) -> List[FieldLocation]:
        """Archive every chunk through a whole-array :class:`WritePlan`:
        same-shape chunks encode in one Pallas launch, chunks bound for one
        storage unit archive as one batched store write.  ``flush=True``
        commits before returning (FDB visibility rule 3)."""
        values = np.asarray(values)
        if values.shape != self.shape:
            raise ValueError(f"write shape {values.shape} != array shape "
                             f"{self.shape}")
        key = (slice(None),) * self.grid.ndim
        return self.write_plan(key, values).execute(flush=flush)

    def write_at(self, key, values, flush: bool = True
                 ) -> List[FieldLocation]:
        """Chunk-aligned in-place assignment: ``arr[sel] = values``.

        Only chunks the selection touches are re-archived — through a
        :class:`WritePlan`, so coalescible chunks batch into single store
        writes.  Fully covered chunks are encoded from ``values`` directly;
        partially covered ones do read-modify-write (fetch, patch,
        re-archive) through the bounded executor — a chunk never written
        before patches onto zeros, the Zarr fill-value convention.

        Visibility (FDB rule 3): when RMW is needed and this client has
        unflushed archives, the FDB is flushed *before* fetching, so its own
        earlier unflushed chunks are seen rather than lost (no barrier is
        paid when the client is clean); ``flush=True`` commits the new chunk
        versions before returning.  With lossy codecs (``field8``/``field16``) RMW
        re-quantises the whole chunk, so untouched elements of partially
        covered chunks may shift within the quantisation bound.
        """
        return self.write_plan(key, values).execute(flush=flush)

    def __setitem__(self, key, values) -> None:
        self.write_at(key, values, flush=True)

    # -- read path -------------------------------------------------------------
    def _fetch_chunk(self, idx: Index) -> np.ndarray:
        """Decode one whole chunk for read-modify-write (always writable);
        a chunk never written decodes as zeros (fill-value convention)."""
        store = self.store
        handle = store.fdb.retrieve_handle(store._ident(chunk_key(idx)))
        shape = self.grid.chunk_shape(idx)
        if handle is None or handle.length() == 0:
            return np.zeros(shape, self.dtype)
        chunk = self._codec.decode(handle.read(), shape, self.dtype)
        return chunk if chunk.flags.writeable else chunk.copy()

    def read_plan(self, key, fill_missing: bool = True) -> "ReadPlan":
        """Plan a read without moving data: resolves every intersecting
        chunk to its backend handle and groups coalescible ones.  Use
        :meth:`ReadPlan.read_ops` to see the I/O-op count before (or
        without) executing.

        ``fill_missing=True`` (default) reads never-written chunks as zeros
        — the Zarr fill-value convention that makes sparsely-populated
        arrays (create + partial writes) readable.  The flip side: on a
        fully ``save()``\\ d array a missing chunk means lost or
        not-yet-flushed data, and zeros would mask that — pass
        ``fill_missing=False`` to get a ``KeyError`` at plan time instead
        (consumers that require every chunk present, e.g. checkpoint
        restores of dense tensors).
        """
        sel, squeeze = self.grid.normalize_key(key)
        return ReadPlan(self, sel, squeeze, fill_missing=fill_missing)

    def __getitem__(self, key) -> np.ndarray:
        return self.read_plan(key).execute()

    def read(self, fill_missing: bool = True) -> np.ndarray:
        """Read the whole array.  ``fill_missing=False`` raises ``KeyError``
        on never-written chunks instead of zero-filling — for consumers of
        dense arrays where a missing chunk means lost data."""
        key = (slice(None),) * self.grid.ndim
        return self.read_plan(key, fill_missing=fill_missing).execute()


class WritePlan:
    """Materialised write-side I/O plan for one selection of a
    :class:`ChunkedArray` — the mirror of :class:`ReadPlan`.

    Construction resolves every chunk the selection touches to its
    destination storage unit (:meth:`repro.core.FDB.archive_placement` —
    placement only, no data I/O) and groups chunks landing in the same unit
    with :func:`repro.core.group_mergeable`: posix chunks appending into one
    writer's data file archive as ONE batched store-level write
    (``FDB.archive_batch`` → a single buffered append), while object-store
    chunks keep one independent archive op in flight each — the two sides of
    the paper's object-store/POSIX trade-off, now symmetric with reads.
    :meth:`write_ops` reports the store-level write count :meth:`execute`
    will issue.

    Executing encodes every tile through the codec's *batched* path
    (:meth:`~.codec.Codec.encode_batch`): all same-shape chunks — the
    interior of any multi-chunk write — quantise in one Pallas kernel
    launch (grid over chunks × blocks), ragged edge chunks fall back to
    per-chunk launches, and the bytes are identical either way.  The cost of
    batching is that the plan materialises every encoded tile at once
    (the per-chunk path only ever held the executor window's worth);
    callers archiving arrays far larger than memory should write in
    selections, as the checkpointer and field store do per-tensor/field.
    """

    def __init__(self, array: "ChunkedArray", sel, values: np.ndarray):
        self.array = array
        self.values = values
        store = array.store
        #: (chunk_idx, within_chunk_slices, value_slices, fully_covered)
        self.tasks = list(array.grid.write_plan(sel))
        if self.tasks:
            # the chunk dim is an element dim, so every chunk of one array
            # shares (dataset, collocation) — one placement resolve covers
            # the whole plan
            placement = store.fdb.archive_placement(
                store._ident(chunk_key(self.tasks[0][0])))
            placements = [placement] * len(self.tasks)
        else:
            placements = []
        #: positions-into-tasks per batched store write
        self.groups: List[List[int]] = group_mergeable(placements)

    @property
    def n_chunks(self) -> int:
        return len(self.tasks)

    @property
    def rmw_chunks(self) -> int:
        """Chunks only partially covered by the selection — they fetch and
        patch (read-modify-write) before re-encoding."""
        return sum(1 for _i, _c, _v, full in self.tasks if not full)

    def write_ops(self) -> int:
        """Store-level write operations :meth:`execute` will issue (after
        coalescing) — the twin of :meth:`ReadPlan.read_ops`."""
        return len(self.groups)

    def execute(self, flush: bool = True) -> List[FieldLocation]:
        """Encode (batched), archive (one submission per group), and — with
        ``flush=True`` — commit (FDB visibility rule 3).  Returns per-chunk
        :class:`FieldLocation`\\ s in plan order."""
        if not self.tasks:
            return []
        arr, values = self.array, self.values
        store, codec = arr.store, arr._codec
        fdb = store.fdb
        rmw = [pos for pos, (_i, _c, _v, full) in enumerate(self.tasks)
               if not full]
        if rmw and fdb.dirty:
            fdb.flush()         # make own unflushed chunks RMW-visible
        tiles: List[Optional[np.ndarray]] = [None] * len(self.tasks)
        for pos, (_idx, _chunk_sel, val_sel, full) in enumerate(self.tasks):
            if full:
                tiles[pos] = values[val_sel]

        def fetch_and_patch(pos: int) -> None:
            idx, chunk_sel, val_sel, _full = self.tasks[pos]
            tile = arr._fetch_chunk(idx)
            tile[chunk_sel] = values[val_sel]
            tiles[pos] = tile

        if rmw:                 # RMW fetches overlap through the executor
            store.executor.map_ordered(fetch_and_patch, rmw)
        blobs = codec.encode_batch(tiles)

        locs: List[Optional[FieldLocation]] = [None] * len(self.tasks)

        def put(group: List[int]) -> List[FieldLocation]:
            # one store-level submission per group: a posix group lands as
            # a single buffered append; object groups are singletons
            return fdb.archive_batch(
                [(store._ident(chunk_key(self.tasks[pos][0])), blobs[pos])
                 for pos in group])

        batches = store.executor.map_ordered(put, self.groups)
        for group, batch_locs in zip(self.groups, batches):
            for pos, loc in zip(group, batch_locs):
                locs[pos] = loc
        if flush:
            fdb.flush()
        return locs             # type: ignore[return-value]


class ReadPlan:
    """Materialised I/O plan for one selection of a :class:`ChunkedArray`.

    Chunk identifiers are resolved to backend :class:`DataHandle`\\ s up
    front (catalogue lookups only — no payload I/O), then grouped with
    :func:`repro.core.group_mergeable`: handles over the same storage unit
    (posix chunks living in one writer's data file) merge, so adjacent
    chunks coalesce into single ranged reads — the POSIX backend's key read
    optimisation — while object-store chunks stay one independent op each,
    which is what those backends want kept in flight.  Executing scatters
    decoded chunks into the output array, one executor task per group.
    """

    def __init__(self, array: "ChunkedArray", sel, squeeze,
                 fill_missing: bool = True):
        self.array = array
        self.sel = sel
        self.squeeze = squeeze
        store = array.store
        self.tasks = list(array.grid.intersecting(sel))
        present: List[int] = []
        handles = []
        #: positions of chunks never written — they read as zeros (the same
        #: fill-value convention the write path patches onto), no I/O
        self.missing: List[int] = []
        for pos, (idx, _chunk_sel, _out_sel) in enumerate(self.tasks):
            h = store.fdb.retrieve_handle(store._ident(chunk_key(idx)))
            if h is None or h.length() == 0:
                if not fill_missing:
                    raise KeyError(
                        f"missing chunk {idx} of array at {store.base}")
                self.missing.append(pos)
            else:
                present.append(pos)
                handles.append(h)
        #: (positions-into-tasks, merged handle) per I/O batch
        self.batches: List[Tuple[List[int], MultiHandle]] = [
            ([present[i] for i in group],
             MultiHandle([handles[i] for i in group]))
            for group in group_mergeable(handles)]

    @property
    def n_chunks(self) -> int:
        return len(self.tasks)

    def read_ops(self) -> int:
        """I/O operations :meth:`execute` will issue (after coalescing)."""
        return sum(mh.read_ops() for _g, mh in self.batches)

    def execute(self) -> np.ndarray:
        arr = self.array
        grid, codec = arr.grid, arr._codec
        out = np.empty(grid.selection_shape(self.sel), arr.dtype)
        for pos in self.missing:
            out[self.tasks[pos][2]] = 0

        def run_batch(positions: List[int], mh: MultiHandle) -> None:
            # one coalesced read per batch, one batched decode (equal-shape
            # chunks share a kernel launch); per-chunk payloads scatter into
            # disjoint output regions → concurrent assembly is safe
            shapes = [grid.chunk_shape(self.tasks[pos][0])
                      for pos in positions]
            chunks = codec.decode_batch(mh.read_parts(), shapes, arr.dtype)
            for pos, chunk in zip(positions, chunks):
                _idx, chunk_sel, out_sel = self.tasks[pos]
                out[out_sel] = chunk[chunk_sel]

        arr.store.executor.map_ordered(lambda b: run_batch(*b), self.batches)
        if self.squeeze:
            out = out.reshape(tuple(
                s for a, s in enumerate(out.shape) if a not in self.squeeze))
        return out

"""Chunked N-D arrays over the FDB: the storage layer the paper's access
pattern wants (many independent object-granular I/Os per request).

An array is split on a :class:`~.grid.ChunkGrid`; every chunk is archived as
one FDB object whose element key encodes the chunk index (``c<i>.<j>...``,
generation-prefixed ``g<N>.c...`` after a reshard), and a small
:class:`~.meta.ArrayMeta` object rides under the reserved element value
``meta``.  Slicing ``arr[10:20, :]`` retrieves only the intersecting chunks
— in parallel, through the bounded :class:`~.executor.ChunkExecutor` — on
any of the four backends (daos / rados / posix / s3).  Selections may be
strided (``arr[::4]``): only the chunks holding a selected point are
touched, on the read and the write path alike.

The store is schema-agnostic: it binds to an existing :class:`repro.core.FDB`
plus a *base identifier* covering every schema dimension except the chunk
dimension.  With the dedicated ``tensor`` schema that base is
``{store, array, writer}``; with the ``ckpt`` schema the chunk index rides
the ``shard`` element dim so checkpoint tensors become chunked arrays without
a second catalogue.

All three data paths plan before they touch bytes — the two halves of the
paper's object-store/POSIX trade-off, plus their composition:

* **Reads** build a :class:`ReadPlan`: every intersecting chunk is resolved
  to its backend handle (catalogue only, no data I/O), and handles over the
  same storage unit — posix chunks of one data file — are grouped so adjacent
  ranges coalesce into single large reads (``FileRangeHandle`` merging),
  while object-store chunks keep one op in flight each.  ``read_ops()`` on
  the plan reports the I/O-op count a read will issue.
* **Writes** (``write``, ``arr[sel] = values``, ``write_at``) build a
  :class:`WritePlan` — the mirror of the read side.  Every chunk the
  selection touches is resolved to its destination storage unit
  (``FDB.archive_placement``, placement only, no I/O) and chunks landing in
  the same unit — posix chunks appending into one writer's data file — are
  grouped into batched store-level writes (``FDB.archive_batch``), while
  object-store chunks keep one archive op in flight each.
  ``write_ops()`` on the plan reports the store-level write count, the twin
  of ``ReadPlan.read_ops()``.  Encoding is batched (same-shape chunks share
  one ``Codec.encode_batch`` kernel launch, ragged edge chunks fall back
  per-chunk) and *staged*: the plan is executed in sub-batches of at most
  one executor window (``WritePlan.window`` chunks), so peak staged bytes
  are bounded no matter how large the plan — arrays far larger than memory
  archive without materialising every encoded tile at once.  Chunks fully
  covered by the selection encode from the new values outright; partially
  covered chunks do read-modify-write, with the fetches routed through a
  whole-chunk :class:`ReadPlan` (:meth:`ReadPlan.for_chunks`) so adjacent
  posix RMW reads coalesce exactly like normal reads.  Chunks never written
  before read as zeros (the Zarr fill-value convention).  A ``flush()``
  barrier after the archives preserves FDB visibility rule 3 — and partial
  writes flush *first* as well, so their RMW fetches see this writer's own
  earlier unflushed chunks.  On a *session-bound* store (multi-writer), the
  plan additionally acquires the chunk-range **leases** covering its
  selection at plan time — overlap with another writer fails fast with
  :class:`~repro.core.LeaseConflictError` — and validates its lease epochs
  before every stage of archives, so a fenced stale writer raises
  :class:`~repro.core.StaleLeaseError` instead of silently merging.
* **Reshards** (``arr.reshard(new_chunks)``) compose the two: a
  :class:`~.reshard.ReshardPlan` streams the array onto a new chunk grid —
  destination chunks in bounded rectangular batches, each batch one
  coalesced source ``ReadPlan`` and one coalesced destination ``WritePlan``
  — never materialising the whole array client-side.  The new grid's chunks
  live under a fresh layout *generation* (see :mod:`.meta`), so the flip is
  one transactional metadata replace and old-grid chunks are retained
  versioned, never readable as wrong data.
"""
from __future__ import annotations

import dataclasses
from typing import List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core import (FDB, FieldLocation, Identifier, LeaseConflictError,
                        MultiHandle, StaleLeaseError, WriterSession,
                        deadline_scope, group_mergeable)
from .cache import ChunkCache
from .codec import Codec, get_codec
from .executor import ChunkExecutor
from .grid import ChunkGrid, merge_id_ranges
from .meta import META_CHUNK_KEY, ArrayMeta, TreeCatalogue, auto_chunks

Index = Tuple[int, ...]


class LayoutMismatchError(ValueError):
    """Raised on re-create of an existing array with a different layout
    (unless the caller opted into ``on_mismatch="retain"``, which bumps the
    layout generation instead — see :meth:`TensorStore.create`)."""


def chunk_key(idx: Index, generation: int = 0) -> str:
    """Element-key value for a chunk index, e.g. ``c0.3.1`` — prefixed with
    the layout generation (``g2.c0.3.1``) for resharded layouts, so chunk
    keys of different grids over one array slot can never collide.

    ``.`` as separator: ``/`` is the FDB multi-value expression separator and
    ``,``/``=`` are taken by the canonical identifier form.  Generation 0
    stays unprefixed for compatibility with pre-generation (format v1)
    arrays.
    """
    key = "c" + ".".join(str(i) for i in idx)
    return key if generation == 0 else f"g{generation}.{key}"


class TensorStore:
    """A named slot for one chunked array inside an FDB.

    ``session`` (optional) binds the slot to a
    :class:`repro.core.WriterSession`: every :class:`WritePlan` built on it
    acquires the chunk-range leases covering its selection at *plan* time
    (failing fast with :class:`repro.core.LeaseConflictError` on overlap
    with another writer), validates its lease epochs before every stage of
    archives (:class:`repro.core.StaleLeaseError` fences a writer whose
    lease was broken and re-acquired), and tracks dirty/flush-barrier state
    per session — the contract that makes two writers on disjoint chunk
    ranges of one array provably safe.  Without a session the store keeps
    the original single-writer behaviour: no leases, client-level barriers.
    """

    def __init__(self, fdb: Optional[FDB], base: Mapping[str, object],
                 chunk_dim: Optional[str] = None,
                 executor: Optional[ChunkExecutor] = None,
                 session: Optional[WriterSession] = None,
                 tree: Optional[TreeCatalogue] = None):
        if session is not None:
            if fdb is None:
                fdb = session.fdb
            elif session.fdb is not fdb:
                raise ValueError("session belongs to a different FDB client")
        elif fdb is None:
            raise ValueError("TensorStore needs an FDB client or a session")
        self.fdb = fdb
        self.session = session
        schema = fdb.schema
        self.chunk_dim = chunk_dim or schema.element_dims[-1]
        if self.chunk_dim not in schema.element_dims:
            raise KeyError(f"chunk dim {self.chunk_dim!r} is not an element "
                           f"dim of schema {schema.name!r}")
        self.base = {str(k): str(v) for k, v in base.items()}
        missing = [d for d in schema.all_dims
                   if d != self.chunk_dim and d not in self.base]
        if missing:
            raise KeyError(f"tensorstore base {self.base} missing dims "
                           f"{missing} of schema {schema.name!r}")
        #: explicit executor, or None to track the FDB client's own
        self._executor = executor
        #: consolidated-metadata catalogue for this array's dataset tree
        #: (owned by the facade, e.g. ``ChunkedFieldStore``); when set,
        #: metadata flips (create, reshard) mirror into it so whole-tree
        #: opens stay one fetch
        self.tree = tree

    @property
    def executor(self) -> ChunkExecutor:
        """This store's bounded I/O executor.  When none was passed in, the
        FDB client's own (``FDB.io_executor``) is resolved *per use*, not
        cached: the client rebuilds it on an ``io_parallelism`` config
        change, and a reference taken at construction would go stale (a
        shut-down pool)."""
        if self._executor is not None:
            return self._executor
        return self.fdb.io_executor

    @property
    def client(self):
        """What archives and flush barriers route through: the bound
        :class:`~repro.core.WriterSession` when there is one (per-session
        dirty tracking), the FDB client otherwise — both expose the same
        archive/flush/dirty surface."""
        return self.session if self.session is not None else self.fdb

    # -- identifiers -----------------------------------------------------------
    def _ident(self, chunk_value: str) -> Identifier:
        return Identifier({**self.base, self.chunk_dim: chunk_value})

    # -- lifecycle -------------------------------------------------------------
    def exists(self) -> bool:
        return self.fdb.retrieve(self._ident(META_CHUNK_KEY)).length() > 0

    def create(self, shape: Sequence[int], dtype,
               chunks: Optional[Sequence[int]] = None,
               codec: str = "raw",
               on_mismatch: str = "error") -> "ChunkedArray":
        """Archive the metadata object and return the (empty) array.

        Re-creating over an existing array with an *unchanged* layout is a
        clean transactional replace (FDB rule 5): the live generation is
        kept, so every new chunk key overwrites its predecessor.  A
        different chunk grid / dtype / codec cannot reuse the old keys —
        there is no per-object delete in the FDB API, so the old grid's
        chunk objects cannot be removed.  ``on_mismatch`` picks the policy:

        * ``"error"`` (default): raise :class:`LayoutMismatchError`; wipe
          the array's dataset first if the old data is expendable (what
          :meth:`repro.data.ChunkedFieldStore.put_field` does — the *wipe*
          policy, which reclaims space).
        * ``"retain"``: bump the layout generation — the new layout's
          chunks live under fresh generation-prefixed keys
          (:func:`chunk_key`) and the metadata replace flips readers over;
          old-generation chunks are retained versioned (unreachable, never
          readable as wrong data) until the dataset is wiped.  This is the
          policy :meth:`ChunkedArray.reshard` builds on.
        """
        if on_mismatch not in ("error", "retain"):
            raise ValueError(f"on_mismatch must be 'error' or 'retain', "
                             f"got {on_mismatch!r}")
        get_codec(codec)        # validate early
        shape = tuple(int(s) for s in shape)
        dtype = np.dtype(dtype)
        if chunks is None:
            chunks = auto_chunks(shape, dtype)
        meta = ArrayMeta(shape=shape, dtype=dtype.name,
                         chunks=tuple(int(c) for c in chunks), codec=codec)
        handle = self.fdb.retrieve(self._ident(META_CHUNK_KEY))
        if handle.length():
            old = ArrayMeta.from_bytes(handle.read())
            if old.layout_matches(meta):
                meta = old          # same layout: keep the live generation,
                # so re-written chunk keys land on (and replace) their
                # predecessors instead of forking a new namespace
            elif on_mismatch == "retain":
                meta = dataclasses.replace(meta,
                                           generation=old.generation + 1)
            else:
                raise LayoutMismatchError(
                    f"array at {self.base} already exists with layout "
                    f"{old} != {meta}; wipe it before re-creating with a "
                    f"different layout, or pass on_mismatch='retain' to "
                    f"version the old chunks out")
        self.client.archive(self._ident(META_CHUNK_KEY), meta.to_bytes())
        if self.tree is not None:
            self.tree.record(self.base[self.tree.member_dim], meta,
                             client=self.client)
        return ChunkedArray(self, meta)

    def open(self) -> "ChunkedArray":
        handle = self.fdb.retrieve(self._ident(META_CHUNK_KEY))
        if handle.length() == 0:
            raise FileNotFoundError(
                f"no tensorstore array at {self.base} "
                f"(backend {self.fdb.config.backend})")
        return ChunkedArray(self, ArrayMeta.from_bytes(handle.read()))

    def save(self, values, chunks: Optional[Sequence[int]] = None,
             codec: str = "raw") -> "ChunkedArray":
        """create() + write() + flush() in one call."""
        values = np.asarray(values)
        arr = self.create(values.shape, values.dtype, chunks=chunks,
                          codec=codec)
        arr.write(values)
        return arr

    def recover(self):
        """Crash-recovery sweep of this array slot's lease scope
        (:meth:`repro.core.FDB.recover`): purge TTL-expired leases,
        quarantine dead writers' archived-but-unflushed chunk intents, and
        — when the array exists — report chunk keys from layout
        generations *newer* than the live one (the debris of a reshard
        that died before its metadata flip).  Returns the
        :class:`repro.core.RecoveryReport`."""
        live = None
        handle = self.fdb.retrieve(self._ident(META_CHUNK_KEY))
        if handle.length():
            meta = ArrayMeta.from_bytes(handle.read())
            live = f"g{meta.generation}"
        return self.fdb.recover(self._ident(META_CHUNK_KEY),
                                live_resource=live)

    def garbage_report(self) -> "GarbageReport":
        """Account the retained old-generation chunk bytes of this array.

        Reshards and ``create(on_mismatch="retain")`` version superseded
        chunks out instead of deleting them (the FDB API has no per-object
        delete), so every re-layout leaves the previous generation's chunk
        objects behind — unreachable, never wrongly readable, but holding
        space until the array's dataset is wiped.  This walks the
        catalogue's entries for the array slot (``FDB.list``, index only,
        no payload I/O) and splits them into the live generation vs
        everything else — the groundwork for an old-generation reclamation
        pass (copy live generation + wipe), and a ``bench_tensorstore``
        column so the retained-garbage cost of a reshard stays visible.

        Only *flushed* entries are visible (rule 3), and only this store's
        collocation key (its ``writer``/``host`` base value) is scanned.
        """
        arr = self.open()       # live generation comes from the metadata
        live_gen = arr.meta.generation
        live_chunks = live_bytes = garbage_chunks = garbage_bytes = 0
        gens = set()
        for ident, loc in self.fdb.list(dict(self.base)):
            value = ident[self.chunk_dim]
            if value == META_CHUNK_KEY:
                continue
            gen = 0
            head = value.split(".", 1)[0]
            if head.startswith("g") and head[1:].isdigit():
                gen = int(head[1:])
            if gen == live_gen:
                live_chunks += 1
                live_bytes += loc.length
            else:
                garbage_chunks += 1
                garbage_bytes += loc.length
                gens.add(gen)
        return GarbageReport(live_generation=live_gen,
                             live_chunks=live_chunks, live_bytes=live_bytes,
                             garbage_chunks=garbage_chunks,
                             garbage_bytes=garbage_bytes,
                             garbage_generations=tuple(sorted(gens)))


@dataclasses.dataclass(frozen=True)
class GarbageReport:
    """What :meth:`TensorStore.garbage_report` found: catalogue-indexed
    chunk objects of the live layout generation vs retained older
    generations (bytes are stored object sizes, i.e. encoded)."""
    live_generation: int
    live_chunks: int
    live_bytes: int
    garbage_chunks: int
    garbage_bytes: int
    garbage_generations: Tuple[int, ...]


class ChunkedArray:
    def __init__(self, store: TensorStore, meta: ArrayMeta):
        self.store = store
        self.meta = meta
        self.grid: ChunkGrid = meta.grid()
        self._codec: Codec = get_codec(meta.codec)

    # -- introspection ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        return self.meta.shape

    @property
    def dtype(self) -> np.dtype:
        return self.meta.npdtype

    @property
    def chunks(self) -> Tuple[int, ...]:
        return self.meta.chunks

    @property
    def n_chunks(self) -> Tuple[int, ...]:
        return self.grid.n_chunks

    def __repr__(self) -> str:
        return (f"ChunkedArray(shape={self.shape}, dtype={self.dtype.name}, "
                f"chunks={self.chunks}, codec={self.meta.codec}"
                + (f", generation={self.meta.generation}"
                   if self.meta.generation else "") + ")")

    def chunk_ident(self, idx: Index) -> Identifier:
        """FDB identifier of chunk ``idx`` under this array's live layout
        generation."""
        return self.store._ident(chunk_key(idx, self.meta.generation))

    # -- write path ------------------------------------------------------------
    def write_plan(self, key, values) -> "WritePlan":
        """Plan a write without moving data — the mirror of
        :meth:`read_plan`: every chunk the selection touches is resolved to
        its destination storage unit and coalescible chunks are grouped
        into batched store writes, staged at most one executor window at a
        time.  Use :meth:`WritePlan.write_ops` to see the store-level write
        count before (or without) executing.

        ``values`` broadcasts against the selection shape (so
        ``arr[10:20, :] = 0.0`` works).  The selection may be strided
        (``arr[::2] = v``): stride gaps are preserved via read-modify-write
        of the touched chunks.  Negative steps work too
        (``arr[::-1] = v``, ``arr[50:10:-4] = v``): like the read path,
        the selection normalises to its positive-step mirror — the I/O
        plan visits chunks in ascending order — and ``values`` is flipped
        once client-side so elements land exactly where NumPy assignment
        would put them.
        """
        sel, squeeze, flips = self.grid.normalize_read_key(key)
        sel_shape = self.grid.selection_shape(sel)
        values = np.asarray(values)
        if squeeze and values.ndim == len(sel_shape) - len(squeeze):
            # integer-indexed axes were dropped by the caller: re-insert them
            values = np.expand_dims(values, tuple(squeeze))
        values = np.broadcast_to(values.astype(self.dtype, copy=False),
                                 sel_shape)
        if flips:
            # reversed axes: the plan's selection ascends, so the flipped
            # view of the (already broadcast) values pairs values[0] with
            # the selection's *last* point — NumPy's reversed-assignment
            # order — while the I/O below stays the positive-step plan
            values = values[tuple(slice(None, None, -1) if a in flips
                                  else slice(None)
                                  for a in range(values.ndim))]
        return WritePlan(self, sel, values)

    def write(self, values, flush: bool = True) -> List[FieldLocation]:
        """Archive every chunk through a whole-array :class:`WritePlan`:
        same-shape chunks encode in one Pallas launch, chunks bound for one
        storage unit archive as batched store writes, staged one executor
        window at a time.  ``flush=True`` commits before returning (FDB
        visibility rule 3)."""
        values = np.asarray(values)
        if values.shape != self.shape:
            raise ValueError(f"write shape {values.shape} != array shape "
                             f"{self.shape}")
        key = (slice(None),) * self.grid.ndim
        return self.write_plan(key, values).execute(flush=flush)

    def write_at(self, key, values, flush: bool = True
                 ) -> List[FieldLocation]:
        """Chunk-aligned in-place assignment: ``arr[sel] = values``.

        Only chunks the selection touches are re-archived — through a
        :class:`WritePlan`, so coalescible chunks batch into store writes.
        Fully covered chunks are encoded from ``values`` directly;
        partially covered ones (including every chunk of a strided
        selection) do read-modify-write — fetch, patch, re-archive — with
        the fetches coalesced through a whole-chunk :class:`ReadPlan`; a
        chunk never written before patches onto zeros, the Zarr fill-value
        convention.

        Visibility (FDB rule 3): when RMW is needed and this client has
        unflushed archives, the FDB is flushed *before* fetching, so its own
        earlier unflushed chunks are seen rather than lost (no barrier is
        paid when the client is clean); ``flush=True`` commits the new chunk
        versions before returning.  With lossy codecs (``field8``/``field16``) RMW
        re-quantises the whole chunk, so untouched elements of partially
        covered chunks may shift within the quantisation bound.
        """
        return self.write_plan(key, values).execute(flush=flush)

    def __setitem__(self, key, values) -> None:
        self.write_at(key, values, flush=True)

    # -- read path -------------------------------------------------------------
    def read_plan(self, key, fill_missing: bool = True) -> "ReadPlan":
        """Plan a read without moving data: resolves every intersecting
        chunk to its backend handle and groups coalescible ones.  Use
        :meth:`ReadPlan.read_ops` to see the I/O-op count before (or
        without) executing.  The selection may be strided (``arr[::4]``),
        including *negative* steps (``arr[::-1]``, ``arr[50:10:-4]``):
        reversed slices normalise to their positive-step mirror — the I/O
        plan visits chunks in ascending order exactly as if the selection
        were forward — and the assembled output is flipped client-side.
        Only chunks holding a selected point are resolved at all.

        ``fill_missing=True`` (default) reads never-written chunks as zeros
        — the Zarr fill-value convention that makes sparsely-populated
        arrays (create + partial writes) readable.  The flip side: on a
        fully ``save()``\\ d array a missing chunk means lost or
        not-yet-flushed data, and zeros would mask that — pass
        ``fill_missing=False`` to get a ``KeyError`` at plan time instead
        (consumers that require every chunk present, e.g. checkpoint
        restores of dense tensors).
        """
        sel, squeeze, flips = self.grid.normalize_read_key(key)
        return ReadPlan(self, sel, squeeze, fill_missing=fill_missing,
                        flips=flips)

    def __getitem__(self, key) -> np.ndarray:
        return self.read_plan(key).execute()

    def read(self, fill_missing: bool = True) -> np.ndarray:
        """Read the whole array.  ``fill_missing=False`` raises ``KeyError``
        on never-written chunks instead of zero-filling — for consumers of
        dense arrays where a missing chunk means lost data."""
        key = (slice(None),) * self.grid.ndim
        return self.read_plan(key, fill_missing=fill_missing).execute()

    # -- reshard path ----------------------------------------------------------
    def reshard_plan(self, new_chunks, codec: Optional[str] = None,
                     sel=None, window: Optional[int] = None,
                     fill_missing: bool = True) -> "ReshardPlan":
        """Plan a re-layout of this array onto a new chunk grid (and
        optionally a new codec, or a strided sub-selection of the source)
        without moving data — see :class:`~.reshard.ReshardPlan`.  Use
        :meth:`~.reshard.ReshardPlan.read_ops` /
        :meth:`~.reshard.ReshardPlan.write_ops` to see the coalesced I/O-op
        counts before (or without) executing.

        Resharding is a whole-array re-layout — a *single-writer*
        administrative operation, not a leased range write — so it is not
        available through a writer session."""
        if self.store.session is not None:
            raise NotImplementedError(
                "reshard is a single-writer re-layout of the whole array "
                "slot and is not supported inside a writer session; run it "
                "on a session-less TensorStore")
        from .reshard import ReshardPlan
        return ReshardPlan(self, new_chunks, codec=codec, sel=sel,
                           window=window, fill_missing=fill_missing)

    def reshard(self, new_chunks, codec: Optional[str] = None, sel=None,
                window: Optional[int] = None, fill_missing: bool = True,
                flush: bool = True) -> "ChunkedArray":
        """Rewrite this array onto a new chunk grid — streaming, never
        materialising the whole array client-side.

        Each bounded batch of destination chunks is read from the source
        grid through one coalesced :class:`ReadPlan` and archived through
        one coalesced :class:`WritePlan`; the new grid's chunks live under
        a fresh layout generation, and a final transactional metadata
        replace (plus the ``flush=True`` commit barrier) flips readers onto
        the new grid.  Old-generation chunks are retained versioned —
        unreachable, reclaimed only by wiping the array's dataset.

        ``sel`` (optional, slices only) reshards a sub-selection — possibly
        strided, e.g. every other level — so a consumer grid can subsample
        the producer's; the array's shape becomes the selection's shape.
        ``codec`` re-encodes (e.g. raw → field16) on the way through.
        Returns this array, mutated onto the new layout.
        """
        self.reshard_plan(new_chunks, codec=codec, sel=sel, window=window,
                          fill_missing=fill_missing).execute(flush=flush)
        return self


class WritePlan:
    """Materialised write-side I/O plan for one selection of a
    :class:`ChunkedArray` — the mirror of :class:`ReadPlan`.

    Construction resolves the destination storage unit of every chunk the
    selection touches (:meth:`repro.core.FDB.archive_placement` — placement
    only, no data I/O; chunks of one array share their collocation, so one
    resolve covers the plan) and splits the plan into *stages* of at most
    one executor window (:attr:`window` chunks, from the executor's
    ``max_in_flight``).  Within a stage, chunks landing in the same unit —
    posix chunks appending into one writer's data file — archive as ONE
    batched store-level write (``FDB.archive_batch`` → a single buffered
    append), while object-store chunks keep one independent archive op in
    flight each — the two sides of the paper's object-store/POSIX
    trade-off, now symmetric with reads.  :meth:`write_ops` reports the
    store-level write count :meth:`execute` will issue.

    Staging bounds memory: a stage encodes its tiles (through the codec's
    batched single-kernel-launch path, :meth:`~.codec.Codec.encode_batch`;
    ragged edge chunks fall back per-chunk, byte-identical either way),
    archives them, and releases them before the next stage starts — so peak
    staged bytes are ~one executor window of encoded chunks regardless of
    plan size.  The trade-off: a posix plan larger than the window issues
    one batched write *per stage* instead of one total, still far below
    one-per-chunk.  Partially covered chunks fetch-and-patch first, with
    the stage's fetches coalesced through :meth:`ReadPlan.for_chunks` —
    adjacent posix RMW reads merge into single ranged reads exactly like
    normal reads.
    """

    def __init__(self, array: "ChunkedArray", sel, values: np.ndarray):
        self.array = array
        self.values = values
        store = array.store
        #: this client's tracer (repro.obs) — plan lifecycle spans
        self.tracer = store.fdb.tracer
        #: the bound writer session (multi-writer mode) or None
        self.session: Optional[WriterSession] = store.session
        with self.tracer.span("plan.resolve", kind="write") as sp:
            self._resolve_plan(sel, store)
            if sp is not None:
                sp.attrs["chunks"] = len(self.tasks)

    def _resolve_plan(self, sel, store: TensorStore) -> None:
        """Placement + staging + lease acquisition — the no-data-I/O half
        of the plan, wrapped in the ``plan.resolve`` span."""
        array = self.array
        #: (chunk_idx, within_chunk_slices, value_slices, fully_covered)
        self.tasks = list(array.grid.write_plan(sel))
        #: the client's decoded-chunk cache: every archived chunk is
        #: invalidated (and pended until the flush barrier), so a reader
        #: of this client can never be served bytes this write superseded
        self._cache = store.fdb.chunk_cache
        if self._cache is not None:
            self._cache_scope = ChunkCache.scope(store.base)
            self._cache_gen = array.meta.generation
        #: staging window: most chunks encoded/held at once (executor's
        #: in-flight bound, resolved at plan time)
        self.window = max(1, store.executor.max_in_flight)
        if self.tasks:
            # the chunk dim is an element dim, so every chunk of one array
            # shares (dataset, collocation) — one placement resolve covers
            # the whole plan
            placement = store.fdb.archive_placement(
                array.chunk_ident(self.tasks[0][0]))
            self._mergeable = placement.mergeable_with(placement)
        else:
            self._mergeable = False
        #: consecutive position runs staged (encoded + archived) together
        self.stages: List[List[int]] = [
            list(range(lo, min(lo + self.window, len(self.tasks))))
            for lo in range(0, len(self.tasks), self.window)]
        #: leases covering the touched chunks: (lo, hi, epoch, created) per
        #: disjoint linear chunk-id range — acquired HERE, at plan time, so
        #: overlapping writers fail fast (LeaseConflictError) before any
        #: byte moves; ``created`` marks ranges this plan acquired (vs
        #: ranges the session already held, which it must not release)
        self.leases: List[Tuple[int, int, int, bool]] = []
        if self.session is not None and self.tasks:
            grid = array.grid
            self._lease_ident = array.chunk_ident(self.tasks[0][0])
            #: lease resource = the live layout generation's chunk-id space
            #: (a reshard opens a fresh space, so leases die with layouts)
            self._lease_resource = f"g{array.meta.generation}"
            #: protocol-checker correlation attrs (docs/analysis.md): the
            #: canonical lease scope + the owning writer id, stamped on
            #: this plan's io.archive / rmw.fetch spans
            self._lease_scope = store.fdb.lease_scope(self._lease_ident)
            acquired: List[Tuple[int, int, int, bool]] = []
            try:
                for lo, hi in merge_id_ranges(
                        grid.linear_id(t[0]) for t in self.tasks):
                    created = not self.session.holds(
                        self._lease_ident, self._lease_resource, lo, hi)
                    epoch = self.session.acquire_lease(
                        self._lease_ident, self._lease_resource, lo, hi)
                    acquired.append((lo, hi, epoch, created))
            except BaseException:
                # roll back this plan's own acquisitions on a conflict
                # mid-way, so a failed plan holds nothing
                for lo, hi, _epoch, created in acquired:
                    if created:
                        self.session.release_lease(
                            self._lease_ident, self._lease_resource, lo, hi)
                raise
            self.leases = acquired

    def check_leases(self) -> None:
        """Epoch-fencing gate (session-bound plans only): raise
        :class:`~repro.core.StaleLeaseError` unless every lease backing
        this plan is still current.  :meth:`execute` runs it before the RMW
        fetches and before each stage's archives, so a writer whose lease
        was broken and re-acquired aborts instead of committing."""
        if self.session is not None:
            for lo, hi, epoch, _created in self.leases:
                self.session.check_lease(self._lease_ident,
                                         self._lease_resource, lo, hi, epoch)

    def release_leases(self) -> None:
        """Release the leases this plan acquired (ranges the session
        already held stay held).  Called by :meth:`execute` after its
        commit barrier; call it directly to abandon a planned-but-never-
        executed write."""
        if self.session is not None:
            kept = []
            for lo, hi, epoch, created in self.leases:
                if created:
                    self.session.release_lease(
                        self._lease_ident, self._lease_resource, lo, hi)
                else:
                    kept.append((lo, hi, epoch, created))
            self.leases = kept

    def _protocol_attrs(self) -> dict:
        """Correlation attrs for the protocol checker (docs/analysis.md):
        which writer archived under which lease scope/resource on which
        client.  Empty on the single-writer (sessionless) path — there is
        no lease contract to check."""
        if self.session is None:
            return {}
        return {"owner": self.session.writer_id,
                "scope": self._lease_scope,
                "resource": self._lease_resource,
                "client": self.session.fdb.client_id}

    def _stage_groups(self, stage: List[int]) -> List[List[int]]:
        """Positions-into-tasks per batched store write within one stage."""
        if self._mergeable:
            return [list(stage)]
        return [[pos] for pos in stage]

    @property
    def groups(self) -> List[List[int]]:
        """Positions-into-tasks per batched store write, across stages."""
        return [g for stage in self.stages for g in self._stage_groups(stage)]

    @property
    def n_chunks(self) -> int:
        return len(self.tasks)

    @property
    def rmw_chunks(self) -> int:
        """Chunks only partially covered by the selection — they fetch and
        patch (read-modify-write) before re-encoding."""
        return sum(1 for _i, _c, _v, full in self.tasks if not full)

    def write_ops(self) -> int:
        """Store-level write operations :meth:`execute` will issue (after
        coalescing, one batch per storage unit per stage) — the twin of
        :meth:`ReadPlan.read_ops`."""
        return sum(len(self._stage_groups(stage)) for stage in self.stages)

    def execute(self, flush: bool = True,
                deadline: Optional[float] = None) -> List[FieldLocation]:
        """Stage by stage: fetch-and-patch (coalesced), encode (batched),
        archive (one submission per group), release — and, with
        ``flush=True``, commit (FDB visibility rule 3) and release this
        plan's leases.  Returns per-chunk :class:`FieldLocation`\\ s in
        plan order.

        Session-bound plans run the epoch-fencing gate before the RMW
        fetches and before every stage's archives; with ``flush=False`` the
        leases stay held (the chunks are archived but not yet visible — the
        session's later flush/close is the commit barrier, and releasing
        earlier would let the next holder RMW not-yet-visible bytes).

        ``deadline`` (seconds) is the *plan's* retry budget: it rides the
        ambient :func:`repro.core.deadline_scope` through the executor
        hand-off, so every facade-level retry under this plan gives up with
        :class:`repro.core.DeadlineExceeded` once the shared budget runs
        out rather than each op backing off independently.
        """
        if not self.tasks:
            return []
        with self.tracer.span("plan.execute", kind="write",
                              chunks=self.n_chunks, stages=len(self.stages),
                              rmw=self.rmw_chunks):
            with deadline_scope(deadline):
                return self._execute(flush)

    def _execute(self, flush: bool) -> List[FieldLocation]:
        arr, values = self.array, self.values
        store, codec = arr.store, arr._codec
        fdb = store.fdb
        metrics = self.tracer.metrics
        # archives/barriers route per session when one is bound — its
        # dirty bit decides the RMW pre-flush (sound because the RMW
        # chunks are covered by OUR lease: no other session's unflushed
        # archives can be hiding under them).  Deliberately the session
        # captured at PLAN time, not store.client: the leases recorded on
        # this plan belong to that session
        client = self.session or fdb
        if self.rmw_chunks and client.dirty:
            client.flush()      # make own unflushed chunks RMW-visible
        locs: List[Optional[FieldLocation]] = [None] * len(self.tasks)
        for si, stage in enumerate(self.stages):
            with self.tracer.span("plan.stage", stage=si,
                                  chunks=len(stage)):
                self._run_stage(stage, locs, client, codec, values, metrics)
        if flush:
            client.flush()
            self.release_leases()
        return locs             # type: ignore[return-value]

    def _run_stage(self, stage: List[int], locs, client, codec,
                   values: np.ndarray, metrics) -> None:
        arr, store = self.array, self.array.store
        tiles: List[Optional[np.ndarray]] = [None] * len(stage)
        rmw = [(k, pos) for k, pos in enumerate(stage)
               if not self.tasks[pos][3]]
        if rmw:             # coalesced whole-chunk fetches, then patch
            # lease-protected fetch: fence before reading bytes we are
            # about to patch — a broken lease means another writer may
            # own (and be mid-write on) these chunks
            self.check_leases()
            metrics.counter("rmw.fetched_chunks").inc(len(rmw))
            with self.tracer.span("rmw.fetch", chunks=len(rmw),
                                  **self._protocol_attrs()):
                fetch = ReadPlan.for_chunks(
                    arr, [self.tasks[pos][0] for _k, pos in rmw])
                for (k, pos), tile in zip(rmw, fetch.read_chunks()):
                    _idx, chunk_sel, val_sel, _full = self.tasks[pos]
                    tile[chunk_sel] = values[val_sel]
                    tiles[k] = tile
        for k, pos in enumerate(stage):
            _idx, _chunk_sel, val_sel, full = self.tasks[pos]
            if full:
                tiles[k] = values[val_sel]
        with self.tracer.span("codec.encode", chunks=len(stage),
                              codec=codec.name) as sp:
            blobs = codec.encode_batch(tiles)
            nbytes = sum(len(b) for b in blobs)
            if sp is not None:
                sp.attrs["nbytes"] = nbytes
        metrics.counter("codec.bytes_encoded").inc(nbytes)
        idents = [arr.chunk_ident(self.tasks[pos][0]) for pos in stage]
        #: linear chunk ids per stage position — io.archive spans carry
        #: them so the checker can test lease coverage per archived chunk
        lin = ([arr.grid.linear_id(self.tasks[pos][0]) for pos in stage]
               if self.session is not None else None)

        def put(ks: List[int]) -> List[FieldLocation]:
            # one store-level submission per group: a posix group lands
            # as a single buffered append; object groups are singletons
            with self.tracer.span("io.archive", chunks=len(ks),
                                  backend=store.fdb.config.backend,
                                  **self._protocol_attrs()) as sp:
                batch_locs = client.archive_batch(
                    [(idents[k], blobs[k]) for k in ks])
                if sp is not None:
                    sp.attrs["nbytes"] = sum(len(blobs[k]) for k in ks)
                    if lin is not None:
                        sp.attrs["chunk_ids"] = [lin[k] for k in ks]
            if lin is not None:
                # crash-recovery breadcrumb: these chunks are archived but
                # not yet flushed — journal them deployment-wide so
                # fdb.recover() can quarantine them if this writer dies
                # before its commit barrier (flush clears the journal)
                self.session.mark_dirty_chunks(
                    self._lease_ident, self._lease_resource,
                    [lin[k] for k in ks])
            if self._cache is not None:
                # archived ≠ visible (rule 3): drop the superseded entry
                # and pend the key until this client's flush publishes it
                for k in ks:
                    self._cache.invalidate(
                        (self._cache_scope, self._cache_gen,
                         tuple(self.tasks[stage[k]][0])))
            return batch_locs

        # the fencing gate runs per stage, right before its archives: a
        # stale writer loses at most one in-flight stage to the race
        # window between check and archive, and can never pass another
        # barrier after its lease was re-acquired
        self.check_leases()
        # the one grouping decision lives in _stage_groups — write_ops()
        # accounting and execution must never diverge (check.sh asserts
        # on the plan's claim); stages are contiguous position runs, so
        # stage-local index = position - stage[0]
        kgroups = [[pos - stage[0] for pos in group]
                   for group in self._stage_groups(stage)]
        batches = store.executor.map_ordered(
            put, kgroups,
            describe=lambda ks: (
                f"op=io.archive backend={store.fdb.config.backend} "
                f"chunk_ids="
                f"{[lin[k] for k in ks] if lin is not None else [stage[k] for k in ks]}"))
        for ks, batch_locs in zip(kgroups, batches):
            for k, loc in zip(ks, batch_locs):
                locs[stage[k]] = loc


class ReadPlan:
    """Materialised I/O plan for one selection of a :class:`ChunkedArray`.

    Chunk identifiers are resolved to backend :class:`DataHandle`\\ s up
    front (catalogue lookups only — no payload I/O), then grouped with
    :func:`repro.core.group_mergeable`: handles over the same storage unit
    (posix chunks living in one writer's data file) merge, so adjacent
    chunks coalesce into single ranged reads — the POSIX backend's key read
    optimisation — while object-store chunks stay one independent op each,
    which is what those backends want kept in flight.  Executing scatters
    decoded chunks into the output array, one executor task per group.

    Two consumption modes share the resolved batches: :meth:`execute`
    assembles the selection into one output array (strided selections
    scatter through their strided within-chunk slices), while
    :meth:`read_chunks` — on plans built by :meth:`for_chunks` — returns
    whole decoded chunks, the write path's coalesced RMW fetch.
    """

    def __init__(self, array: "ChunkedArray", sel, squeeze,
                 fill_missing: bool = True,
                 flips: Sequence[int] = ()):
        self.array = array
        self.sel = sel
        self.squeeze = squeeze
        self.tracer = array.store.fdb.tracer
        #: axes to reverse client-side after assembly — how negative-step
        #: selections are served from a positive-step (ascending) I/O plan
        self.flips = tuple(flips)
        self.tasks = list(array.grid.intersecting(sel))
        self._bind_cache(array.store.fdb.chunk_cache)
        with self.tracer.span("plan.resolve", kind="read",
                              chunks=len(self.tasks)):
            self._resolve(fill_missing)

    @classmethod
    def for_chunks(cls, array: "ChunkedArray", indices: Sequence[Index],
                   fill_missing: bool = True) -> "ReadPlan":
        """Plan whole-chunk fetches for an explicit chunk-index list — the
        write path's RMW hook (:meth:`read_chunks` consumes it): the listed
        chunks resolve and coalesce exactly like a selection's, so adjacent
        posix RMW fetches merge into single ranged reads."""
        plan = cls.__new__(cls)
        plan.array = array
        plan.sel = None
        plan.squeeze = ()
        plan.flips = ()
        plan.tracer = array.store.fdb.tracer
        plan.tasks = [
            (tuple(idx),
             tuple(slice(0, n, 1) for n in array.grid.chunk_shape(idx)),
             None)
            for idx in indices]
        # RMW fetches bypass the chunk cache entirely (no lookup, no
        # populate): the fetched bytes are about to be patched and
        # re-archived, so caching them would pin a doomed version
        plan._bind_cache(None)
        with plan.tracer.span("plan.resolve", kind="chunks",
                              chunks=len(plan.tasks)):
            plan._resolve(fill_missing)
        return plan

    def _bind_cache(self, cache: Optional[ChunkCache]) -> None:
        """Attach the client's decoded-chunk cache (or None).  Hits are
        collected during :meth:`_resolve` — cached chunks never resolve a
        handle, so they are invisible to :meth:`read_ops` and issue no
        backend ops at all."""
        self._cache = cache
        #: position → decoded chunk served from the cache
        self._cached: dict = {}
        #: position → cache version token for a post-fetch populate
        self._tokens: dict = {}
        if cache is not None:
            self._cache_scope = ChunkCache.scope(self.array.store.base)
            self._cache_gen = self.array.meta.generation

    @property
    def cache_hits(self) -> int:
        """Chunks of this plan served from the decoded-chunk cache."""
        return len(self._cached)

    def _consult_cache(self) -> None:
        if self._cache is None or not self.tasks:
            return
        with self.tracer.span("cache.lookup", chunks=len(self.tasks)) as sp:
            for pos, task in enumerate(self.tasks):
                key = (self._cache_scope, self._cache_gen, tuple(task[0]))
                chunk, token = self._cache.lookup(key)
                if chunk is not None:
                    self._cached[pos] = chunk
                else:
                    self._tokens[pos] = token
            if sp is not None:
                sp.attrs["hits"] = len(self._cached)
                sp.attrs["misses"] = len(self._tokens)

    def _populate_cache(self, pos: int, chunk: np.ndarray) -> None:
        """Offer a freshly decoded chunk to the cache (no-op when the key
        was invalidated or pended since :meth:`_consult_cache` issued the
        token — a concurrent overwrite wins)."""
        token = self._tokens.get(pos) if self._cache is not None else None
        if token is not None:
            self._cache.put(
                (self._cache_scope, self._cache_gen,
                 tuple(self.tasks[pos][0])), chunk, token)

    def _resolve(self, fill_missing: bool) -> None:
        """Resolve every task's chunk to its backend handle and group
        coalescible handles into I/O batches (no data I/O)."""
        store = self.array.store
        # cache consult FIRST: a hit never resolves a handle, so cached
        # chunks are invisible to read_ops() and reach no backend at all
        self._consult_cache()
        present: List[int] = []
        handles = []
        #: positions of chunks never written — they read as zeros (the same
        #: fill-value convention the write path patches onto), no I/O
        self.missing: List[int] = []
        for pos, (idx, _chunk_sel, _out_sel) in enumerate(self.tasks):
            if pos in self._cached:
                continue
            h = store.fdb.retrieve_handle(self.array.chunk_ident(idx))
            if h is None or h.length() == 0:
                if not fill_missing:
                    raise KeyError(
                        f"missing chunk {idx} of array at {store.base}")
                self.missing.append(pos)
            else:
                present.append(pos)
                handles.append(h)
        #: (positions-into-tasks, merged handle) per I/O batch
        self.batches: List[Tuple[List[int], MultiHandle]] = [
            ([present[i] for i in group],
             MultiHandle([handles[i] for i in group]))
            for group in group_mergeable(handles)]

    @property
    def n_chunks(self) -> int:
        return len(self.tasks)

    def read_ops(self) -> int:
        """I/O operations :meth:`execute` will issue (after coalescing)."""
        return sum(mh.read_ops() for _g, mh in self.batches)

    def read_chunks(self) -> List[np.ndarray]:
        """Decode every planned chunk *whole*, in task order — always
        writable, missing chunks as zeros (fill-value convention).  One
        coalesced read + one batched decode per I/O batch, through the
        bounded executor — the write path's RMW fetch."""
        arr = self.array
        grid, codec = arr.grid, arr._codec
        out: List[Optional[np.ndarray]] = [None] * len(self.tasks)
        for pos in self.missing:
            out[pos] = np.zeros(grid.chunk_shape(self.tasks[pos][0]),
                                arr.dtype)
        for pos, cached in self._cached.items():
            out[pos] = cached.copy()    # cached entries are read-only

        def run_batch(positions: List[int], mh: MultiHandle) -> None:
            shapes = [grid.chunk_shape(self.tasks[pos][0])
                      for pos in positions]
            parts = self._fetch(mh, len(positions))
            with self.tracer.span("codec.decode", chunks=len(positions),
                                  codec=codec.name):
                chunks = codec.decode_batch(parts, shapes, arr.dtype)
            for pos, chunk in zip(positions, chunks):
                self._populate_cache(pos, chunk)
                out[pos] = chunk if chunk.flags.writeable else chunk.copy()

        arr.store.executor.map_ordered(
            lambda b: run_batch(*b), self.batches,
            describe=lambda b: (
                f"op=io.fetch backend={arr.store.fdb.config.backend} "
                f"chunks={[self.tasks[pos][0] for pos in b[0]]}"))
        return out              # type: ignore[return-value]

    def _fetch(self, mh: MultiHandle, n_chunks: int) -> List[bytes]:
        """One coalesced backend read, wrapped in the ``io.fetch`` span
        (the ``t_io`` phase) and counted into ``codec.bytes_decoded`` —
        shared by both consumption modes, and running on an executor worker
        thread with the caller's span context propagated."""
        backend = self.array.store.fdb.config.backend
        with self.tracer.span("io.fetch", ops=mh.read_ops(),
                              chunks=n_chunks, backend=backend) as sp:
            parts = mh.read_parts()
            nbytes = sum(len(p) for p in parts)
            if sp is not None:
                sp.attrs["nbytes"] = nbytes
        self.tracer.metrics.counter("codec.bytes_decoded").inc(nbytes)
        return parts

    def execute(self, deadline: Optional[float] = None) -> np.ndarray:
        """Assemble the selection.  ``deadline`` (seconds) bounds the
        plan's facade-level retries via the ambient
        :func:`repro.core.deadline_scope`, like the write side."""
        if self.sel is None:
            raise TypeError("whole-chunk plan (for_chunks) has no selection "
                            "to assemble; use read_chunks()")
        arr = self.array
        grid, codec = arr.grid, arr._codec
        with self.tracer.span("plan.execute", kind="read",
                              chunks=self.n_chunks,
                              batches=len(self.batches)), \
                deadline_scope(deadline):
            out = np.empty(grid.selection_shape(self.sel), arr.dtype)
            for pos in self.missing:
                out[self.tasks[pos][2]] = 0
            for pos, cached in self._cached.items():
                _idx, chunk_sel, out_sel = self.tasks[pos]
                out[out_sel] = cached[chunk_sel]

            def run_batch(positions: List[int], mh: MultiHandle) -> None:
                # one coalesced read per batch, one batched decode
                # (equal-shape chunks share a kernel launch); per-chunk
                # payloads scatter into disjoint output regions →
                # concurrent assembly is safe
                shapes = [grid.chunk_shape(self.tasks[pos][0])
                          for pos in positions]
                parts = self._fetch(mh, len(positions))
                with self.tracer.span("codec.decode",
                                      chunks=len(positions),
                                      codec=codec.name):
                    chunks = codec.decode_batch(parts, shapes, arr.dtype)
                for pos, chunk in zip(positions, chunks):
                    self._populate_cache(pos, chunk)
                    _idx, chunk_sel, out_sel = self.tasks[pos]
                    out[out_sel] = chunk[chunk_sel]

            arr.store.executor.map_ordered(
                lambda b: run_batch(*b), self.batches,
                describe=lambda b: (
                    f"op=io.fetch backend={arr.store.fdb.config.backend} "
                    f"chunks={[self.tasks[pos][0] for pos in b[0]]}"))
        if self.flips:          # negative-step axes: one client-side flip
            out = out[tuple(slice(None, None, -1) if a in self.flips
                            else slice(None) for a in range(out.ndim))]
        if self.squeeze:
            out = out.reshape(tuple(
                s for a, s in enumerate(out.shape) if a not in self.squeeze))
        return out

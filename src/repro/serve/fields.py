"""Batched field serving: many readers' window requests over ONE shared
FDB client, decoded-chunk cache and consolidated-metadata open.

The paper's product-generation (PGEN) pattern is a fan-out of small
window reads against fields one producer archived — regional extractions,
per-level slices, time series probes.  Naively each reader opens its own
client (N metadata round-trips, zero cross-reader reuse).  This engine is
the serving-side fix, composing the read-path machinery of PR 10:

* **one** :class:`~repro.data.pipeline.ChunkedFieldStore` is shared by
  every request — one FDB client, one bounded executor, one decoded-chunk
  :class:`~repro.tensorstore.ChunkCache` (``repro.tensorstore.cache``), so
  overlapping windows decode a chunk once and serve the rest from memory;
* the cold open uses :meth:`~repro.data.pipeline.ChunkedFieldStore.open_tree`
  — the consolidated-metadata fetch: every requested field opens from a
  single catalogue object instead of one ``meta`` round-trip per field;
* requests are drained in **waves** (the continuous-batching idiom of
  :class:`~.engine.ServeEngine`, minus the GPU): each wave groups queued
  requests by field so one open serves the group, under a
  ``serve.field_wave`` span.

The module is deliberately jax-free — field serving is pure storage I/O —
so benchmarks and workflow drivers can import it without pulling the
model stack (``from repro.serve.fields import FieldServeEngine``).
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.data.pipeline import ChunkedFieldStore
from repro.obs.trace import GLOBAL_TRACER, Tracer


@dataclasses.dataclass
class FieldRequest:
    """One reader's window request: a field name plus a selection tuple
    (anything ``ChunkedArray.read_plan`` accepts — slices, ints, strided
    and negative-step slices)."""
    rid: int
    field: str
    selection: Tuple = ()
    #: raise instead of zero-filling when the window hits unwritten chunks
    fill_missing: bool = True
    result: Optional[np.ndarray] = None
    error: Optional[str] = None
    done: bool = False


class FieldServeEngine:
    """Wave-batched window serving over one shared field-store client.

    >>> engine = FieldServeEngine(store)          # a ChunkedFieldStore
    >>> engine.submit(FieldRequest(0, "t2m", (slice(0, 120),)))
    >>> engine.submit(FieldRequest(1, "t2m", (slice(60, 180),)))
    >>> done = engine.run()                       # one wave, chunks shared

    ``run`` drains the queue in waves of at most ``wave_slots`` requests.
    Within a wave, requests group by field: the group's array opens once
    (served from the consolidated-metadata mirror after the first wave's
    single ``open_tree`` fetch) and each window executes its own coalesced
    read plan against the shared decoded-chunk cache — a window another
    reader already pulled through the cache costs zero backend ops.
    """

    def __init__(self, store: ChunkedFieldStore, wave_slots: int = 8,
                 tracer: Optional[Tracer] = None):
        self.store = store
        self.wave_slots = max(1, int(wave_slots))
        self.tracer = tracer or store.fdb.tracer or GLOBAL_TRACER
        self.queue: "queue.Queue[FieldRequest]" = queue.Queue()
        self._opened = False
        self.stats = {"waves": 0, "requests": 0, "errors": 0,
                      "fields": 0, "open_us": 0}

    def submit(self, req: FieldRequest) -> None:
        self.queue.put(req)

    def _cold_open(self) -> None:
        """First wave: consolidated open — one catalogue fetch primes the
        open cache for every field the tree knows."""
        if self._opened:
            return
        t0 = time.perf_counter_ns()
        known = self.store.open_tree()
        self.stats["fields"] = len(known)
        self.stats["open_us"] = (time.perf_counter_ns() - t0) // 1000
        self._opened = True

    def _serve_one(self, req: FieldRequest) -> None:
        try:
            arr = self.store.open_field(req.field)
            req.result = arr.read_plan(
                tuple(req.selection),
                fill_missing=req.fill_missing).execute()
        except (KeyError, TypeError, IndexError,
                NotImplementedError) as e:
            req.error = f"{type(e).__name__}: {e}"
            self.stats["errors"] += 1
        req.done = True

    def run(self) -> List[FieldRequest]:
        """Drain the queue; returns completed requests in service order."""
        retired: List[FieldRequest] = []
        while not self.queue.empty():
            wave: List[FieldRequest] = []
            while len(wave) < self.wave_slots and not self.queue.empty():
                wave.append(self.queue.get())
            if not wave:
                break
            by_field: Dict[str, List[FieldRequest]] = {}
            for req in wave:
                by_field.setdefault(req.field, []).append(req)
            with self.tracer.span("serve.field_wave", requests=len(wave),
                                  fields=len(by_field)):
                self._cold_open()
                # field-grouped order: one open per field serves its
                # group, and same-field windows hit the chunks the
                # group's first request just cached
                for field in sorted(by_field):
                    for req in by_field[field]:
                        self._serve_one(req)
                        retired.append(req)
            self.stats["waves"] += 1
            self.stats["requests"] += len(wave)
        return retired

    def cache_stats(self) -> Dict[str, float]:
        """Decoded-chunk cache effectiveness over everything served so
        far, read off the shared client's metrics registry."""
        m = self.store.fdb.metrics()
        hits = m.get("cache.hits", {}).get("value", 0)
        misses = m.get("cache.misses", {}).get("value", 0)
        total = hits + misses
        return {"hits": hits, "misses": misses,
                "hit_rate": (hits / total) if total else 0.0}


__all__ = ["FieldRequest", "FieldServeEngine"]

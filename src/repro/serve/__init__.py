from .engine import ServeEngine, Request
from .fields import FieldRequest, FieldServeEngine

__all__ = ["ServeEngine", "Request", "FieldRequest", "FieldServeEngine"]

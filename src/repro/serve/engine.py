"""Batched serving engine: slot-based continuous batching over the decode
step, with FDB-checkpoint weight loading.

Requests are assembled into a fixed-slot batch; prefill fills each slot's
cache region; the decode loop advances all active slots one token per step,
retiring finished sequences and admitting queued requests into freed slots
(continuous batching).  The cache is a single (B, max_len, ...) pytree so
the jitted decode step never re-specialises.
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ArchConfig
from repro.obs.trace import GLOBAL_TRACER, Tracer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 256, dtype=jnp.float32,
                 greedy: bool = True, tracer: Optional[Tracer] = None):
        self.cfg = cfg
        self.params = params
        self.tracer = tracer or GLOBAL_TRACER
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self.cache = lm.init_cache(cfg, batch_slots, max_len, dtype)
        self._decode = jax.jit(
            lambda p, t, c, pos: lm.decode_step(cfg, p, t, c, pos))
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.active: Dict[int, Optional[Request]] = {
            i: None for i in range(batch_slots)}
        self.pos = 0
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "retired": 0}

    def submit(self, req: Request) -> None:
        self.queue.put(req)

    def _admit(self) -> None:
        for slot, occupant in self.active.items():
            if occupant is None and not self.queue.empty():
                self.active[slot] = self.queue.get()

    def run(self, max_steps: int = 512) -> List[Request]:
        """Drain the queue; returns retired requests."""
        retired: List[Request] = []
        self._admit()
        # Serve batches in lockstep waves: prompts are left-aligned per wave.
        while any(r is not None for r in self.active.values()) \
                or not self.queue.empty():
            wave = [r for r in self.active.values() if r is not None]
            plen = max(len(r.prompt) for r in wave)
            with self.tracer.span("serve.wave", requests=len(wave),
                                  prompt_len=plen):
                tokens = np.zeros((self.slots, plen), np.int32)
                for i, (slot, r) in enumerate(self.active.items()):
                    if r is not None:
                        tokens[slot, plen - len(r.prompt):] = r.prompt
                # prefill = sequential decode over prompt tokens (correct for
                # every family incl. recurrent; simple for the example driver)
                self.cache = lm.init_cache(self.cfg, self.slots, self.max_len,
                                           jnp.float32)
                logits = None
                with self.tracer.span("serve.prefill",
                                      tokens=plen * len(wave)):
                    for t in range(plen):
                        logits, self.cache = self._decode(
                            self.params, jnp.asarray(tokens[:, t:t + 1]),
                            self.cache, jnp.asarray(t, jnp.int32))
                self.stats["prefill_tokens"] += plen * len(wave)
                # decode loop
                max_new = max(r.max_new_tokens for r in wave)
                cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                with self.tracer.span("serve.decode", max_new=max_new) as sp:
                    steps = 0
                    for step in range(min(max_new, max_steps)):
                        for slot, r in self.active.items():
                            if r is not None \
                                    and len(r.out_tokens) < r.max_new_tokens:
                                r.out_tokens.append(int(cur[slot]))
                        logits, self.cache = self._decode(
                            self.params, cur[:, None], self.cache,
                            jnp.asarray(plen + step, jnp.int32))
                        cur = jnp.argmax(logits[:, -1],
                                         axis=-1).astype(jnp.int32)
                        self.stats["decode_steps"] += 1
                        steps += 1
                    if sp is not None:
                        sp.attrs["steps"] = steps
                for slot, r in list(self.active.items()):
                    if r is not None:
                        r.done = True
                        retired.append(r)
                        self.stats["retired"] += 1
                        self.active[slot] = None
            self._admit()
        return retired

"""FDB-backed distributed checkpointing — the paper's technique as the
framework's storage substrate (DESIGN.md §2).

Mapping of training state onto the FDB schema (``ckpt`` schema):

  dataset key    = {run, kind, step}       → one container/dir per step:
                                              wiping a step = container destroy
  collocation key= {host}                  → contention-free index per writer
                                              host (the paper's C7 lever)
  element key    = {tensor, shard}         → one FDB object per tensor shard

Semantics used:
  * ``archive()`` each shard (optionally field-codec compressed),
  * ``flush()``  = the checkpoint *commit barrier* (visibility rule 3),
  * restore      = ``list()`` + merged ``retrieve()`` + reassembly,
  * write+read contention (training writes step N while an evaluator reads
    step N-k) is exactly the paper's NWP producer/PGEN pattern and is safe
    under every backend's consistency model.

Async mode archives from a background thread (the paper's I/O-server
pattern: compute and storage I/O overlap); ``wait()`` joins before the next
checkpoint or at exit.  ``save_sharded()`` is the *multi-writer* variant:
one :class:`~repro.core.WriterSession` per simulated rank, each leasing and
writing its own chunk band of every tensor concurrently (chunk-range
leases, ``repro.core.lease``), with a single flush as the step commit
barrier.

Storage path (``chunked=True``, the default): every tensor is a
``repro.tensorstore`` chunked array — the chunk index rides the ``shard``
element dim, and each tensor archives through a coalesced
:class:`~repro.tensorstore.WritePlan`: same-shape chunks encode in one
Pallas codec launch, chunks bound for one storage unit (posix data files)
land as a single batched store write, and independent object writes overlap
through the FDB client's bounded I/O executor.  Restore can read partial
tensors per host (``open_tensor()``) or patch them in place
(``update_tensor()``, chunk-aligned partial writes); ``compress`` selects
the ``field8`` per-chunk codec instead of a post-hoc buffer hack.
``chunked=False`` keeps the legacy one-blob-per-shard layout (its shard
blobs now batch through ``FDB.archive_many``), and restore transparently
falls back to it for checkpoints written by older runs.

Topology changes: a run restarted with a different ``n_shards`` can restore
a checkpoint saved under the old banding as-is (``restore()`` reads whole
tensors from whatever grid they carry), and ``reshard_tensor()`` /
``reshard_step()`` re-band the saved tensors onto the new topology as a
streaming reshard (bounded batches of coalesced reads + writes, old-banding
chunks retained versioned) so sharded partial reads line up again.  A
*re-save* of a step under a new banding bumps the tensor's layout
generation (``create(on_mismatch="retain")``) instead of failing — new-grid
chunks live under fresh generation-prefixed keys, never colliding with the
old grid's.
"""
from __future__ import annotations

import contextvars
import io
import queue
import socket
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import FDB, FDBConfig, Identifier
from repro.core.schema import CHECKPOINT_SCHEMA
from repro.tensorstore import ChunkedArray, TensorStore, auto_chunks


def _tensor_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    # NB: "." not "/" — "/" is the FDB multi-value expression separator
    return ".".join(parts)


def _pack(arr: np.ndarray) -> bytes:
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _unpack(raw: bytes) -> np.ndarray:
    return np.load(io.BytesIO(raw), allow_pickle=False)


class FDBCheckpointer:
    def __init__(self, run: str, fdb_config: Optional[FDBConfig] = None,
                 n_shards: int = 1, asynchronous: bool = False,
                 compress: bool = False, host: Optional[str] = None,
                 chunked: bool = True, shutdown_timeout: float = 5.0,
                 tracer=None, faults=None, retry=None, meter=None):
        cfg = fdb_config or FDBConfig(backend="daos")
        if cfg.resolved_schema().name != "ckpt":
            import dataclasses
            cfg = dataclasses.replace(cfg, schema=CHECKPOINT_SCHEMA)
        # tracer/faults/retry/meter flow to the client so workflow forecast
        # stages can trace, chaos-test and cost-model sharded checkpoints
        self.fdb = FDB(cfg, meter=meter, tracer=tracer, faults=faults,
                       retry=retry)
        self.run = run
        self.n_shards = n_shards
        self.compress = compress
        self.chunked = chunked
        self.host = host or socket.gethostname()
        self.asynchronous = asynchronous
        self.shutdown_timeout = shutdown_timeout
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._errors: List[BaseException] = []
        if asynchronous:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # -- write path -----------------------------------------------------------
    def _dataset(self, kind: str, step: int) -> Dict[str, str]:
        return {"run": self.run, "kind": kind, "step": str(step)}

    def _tensor_store(self, kind: str, step: int, name: str) -> TensorStore:
        base = {**self._dataset(kind, step), "host": self.host,
                "tensor": name}
        return TensorStore(self.fdb, base, chunk_dim="shard")

    def _compressible(self, arr: np.ndarray) -> bool:
        return arr.dtype in (np.float32, np.float16) and arr.ndim >= 2 \
            and arr.size >= 1024

    def _tensor_chunks(self, shape, dtype):
        """n_shards > 1 splits along axis 0 (one chunk row-band per shard);
        otherwise ~1 MiB auto chunks."""
        if self.n_shards > 1 and len(shape) >= 1 and shape[0] > 1:
            first = -(-shape[0] // self.n_shards)
            return (first,) + tuple(shape[1:])
        return auto_chunks(tuple(shape), dtype)

    def _archive_tree(self, kind: str, step: int, tree) -> None:
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        for path, leaf in flat:
            arr = np.asarray(leaf)
            if self.chunked:
                codec = "field8" if self.compress and self._compressible(arr) \
                    else "raw"
                ts = self._tensor_store(kind, step, _tensor_name(path))
                # on_mismatch="retain": a layout change across re-saves of
                # this step (e.g. a different n_shards) bumps the layout
                # generation — the new grid's chunks live under fresh
                # generation-prefixed keys and the metadata replace flips
                # readers over; old-grid chunks stay behind as versioned,
                # unreachable garbage, never as wrong reads
                chunked = ts.create(arr.shape, arr.dtype,
                                    chunks=self._tensor_chunks(arr.shape,
                                                               arr.dtype),
                                    codec=codec, on_mismatch="retain")
                # the step-level flush() in _do_save is the commit barrier
                chunked.write(arr, flush=False)
                continue
            # tombstone any chunked metadata from a previous save of this
            # step, so chunked-first restore falls through to these blobs
            # instead of returning stale chunked data
            self.fdb.archive(Identifier({**self._dataset(kind, step),
                                         "host": self.host,
                                         "tensor": _tensor_name(path),
                                         "shard": "meta"}), b"")
            payload = arr
            if self.compress and self._compressible(arr):
                payload = self._compress(arr)
            shards = np.array_split(payload.reshape(-1), self.n_shards) \
                if self.n_shards > 1 else [payload]
            # batched archive: shard blobs coalesce per storage unit (posix)
            # and overlap through the client's bounded executor elsewhere
            self.fdb.archive_many(
                [(Identifier({**self._dataset(kind, step),
                              "host": self.host,
                              "tensor": _tensor_name(path),
                              "shard": str(si)}),
                  _pack(np.asarray(shard)))
                 for si, shard in enumerate(shards)])

    def _compress(self, arr: np.ndarray) -> np.ndarray:
        from repro.kernels import ops
        flat = arr.reshape(-1)
        c = 128
        n = (flat.size // c) * c
        if n == 0:
            return arr
        head = flat[:n].reshape(-1, c)
        rows = head.shape[0]
        block = next(b for b in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                     if rows % b == 0)
        q, s, m = ops.field_encode(head, block=block)
        # store quantised ints + scales in one buffer (simple container)
        out = np.concatenate([
            np.asarray(q, np.int8).reshape(-1).view(np.uint8),
            np.asarray(s, np.float32).view(np.uint8).reshape(-1),
            np.asarray(m, np.float32).view(np.uint8).reshape(-1),
            flat[n:].astype(np.float32).view(np.uint8).reshape(-1),
        ]).astype(np.uint8)
        return out

    def _decompress(self, buf: np.ndarray, ref: np.ndarray) -> np.ndarray:
        from repro.kernels import ops
        size = ref.size
        c = 128
        n = (size // c) * c
        rows = n // c
        block = next(b for b in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                     if rows % b == 0) if rows else 1
        nb = rows // block if rows else 0
        q = buf[:n].view(np.int8).reshape(rows, c)
        off = n
        s = buf[off:off + 4 * nb].view(np.float32)
        off += 4 * nb
        m = buf[off:off + 4 * nb].view(np.float32)
        off += 4 * nb
        tail = buf[off:].view(np.float32)
        head = np.asarray(ops.field_decode(q, s, m, block=block))
        return np.concatenate([head.reshape(-1), tail]).astype(np.float32)

    def save_sharded(self, step: int, params, opt_state=None,
                     extra: Optional[Dict[str, Any]] = None) -> None:
        """Multi-writer checkpoint save: every simulated rank leases and
        writes its own shard band concurrently — the paper's parallel
        I/O-server archive pattern on top of writer sessions.

        Each of the ``n_shards`` ranks gets its own
        :class:`~repro.core.WriterSession`; a rank's row band of every
        tensor aligns exactly with the tensor's chunk banding
        (``_tensor_chunks``), so each rank's :class:`WritePlan` acquires
        the covering chunk-range lease (disjoint across ranks by
        construction — a misconfigured overlap fails fast with
        ``LeaseConflictError`` instead of racing), encodes and archives
        its full-cover chunks with no RMW, and all ranks' chunk I/O flows
        through the one bounded client executor.  Tensors too small to
        band (scalars, single rows) are written whole by rank 0.  One
        client ``flush()`` at the end is the step commit barrier, after
        which every rank's session closes (releasing its leases).

        Runs synchronously (unlike :meth:`save`, there is no async-queue
        variant: the ranks *are* the concurrency).  Requires the chunked
        layout.  Restore is unchanged — the result is byte-identical to a
        sequential :meth:`save` of the same state.

        Failure atomicity: if any rank fails, *nothing is flushed* — the
        step's partial archives stay invisible (rule 3) and every rank's
        leases are released, so a previous good save of the step remains
        the live one.  Retry the save (same chunk keys re-archive
        consistently) or :meth:`wipe_step` before the next barrier on this
        client publishes the leftovers.
        """
        if not self.chunked:
            raise ValueError("save_sharded requires the chunked layout "
                             "(chunked=True)")
        n_ranks = max(1, self.n_shards)
        with self.fdb.tracer.span("ckpt.save_sharded", step=step,
                                  ranks=n_ranks):
            self._save_sharded(step, n_ranks, params, opt_state, extra)

    def _save_sharded(self, step: int, n_ranks: int, params, opt_state,
                      extra) -> None:
        trees = [("params", jax.tree.map(np.asarray, params))]
        if opt_state is not None:
            trees.append(("opt", jax.tree.map(np.asarray, opt_state)))
        #: per-rank (kind, name, meta, selection, values) write jobs
        jobs: List[List[Tuple[str, str, Any, tuple, np.ndarray]]] = \
            [[] for _ in range(n_ranks)]
        for kind, tree in trees:
            flat = jax.tree_util.tree_flatten_with_path(tree)[0]
            for path, leaf in flat:
                arr = np.asarray(leaf)
                name = _tensor_name(path)
                codec = "field8" if self.compress and \
                    self._compressible(arr) else "raw"
                chunks = self._tensor_chunks(arr.shape, arr.dtype)
                created = self._tensor_store(kind, step, name).create(
                    arr.shape, arr.dtype, chunks=chunks, codec=codec,
                    on_mismatch="retain")
                banded = (n_ranks > 1 and arr.ndim >= 1 and arr.shape[0] > 1)
                if banded:
                    band = chunks[0]
                    tail = (slice(None),) * (arr.ndim - 1)
                    for r in range(n_ranks):
                        lo, hi = r * band, min((r + 1) * band, arr.shape[0])
                        if lo < hi:
                            jobs[r].append((kind, name, created.meta,
                                            (slice(lo, hi),) + tail,
                                            arr[lo:hi]))
                else:
                    jobs[0].append((kind, name, created.meta,
                                    (slice(None),) * arr.ndim, arr))
        sessions = [self.fdb.session(f"rank{r}") for r in range(n_ranks)]
        errors: List[BaseException] = []

        def run_rank(r: int) -> None:
            try:
                for kind, name, meta, sel, values in jobs[r]:
                    ts = TensorStore(
                        None, {**self._dataset(kind, step),
                               "host": self.host, "tensor": name},
                        chunk_dim="shard", session=sessions[r])
                    # bind the created metadata directly: it is not
                    # flushed yet, so an open() could not see it (rule 3)
                    ChunkedArray(ts, meta).write_plan(
                        sel, values).execute(flush=False)
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        # run each rank in a copy of this context so the obs span context
        # (and meter client tags) survive the thread hop, exactly like
        # ChunkExecutor.submit does for pool workers
        threads = [threading.Thread(
                       target=contextvars.copy_context().run,
                       args=(run_rank, r), name=f"ckpt-rank{r}")
                   for r in range(n_ranks) if jobs[r]]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            # abandon WITHOUT flushing: a close() here would flush the
            # dirty sessions and publish a partial checkpoint — versioning
            # out any previous good save of this step.  The partial
            # archives stay invisible (rule 3); retrying the save rewrites
            # the same chunk keys consistently, or wipe_step() discards.
            for s in sessions:
                s.release_all()
            raise errors[0]
        if extra:
            for k, v in extra.items():
                ident = Identifier({**self._dataset("meta", step),
                                    "host": self.host, "tensor": k,
                                    "shard": "0"})
                self.fdb.archive(ident, _pack(np.asarray(v)))
        # the step commit barrier: one flush publishes every rank's chunks
        # (and clears every session's dirty flag); closing then releases
        # each rank's leases without a second flush
        self.fdb.flush()
        for s in sessions:
            s.close()

    def save(self, step: int, params, opt_state=None,
             extra: Optional[Dict[str, Any]] = None) -> None:
        """Archive a full training state; commit via flush() barrier."""
        job = ("save", step, jax.tree.map(np.asarray, params),
               jax.tree.map(np.asarray, opt_state) if opt_state is not None
               else None, extra)
        if self.asynchronous:
            self._q.put(job)
        else:
            self._do_save(*job[1:])

    def _do_save(self, step, params, opt_state, extra) -> None:
        with self.fdb.tracer.span("ckpt.save", step=step):
            self._do_save_traced(step, params, opt_state, extra)

    def _do_save_traced(self, step, params, opt_state, extra) -> None:
        self._archive_tree("params", step, params)
        if opt_state is not None:
            self._archive_tree("opt", step, opt_state)
        if extra:
            for k, v in extra.items():
                ident = Identifier({**self._dataset("meta", step),
                                    "host": self.host, "tensor": k,
                                    "shard": "0"})
                self.fdb.archive(ident, _pack(np.asarray(v)))
        # the commit barrier: data+index persistent and visible after this
        self.fdb.flush()

    def _drain(self) -> None:
        while True:
            job = self._q.get()
            if job is None:
                return
            try:
                self._do_save(*job[1:])
            except BaseException as e:  # noqa: BLE001
                self._errors.append(e)
            finally:
                self._q.task_done()

    def wait(self) -> None:
        if self.asynchronous:
            self._q.join()
        if self._errors:
            raise self._errors[0]

    # -- read path -------------------------------------------------------------
    def available_steps(self, kind: str = "params") -> List[int]:
        steps = set()
        for ident, _loc in self.fdb.list({"run": self.run, "kind": kind}):
            steps.add(int(ident["step"]))
        return sorted(steps)

    def open_tensor(self, step: int, name: str, kind: str = "params"
                    ) -> ChunkedArray:
        """Open one tensor of a chunked checkpoint for partial reads — e.g.
        ``ck.open_tensor(step, "layer0.w")[1000:2000]`` retrieves only the
        intersecting chunks archived by this host."""
        return self._tensor_store(kind, step, name).open()

    def update_tensor(self, step: int, name: str, selection, values,
                      kind: str = "params") -> ChunkedArray:
        """Chunk-aligned in-place update of one saved tensor.

        The in-place assimilation pattern applied to training state: patch a
        slice of a saved parameter — or, with ``kind="opt"``, optimizer-state
        — tensor: ``ck.update_tensor(step, "mu.l0.w", slice(0, 4096), rows,
        kind="opt")``.  Only the chunks the selection touches are
        re-archived (partially covered chunks read-modify-write).  The
        update is committed (flushed) before returning, so a restore on any
        host sees it.  Requires a chunked checkpoint (the default layout);
        ``kind`` defaults to ``"params"`` like :meth:`open_tensor`.
        """
        arr = self.open_tensor(step, name, kind)
        arr.write_at(selection, values, flush=True)
        return arr

    def reshard_tensor(self, step: int, name: str, kind: str = "params",
                       chunks=None) -> ChunkedArray:
        """Re-chunk one saved tensor onto this checkpointer's topology —
        the restore-side half of a topology change: a run restarted with a
        different ``n_shards`` (or host count) reshards the tensors it owns
        onto its own shard banding before sharded partial reads
        (:meth:`open_tensor` row-band slices) line up again.

        Streams through :meth:`repro.tensorstore.ChunkedArray.reshard` —
        bounded batches of coalesced reads + writes, never the whole tensor
        client-side; the old banding's chunks are retained versioned under
        the previous layout generation.  ``chunks`` overrides the target
        grid (default: this checkpointer's ``_tensor_chunks`` banding).
        Requires a chunked checkpoint (the default layout).
        """
        arr = self.open_tensor(step, name, kind)
        if chunks is None:
            chunks = self._tensor_chunks(arr.shape, arr.dtype)
        return arr.reshard(chunks, flush=True)

    def reshard_step(self, step: int, template, kind: str = "params"
                     ) -> None:
        """Reshard every tensor of a saved step onto this checkpointer's
        topology (see :meth:`reshard_tensor`): restore onto a different
        chunking than the checkpoint was saved with, without a full
        client-side rewrite.  ``template`` names the tensors (any pytree
        shaped like the saved state)."""
        flat = jax.tree_util.tree_flatten_with_path(template)[0]
        for path, _leaf in flat:
            self.reshard_tensor(step, _tensor_name(path), kind)

    def _restore_tensor(self, step: int, kind: str, name: str,
                        ref: np.ndarray) -> np.ndarray:
        """Chunked-first restore; falls back to the legacy per-shard blobs
        so old checkpoints stay readable."""
        try:
            arr = self._tensor_store(kind, step, name).open()
        except FileNotFoundError:
            arr = None
        if arr is not None:
            # strict read: a saved tensor is dense, so a missing chunk is
            # lost data (unflushed writer, partial wipe) — raise rather
            # than resume training from silently zero-filled state
            return arr.read(fill_missing=False)
        shards = []
        for si in range(self.n_shards):
            handle = self.fdb.retrieve({**self._dataset(kind, step),
                                        "host": self.host,
                                        "tensor": name,
                                        "shard": str(si)})
            if handle.length() == 0:
                raise FileNotFoundError(
                    f"checkpoint step {step} missing {name}#{si}")
            shards.append(_unpack(handle.read()))
        arr = np.concatenate(shards) if len(shards) > 1 else shards[0]
        if arr.dtype == np.uint8 and ref.dtype != np.uint8:
            arr = self._decompress(arr, ref)
        return arr

    def restore(self, step: int, template, kind: str = "params"):
        """Rebuild a pytree like ``template`` from archived tensors."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = []
        with self.fdb.tracer.span("ckpt.restore", step=step, kind=kind,
                                  tensors=len(flat)):
            for path, leaf in flat:
                ref = np.asarray(leaf)
                arr = self._restore_tensor(step, kind, _tensor_name(path),
                                           ref)
                arr = arr.reshape(ref.shape) if arr.size == ref.size else arr
                leaves.append(arr.astype(ref.dtype))
        return treedef.unflatten(
            [jax.numpy.asarray(a) for a in leaves])

    def restore_latest(self, template, kind: str = "params"
                       ) -> Tuple[Optional[int], Any]:
        steps = self.available_steps(kind)
        if not steps:
            return None, template
        step = steps[-1]
        return step, self.restore(step, template, kind)

    def wipe_step(self, step: int) -> None:
        for kind in ("params", "opt", "meta"):
            self.fdb.wipe(self._dataset(kind, step))

    def close(self) -> None:
        if self.asynchronous:
            self.wait()
            self._q.put(None)
            if self._worker:
                self._worker.join(timeout=self.shutdown_timeout)
                if self._worker.is_alive():
                    # a silently-dropped join here would let close() return
                    # with a save possibly still archiving — the caller
                    # would tear down (or exit) under a half-written,
                    # unflushed step believing it durable
                    raise RuntimeError(
                        f"checkpoint async worker failed to shut down "
                        f"within {self.shutdown_timeout}s "
                        f"({max(0, self._q.unfinished_tasks - 1)} save "
                        f"job(s) still "
                        f"pending); a save may still be in flight — "
                        f"the step is NOT durable until flush")
        self.fdb.close()

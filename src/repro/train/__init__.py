from .optimizer import AdamWConfig, adamw_init, adamw_update
from .steps import make_train_step, make_prefill_step, make_decode_step

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "make_train_step", "make_prefill_step", "make_decode_step"]

"""Step builders: train_step / prefill_step / serve(decode)_step.

These are the functions the dry-run lowers and the drivers execute.  All are
pure; distribution comes entirely from input shardings + the SP activation
constraints injected via the MeshPlan.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ArchConfig
from repro.sharding import context as shctx
from repro.sharding.partition import MeshPlan, constrain_activations
from .optimizer import AdamWConfig, adamw_update


def _ctx_of(plan: Optional[MeshPlan]) -> Optional["shctx.ShardingCtx"]:
    if plan is None or not plan.extra:
        return None
    return shctx.ShardingCtx(
        mesh=plan.mesh, dp_axes=plan.dp_axes,
        ffn=plan.extra.get("ffn"),
        moe_gather_seq=plan.extra.get("moe_gather_seq", False),
        attn=plan.extra.get("attn"),
        attn_q_chunk=plan.extra.get("attn_q_chunk", 2048))


def make_train_step(cfg: ArchConfig, plan: Optional[MeshPlan] = None,
                    opt_cfg: AdamWConfig = AdamWConfig(),
                    mamba_chunk: int = 256) -> Callable:
    """(params, opt_state, batch) → (params, opt_state, metrics).

    batch: {"tokens": (B,S), "labels": (B,S) [, "frames", "patches"]}.
    """
    constrain = None
    if plan is not None and plan.sp:
        constrain = functools.partial(constrain_activations, plan=plan)
    remat = plan.remat if plan is not None else False

    ctx = _ctx_of(plan)

    def train_step(params, opt_state, batch):
        with shctx.use(ctx):
            def loss(p):
                return lm.loss_fn(
                    cfg, p, batch["tokens"], batch["labels"],
                    encoder_frames=batch.get("frames"),
                    prefix_embeds=batch.get("patches"),
                    remat=remat, mamba_chunk=mamba_chunk,
                    constrain=constrain)

            loss_val, grads = jax.value_and_grad(loss)(params)
            new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                                   opt_cfg)
            return new_params, new_opt, {"loss": loss_val, **om}

    return train_step


def make_prefill_step(cfg: ArchConfig, plan: Optional[MeshPlan] = None,
                      mamba_chunk: int = 256,
                      seq_len: Optional[int] = None) -> Callable:
    """(params, batch, cache) → (last logits, filled cache).

    For long prefills, attention switches to the shard_map sequence-parallel
    chunked path (bounded score memory, any head count)."""
    attn_impl = None
    if plan is not None and seq_len is not None:
        from repro.sharding.sp_attention import (
            SP_ATTN_THRESHOLD, sp_prefill_attention,
            tp_chunked_prefill_attention)
        if seq_len >= SP_ATTN_THRESHOLD and plan.tp_size > 1:
            if (plan.extra.get("attn") == "tp_chunked"
                    and cfg.n_heads % plan.tp_size == 0):
                attn_impl = functools.partial(
                    tp_chunked_prefill_attention, mesh=plan.mesh,
                    dp_axes=plan.dp_axes,
                    q_chunk=plan.extra.get("attn_q_chunk", 2048))
            else:
                attn_impl = functools.partial(sp_prefill_attention,
                                              mesh=plan.mesh,
                                              dp_axes=plan.dp_axes)

    ctx = _ctx_of(plan)
    constrain = None
    if plan is not None and plan.sp:
        constrain = functools.partial(constrain_activations, plan=plan)

    def prefill_step(params, batch, cache):
        with shctx.use(ctx):
            return lm.prefill(cfg, params, batch.get("tokens"), cache,
                              encoder_frames=batch.get("frames"),
                              prefix_embeds=batch.get("patches"),
                              mamba_chunk=mamba_chunk, attn_impl=attn_impl,
                              constrain=constrain)
    return prefill_step


def make_decode_step(cfg: ArchConfig, plan: Optional[MeshPlan] = None
                     ) -> Callable:
    """(params, token (B,1), cache, pos) → (logits, cache)."""
    def decode_step(params, token, cache, pos):
        return lm.decode_step(cfg, params, token, cache, pos)
    return decode_step

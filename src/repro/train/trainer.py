"""Training driver with FDB checkpoint/restart, async archival, straggler
monitoring, and deterministic data-shard reassignment (fault tolerance)."""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ArchConfig
from repro.sharding.partition import MeshPlan
from .checkpoint import FDBCheckpointer
from .optimizer import AdamWConfig, adamw_init
from .steps import make_train_step


class WorkerFailure(RuntimeError):
    """Simulated node failure (tests / chaos drills)."""


class StragglerMonitor:
    """Flags steps slower than ``threshold×`` the rolling median; the driver
    responds by reassigning that host's data shard (deterministic remap) —
    the I/O-side mitigation the thesis's I/O-server design enables."""

    def __init__(self, window: int = 20, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.durations: List[float] = []
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        self.durations.append(dt)
        hist = self.durations[-self.window:]
        if len(hist) >= 5:
            med = float(np.median(hist[:-1]))
            if dt > self.threshold * med:
                self.flagged += 1
                return True
        return False


def reassign_shard(host_idx: int, n_hosts: int, epoch: int) -> int:
    """Deterministic shard remap — every worker computes the same answer
    without coordination (restart-safe)."""
    return (host_idx + epoch * 7919) % n_hosts


class Trainer:
    def __init__(self, cfg: ArchConfig, plan: Optional[MeshPlan] = None,
                 opt_cfg: AdamWConfig = AdamWConfig(),
                 checkpointer: Optional[FDBCheckpointer] = None,
                 ckpt_every: int = 50, seed: int = 0,
                 param_dtype=jnp.float32,
                 batch_fn: Optional[Callable[[int], Dict[str, Any]]] = None,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 mamba_chunk: int = 256):
        self.cfg = cfg
        self.plan = plan
        self.ckpt = checkpointer
        self.ckpt_every = ckpt_every
        self.batch_fn = batch_fn
        self.fault_hook = fault_hook
        self.monitor = StragglerMonitor()
        self.metrics: List[Dict[str, float]] = []

        key = jax.random.PRNGKey(seed)
        self.params = lm.init_params(cfg, key, param_dtype)
        self.opt_state = adamw_init(self.params)
        self.step = 0
        self._step_fn = jax.jit(
            make_train_step(cfg, plan, opt_cfg, mamba_chunk=mamba_chunk),
            donate_argnums=(0, 1))

    # -- checkpoint/restart ------------------------------------------------
    def maybe_restore(self) -> int:
        if self.ckpt is None:
            return 0
        step, params = self.ckpt.restore_latest(self.params)
        if step is None:
            return 0
        self.params = params
        try:
            self.opt_state = self.ckpt.restore(step, self.opt_state, "opt")
        except FileNotFoundError:
            pass
        self.step = step
        return step

    def save(self) -> None:
        if self.ckpt is not None:
            self.ckpt.save(self.step, self.params, self.opt_state,
                           extra={"step": self.step})

    # -- training loop -------------------------------------------------------
    def fit(self, n_steps: int, log_every: int = 10) -> List[Dict[str, float]]:
        assert self.batch_fn is not None
        start = self.step
        while self.step < start + n_steps:
            if self.fault_hook is not None:
                self.fault_hook(self.step)
            batch = self.batch_fn(self.step)
            t0 = time.time()
            self.params, self.opt_state, m = self._step_fn(
                self.params, self.opt_state, batch)
            loss = float(m["loss"])
            dt = time.time() - t0
            straggle = self.monitor.observe(dt)
            self.step += 1
            rec = {"step": self.step, "loss": loss, "dt": dt,
                   "straggler": float(straggle)}
            self.metrics.append(rec)
            if self.step % log_every == 0:
                print(f"step {self.step}: loss={loss:.4f} dt={dt*1e3:.0f}ms"
                      + (" [straggler→reshard]" if straggle else ""),
                      flush=True)
            if self.ckpt is not None and self.step % self.ckpt_every == 0:
                self.save()
        if self.ckpt is not None:
            self.save()
            self.ckpt.wait()
        return self.metrics


def run_with_restarts(make_trainer: Callable[[], Trainer], n_steps: int,
                      max_restarts: int = 3) -> Trainer:
    """Restart-from-checkpoint supervision loop (node-failure recovery)."""
    restarts = 0
    while True:
        trainer = make_trainer()
        resumed = trainer.maybe_restore()
        remaining = n_steps - trainer.step
        if remaining <= 0:
            return trainer
        try:
            trainer.fit(remaining)
            return trainer
        except WorkerFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            print(f"[ft] worker failed at step {trainer.step}; restart "
                  f"{restarts}/{max_restarts} (resumed from {resumed})",
                  flush=True)

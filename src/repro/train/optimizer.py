"""AdamW, from scratch (no optax in this container).

Moments are f32 regardless of param dtype; the decoupled weight-decay and
bias-correction follow Loshchilov & Hutter.  Moment tensors inherit the
param shardings (FSDP: optimizer state is always sharded — ZeRO-style)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(grads, opt_state, params, cfg: AdamWConfig
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        m_hat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        v_hat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - cfg.lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm}

"""The FDB facade (thesis §2.7): archive / flush / retrieve / list / axes.

Backend-agnostic: pairs any conforming Catalogue with any conforming Store
(``FDBConfig``), enforcing the API semantics (see ``docs/architecture.md``
for how the tensorstore plans lean on each rule):

1. data is visible-and-indexed or not (ACID);
2. ``archive()`` blocks until the FDB controls (a copy of) the data;
3. ``flush()`` blocks until all archived data is persistent + visible —
   archive-without-flush is not readable, *not even by the archiving
   client itself* (which is why RMW and reshard paths pre-flush);
4. visible data is immutable;
5. re-archiving an identifier transactionally replaces it — the only
   "update" primitive, and the hook layout flips (tensorstore metadata
   replace) build on.

Deliberately absent: a per-object delete.  ``wipe()`` removes whole
datasets (container destroy), so layers that re-layout data under live
identifiers must *version* superseded objects out (the tensorstore's
generation-prefixed chunk keys) rather than delete them.
"""
from __future__ import annotations

import contextvars
import dataclasses
import itertools
import re
import threading
import time
import weakref
from typing import (Callable, Dict, Iterator, List, Mapping, Optional,
                    Sequence, Tuple, Union)

import numpy as np

from .engine.daos import DaosEngine
from .engine.meter import GLOBAL_METER, Meter
from .engine.rados import RadosEngine
from .engine.s3 import S3Engine
from .faults import FaultInjector
from .handle import (DataHandle, FieldLocation, MultiHandle, PlacementHandle,
                     group_mergeable)
from .interfaces import Catalogue, Store
from .lease import Lease, LeaseConflictError, StaleLeaseError
from .retry import RetryPolicy
from .schema import (CHECKPOINT_SCHEMA, Identifier, NWP_OBJECT_SCHEMA,
                     NWP_POSIX_SCHEMA, SCHEMAS, Schema)
from repro.obs.locks import NamedLock
from repro.obs.trace import GLOBAL_TRACER, Span, Tracer

BytesLike = Union[bytes, bytearray, memoryview, np.ndarray]

#: process-wide FDB client sequence — client_id labels in spans ("c3")
#: distinguish clients when several share one tracer (GLOBAL_TRACER)
_CLIENT_SEQ = itertools.count(1)

#: ambient re-validation hook for facade-level retries: a WriterSession
#: installs its lease re-validation (``check_held``) here around its
#: archive calls, so a retried archive re-fences its epochs *before*
#: re-archiving — a broken lease aborts the retry with StaleLeaseError
#: instead of silently double-archiving into a re-acquired range.  A
#: ContextVar so it survives the executor's context hand-off.
_ON_RETRY: "contextvars.ContextVar[Optional[Callable[[], None]]]" = \
    contextvars.ContextVar("fdb_retry_revalidate", default=None)

#: element values of generation-versioned chunk keys ("g2.c0.1") — the
#: stale-generation scan recover() runs after a half-flipped reshard
_GEN_RE = re.compile(r"g(\d+)\.")


def _as_bytes(data: BytesLike) -> bytes:
    if isinstance(data, np.ndarray):
        return data.tobytes()
    return bytes(data)


def as_identifier(identifier: Union[Identifier, Mapping[str, object]]
                  ) -> Identifier:
    """Canonicalise one user-supplied identifier mapping — the single place
    non-string values are handled for every FDB entry point.

    Scalar values are stringified (``{"step": 0}`` ≡ ``{"step": "0"}``, the
    way ``axes()`` always did), and sequence values become ``/``-joined
    multi-value request expressions (``{"step": [0, 6]}`` ≡
    ``{"step": "0/6"}``), matching :meth:`Identifier.matches` semantics.
    Request expressions are only meaningful on the retrieve side;
    ``archive()`` rejects them.
    Lists/tuples keep the caller's order (which fixes the byte order of a
    multi-object ``retrieve().read()``); unordered sets are sorted *by their
    string form* ("12" < "2") purely for determinism — callers that care
    about payload order should pass a list.
    """
    if isinstance(identifier, Identifier):
        return identifier
    out: Dict[str, str] = {}
    for k, v in identifier.items():
        if isinstance(v, (set, frozenset)):
            v = "/".join(sorted(str(x) for x in v))
        elif isinstance(v, (list, tuple)):
            v = "/".join(str(x) for x in v)
        out[str(k)] = str(v)
    return Identifier(out)


@dataclasses.dataclass
class FDBConfig:
    """Deployment-time configuration (the FDB administrator's file)."""
    backend: str = "daos"                 # daos | rados | posix | s3
    schema: Union[str, Schema] = "nwp-object"
    root: str = "/tmp/fdb"                # posix backend root dir
    pool: str = "fdb"
    # engine sizing (per simulated deployment)
    daos_targets: int = 16
    rados_osds: int = 16
    rados_pg_count: int = 512
    rados_max_object_size: int = 128 * 1024 * 1024
    lustre_osts: int = 16
    lustre_stripe_count: int = 8
    lustre_stripe_size: int = 8 * 1024 * 1024
    # backend design options (thesis Fig. 3.5 sweeps)
    rados_encapsulation: str = "namespace"
    rados_object_mode: str = "per_field"
    rados_persistence: str = "immediate"
    rados_replication: int = 1
    rados_ec: Optional[Tuple[int, int]] = None
    daos_oclass: str = "OC_S1"
    s3_object_mode: str = "per_field"
    # catalogue/store cross-pairing: e.g. s3 store needs another catalogue
    catalogue_backend: Optional[str] = None
    #: batched-archive overlap depth (archive_many / tensorstore writes);
    #: <= 1 serializes archives
    io_parallelism: int = 8
    #: decoded-chunk LRU cache budget for this client's readers
    #: (``fdb.chunk_cache``); 0 disables the cache entirely — the default,
    #: so op-count accounting stays exact unless serving opts in
    #: (``ChunkedFieldStore`` turns it on)
    chunk_cache_bytes: int = 0
    chunk_cache_entries: int = 1024

    def resolved_schema(self) -> Schema:
        if isinstance(self.schema, Schema):
            return self.schema
        return SCHEMAS[self.schema]


#: process-global shared engines, keyed by config identity — multiple FDB
#: instances (writer + reader "processes") hit the same simulated cluster.
_ENGINES: Dict[Tuple, object] = {}
_ENGINES_LOCK = threading.Lock()


def shared_engine(kind: str, cfg: FDBConfig, meter: Optional[Meter] = None):
    key = (kind, cfg.pool, cfg.daos_targets, cfg.rados_osds,
           cfg.rados_pg_count, cfg.rados_max_object_size, id(meter))
    with _ENGINES_LOCK:
        eng = _ENGINES.get(key)
        if eng is None:
            if kind == "daos":
                eng = DaosEngine(n_targets=cfg.daos_targets, meter=meter)
            elif kind == "rados":
                eng = RadosEngine(n_osds=cfg.rados_osds,
                                  max_object_size=cfg.rados_max_object_size,
                                  meter=meter)
            elif kind == "s3":
                eng = S3Engine(meter=meter)
            else:
                raise ValueError(kind)
            _ENGINES[key] = eng
        return eng


def reset_engines() -> None:
    with _ENGINES_LOCK:
        _ENGINES.clear()


class FDB:
    """One FDB client instance ≈ one producer/consumer process."""

    def __init__(self, config: Optional[FDBConfig] = None,
                 meter: Optional[Meter] = None,
                 tracer: Optional[Tracer] = None,
                 retry: Optional[RetryPolicy] = None,
                 faults: Optional[FaultInjector] = None, **overrides):
        if config is None:
            config = FDBConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self.schema = config.resolved_schema()
        self.meter = meter or GLOBAL_METER
        #: structured tracing + metrics (repro.obs); defaults to the shared
        #: process tracer, disabled out of the box — pass a private
        #: ``Tracer(enabled=True)`` for an isolated per-client buffer
        self.tracer = tracer or GLOBAL_TRACER
        #: facade-level retry policy: transient backend errors on the
        #: archive / flush / retrieve-handle paths are re-driven through it
        #: (safe per rule 5 — re-archiving transactionally replaces)
        self.retry = retry if retry is not None else RetryPolicy()
        #: fault injection (tests/chaos bench): wraps the freshly built
        #: backend pair so every data-path op consults the injector
        self.faults = faults
        #: stable per-process client label carried on flush/archive spans,
        #: so the protocol checker can attribute barriers when several
        #: clients share one tracer
        self.client_id = f"c{next(_CLIENT_SEQ)}"
        self.store, self.catalogue = self._build_backends()
        if faults is not None:
            self.store, self.catalogue = faults.wrap(self.store,
                                                     self.catalogue)
        # count TTL expiries on this client's metrics (the listener fires
        # whichever client's purge sweep finds them)
        _m = self.tracer.metrics
        self.catalogue.lease_table().add_expiry_listener(
            lambda leases: _m.counter("lease.expired").inc(len(leases)))
        self._closed = False
        self._dirty = False
        self._io_executor = None        # lazily built, see io_executor
        self._io_executor_size = 0
        self._chunk_cache = None        # lazily built, see chunk_cache
        self._io_lock = NamedLock("fdb.io")
        #: serialises flush(): concurrent barriers (two writer sessions
        #: committing at once) would race the posix catalogue's
        #: getsize-then-append partial-index bookkeeping
        self._flush_lock = NamedLock("fdb.flush")
        #: archive sequence number (with its lock): flush() clears dirty
        #: flags only when no archive landed since it captured the marker,
        #: so a chunk archived *during* another session's barrier can never
        #: be marked clean while still unpublished
        self._archive_seq = 0
        self._dirty_lock = NamedLock("fdb.dirty")
        #: live writer sessions of this client (weak: an abandoned session
        #: must not keep the client's dirty bookkeeping alive)
        self._sessions: "weakref.WeakSet[WriterSession]" = weakref.WeakSet()

    # -- backend wiring ------------------------------------------------------
    def _build_backends(self) -> Tuple[Store, Catalogue]:
        cfg = self.config
        cat_kind = cfg.catalogue_backend or cfg.backend
        store: Store
        catalogue: Catalogue
        if cfg.backend == "daos":
            eng = shared_engine("daos", cfg, self.meter)
            store = DaosStore(eng, pool=cfg.pool, oclass=cfg.daos_oclass)
        elif cfg.backend == "rados":
            eng = shared_engine("rados", cfg, self.meter)
            store = RadosStore(eng, pool=cfg.pool,
                               encapsulation=cfg.rados_encapsulation,
                               object_mode=cfg.rados_object_mode,
                               persistence=cfg.rados_persistence,
                               pg_count=cfg.rados_pg_count,
                               replication=cfg.rados_replication,
                               ec=cfg.rados_ec)
        elif cfg.backend == "posix":
            sim = self._shared_lustre(cfg)
            store = PosixStore(sim)
        elif cfg.backend == "s3":
            eng = shared_engine("s3", cfg, self.meter)
            store = S3Store(eng, object_mode=cfg.s3_object_mode)
            if cfg.catalogue_backend is None:
                cat_kind = "daos"   # S3 has no conforming catalogue (§3.3)
        else:
            raise ValueError(f"unknown backend {cfg.backend!r}")

        if cat_kind == "daos":
            eng = shared_engine("daos", cfg, self.meter)
            catalogue = DaosCatalogue(eng, self.schema, pool=cfg.pool)
        elif cat_kind == "rados":
            eng = shared_engine("rados", cfg, self.meter)
            catalogue = RadosCatalogue(eng, self.schema, pool=cfg.pool,
                                       persistence=cfg.rados_persistence)
        elif cat_kind == "posix":
            catalogue = PosixCatalogue(self._shared_lustre(cfg), self.schema)
        else:
            raise ValueError(f"no conforming catalogue for {cat_kind!r}")
        return store, catalogue

    def _shared_lustre(self, cfg: FDBConfig) -> "LustreSim":
        # geometry is part of the identity (mirroring shared_engine): two
        # FDBs on one root with different OST/stripe settings must not
        # silently share a sim, or stripe-geometry sweeps measure the first
        # configuration repeatedly
        key = ("lustre", cfg.root, cfg.lustre_osts, cfg.lustre_stripe_count,
               cfg.lustre_stripe_size, id(self.meter))
        with _ENGINES_LOCK:
            sim = _ENGINES.get(key)
            if sim is None:
                sim = LustreSim(cfg.root, n_osts=cfg.lustre_osts,
                                stripe_count=cfg.lustre_stripe_count,
                                stripe_size=cfg.lustre_stripe_size,
                                meter=self.meter)
                _ENGINES[key] = sim
        return sim

    # -- the four primary API methods (Listing 2.2) -----------------------------
    def _split_archivable(self, identifier: Union[Identifier,
                                                  Mapping[str, object]]):
        """Canonicalise + split an archive identifier, rejecting multi-value
        request expressions ("0/6", or a sequence value): they would
        catalogue the object under a key no retrieve can ever expand back
        to — archive one object per fully-specified identifier."""
        ident = as_identifier(identifier)
        multi = [k for k, v in ident.items() if "/" in v]
        if multi:
            raise ValueError(
                f"archive identifier {ident!r} has multi-value request "
                f"expressions on dims {multi}; archive one object per "
                f"fully-specified identifier")
        return self.schema.split(ident)

    def archive(self, identifier: Union[Identifier, Mapping[str, object]],
                data: BytesLike) -> FieldLocation:
        return self._archive_split(self._split_archivable(identifier),
                                   _as_bytes(data))

    def _archive_split(self, split, data: bytes) -> FieldLocation:
        """Archive one pre-split (dataset, collocation, element) triple —
        the shared tail of :meth:`archive`/:meth:`archive_many`, so batch
        paths canonicalise each identifier exactly once.

        The whole store-archive + catalogue-index unit is the retry scope:
        rule 5 (re-archiving transactionally replaces) makes re-driving it
        idempotent even when the first attempt died between the two."""
        dataset, collocation, element = split

        def attempt() -> FieldLocation:
            with self.tracer.span("fdb.archive", nbytes=len(data)):
                loc = self.store.archive(data, dataset, collocation)
                self.catalogue.archive(dataset, collocation, element, loc)
            return loc

        loc = self.retry.call(attempt, op="fdb.archive",
                              metrics=self.tracer.metrics,
                              on_retry=_ON_RETRY.get())
        self._mark_dirty()
        return loc

    def _mark_dirty(self) -> None:
        with self._dirty_lock:
            self._archive_seq += 1
            self._dirty = True

    def archive_placement(self, identifier: Union[Identifier,
                                                  Mapping[str, object]]
                          ) -> PlacementHandle:
        """Resolve where an ``archive(identifier, ...)`` would land —
        placement only, no data I/O: the write-side twin of
        :meth:`retrieve_handle`.  Handles over the same storage unit (posix
        archives appending into one writer's data file) are mutually
        mergeable, so :func:`repro.core.group_mergeable` groups them into
        single :meth:`archive_batch` submissions — the tensorstore
        ``WritePlan``'s planning hook."""
        dataset, collocation, _element = self._split_archivable(identifier)
        return PlacementHandle(self.store.placement(dataset, collocation))

    def archive_batch(self, items: Sequence[Tuple[Mapping[str, object],
                                                  BytesLike]]
                      ) -> List[FieldLocation]:
        """Archive several fully-specified objects through ONE store-level
        batched write + one catalogue batch.

        The store coalesces items bound for the same storage unit into a
        single write (posix: one buffered append per data file); on object
        backends the batch degenerates to the per-item loop, so callers
        wanting op-level overlap there should submit one batch per executor
        slot (what :meth:`archive_many` and the tensorstore ``WritePlan``
        do).  Per-item semantics are rule 2/3-unchanged: on return the FDB
        controls all payloads; visibility still requires ``flush()``."""
        return self._archive_batch_split(
            [(self._split_archivable(ident), _as_bytes(data))
             for ident, data in items])

    def _archive_batch_split(self, split) -> List[FieldLocation]:
        """Batch-archive pre-split ``((dataset, collocation, element),
        bytes)`` pairs — one store submission + one catalogue batch.  The
        whole batch is one retry unit (idempotent per rule 5, like
        :meth:`_archive_split`)."""

        def attempt() -> List[FieldLocation]:
            with self.tracer.span("fdb.archive_batch", items=len(split),
                                  nbytes=sum(len(d) for _s, d in split)):
                locs = self.store.archive_batch(
                    [(data, dataset, collocation)
                     for (dataset, collocation, _e), data in split])
                self.catalogue.archive_batch(
                    [(dataset, collocation, element, loc)
                     for ((dataset, collocation, element), _d), loc
                     in zip(split, locs)])
            return locs

        locs = self.retry.call(attempt, op="fdb.archive_batch",
                               metrics=self.tracer.metrics,
                               on_retry=_ON_RETRY.get())
        if split:
            self._mark_dirty()
        return locs

    @property
    def io_executor(self):
        """This client's bounded I/O executor (``archive_many`` overlap,
        tensorstore chunk I/O), sized by ``config.io_parallelism`` — one
        per FDB instead of one per call, rebuilt if the configured depth
        changes, shut down in :meth:`close`.  A closed client refuses to
        mint a fresh pool (nothing would ever shut it down again)."""
        # lint: disable=L001 -- documented cycle-breaker: lazy import so
        # core never loads tensorstore at module import time
        from repro.tensorstore.executor import ChunkExecutor
        size = max(1, self.config.io_parallelism)
        with self._io_lock:
            # checked under the lock: close() flips _closed under the same
            # lock, so a concurrent close cannot slip between the check and
            # the build and leave a fresh pool nothing will shut down
            if self._closed:
                raise RuntimeError(
                    "FDB client is closed; its I/O executor cannot be "
                    "rebuilt")
            ex = self._io_executor
            if ex is None or self._io_executor_size != size:
                if ex is not None:
                    # lint: disable=L003 -- resize path: the drained pool
                    # must be gone before a caller can see the new one
                    ex.shutdown(wait=True)
                ex = self._io_executor = ChunkExecutor(max_workers=size)
                self._io_executor_size = size
            return ex

    @property
    def chunk_cache(self):
        """This client's shared decoded-chunk LRU cache, or ``None`` when
        ``config.chunk_cache_bytes`` is 0 (the default).  Read plans
        consult it before resolving handles; write plans invalidate the
        chunks they archive; :meth:`flush`'s clean path publishes them
        and :meth:`wipe` drops the wiped dataset's entries."""
        if self.config.chunk_cache_bytes <= 0:
            return None
        cache = self._chunk_cache
        if cache is None:
            # lint: disable=L001 -- documented cycle-breaker: lazy import so
            # core never loads tensorstore at module import time
            from repro.tensorstore.cache import ChunkCache
            with self._io_lock:
                cache = self._chunk_cache
                if cache is None:
                    cache = self._chunk_cache = ChunkCache(
                        self.config.chunk_cache_bytes,
                        self.config.chunk_cache_entries,
                        metrics=self.tracer.metrics)
        return cache

    def archive_many(self, items: Sequence[Tuple[Mapping[str, object],
                                                 BytesLike]],
                     parallelism: Optional[int] = None,
                     executor=None) -> List[FieldLocation]:
        """The thesis's efficient multi-object archive() variant.

        Batched semantics: every item is archived as an independent object
        (identifier → one store object + one catalogue entry), with two
        levers applied per the paper's findings: items whose payloads land
        in the same storage unit (posix data files, via
        :meth:`archive_placement` + :func:`group_mergeable`) coalesce into
        one batched store write, and the resulting batches are submitted
        through a bounded-depth I/O executor so independent object writes
        *overlap* instead of running as a serial per-item loop.  Returns the
        :class:`FieldLocation` of every item in input order.  Per-item API
        semantics are unchanged: on return the FDB controls (a copy of) all
        data (rule 2); visibility still requires ``flush()`` (rule 3).
        ``parallelism`` (defaulting to ``config.io_parallelism``) sets the
        overlap depth; values <= 1 fall back to the serial loop.  An
        explicit ``executor`` overrides both.
        """
        items = list(items)
        if parallelism is None:
            parallelism = self.config.io_parallelism
        if executor is None and (parallelism <= 1 or len(items) <= 1):
            return [self.archive(ident, data) for ident, data in items]
        if executor is None:
            if parallelism == self.config.io_parallelism:
                executor = self.io_executor
            else:
                # explicit non-default depth: use the shared process-global
                # pool of that size (not owned by this client, never shut
                # down here)
                # lint: disable=L001 -- documented cycle-breaker: lazy
                # import keeps core free of tensorstore at module load
                from repro.tensorstore.executor import sized_executor
                executor = sized_executor(parallelism)
        # canonicalise + split each identifier exactly once; both the
        # placement pre-pass and the archive submissions reuse the triples
        split = [(self._split_archivable(ident), _as_bytes(data))
                 for ident, data in items]
        placements = [
            PlacementHandle(self.store.placement(dataset, collocation))
            for (dataset, collocation, _e), _d in split]
        groups = group_mergeable(placements)
        if len(groups) == len(items):       # nothing coalesces (object
            return executor.map_ordered(    # backends): one op per item
                lambda pair: self._archive_split(*pair), split)
        locs: List[Optional[FieldLocation]] = [None] * len(items)
        batches = executor.map_ordered(
            lambda group: self._archive_batch_split(
                [split[pos] for pos in group]),
            groups)
        for group, batch_locs in zip(groups, batches):
            for pos, loc in zip(group, batch_locs):
                locs[pos] = loc
        return locs                          # type: ignore[return-value]

    @property
    def dirty(self) -> bool:
        """True while this client has archived data not yet flush()ed —
        i.e. a flush() barrier would actually publish something (rule 3)."""
        return self._dirty

    def flush(self) -> None:
        # serialised: two sessions' commit barriers must not interleave
        # inside the backends (the posix catalogue appends partial-index
        # records at offsets it just measured)
        with self.tracer.span("fdb.flush", backend=self.config.backend,
                              dirty=self._dirty,
                              client=self.client_id), self._flush_lock:
            # capture markers FIRST: an archive completing before a marker
            # is included in the flush below; one completing after bumps
            # its sequence, so the conditional clear leaves it dirty —
            # never clean-but-unpublished (the RMW pre-flush depends on it)
            sessions = list(self._sessions)
            marks = [(s, s._dirty_mark()) for s in sessions]
            with self._dirty_lock:
                client_mark = self._archive_seq

            def barrier() -> None:
                # retried as one unit: a re-driven store flush is a no-op
                # for already-persistent data, so a transient catalogue
                # failure cannot leave the pair half-committed
                # lint: disable=L003 -- flush IS the serialised barrier: the
                # held _flush_lock is what gives rule-3 its atomicity
                self.store.flush()
                self.catalogue.flush()  # lint: disable=L003 -- same barrier

            self.retry.call(barrier, op="fdb.flush",
                            metrics=self.tracer.metrics)
            clean = False
            with self._dirty_lock:
                if self._archive_seq == client_mark:
                    self._dirty = False
                    clean = True
            if clean:
                # barrier covered every archive this client journaled; an
                # archive racing the barrier keeps the journal (and dirty
                # flag) until the next flush — never clean-but-unpublished
                self.catalogue.lease_table().clear_dirty_client(
                    self.client_id)
                if self._chunk_cache is not None:
                    # overwritten chunks are visible now: let readers
                    # cache their fresh bytes again
                    self._chunk_cache.publish_pending()
            # one store/catalogue flush publishes everything this *client*
            # archived, whichever session produced it — so every session's
            # barrier up to its captured marker is satisfied too
            for session, mark in marks:
                session._clear_dirty_if(mark)

    # -- writer sessions + chunk-range leases -------------------------------
    def session(self, writer_id: str, lease_ttl: Optional[float] = None,
                heartbeat_interval: Optional[float] = None,
                lease_block: bool = False,
                lease_timeout: Optional[float] = None
                ) -> "WriterSession":
        """Open a :class:`WriterSession` — one logical writer identity on
        this client, with its own dirty/flush-barrier bookkeeping and a
        ledger of the chunk-range leases it holds.  Several sessions may
        share one client (the I/O-server pattern: many producer tasks, one
        FDB connection); their writes into one array are made safe by the
        catalogue-level lease table, not by schema separation.

        ``lease_ttl`` makes every lease the session acquires expire unless
        renewed (crash safety: a dead writer's ranges free themselves);
        ``heartbeat_interval`` starts a daemon thread renewing them every
        that-many seconds (requires ``lease_ttl``; pick interval well under
        the TTL — a third is conventional).

        ``lease_block=True`` makes every lease the session acquires *queue*
        on conflicting ranges (bounded by ``lease_timeout`` seconds) instead
        of failing fast — the workflow-stage posture, where transient
        overlap between concurrent writers is waited out, not errored."""
        if self._closed:
            raise RuntimeError("FDB client is closed; cannot open a session")
        session = WriterSession(self, str(writer_id), lease_ttl=lease_ttl,
                                heartbeat_interval=heartbeat_interval,
                                lease_block=lease_block,
                                lease_timeout=lease_timeout)
        self._sessions.add(session)
        return session

    def _lease_split(self, identifier: Union[Identifier,
                                             Mapping[str, object]]
                     ) -> Tuple[Identifier, Identifier]:
        """Split a lease identifier into (dataset, collocation) keys.  The
        identifier must cover the dataset + collocation dims; element dims
        are irrelevant (leases are per chunk-id *range*, not per key) and
        are ignored if present."""
        ident = as_identifier(identifier)
        need = self.schema.dataset_dims + self.schema.collocation_dims
        missing = [d for d in need if d not in ident]
        if missing:
            raise KeyError(f"lease identifier {ident!r} missing dims "
                           f"{missing} of schema {self.schema.name!r}")
        return (ident.subset(self.schema.dataset_dims),
                ident.subset(self.schema.collocation_dims))

    def lease_scope(self, identifier: Union[Identifier,
                                            Mapping[str, object]]) -> str:
        """Canonical label of the identifier's (dataset, collocation) lease
        key — the ``scope`` attr every ``lease.*`` span carries, so the
        protocol checker (``repro.analysis.protocol``) can correlate lease
        events with the archives they cover."""
        dataset, collocation = self._lease_split(identifier)
        return self._lease_scope_split(dataset, collocation)

    @staticmethod
    def _lease_scope_split(dataset: Identifier,
                           collocation: Identifier) -> str:
        return f"{dataset.canonical()}|{collocation.canonical()}"

    def acquire_lease(self, identifier: Union[Identifier,
                                              Mapping[str, object]],
                      resource: str, lo: int, hi: int, owner: str,
                      ttl: Optional[float] = None, block: bool = False,
                      timeout: Optional[float] = None) -> int:
        """Acquire an exclusive epoch-fenced lease on chunk-id range
        ``[lo, hi)`` of ``resource`` under the identifier's (dataset,
        collocation) key; returns the epoch.  Raises ``LeaseConflictError``
        on overlap with another owner.  ``ttl`` bounds the lease's life
        between :meth:`renew_lease` heartbeats (expiry = release, on the
        deployment's shared lease clock); ``block=True`` queues on a
        conflicting range until it frees — or its holder's TTL lapses —
        giving up with ``LeaseConflictError`` after ``timeout`` seconds.
        Usually reached through :meth:`WriterSession.acquire_lease`, which
        also ledgers the lease for release at session close."""
        dataset, collocation = self._lease_split(identifier)
        m = self.tracer.metrics
        attrs = {} if ttl is None else {"ttl": ttl}
        with self.tracer.span(
                "lease.acquire", resource=resource, lo=lo, hi=hi,
                owner=owner,
                scope=self._lease_scope_split(dataset, collocation),
                **attrs) as sp:
            # blocking acquires meter their queueing delay: the lease-wait
            # histogram is the workflow-level contention signal (how long
            # did assimilation writers wait on each other's windows)
            t0 = time.perf_counter() if block else 0.0
            try:
                epoch = self.catalogue.acquire_lease(dataset, collocation,
                                                     resource, lo, hi, owner,
                                                     ttl=ttl, block=block,
                                                     timeout=timeout)
            except LeaseConflictError:
                if block:
                    m.histogram("lease.wait_us").observe(
                        (time.perf_counter() - t0) * 1e6)
                m.counter("lease.conflicts").inc()
                raise
            if block:
                wait_us = (time.perf_counter() - t0) * 1e6
                m.histogram("lease.wait_us").observe(wait_us)
                if sp is not None:
                    sp.attrs["wait_us"] = round(wait_us, 1)
            if sp is not None:
                sp.attrs["epoch"] = epoch
        m.counter("lease.acquired").inc()
        return epoch

    def renew_lease(self, identifier: Union[Identifier,
                                            Mapping[str, object]],
                    resource: str, owner: str,
                    ttl: Optional[float] = None) -> int:
        """Heartbeat: re-arm the TTL of every lease ``owner`` holds on
        ``resource`` under the identifier's (dataset, collocation) key,
        preserving epochs (a renewal is *not* a re-acquire — fenced archives
        stay valid across it).  Returns the number of leases renewed; 0
        means the owner holds nothing there any more (expired and possibly
        re-leased — the writer must re-acquire and re-fence)."""
        dataset, collocation = self._lease_split(identifier)
        return self._renew_split(dataset, collocation, str(resource), owner,
                                 ttl)

    def _renew_split(self, dataset: Identifier, collocation: Identifier,
                     resource: str, owner: str,
                     ttl: Optional[float]) -> int:
        with self.tracer.span(
                "lease.renew", resource=resource, owner=owner, ttl=ttl,
                scope=self._lease_scope_split(dataset, collocation)) as sp:
            n = self.catalogue.lease_table().renew(
                self.catalogue.lease_key(dataset, collocation, resource),
                owner, ttl)
            if sp is not None:
                sp.attrs["renewed"] = n
        return n

    def mark_dirty_chunks(self, identifier: Union[Identifier,
                                                  Mapping[str, object]],
                          resource: str, owner: str,
                          chunk_ids: Sequence[int]) -> None:
        """Journal a leased writer's archived-but-unflushed chunk ids in
        the deployment-shared dirty-intent journal (on the lease table, so
        *other* clients can see them).  ``flush()`` clears this client's
        intents once the barrier publishes; intents left behind by a writer
        whose leases lapsed are what :meth:`recover` quarantines."""
        dataset, collocation = self._lease_split(identifier)
        self.catalogue.lease_table().mark_dirty(
            self.catalogue.lease_key(dataset, collocation, str(resource)),
            owner, chunk_ids, self.client_id)

    def release_lease(self, identifier: Union[Identifier,
                                              Mapping[str, object]],
                      resource: str, lo: int, hi: int, owner: str) -> None:
        """Release ``owner``'s leases overlapping ``[lo, hi)``.  Any client
        may break any owner's lease (the coordinator escape hatch for a
        presumed-dead writer) — epoch fencing rejects the broken holder's
        late archives, so breaking is safe, merely rude."""
        dataset, collocation = self._lease_split(identifier)
        self._release_lease_split(dataset, collocation, resource, lo, hi,
                                  owner, exact=False)

    def _release_lease_split(self, dataset: Identifier,
                             collocation: Identifier, resource: str,
                             lo: int, hi: int, owner: str,
                             exact: bool) -> None:
        """The one release path (facade + sessions): every lease release
        emits a ``lease.release`` span, the event the protocol checker
        orders against flush barriers."""
        with self.tracer.span(
                "lease.release", resource=str(resource), lo=lo, hi=hi,
                owner=owner, exact=exact,
                scope=self._lease_scope_split(dataset, collocation)):
            self.catalogue.release_lease(dataset, collocation,
                                         str(resource), lo, hi, owner,
                                         exact=exact)

    def lease_holders(self, identifier: Union[Identifier,
                                              Mapping[str, object]],
                      resource: str) -> List[Lease]:
        """All active leases on ``resource`` under the identifier's
        (dataset, collocation) key — observability for coordinators."""
        dataset, collocation = self._lease_split(identifier)
        return self.catalogue.lease_holders(dataset, collocation, resource)

    def check_lease(self, identifier: Union[Identifier,
                                            Mapping[str, object]],
                    resource: str, lo: int, hi: int, owner: str,
                    epoch: int) -> None:
        """Fencing gate: raise ``StaleLeaseError`` unless ``owner`` still
        holds a covering lease at exactly ``epoch``."""
        dataset, collocation = self._lease_split(identifier)
        with self.tracer.span(
                "lease.check", resource=resource, lo=lo, hi=hi, owner=owner,
                epoch=epoch,
                scope=self._lease_scope_split(dataset, collocation)):
            try:
                self.catalogue.check_lease(dataset, collocation, resource,
                                           lo, hi, owner, epoch)
            except StaleLeaseError:
                self.tracer.metrics.counter("lease.stale").inc()
                raise

    # -- crash recovery ------------------------------------------------------
    def recover(self, identifier: Union[Identifier, Mapping[str, object]],
                live_resource: Optional[str] = None) -> "RecoveryReport":
        """Scan the identifier's (dataset, collocation) lease scope for the
        wreckage of dead writers and mop it up:

        * **expired leases** are purged (epoch fencing already fences their
          holders' late archives; purging just frees the ranges);
        * **orphaned dirty intents** — chunk ids a writer journaled as
          archived-but-unflushed and then stopped heartbeating for — are
          *quarantined*: their archives lived only in the dead client's
          unflushed state (rule 3), so there is nothing to repair; the
          report tells the coordinator which chunks must be re-driven.
          Intents whose owner still holds a live lease are left alone (a
          slow writer mid-commit is not a crash);
        * with ``live_resource`` (the array's live layout generation, e.g.
          ``"g1"``), catalogue entries from *newer* generations — the
          debris of a half-flipped reshard that died between archiving
          ``g2`` chunks and replacing the array metadata — are reported as
          stale so the coordinator can re-run or ignore the reshard.

        Safe to run any time, from any client: it never touches live
        leases, and recovery of a healthy scope returns a clean report.
        Every sweep is emitted as a ``fdb.recover`` span whose ``expired``
        / ``orphans`` attrs let the protocol checker verify the recovery
        invariants (no purge under a live heartbeat)."""
        dataset, collocation = self._lease_split(identifier)
        prefix = (dataset.canonical(), collocation.canonical())
        tbl = self.catalogue.lease_table()
        m = self.tracer.metrics
        with self.tracer.span(
                "fdb.recover", client=self.client_id,
                scope=self._lease_scope_split(dataset, collocation)) as sp:
            expired = [
                {"resource": key[2], "owner": lease.owner, "lo": lease.lo,
                 "hi": lease.hi, "epoch": lease.epoch}
                for key, lease in tbl.purge_expired(prefix)]
            orphans = [
                {"resource": key[2], "owner": owner,
                 "chunk_ids": list(chunk_ids), "client": client}
                for key, owner, chunk_ids, client in tbl.take_orphans(prefix)]
            n_orphans = sum(len(o["chunk_ids"]) for o in orphans)
            if n_orphans:
                m.counter("recover.orphans").inc(n_orphans)
            stale: List[str] = []
            if live_resource is not None:
                mt = _GEN_RE.match(f"{live_resource}.")
                live_gen = int(mt.group(1)) if mt else 0
                for ident, _loc in self.catalogue.list(dataset,
                                                       dict(collocation)):
                    for value in ident.values():
                        g = _GEN_RE.match(value)
                        if g and int(g.group(1)) > live_gen:
                            stale.append(value)
            if sp is not None:
                sp.attrs["expired"] = expired
                sp.attrs["orphans"] = orphans
                sp.attrs["stale"] = len(stale)
        return RecoveryReport(self._lease_scope_split(dataset, collocation),
                              expired, orphans, sorted(set(stale)))

    def retrieve(self, identifiers: Union[Identifier, Mapping[str, object],
                                          Sequence]) -> MultiHandle:
        if isinstance(identifiers, (Identifier, Mapping)):
            identifiers = [identifiers]
        handles: List[DataHandle] = []
        for ident in identifiers:
            for e in self._expand(as_identifier(ident)):
                h = self.retrieve_handle(e)
                if h is not None:     # absence is not an error (§2.7.1)
                    handles.append(h)
        return MultiHandle(handles)

    def retrieve_handle(self, identifier: Union[Identifier,
                                                Mapping[str, object]]
                        ) -> Optional[DataHandle]:
        """Resolve one fully-specified identifier to its backend
        :class:`DataHandle` — catalogue lookup only, no data I/O.

        Unlike :meth:`retrieve` this keeps the identifier ↔ handle pairing:
        ``None`` means the object does not exist, and the returned handles
        can be regrouped by the caller (``repro.core.handle.group_mergeable``)
        into coalesced reads before any byte moves — the tensorstore read
        path's planning hook.  Multi-value expressions are not expanded here.
        """
        ident = as_identifier(identifier)
        dataset, collocation, element = self.schema.split(ident)

        def attempt() -> Optional[DataHandle]:
            loc = self.catalogue.retrieve(dataset, collocation, element)
            return None if loc is None else self.store.retrieve(loc)

        return self.retry.call(attempt, op="fdb.retrieve",
                               metrics=self.tracer.metrics)

    def _expand(self, ident: Identifier) -> List[Identifier]:
        """Expand multi-value expressions (lists) via axes (§2.7.1 axis())."""
        multi = {k: v for k, v in dict(ident).items() if "/" in v}
        if not multi:
            return [ident]
        out = [dict(ident)]
        for dim, expr in multi.items():
            values = expr.split("/")
            out = [dict(d, **{dim: v}) for d in out for v in values]
        return [Identifier(d) for d in out]

    def list(self, partial: Mapping[str, object]
             ) -> Iterator[Tuple[Identifier, FieldLocation]]:
        partial = dict(partial)
        dataset_part = {k: v for k, v in partial.items()
                        if k in self.schema.dataset_dims}
        for dataset in self._matching_datasets(dataset_part):
            yield from self.catalogue.list(dataset, partial)

    def _matching_datasets(self, dataset_part: Mapping[str, object]
                           ) -> List[Identifier]:
        if set(dataset_part) == set(self.schema.dataset_dims):
            return [Identifier(dataset_part)]
        return [d for d in self.catalogue.datasets()
                if d.matches(dataset_part)]

    def axes(self, identifier: Mapping[str, object], dim: str) -> frozenset:
        ident = as_identifier(identifier)
        dataset = ident.subset(self.schema.dataset_dims)
        collocation = ident.subset(self.schema.collocation_dims)
        return self.catalogue.axes(dataset, collocation, dim)

    def wipe(self, dataset_part: Mapping[str, object]) -> None:
        """Destroy every matching dataset — data and index together (the
        container-destroy granularity of the thesis's schema mapping).

        This is the FDB's *only* deletion primitive: there is no per-object
        delete, so wiping is also how superseded tensorstore layout
        generations (resharded arrays' old-grid chunks, retained versioned)
        are eventually reclaimed — at the cost of the whole array dataset.
        """
        for dataset in self._matching_datasets(dict(dataset_part)):
            self.store.wipe(dataset)
            self.catalogue.wipe(dataset)
        if self._chunk_cache is not None:
            self._chunk_cache.clear({str(k): str(v)
                                     for k, v in dataset_part.items()})

    # -- observability -------------------------------------------------------
    def trace(self, since: int = 0) -> List[Span]:
        """Finished spans from this client's tracer (oldest first).  Pass a
        ``tracer.mark()`` value as ``since`` for a window.  Empty unless
        tracing is enabled (``fdb.tracer.enable()`` or ``--trace``)."""
        return self.tracer.spans(since)

    def metrics(self) -> Dict[str, Dict[str, object]]:
        """Snapshot of this client's metrics registry: lease counters,
        executor queue/in-flight, codec byte counts, per-backend op latency
        histograms.  Counters (e.g. ``lease.conflicts``) update even while
        span tracing is disabled."""
        return self.tracer.metrics.snapshot()

    def check_protocol(self, since: int = 0):
        """Replay this client's trace window through the concurrency
        protocol checker (``repro.analysis.protocol.check_protocol``) and
        return its list of violations — empty on a healthy run.  Requires
        tracing to have been enabled for the window; spans record the
        lease/flush/archive events the checker orders."""
        # upward import by design: analysis sits above core in the layer
        # DAG, and this convenience hook must not make core depend on it
        # at module load
        from repro.analysis.protocol import check_protocol  # lint: disable=L001 -- lazy convenience hook; core must not import analysis at module load
        window = None
        if self._io_executor is not None:
            window = self._io_executor.max_in_flight
        return check_protocol(self.tracer.spans(since),
                              self.tracer.metrics.snapshot(),
                              max_in_flight=window)

    def abandon(self) -> None:
        """Simulate whole-client death (test/chaos hook), the client-level
        analogue of :meth:`WriterSession.abandon`: every open session is
        abandoned (leases left to lapse by TTL, dirty intents left for
        :meth:`recover`), nothing is flushed — a crashed process never
        reaches its commit barrier — and only *local* resources (the I/O
        pool) are torn down."""
        for session in list(self._sessions):
            if not session._closed:
                session.abandon()
        with self._io_lock:
            if self._io_executor is not None:
                # lint: disable=L003 -- teardown: _closed must flip
                # atomically with the pool draining (see close())
                self._io_executor.shutdown(wait=True)
                self._io_executor = None
                self._io_executor_size = 0
            self._closed = True

    def close(self) -> None:
        if not self._closed:
            self.flush()
            self.catalogue.close()
            self.store.close()
            with self._io_lock:
                # _closed flips under _io_lock so io_executor's guard and
                # this shutdown are atomic with respect to each other
                if self._io_executor is not None:
                    # lint: disable=L003 -- teardown: _closed must flip
                    # atomically with the pool draining (see io_executor)
                    self._io_executor.shutdown(wait=True)
                    self._io_executor = None
                    self._io_executor_size = 0
                self._closed = True

    def __enter__(self) -> "FDB":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


@dataclasses.dataclass
class RecoveryReport:
    """What one :meth:`FDB.recover` sweep found (see its docstring).

    ``expired``: purged TTL-lapsed leases, as dicts with ``resource`` /
    ``owner`` / ``lo`` / ``hi`` / ``epoch``.  ``quarantined``: orphaned
    dirty intents — dicts with ``resource`` / ``owner`` / ``chunk_ids`` /
    ``client`` — whose chunks must be re-driven by a live writer.
    ``stale``: catalogue element values from layout generations newer than
    the live one (half-flipped reshard debris), report-only.
    """
    scope: str
    expired: List[Dict[str, object]]
    quarantined: List[Dict[str, object]]
    stale: List[str]

    @property
    def clean(self) -> bool:
        return not (self.expired or self.quarantined or self.stale)

    @property
    def orphan_chunks(self) -> int:
        return sum(len(q["chunk_ids"]) for q in self.quarantined)


class WriterSession:
    """One logical writer identity on an FDB client — the unit multi-writer
    safety is built around.

    A session carries three things a bare client cannot:

    * **identity** — ``writer_id``, the lease *owner* string used by the
      catalogue-level lease table;
    * **a lease ledger** — every chunk-range lease acquired through the
      session is recorded with its epoch, validated by :meth:`check_lease`
      (epoch fencing) before lease-protected archives commit, and released
      at :meth:`close`;
    * **a per-session flush barrier** — :attr:`dirty` tracks whether *this
      session* archived since the last client flush, so visibility
      decisions (rule 3: the RMW pre-flush) are made per session, not per
      client.  That is sound precisely *because* of leases: the chunks a
      leased writer read-modify-writes are covered by its own lease, so no
      other session's unflushed archives can be hiding under them —
      another session's dirty state is irrelevant to this session's reads.
      ``flush()`` remains a client-level barrier (one store flush publishes
      everything), which clears every session's dirty flag at once.

    Sessions are cheap; open one per producer task
    (``fdb.session("rank3")``).  :meth:`close` flushes if the session is
    dirty *before* releasing its leases — releasing a lease over unflushed
    chunks would let the next holder RMW stale bytes and race our late
    flush, the exact silent merge leases exist to prevent.
    """

    def __init__(self, fdb: FDB, writer_id: str,
                 lease_ttl: Optional[float] = None,
                 heartbeat_interval: Optional[float] = None,
                 lease_block: bool = False,
                 lease_timeout: Optional[float] = None):
        self.fdb = fdb
        self.writer_id = writer_id
        self.lease_ttl = lease_ttl
        #: session-level acquire posture: plans and bare acquire_lease()
        #: calls default to these, so a "workflow" session waits out
        #: transient overlap instead of raising LeaseConflictError
        self.lease_block = lease_block
        self.lease_timeout = lease_timeout
        self._dirty = False
        self._seq = 0           # archive sequence, see FDB.flush's markers
        self._closed = False
        self._lock = NamedLock("fdb.session")
        #: (dataset, collocation, resource, lo, hi) -> epoch
        self._held: Dict[Tuple[Identifier, Identifier, str, int, int],
                         int] = {}
        self._hb_stop = threading.Event()
        self._hb_thread: Optional["threading.Thread"] = None
        if heartbeat_interval is not None:
            if lease_ttl is None:
                raise ValueError("heartbeat_interval requires lease_ttl "
                                 "(there is nothing to renew without one)")
            # lint: disable=L005 -- the lease-heartbeat daemon is part of
            # the session lifecycle, stopped/joined in close(); Event.wait
            # paces it so stop is prompt and no bare sleep is involved
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop, args=(heartbeat_interval,),
                name=f"lease-heartbeat-{writer_id}", daemon=True)
            self._hb_thread.start()

    def _heartbeat_loop(self, interval: float) -> None:
        while not self._hb_stop.wait(interval):
            try:
                self.heartbeat()
            except Exception:
                # the daemon must not die on a transient renew hiccup; a
                # genuinely lost lease surfaces at the next fencing gate
                # (check_held / check_lease), with full context
                pass

    def _stop_heartbeat(self) -> None:
        self._hb_stop.set()
        thread, self._hb_thread = self._hb_thread, None
        if thread is not None:
            thread.join(timeout=5)

    def heartbeat(self, ttl: Optional[float] = None) -> int:
        """Renew the TTL on every (dataset, collocation, resource) group
        this session's ledger covers, preserving epochs; returns the number
        of leases renewed.  0 with a non-empty ledger means the TTLs
        already lapsed — the session's next fencing gate will raise."""
        ttl = ttl if ttl is not None else self.lease_ttl
        with self._lock:
            groups = {(d, c, r) for (d, c, r, _lo, _hi) in self._held}
        renewed = 0
        for dataset, collocation, resource in groups:
            renewed += self.fdb._renew_split(dataset, collocation, resource,
                                             self.writer_id, ttl)
        return renewed

    def abandon(self) -> None:
        """Simulate writer death (test/chaos hook): stop heartbeating and
        mark the session closed WITHOUT flushing or releasing anything —
        its leases must lapse by TTL and its journaled dirty intents wait
        for :meth:`FDB.recover`."""
        self._stop_heartbeat()
        self._closed = True

    def _bump_dirty(self) -> None:
        with self._lock:
            self._seq += 1
            self._dirty = True

    def _dirty_mark(self) -> int:
        with self._lock:
            return self._seq

    def _clear_dirty_if(self, mark: int) -> None:
        """Clear dirty unless an archive landed after ``mark`` was captured
        (that archive may not be covered by the flush that just ran)."""
        with self._lock:
            if self._seq == mark:
                self._dirty = False

    def __repr__(self) -> str:
        return (f"WriterSession({self.writer_id!r}, "
                f"leases={len(self._held)}, dirty={self._dirty}"
                + (", closed" if self._closed else "") + ")")

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                f"writer session {self.writer_id!r} is closed")

    # -- leases --------------------------------------------------------------
    def _ledger_key(self, identifier, resource: str, lo: int, hi: int):
        dataset, collocation = self.fdb._lease_split(identifier)
        return (dataset, collocation, str(resource), int(lo), int(hi))

    def holds(self, identifier, resource: str, lo: int, hi: int) -> bool:
        """True when this session's ledger records a lease on exactly
        ``[lo, hi)`` (used by plans to tell a fresh acquire from a
        re-acquire they must not release)."""
        key = self._ledger_key(identifier, resource, lo, hi)
        with self._lock:
            return key in self._held

    def acquire_lease(self, identifier, resource: str, lo: int, hi: int,
                      block: Optional[bool] = None,
                      timeout: Optional[float] = None) -> int:
        """Acquire ``[lo, hi)`` for this session's writer id and ledger it;
        returns the epoch.  Raises ``LeaseConflictError`` on overlap with
        another owner; re-acquiring a ledgered range is idempotent (and
        re-arms its TTL).  ``block=True`` queues on a conflicting range
        until it frees or ``timeout`` seconds pass; both default to the
        session's ``lease_block``/``lease_timeout`` posture.  The session's
        ``lease_ttl`` (if any) applies to every lease acquired here."""
        self._check_open()
        if block is None:
            block = self.lease_block
        if timeout is None:
            timeout = self.lease_timeout
        epoch = self.fdb.acquire_lease(identifier, resource, lo, hi,
                                       owner=self.writer_id,
                                       ttl=self.lease_ttl, block=block,
                                       timeout=timeout)
        key = self._ledger_key(identifier, resource, lo, hi)
        with self._lock:
            self._held[key] = epoch
        return epoch

    def release_lease(self, identifier, resource: str, lo: int,
                      hi: int) -> None:
        """Release this session's lease on exactly ``[lo, hi)`` and drop it
        from the ledger.  Holder-side release is *exact-range*: a session
        may hold overlapping leases (two plans over intersecting windows),
        and giving one back must not sweep away its siblings — overlap
        release is the coordinator's tool (:meth:`FDB.release_lease`)."""
        dataset, collocation = self.fdb._lease_split(identifier)
        self.fdb._release_lease_split(dataset, collocation, str(resource),
                                      lo, hi, self.writer_id, exact=True)
        with self._lock:
            self._held.pop((dataset, collocation, str(resource), int(lo),
                            int(hi)), None)

    def check_lease(self, identifier, resource: str, lo: int, hi: int,
                    epoch: int) -> None:
        """Epoch-fencing gate (raises ``StaleLeaseError``) — run before
        archiving into a leased range."""
        self.fdb.check_lease(identifier, resource, lo, hi,
                             owner=self.writer_id, epoch=epoch)

    def check_held(self) -> None:
        """Validate every ledgered lease is still current (epoch fencing);
        raises ``StaleLeaseError`` on the first broken one."""
        with self._lock:
            held = list(self._held.items())
        for (dataset, collocation, resource, lo, hi), epoch in held:
            self.fdb.catalogue.check_lease(dataset, collocation, resource,
                                           lo, hi, self.writer_id, epoch)

    def lease_holders(self, identifier, resource: str) -> List[Lease]:
        return self.fdb.lease_holders(identifier, resource)

    @property
    def held_leases(self) -> List[Tuple[Identifier, Identifier, str, int,
                                        int, int]]:
        """Ledger snapshot: (dataset, collocation, resource, lo, hi,
        epoch) per held lease."""
        with self._lock:
            return [k + (e,) for k, e in sorted(self._held.items(),
                                                key=lambda kv: kv[0][2:])]

    def release_all(self) -> None:
        """Release every ledgered lease (stale entries release as no-ops)."""
        with self._lock:
            held, self._held = list(self._held), {}
        for dataset, collocation, resource, lo, hi in held:
            self.fdb._release_lease_split(dataset, collocation, resource,
                                          lo, hi, self.writer_id,
                                          exact=True)

    def mark_dirty_chunks(self, identifier, resource: str,
                          chunk_ids: Sequence[int]) -> None:
        """Journal this writer's archived-but-unflushed ``chunk_ids`` in
        the deployment-shared dirty-intent journal (crash-recovery
        breadcrumbs for :meth:`FDB.recover`); cleared by the client's next
        published flush."""
        self.fdb.mark_dirty_chunks(identifier, resource, self.writer_id,
                                   chunk_ids)

    # -- archive / visibility (the FDB surface plans consume) ----------------
    # each archive entry point installs the session's lease re-validation
    # as the facade retry's on_retry hook: a retried archive re-fences
    # before re-archiving (StaleLeaseError beats silent double-archive)
    def archive(self, identifier, data: BytesLike) -> FieldLocation:
        self._check_open()
        token = _ON_RETRY.set(self.check_held)
        try:
            loc = self.fdb.archive(identifier, data)
        finally:
            _ON_RETRY.reset(token)
        self._bump_dirty()
        return loc

    def archive_batch(self, items) -> List[FieldLocation]:
        self._check_open()
        token = _ON_RETRY.set(self.check_held)
        try:
            locs = self.fdb.archive_batch(items)
        finally:
            _ON_RETRY.reset(token)
        if items:
            self._bump_dirty()
        return locs

    def archive_many(self, items, parallelism: Optional[int] = None,
                     executor=None) -> List[FieldLocation]:
        self._check_open()
        items = list(items)
        token = _ON_RETRY.set(self.check_held)
        try:
            locs = self.fdb.archive_many(items, parallelism=parallelism,
                                         executor=executor)
        finally:
            _ON_RETRY.reset(token)
        if items:
            self._bump_dirty()
        return locs

    def archive_placement(self, identifier) -> PlacementHandle:
        return self.fdb.archive_placement(identifier)

    def retrieve(self, identifiers) -> MultiHandle:
        return self.fdb.retrieve(identifiers)

    def retrieve_handle(self, identifier) -> Optional[DataHandle]:
        return self.fdb.retrieve_handle(identifier)

    @property
    def dirty(self) -> bool:
        """True while *this session* has archived data not yet covered by a
        client flush — the per-session rule-3 barrier state."""
        return self._dirty

    def flush(self) -> None:
        """Client-level flush (publishes everything archived on the client;
        clears every session's dirty flag, this one's included)."""
        with self.fdb.tracer.span("session.commit", writer=self.writer_id):
            self.fdb.flush()

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Flush if dirty, then release all held leases.  Order matters:
        a lease released over unflushed chunks would let its next holder
        RMW bytes that are not yet visible and then race this client's
        late flush — the silent merge leases exist to prevent."""
        if self._closed:
            return
        self._stop_heartbeat()
        with self.fdb.tracer.span("session.close", writer=self.writer_id,
                                  leases=len(self._held)):
            if self._dirty:
                self.fdb.flush()
            self.release_all()
        self._closed = True

    def __enter__(self) -> "WriterSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# late imports to avoid cycles
from .backends.daos import DaosCatalogue, DaosStore          # noqa: E402
from .backends.posix import LustreSim, PosixCatalogue, PosixStore  # noqa: E402
from .backends.rados import RadosCatalogue, RadosStore       # noqa: E402
from .backends.s3 import S3Store                             # noqa: E402

"""DataHandles: backend-specific readers with merge support (thesis §2.7.1).

A ``Store.retrieve()`` returns a :class:`DataHandle` without performing I/O;
data is only read when the handle is consumed.  Handles from the same backend
may support *merging*, so that a multi-object ``FDB.retrieve()`` issues as few
I/O operations as possible (adjacent file ranges coalesce into single reads —
the POSIX backend's key read optimisation).
"""
from __future__ import annotations

import bisect
import dataclasses
import json
from typing import Callable, List, Optional, Sequence, Tuple


class ShortReadError(IOError):
    """A backend returned fewer bytes than a handle's range requires.

    Raised instead of silently returning short data: a range not covered by
    any coalesced segment means the storage unit is truncated or the data is
    not yet visible (unflushed writer, FDB rule 3)."""


@dataclasses.dataclass(frozen=True)
class FieldLocation:
    """A URI-like descriptor of where an object's bytes live.

    ``scheme`` identifies the backend family ("posix", "daos", "rados", "s3");
    the remaining parts are backend-interpreted.
    """

    scheme: str
    container: str          # dataset dir / DAOS container / RADOS namespace / bucket
    unit: str               # file path / array OID / object name / S3 key
    offset: int
    length: int
    pool: str = ""          # DAOS pool / RADOS pool ("" where n/a)

    def uri(self) -> str:
        return (f"{self.scheme}://{self.pool}/{self.container}/{self.unit}"
                f"?offset={self.offset}&length={self.length}")

    def to_bytes(self) -> bytes:
        return json.dumps(dataclasses.asdict(self), separators=(",", ":")
                          ).encode()

    @staticmethod
    def from_bytes(b: bytes) -> "FieldLocation":
        return FieldLocation(**json.loads(b.decode()))


@dataclasses.dataclass(frozen=True)
class PlacementHandle:
    """Where an archive *would* land, resolved before any byte is written —
    the write-side analogue of a :class:`DataHandle`.

    ``unit`` names the destination storage unit when archives to the same
    (dataset, collocation) key append into one shared unit (the posix
    backend's per-writer data file); such handles are mutually mergeable, so
    :func:`group_mergeable` groups them into one batched store-level write —
    the write-side mirror of read coalescing.  ``unit=None`` means every
    archive creates its own independent object (object-store backends): the
    handle does not merge even with itself, each archive keeps its own
    in-flight op — which is what those backends want.
    """

    unit: Optional[str]

    def mergeable_with(self, other: "PlacementHandle") -> bool:
        return (self.unit is not None
                and isinstance(other, PlacementHandle)
                and other.unit == self.unit)

    def merged(self, other: "PlacementHandle") -> "PlacementHandle":
        assert self.mergeable_with(other)
        return self                     # grouping only: nothing to combine


class DataHandle:
    """Abstract reader.  ``read()`` returns the full payload bytes."""

    def read(self) -> bytes:
        raise NotImplementedError

    def length(self) -> int:
        raise NotImplementedError

    # Merging protocol ------------------------------------------------------
    def mergeable_with(self, other: "DataHandle") -> bool:
        return False

    def merged(self, other: "DataHandle") -> "DataHandle":
        raise NotImplementedError("handle does not support merging")


class MemoryHandle(DataHandle):
    def __init__(self, payload: bytes):
        self._payload = payload

    def read(self) -> bytes:
        return self._payload

    def length(self) -> int:
        return len(self._payload)


class LazyHandle(DataHandle):
    """Reads via a thunk; used by object-store backends (one object = one
    read op, no merging benefit — thesis §3.1.1 retrieve())."""

    def __init__(self, thunk: Callable[[], bytes], nbytes: int):
        self._thunk = thunk
        self._nbytes = nbytes

    def read(self) -> bytes:
        return self._thunk()

    def length(self) -> int:
        return self._nbytes


@dataclasses.dataclass(frozen=True)
class _Range:
    offset: int
    length: int


class FileRangeHandle(DataHandle):
    """Handle over one or more byte ranges of a single storage unit (file).

    Supports merging: handles over the same unit coalesce; adjacent ranges
    collapse into single larger reads.  ``reader(unit, offset, length)`` is
    supplied by the backend.
    """

    def __init__(self, reader: Callable[[str, int, int], bytes], unit: str,
                 ranges: Sequence[_Range]):
        self._reader = reader
        self._unit = unit
        self._ranges: List[_Range] = list(ranges)

    @classmethod
    def single(cls, reader: Callable[[str, int, int], bytes], unit: str,
               offset: int, length: int) -> "FileRangeHandle":
        return cls(reader, unit, [_Range(offset, length)])

    @property
    def unit(self) -> str:
        return self._unit

    @property
    def ranges(self) -> List[_Range]:
        return list(self._ranges)

    def length(self) -> int:
        return sum(r.length for r in self._ranges)

    def read(self) -> bytes:
        # Issue coalesced I/O, but return bytes in *request* order.  Each
        # requested range lies inside exactly one coalesced segment by
        # construction, found by bisect on the sorted segment offsets; a
        # range a (short) segment does not cover raises ShortReadError
        # instead of silently dropping bytes.
        segments = [(r.offset, self._reader(self._unit, r.offset, r.length))
                    for r in self._coalesced()]
        seg_offs = [off for off, _ in segments]
        out = bytearray()
        for r in self._ranges:
            i = bisect.bisect_right(seg_offs, r.offset) - 1
            seg = segments[i][1] if i >= 0 else b""
            lo = r.offset - seg_offs[i] if i >= 0 else 0
            if i < 0 or lo + r.length > len(seg):
                raise ShortReadError(
                    f"range [{r.offset}, {r.offset + r.length}) of "
                    f"{self._unit!r} not covered by any read segment "
                    f"(got {len(seg)} bytes at {seg_offs[i] if i >= 0 else 0})")
            out += seg[lo:lo + r.length]
        return bytes(out)

    def read_ops(self) -> int:
        """Number of I/O operations a read() will issue (for benchmarks)."""
        return len(self._coalesced())

    def _coalesced(self) -> List[_Range]:
        rs = sorted(self._ranges, key=lambda r: r.offset)
        out: List[_Range] = []
        for r in rs:
            if out and out[-1].offset + out[-1].length >= r.offset:
                end = max(out[-1].offset + out[-1].length,
                          r.offset + r.length)
                out[-1] = _Range(out[-1].offset, end - out[-1].offset)
            else:
                out.append(r)
        return out

    def mergeable_with(self, other: DataHandle) -> bool:
        return isinstance(other, FileRangeHandle) and other._unit == self._unit

    def merged(self, other: DataHandle) -> "FileRangeHandle":
        assert isinstance(other, FileRangeHandle) and other._unit == self._unit
        return FileRangeHandle(self._reader, self._unit,
                               self._ranges + other._ranges)


class MultiHandle(DataHandle):
    """Concatenation of several handles, merging mergeable neighbours.

    This is what the top-level ``FDB.retrieve()`` returns for multi-object
    requests.  Per-object boundaries are preserved via :meth:`parts`.
    """

    def __init__(self, handles: Sequence[DataHandle]):
        self._parts: List[DataHandle] = list(handles)
        # Build the merged I/O plan: group consecutive mergeable handles.
        plan: List[DataHandle] = []
        for h in self._parts:
            if plan and plan[-1].mergeable_with(h):
                plan[-1] = plan[-1].merged(h)
            else:
                plan.append(h)
        self._plan = plan

    def parts(self) -> List[DataHandle]:
        return list(self._parts)

    def length(self) -> int:
        return sum(h.length() for h in self._parts)

    def read(self) -> bytes:
        return b"".join(h.read() for h in self._plan)

    def read_parts(self) -> List[bytes]:
        """Read and split back into per-object payloads."""
        blob = self.read()
        out, pos = [], 0
        for h in self._parts:
            n = h.length()
            out.append(blob[pos:pos + n])
            pos += n
        return out

    def read_ops(self) -> int:
        ops = 0
        for h in self._plan:
            ops += h.read_ops() if isinstance(h, FileRangeHandle) else 1
        return ops


def group_mergeable(handles: Sequence[DataHandle]) -> List[List[int]]:
    """Partition handle positions into coalescible groups.

    Handles that are mutually mergeable (same storage unit, for
    :class:`FileRangeHandle`) land in one group regardless of where they sit
    in the sequence — unlike :class:`MultiHandle`, which only merges
    *consecutive* neighbours, this sees an interleaved fetch plan.
    Non-mergeable handles (object-store :class:`LazyHandle`) get singleton
    groups.  Returns index groups in first-appearance order, so a caller can
    issue one I/O batch per group and scatter results back by position.

    A handle that cannot merge even with itself can never join a group, so
    only merge-capable representatives are scanned — a full object-store
    read of n chunks costs O(n), not O(n²) singleton probes; merge-capable
    handles cost O(n · distinct storage units).
    """
    groups: List[List[int]] = []
    merge_reps: List[Tuple[int, DataHandle]] = []
    for i, h in enumerate(handles):
        if not h.mergeable_with(h):
            groups.append([i])
            continue
        for gi, rep in merge_reps:
            if rep.mergeable_with(h):
                groups[gi].append(i)
                break
        else:
            merge_reps.append((len(groups), h))
            groups.append([i])
    return groups

"""Small shared utilities for the core storage layer."""
from __future__ import annotations

import zlib


def stable_hash(s: str) -> int:
    """Deterministic (process-independent) non-negative hash of a string.

    Python's builtin ``hash`` is salted per process (PYTHONHASHSEED) which
    would make object placement non-reproducible across runs; algorithmic
    placement (thesis §2.3/§2.4) must be deterministic.
    """
    return zlib.crc32(s.encode("utf-8")) & 0xFFFFFFFF

"""Chunk-range leases: the catalogue-level concurrency-control primitive
that makes the tensorstore safely multi-writer.

The paper's operational workload is inherently multi-writer — many model
I/O-server tasks archive fields into one FDB concurrently — and the related
DAOS/NWP work (arXiv:2404.03107, arXiv:2208.06752) shows that *contention
behaviour*, not single-stream bandwidth, is where object stores win.  The
FDB's own schema answer (a collocation key per writer process) keeps the
*index* contention-free but leaves the data racy the moment two writers
share one logical array: chunk keys collide, and the partial-write RMW path
turns a silent last-flush-wins race into observable data loss.

A :class:`LeaseTable` closes that gap with the classic range-lock design:

* leases cover **half-open ranges** ``[lo, hi)`` of linearised chunk ids
  under a ``(dataset, collocation, resource)`` key — the resource names the
  chunk-id space (the tensorstore uses the array's live layout generation,
  so leases can never outlive a re-layout);
* an acquire that **overlaps another owner's** active lease raises
  :class:`LeaseConflictError` — writers fail fast at *plan* time, before a
  single byte moves;
* every acquire is stamped with a key-scoped, monotonically increasing
  **epoch**.  A lease may be broken by a third party (``release`` takes the
  owner explicitly — the coordinator pattern for presumed-dead writers);
  once the range is re-acquired the old holder's epoch can never validate
  again, so its late archives are rejected with :class:`StaleLeaseError`
  instead of silently merged — Gray/Lampson-style epoch fencing.

One table per *simulated deployment*: :func:`shared_lease_table` attaches a
table to the shared engine/sim object (``repro.core.fdb.shared_engine`` /
``LustreSim``), so every FDB client of one deployment — writer and reader
"processes" alike — sees the same lease state, exactly like a lease KV
living inside the real catalogue would behave.  Lease traffic is
control-plane: it is deliberately *not* metered as data-path ops, so
planning-time lease acquisition keeps benchmark meters clean.

This module imports nothing above ``repro.obs`` (the stdlib-only bottom
layer); both the interfaces and every backend reach for it without
creating a cycle.  Its locks are :class:`repro.obs.locks.NamedLock`\\ s
(``lease.table`` / ``lease.host``) so the lock-order recorder sees them.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.obs.locks import NamedLock

Key = Tuple[str, str, str]          # (dataset, collocation, resource) labels

#: the deployment's shared lease clock (seconds).  ``time.perf_counter``
#: by design: the same clock domain as span timestamps
#: (``perf_counter_ns``), so the protocol checker can order lease expiry
#: (TTLs on ``lease.acquire``/``lease.renew`` spans) against traced
#: ``fdb.recover`` events.  Unit tests may install a fake clock via
#: :func:`set_lease_clock`; protocol-checked tests must not (the span
#: clock stays real, and the two domains would diverge).
_CLOCK: Callable[[], float] = time.perf_counter


def set_lease_clock(clock: Optional[Callable[[], float]] = None
                    ) -> Callable[[], float]:
    """Install a fake lease clock (``None`` restores ``perf_counter``);
    returns the previous clock so tests can restore it."""
    global _CLOCK
    prev = _CLOCK
    _CLOCK = time.perf_counter if clock is None else clock
    return prev


def lease_clock() -> float:
    """Now, on the deployment's shared lease clock."""
    return _CLOCK()


class LeaseError(RuntimeError):
    """Base class for lease-protocol violations."""


class LeaseConflictError(LeaseError):
    """An acquire overlapped another owner's active lease.

    Raised at *plan* time by lease-aware writers (the tensorstore
    ``WritePlan``): overlapping writers are rejected before any data
    moves, rather than racing to a last-flush-wins merge."""


class StaleLeaseError(LeaseError):
    """An epoch-fenced commit check failed: the lease backing a write is no
    longer current (released and/or re-acquired since).  The late writer's
    archives must be abandoned, not merged."""


@dataclasses.dataclass(frozen=True)
class Lease:
    """One active lease: ``owner`` holds ``[lo, hi)`` at ``epoch``.

    ``expires_at`` (lease-clock seconds; None = no TTL) is the liveness
    bound: past it the lease is treated as released everywhere — a
    crashed writer's ranges free themselves without a coordinator.  A
    live holder keeps its TTL ahead via heartbeat renewal
    (:meth:`LeaseTable.renew`); epoch fencing makes expiry safe exactly
    like a third-party release — the expired holder's late commit
    checks fail ``StaleLeaseError``.
    """
    owner: str
    lo: int
    hi: int
    epoch: int
    expires_at: Optional[float] = None

    def overlaps(self, lo: int, hi: int) -> bool:
        return self.lo < hi and lo < self.hi

    def covers(self, lo: int, hi: int) -> bool:
        return self.lo <= lo and hi <= self.hi

    def expired(self, now: float) -> bool:
        return self.expires_at is not None and now >= self.expires_at


class LeaseTable:
    """Thread-safe range-lease state for one simulated deployment.

    Keys are ``(dataset, collocation, resource)`` label triples; each key
    carries its own active-lease list and monotonic epoch counter.  All
    methods are O(active leases per key) — lease counts are small (one per
    concurrent writer window), so no interval tree is needed.
    """

    def __init__(self) -> None:
        self._leases: Dict[Key, List[Lease]] = {}
        self._epochs: Dict[Key, int] = {}
        self._lock = NamedLock("lease.table")
        #: release/expiry wake-ups for blocking acquires
        self._cond = threading.Condition(self._lock)
        #: expiry listeners, called OUTSIDE the table lock with the list
        #: of (key, lease) pairs just purged — FDB clients hang their
        #: ``lease.expired`` counters here
        self._listeners: List[Callable[[List[Tuple[Key, Lease]]], None]] = []
        #: dirty-intent journal: chunk ids archived under a lease but not
        #: yet covered by a flush barrier, per key -> owner -> (ids,
        #: archiving client).  Deployment-shared (it lives on this table)
        #: so ``fdb.recover()`` on *any* client can see a dead writer's
        #: torn state — the backends' own unflushed archives are
        #: client-local and invisible.
        self._dirty: Dict[Key, Dict[str, Tuple[Set[int], str]]] = {}

    # -- expiry plumbing -----------------------------------------------------
    def add_expiry_listener(self, fn: Callable[[List[Tuple[Key, Lease]]],
                                               None]) -> None:
        """Register ``fn`` to observe every batch of TTL-purged leases."""
        with self._lock:
            self._listeners.append(fn)

    def _notify_expired(self, expired: List[Tuple[Key, Lease]]) -> None:
        # outside the table lock: listeners bump metrics/log freely
        if expired:
            for fn in list(self._listeners):
                fn(expired)

    def _purge_locked(self) -> List[Tuple[Key, Lease]]:
        """Drop every expired lease (all keys — tables are small) and
        return them; wakes blocking acquires.  Caller holds the lock and
        must run :meth:`_notify_expired` after releasing it."""
        now = _CLOCK()
        out: List[Tuple[Key, Lease]] = []
        for key, active in self._leases.items():
            gone = [l for l in active if l.expired(now)]
            if gone:
                active[:] = [l for l in active if not l.expired(now)]
                out.extend((key, l) for l in gone)
        if out:
            self._cond.notify_all()
        return out

    def acquire(self, key: Key, owner: str, lo: int, hi: int,
                ttl: Optional[float] = None, block: bool = False,
                timeout: Optional[float] = None) -> int:
        """Acquire ``[lo, hi)`` for ``owner``; returns the lease epoch.

        Overlap with *another* owner's active lease raises
        :class:`LeaseConflictError` (listing the holders) — unless
        ``block=True``, in which case the acquire queues: it waits for
        release or TTL expiry of every blocker, up to ``timeout`` seconds
        (None = wait forever), then raises ``LeaseConflictError`` with
        the timeout noted.  An exact re-acquire of a range the owner
        already holds is idempotent — it returns the existing epoch and
        re-arms the TTL; a new (even self-overlapping) range records a
        fresh lease under the next epoch.  ``ttl`` (lease-clock seconds,
        None = no expiry) bounds the lease's life between renewals.
        """
        if not isinstance(lo, int) or not isinstance(hi, int) or lo >= hi:
            raise ValueError(f"lease range [{lo}, {hi}) must be a non-empty "
                             f"half-open int range")
        deadline = None if timeout is None else _CLOCK() + timeout
        expired: List[Tuple[Key, Lease]] = []
        try:
            with self._cond:
                while True:
                    expired.extend(self._purge_locked())
                    now = _CLOCK()
                    active = self._leases.setdefault(key, [])
                    blockers = [l for l in active
                                if l.owner != owner and l.overlaps(lo, hi)]
                    if not blockers:
                        for i, l in enumerate(active):
                            if (l.owner == owner and l.lo == lo
                                    and l.hi == hi):
                                # idempotent re-acquire: TTL re-arms
                                active[i] = dataclasses.replace(
                                    l, expires_at=(None if ttl is None
                                                   else now + ttl))
                                return l.epoch
                        epoch = self._epochs.get(key, 0) + 1
                        self._epochs[key] = epoch
                        active.append(Lease(owner, lo, hi, epoch,
                                            None if ttl is None
                                            else now + ttl))
                        return epoch
                    held = ", ".join(f"{l.owner}:[{l.lo},{l.hi})@e{l.epoch}"
                                     for l in blockers)
                    if not block:
                        raise LeaseConflictError(
                            f"chunk range [{lo}, {hi}) of {key} is leased "
                            f"by {held}; overlapping writers must wait for "
                            f"release")
                    remaining = None if deadline is None else deadline - now
                    if remaining is not None and remaining <= 0:
                        raise LeaseConflictError(
                            f"blocking acquire of [{lo}, {hi}) on {key} "
                            f"timed out after {timeout}s; still leased by "
                            f"{held}")
                    # wake on release/expiry notifies, the earliest
                    # blocker TTL, or a short poll (a fake lease clock
                    # cannot drive the real condvar timeout)
                    waits = [0.05]
                    if remaining is not None:
                        waits.append(remaining)
                    waits.extend(l.expires_at - now for l in blockers
                                 if l.expires_at is not None
                                 and l.expires_at > now)
                    self._cond.wait(max(0.001, min(waits)))
        finally:
            self._notify_expired(expired)

    def release(self, key: Key, owner: str, lo: int, hi: int,
                exact: bool = False) -> None:
        """Release ``owner``'s leases overlapping ``[lo, hi)`` — or, with
        ``exact=True``, only a lease on exactly that range.

        Overlap release is the *coordinator* escape hatch for
        presumed-dead writers (any caller may break any owner's lease;
        epoch fencing makes that safe — the broken holder's later commit
        checks fail).  Exact release is what a lease *holder* uses to give
        back one of its own ranges: an owner may legitimately hold
        overlapping leases (two plans of one session over intersecting
        windows), and releasing one must not sweep away its siblings.
        Releasing a range nobody holds is a no-op.
        """
        with self._lock:
            active = self._leases.get(key)
            if active is not None:
                if exact:
                    active[:] = [l for l in active
                                 if not (l.owner == owner and l.lo == lo
                                         and l.hi == hi)]
                else:
                    active[:] = [l for l in active
                                 if not (l.owner == owner
                                         and l.overlaps(lo, hi))]
                self._cond.notify_all()     # blocked acquires may proceed

    def holders(self, key: Key) -> List[Lease]:
        """All active (unexpired) leases under ``key`` (snapshot, sorted
        by range)."""
        expired: List[Tuple[Key, Lease]] = []
        try:
            with self._lock:
                expired.extend(self._purge_locked())
                return sorted(self._leases.get(key, ()),
                              key=lambda l: (l.lo, l.hi, l.owner))
        finally:
            self._notify_expired(expired)

    def check(self, key: Key, owner: str, lo: int, hi: int,
              epoch: int) -> None:
        """Fencing check: raise :class:`StaleLeaseError` unless ``owner``
        still holds an active lease at exactly ``epoch`` covering
        ``[lo, hi)`` — the commit-time gate a lease-holding writer runs
        before archiving into its range.  An expired lease fails exactly
        like a released one (expiry purges first)."""
        expired: List[Tuple[Key, Lease]] = []
        try:
            with self._lock:
                expired.extend(self._purge_locked())
                for l in self._leases.get(key, ()):
                    if (l.owner == owner and l.epoch == epoch
                            and l.covers(lo, hi)):
                        return
                current = self._epochs.get(key, 0)
        finally:
            self._notify_expired(expired)
        raise StaleLeaseError(
            f"lease [{lo}, {hi})@e{epoch} of {key} held by {owner!r} is no "
            f"longer current (key epoch {current}); the range was released "
            f"or re-acquired — abandon this writer's pending archives")

    def renew(self, key: Key, owner: str,
              ttl: Optional[float] = None) -> int:
        """Heartbeat: re-arm the TTL of every active lease ``owner``
        holds under ``key`` (epochs preserved — renewal is not a
        re-acquire).  Returns the number of leases renewed; 0 means the
        owner holds nothing live (its leases expired — the heartbeat
        arrived too late and the next commit check will fence it)."""
        expired: List[Tuple[Key, Lease]] = []
        try:
            with self._lock:
                expired.extend(self._purge_locked())
                now = _CLOCK()
                active = self._leases.get(key, [])
                n = 0
                for i, l in enumerate(active):
                    if l.owner == owner:
                        active[i] = dataclasses.replace(
                            l, expires_at=(None if ttl is None
                                           else now + ttl))
                        n += 1
                return n
        finally:
            self._notify_expired(expired)

    def purge_expired(self, prefix: Optional[Tuple[str, str]] = None
                      ) -> List[Tuple[Key, Lease]]:
        """Purge every expired lease now and return the purged pairs —
        filtered to keys whose (dataset, collocation) labels match
        ``prefix`` when given (the whole table is still purged).  The
        explicit entry point ``fdb.recover()`` drives."""
        with self._lock:
            expired = self._purge_locked()
        self._notify_expired(expired)
        if prefix is not None:
            expired = [(k, l) for k, l in expired if k[:2] == tuple(prefix)]
        return expired

    # -- dirty-intent journal (crash recovery) -------------------------------
    def mark_dirty(self, key: Key, owner: str, chunk_ids, client: str
                   ) -> None:
        """Journal chunk ids ``owner`` archived under ``key`` through
        ``client`` that are not yet covered by that client's flush
        barrier.  Cleared by :meth:`clear_dirty_client` at flush; what
        survives with no live lease is a dead writer's torn state, found
        by :meth:`take_orphans`."""
        with self._lock:
            per_owner = self._dirty.setdefault(key, {})
            chunks, _client = per_owner.get(owner, (set(), client))
            per_owner[owner] = (chunks | {int(c) for c in chunk_ids},
                                str(client))

    def clear_dirty_client(self, client: str) -> None:
        """Drop every dirty intent archived through ``client`` — its
        flush barrier just published those chunks (client-level, like
        the barrier itself: one flush covers all the client's owners)."""
        with self._lock:
            for key in list(self._dirty):
                per_owner = self._dirty[key]
                for owner in list(per_owner):
                    if per_owner[owner][1] == client:
                        del per_owner[owner]
                if not per_owner:
                    del self._dirty[key]

    def dirty_intents(self, key: Key) -> Dict[str, List[int]]:
        """Snapshot of the journal under ``key``: owner -> chunk ids."""
        with self._lock:
            return {o: sorted(cs)
                    for o, (cs, _c) in self._dirty.get(key, {}).items()}

    def take_orphans(self, prefix: Optional[Tuple[str, str]] = None
                     ) -> List[Tuple[Key, str, List[int], str]]:
        """Remove and return every dirty intent whose owner no longer
        holds *any* active lease under its key — the archived-but-
        unflushed chunks of dead (expired/released) writers, as
        ``(key, owner, chunk_ids, client)``.  Intents under a live lease
        are left alone: their writer may still be flushing."""
        expired: List[Tuple[Key, Lease]] = []
        out: List[Tuple[Key, str, List[int], str]] = []
        try:
            with self._lock:
                expired.extend(self._purge_locked())
                for key in list(self._dirty):
                    if prefix is not None and key[:2] != tuple(prefix):
                        continue
                    live = {l.owner for l in self._leases.get(key, ())}
                    per_owner = self._dirty[key]
                    for owner in list(per_owner):
                        if owner not in live:
                            chunks, client = per_owner.pop(owner)
                            out.append((key, owner, sorted(chunks), client))
                    if not per_owner:
                        del self._dirty[key]
            return out
        finally:
            self._notify_expired(expired)


#: attribute under which a deployment's shared table hangs off its engine/sim
_HOST_ATTR = "_fdb_lease_table"
_HOST_LOCK = NamedLock("lease.host")


def shared_lease_table(host: object) -> LeaseTable:
    """The lease table of one simulated deployment, lazily attached to its
    process-global shared engine/sim object — so every FDB client built on
    that deployment (``shared_engine`` / ``LustreSim`` identity) shares
    lease state, like a lease KV inside the real catalogue would."""
    with _HOST_LOCK:
        table = getattr(host, _HOST_ATTR, None)
        if table is None:
            table = LeaseTable()
            setattr(host, _HOST_ATTR, table)
        return table


class CatalogueLeaseMixin:
    """The Catalogue lease methods, implemented once: delegate to the
    deployment's shared :class:`LeaseTable`.  A backend catalogue mixes
    this in and implements :meth:`_lease_host` to name the process-global
    shared object its deployment is keyed on (engine / LustreSim) — the
    same identity that already makes data visible across FDB clients.
    ``dataset``/``collocation`` are ``Identifier``-likes (anything with a
    ``canonical()``)."""

    def _lease_host(self) -> object:
        raise NotImplementedError

    def _lease_key(self, dataset, collocation, resource: str) -> Key:
        return (dataset.canonical(), collocation.canonical(), str(resource))

    def _leases(self) -> LeaseTable:
        return shared_lease_table(self._lease_host())

    def acquire_lease(self, dataset, collocation, resource: str, lo: int,
                      hi: int, owner: str, ttl: Optional[float] = None,
                      block: bool = False,
                      timeout: Optional[float] = None) -> int:
        return self._leases().acquire(
            self._lease_key(dataset, collocation, resource), owner, lo, hi,
            ttl=ttl, block=block, timeout=timeout)

    def release_lease(self, dataset, collocation, resource: str, lo: int,
                      hi: int, owner: str, exact: bool = False) -> None:
        self._leases().release(
            self._lease_key(dataset, collocation, resource), owner, lo, hi,
            exact=exact)

    def lease_holders(self, dataset, collocation,
                      resource: str) -> List[Lease]:
        return self._leases().holders(
            self._lease_key(dataset, collocation, resource))

    def check_lease(self, dataset, collocation, resource: str, lo: int,
                    hi: int, owner: str, epoch: int) -> None:
        self._leases().check(
            self._lease_key(dataset, collocation, resource), owner, lo, hi,
            epoch)

    def lease_table(self) -> LeaseTable:
        """The deployment's shared lease table — the facade reaches it
        directly for renewal, expiry sweeps and the dirty-intent journal
        (keeping the Catalogue interface to the four lease verbs)."""
        return self._leases()

    def lease_key(self, dataset, collocation, resource: str) -> Key:
        """The table key for (dataset, collocation, resource) — public
        twin of ``_lease_key`` for facade-level recovery code."""
        return self._lease_key(dataset, collocation, resource)


__all__ = ["Lease", "LeaseTable", "LeaseError", "LeaseConflictError",
           "StaleLeaseError", "shared_lease_table", "CatalogueLeaseMixin",
           "set_lease_clock", "lease_clock"]

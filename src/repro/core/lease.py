"""Chunk-range leases: the catalogue-level concurrency-control primitive
that makes the tensorstore safely multi-writer.

The paper's operational workload is inherently multi-writer — many model
I/O-server tasks archive fields into one FDB concurrently — and the related
DAOS/NWP work (arXiv:2404.03107, arXiv:2208.06752) shows that *contention
behaviour*, not single-stream bandwidth, is where object stores win.  The
FDB's own schema answer (a collocation key per writer process) keeps the
*index* contention-free but leaves the data racy the moment two writers
share one logical array: chunk keys collide, and the partial-write RMW path
turns a silent last-flush-wins race into observable data loss.

A :class:`LeaseTable` closes that gap with the classic range-lock design:

* leases cover **half-open ranges** ``[lo, hi)`` of linearised chunk ids
  under a ``(dataset, collocation, resource)`` key — the resource names the
  chunk-id space (the tensorstore uses the array's live layout generation,
  so leases can never outlive a re-layout);
* an acquire that **overlaps another owner's** active lease raises
  :class:`LeaseConflictError` — writers fail fast at *plan* time, before a
  single byte moves;
* every acquire is stamped with a key-scoped, monotonically increasing
  **epoch**.  A lease may be broken by a third party (``release`` takes the
  owner explicitly — the coordinator pattern for presumed-dead writers);
  once the range is re-acquired the old holder's epoch can never validate
  again, so its late archives are rejected with :class:`StaleLeaseError`
  instead of silently merged — Gray/Lampson-style epoch fencing.

One table per *simulated deployment*: :func:`shared_lease_table` attaches a
table to the shared engine/sim object (``repro.core.fdb.shared_engine`` /
``LustreSim``), so every FDB client of one deployment — writer and reader
"processes" alike — sees the same lease state, exactly like a lease KV
living inside the real catalogue would behave.  Lease traffic is
control-plane: it is deliberately *not* metered as data-path ops, so
planning-time lease acquisition keeps benchmark meters clean.

This module imports nothing above ``repro.obs`` (the stdlib-only bottom
layer); both the interfaces and every backend reach for it without
creating a cycle.  Its locks are :class:`repro.obs.locks.NamedLock`\\ s
(``lease.table`` / ``lease.host``) so the lock-order recorder sees them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.obs.locks import NamedLock

Key = Tuple[str, str, str]          # (dataset, collocation, resource) labels


class LeaseError(RuntimeError):
    """Base class for lease-protocol violations."""


class LeaseConflictError(LeaseError):
    """An acquire overlapped another owner's active lease.

    Raised at *plan* time by lease-aware writers (the tensorstore
    ``WritePlan``): overlapping writers are rejected before any data
    moves, rather than racing to a last-flush-wins merge."""


class StaleLeaseError(LeaseError):
    """An epoch-fenced commit check failed: the lease backing a write is no
    longer current (released and/or re-acquired since).  The late writer's
    archives must be abandoned, not merged."""


@dataclasses.dataclass(frozen=True)
class Lease:
    """One active lease: ``owner`` holds ``[lo, hi)`` at ``epoch``."""
    owner: str
    lo: int
    hi: int
    epoch: int

    def overlaps(self, lo: int, hi: int) -> bool:
        return self.lo < hi and lo < self.hi

    def covers(self, lo: int, hi: int) -> bool:
        return self.lo <= lo and hi <= self.hi


class LeaseTable:
    """Thread-safe range-lease state for one simulated deployment.

    Keys are ``(dataset, collocation, resource)`` label triples; each key
    carries its own active-lease list and monotonic epoch counter.  All
    methods are O(active leases per key) — lease counts are small (one per
    concurrent writer window), so no interval tree is needed.
    """

    def __init__(self) -> None:
        self._leases: Dict[Key, List[Lease]] = {}
        self._epochs: Dict[Key, int] = {}
        self._lock = NamedLock("lease.table")

    def acquire(self, key: Key, owner: str, lo: int, hi: int) -> int:
        """Acquire ``[lo, hi)`` for ``owner``; returns the lease epoch.

        Overlap with *another* owner's active lease raises
        :class:`LeaseConflictError` (listing the holders).  An exact
        re-acquire of a range the owner already holds is idempotent and
        returns the existing epoch; a new (even self-overlapping) range
        records a fresh lease under the next epoch.
        """
        if not isinstance(lo, int) or not isinstance(hi, int) or lo >= hi:
            raise ValueError(f"lease range [{lo}, {hi}) must be a non-empty "
                             f"half-open int range")
        with self._lock:
            active = self._leases.setdefault(key, [])
            blockers = [l for l in active
                        if l.owner != owner and l.overlaps(lo, hi)]
            if blockers:
                held = ", ".join(f"{l.owner}:[{l.lo},{l.hi})@e{l.epoch}"
                                 for l in blockers)
                raise LeaseConflictError(
                    f"chunk range [{lo}, {hi}) of {key} is leased by "
                    f"{held}; overlapping writers must wait for release")
            for l in active:
                if l.owner == owner and l.lo == lo and l.hi == hi:
                    return l.epoch          # idempotent re-acquire
            epoch = self._epochs.get(key, 0) + 1
            self._epochs[key] = epoch
            active.append(Lease(owner, lo, hi, epoch))
            return epoch

    def release(self, key: Key, owner: str, lo: int, hi: int,
                exact: bool = False) -> None:
        """Release ``owner``'s leases overlapping ``[lo, hi)`` — or, with
        ``exact=True``, only a lease on exactly that range.

        Overlap release is the *coordinator* escape hatch for
        presumed-dead writers (any caller may break any owner's lease;
        epoch fencing makes that safe — the broken holder's later commit
        checks fail).  Exact release is what a lease *holder* uses to give
        back one of its own ranges: an owner may legitimately hold
        overlapping leases (two plans of one session over intersecting
        windows), and releasing one must not sweep away its siblings.
        Releasing a range nobody holds is a no-op.
        """
        with self._lock:
            active = self._leases.get(key)
            if active is not None:
                if exact:
                    active[:] = [l for l in active
                                 if not (l.owner == owner and l.lo == lo
                                         and l.hi == hi)]
                else:
                    active[:] = [l for l in active
                                 if not (l.owner == owner
                                         and l.overlaps(lo, hi))]

    def holders(self, key: Key) -> List[Lease]:
        """All active leases under ``key`` (snapshot, sorted by range)."""
        with self._lock:
            return sorted(self._leases.get(key, ()),
                          key=lambda l: (l.lo, l.hi, l.owner))

    def check(self, key: Key, owner: str, lo: int, hi: int,
              epoch: int) -> None:
        """Fencing check: raise :class:`StaleLeaseError` unless ``owner``
        still holds an active lease at exactly ``epoch`` covering
        ``[lo, hi)`` — the commit-time gate a lease-holding writer runs
        before archiving into its range."""
        with self._lock:
            for l in self._leases.get(key, ()):
                if (l.owner == owner and l.epoch == epoch
                        and l.covers(lo, hi)):
                    return
            current = self._epochs.get(key, 0)
        raise StaleLeaseError(
            f"lease [{lo}, {hi})@e{epoch} of {key} held by {owner!r} is no "
            f"longer current (key epoch {current}); the range was released "
            f"or re-acquired — abandon this writer's pending archives")


#: attribute under which a deployment's shared table hangs off its engine/sim
_HOST_ATTR = "_fdb_lease_table"
_HOST_LOCK = NamedLock("lease.host")


def shared_lease_table(host: object) -> LeaseTable:
    """The lease table of one simulated deployment, lazily attached to its
    process-global shared engine/sim object — so every FDB client built on
    that deployment (``shared_engine`` / ``LustreSim`` identity) shares
    lease state, like a lease KV inside the real catalogue would."""
    with _HOST_LOCK:
        table = getattr(host, _HOST_ATTR, None)
        if table is None:
            table = LeaseTable()
            setattr(host, _HOST_ATTR, table)
        return table


class CatalogueLeaseMixin:
    """The Catalogue lease methods, implemented once: delegate to the
    deployment's shared :class:`LeaseTable`.  A backend catalogue mixes
    this in and implements :meth:`_lease_host` to name the process-global
    shared object its deployment is keyed on (engine / LustreSim) — the
    same identity that already makes data visible across FDB clients.
    ``dataset``/``collocation`` are ``Identifier``-likes (anything with a
    ``canonical()``)."""

    def _lease_host(self) -> object:
        raise NotImplementedError

    def _lease_key(self, dataset, collocation, resource: str) -> Key:
        return (dataset.canonical(), collocation.canonical(), str(resource))

    def _leases(self) -> LeaseTable:
        return shared_lease_table(self._lease_host())

    def acquire_lease(self, dataset, collocation, resource: str, lo: int,
                      hi: int, owner: str) -> int:
        return self._leases().acquire(
            self._lease_key(dataset, collocation, resource), owner, lo, hi)

    def release_lease(self, dataset, collocation, resource: str, lo: int,
                      hi: int, owner: str, exact: bool = False) -> None:
        self._leases().release(
            self._lease_key(dataset, collocation, resource), owner, lo, hi,
            exact=exact)

    def lease_holders(self, dataset, collocation,
                      resource: str) -> List[Lease]:
        return self._leases().holders(
            self._lease_key(dataset, collocation, resource))

    def check_lease(self, dataset, collocation, resource: str, lo: int,
                    hi: int, owner: str, epoch: int) -> None:
        self._leases().check(
            self._lease_key(dataset, collocation, resource), owner, lo, hi,
            epoch)


__all__ = ["Lease", "LeaseTable", "LeaseError", "LeaseConflictError",
           "StaleLeaseError", "shared_lease_table", "CatalogueLeaseMixin"]

"""Retry/backoff + deadlines: the facade-level answer to transient
storage faults.

Operational NWP archiving cannot stop because one object write hit a
transient backend error (PAPERS.md: arXiv 2404.03107 on I/O contention,
arXiv 2208.06752 on DAOS operational behaviour) — but naive retry loops
scattered through the stack are how systems double-archive, spin on
permanent failures, and hide deadlocks.  This module centralises the
policy; lint rule ``L009`` bans ``time.sleep``/hand-rolled retry loops
everywhere else, so every backoff in the repo goes through here.

Design points, in FDB terms:

* **retry only what is idempotent** — the facade retries whole archive
  units (store archive + catalogue index together): FDB rule 5
  (re-archiving an identifier *transactionally replaces* it) makes a
  re-driven archive safe even when the first attempt died between store
  and catalogue.  Retries never span a flush barrier.
* **retries compose with epoch fencing** — ``RetryPolicy.call`` takes an
  ``on_retry`` hook, run *before* every re-attempt; writer sessions
  install their lease re-validation there, so a retried archive whose
  lease was broken mid-backoff raises ``StaleLeaseError`` instead of
  silently double-archiving into a re-acquired range.
* **bounded, decorrelated** — attempts are capped, and backoff uses
  decorrelated jitter (``delay = U(base, prev * mult)``, capped), the
  AWS-style schedule that avoids retry synchronisation across writers.
* **deadlines are ambient** — a plan sets one per-plan
  :class:`Deadline` via :func:`deadline_scope`; it rides the
  ``contextvars`` context through the ``ChunkExecutor`` hand-off, so
  every facade-level retry under that plan gives up with
  :class:`DeadlineExceeded` when the *plan's* budget runs out, not just
  its own op's.

Observability: every re-attempt bumps the ``retry.attempts`` counter and
(when tracing) records a ``retry.backoff`` span around the sleep; a
bounded give-up bumps ``retry.giveups`` and re-raises the last error with
the attempt count attached as a note.

Stdlib + ``repro.obs`` only (core's bottom-layer discipline).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import random
import time
from typing import Callable, Optional, Tuple, Type

from repro.obs import trace as _trace
from repro.obs.metrics import MetricsRegistry


def _annotate(e: BaseException, note: str) -> None:
    """``add_note`` on 3.11+; an extra ``args`` element (visible in the
    rendered message) on 3.10."""
    add = getattr(e, "add_note", None)
    if add is not None:
        add(note)
    else:
        e.args = e.args + (note,)


class TransientStorageError(RuntimeError):
    """A storage op failed in a way that is expected to heal on its own
    (slow OST, transient network error, backend hiccup) — the *retryable*
    error class.  Backends and the fault injector raise it; permanent
    errors use any other exception type and propagate immediately."""


class DeadlineExceeded(RuntimeError):
    """An op (or the plan above it) ran out of its deadline budget while
    retrying.  ``__cause__`` carries the last underlying error."""


class Deadline:
    """A wall-clock budget on the shared ``perf_counter`` clock.

    Created from a relative budget in seconds; :meth:`remaining` counts
    down from there.  The same clock domain as span timestamps and lease
    expiry, so traces, leases and deadlines order consistently.
    """

    __slots__ = ("seconds", "_expiry")

    def __init__(self, seconds: float):
        self.seconds = float(seconds)
        self._expiry = time.perf_counter() + self.seconds

    def remaining(self) -> float:
        return self._expiry - time.perf_counter()

    @property
    def expired(self) -> bool:
        return self.remaining() <= 0

    def __repr__(self) -> str:
        return f"Deadline({self.seconds}s, {self.remaining():.3f}s left)"


#: the ambient per-plan deadline (see :func:`deadline_scope`) — a
#: ContextVar so it survives the executor's ``copy_context()`` hand-off
_DEADLINE: contextvars.ContextVar[Optional[Deadline]] = \
    contextvars.ContextVar("repro_retry_deadline", default=None)


def current_deadline() -> Optional[Deadline]:
    """The ambient deadline of this context, or None."""
    return _DEADLINE.get()


@contextlib.contextmanager
def deadline_scope(deadline):
    """Install ``deadline`` (a :class:`Deadline`, a float budget in
    seconds, or None for "no budget") as the ambient deadline for the
    duration of the block — what ``plan.execute(deadline=...)`` wraps its
    body in, so every retried facade op under the plan shares one budget.
    """
    if deadline is not None and not isinstance(deadline, Deadline):
        deadline = Deadline(deadline)
    token = _DEADLINE.set(deadline)
    try:
        yield deadline
    finally:
        _DEADLINE.reset(token)


@dataclasses.dataclass
class RetryPolicy:
    """Bounded retry with decorrelated-jitter backoff.

    One policy per FDB client (``FDB(..., retry=RetryPolicy(...))``);
    the default is always safe because it only engages when an op raises
    a retryable error.  ``seed`` pins the jitter sequence for
    reproducible fault-schedule tests; ``sleep`` is injectable so unit
    tests run instantly.
    """

    max_attempts: int = 4
    base_delay: float = 0.005       # first backoff lower bound (seconds)
    max_delay: float = 0.25         # per-sleep cap (seconds)
    multiplier: float = 3.0         # decorrelated-jitter growth factor
    retryable: Tuple[Type[Exception], ...] = (TransientStorageError,)
    seed: Optional[int] = None
    sleep: Callable[[float], None] = time.sleep
    op_timeout: Optional[float] = None   # per-op deadline across attempts

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        self._rng = random.Random(self.seed)

    def call(self, fn: Callable[[], object], *, op: str,
             metrics: Optional[MetricsRegistry] = None,
             on_retry: Optional[Callable[[], None]] = None,
             deadline: Optional[Deadline] = None):
        """Run ``fn()`` under this policy and return its result.

        Retryable errors are re-attempted up to ``max_attempts`` total,
        sleeping a decorrelated-jitter backoff in between; any other
        exception propagates immediately (``InjectedCrash`` is a
        ``BaseException`` precisely so no policy can swallow it).

        ``on_retry`` runs before each re-attempt; an exception it raises
        aborts the retry (a session's lease re-validation raising
        ``StaleLeaseError`` must win over the retry loop).  The op gives
        up with :class:`DeadlineExceeded` when the tightest of
        ``deadline``, the ambient :func:`deadline_scope` deadline, and
        the policy's ``op_timeout`` runs out; on plain attempt
        exhaustion it bumps ``retry.giveups`` and re-raises the last
        error with the attempt count noted.
        """
        metrics = metrics if metrics is not None else _trace.GLOBAL_TRACER.metrics
        deadlines = [d for d in (deadline, _DEADLINE.get()) if d is not None]
        if self.op_timeout is not None:
            deadlines.append(Deadline(self.op_timeout))
        prev_delay = self.base_delay
        for attempt in range(1, self.max_attempts + 1):
            try:
                return fn()
            except self.retryable as e:
                if attempt >= self.max_attempts:
                    metrics.counter("retry.giveups").inc()
                    _annotate(e, f"op {op!r} gave up after {attempt} "
                                 f"attempt(s) ({type(e).__name__})")
                    raise
                budget = min((d.remaining() for d in deadlines),
                             default=None)
                if budget is not None and budget <= 0:
                    raise DeadlineExceeded(
                        f"op {op!r} exceeded its deadline after {attempt} "
                        f"attempt(s)") from e
                metrics.counter("retry.attempts").inc()
                delay = min(self.max_delay,
                            self._rng.uniform(self.base_delay,
                                              prev_delay * self.multiplier))
                prev_delay = delay
                if budget is not None:
                    delay = min(delay, max(0.0, budget))
                if on_retry is not None:
                    on_retry()      # e.g. lease re-validation; may raise
                with _trace.span("retry.backoff", op=op, attempt=attempt,
                                 delay_us=int(delay * 1e6)):
                    self.sleep(delay)
        raise AssertionError("unreachable")  # loop always returns or raises


__all__ = ["TransientStorageError", "DeadlineExceeded", "Deadline",
           "deadline_scope", "current_deadline", "RetryPolicy"]

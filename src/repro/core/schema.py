"""Metadata identifiers and schema-driven key splitting (thesis §2.7).

Every FDB object is identified by a globally unique *metadata identifier*: a
set of key=value pairs conforming to a user-defined :class:`Schema`.  The
schema splits an identifier into three sub-keys which drive data placement:

* **dataset key** — the dataset an object belongs to (one storage container /
  directory per dataset key);
* **collocation key** — objects sharing it are collocated in storage (and
  share an index structure — the contention domain);
* **element key** — identifies the object within a collocated dataset.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Iterator, Mapping, Optional, Tuple


class Identifier(Mapping[str, str]):
    """An immutable, hashable mapping of metadata dimensions to values.

    Values are canonicalised to strings.  Ordering of keys is canonical
    (sorted) for hashing/serialisation so that logically equal identifiers
    compare equal regardless of construction order.
    """

    __slots__ = ("_items", "_hash")

    def __init__(self, mapping: Optional[Mapping[str, object]] = None, **kw: object):
        merged: Dict[str, str] = {}
        if mapping:
            for k, v in mapping.items():
                merged[str(k)] = str(v)
        for k, v in kw.items():
            merged[str(k)] = str(v)
        self._items: Tuple[Tuple[str, str], ...] = tuple(sorted(merged.items()))
        self._hash = hash(self._items)

    # Mapping protocol -----------------------------------------------------
    def __getitem__(self, key: str) -> str:
        for k, v in self._items:
            if k == key:
                return v
        raise KeyError(key)

    def __iter__(self) -> Iterator[str]:
        return (k for k, _ in self._items)

    def __len__(self) -> int:
        return len(self._items)

    def __hash__(self) -> int:
        return self._hash

    def __eq__(self, other: object) -> bool:
        if isinstance(other, Identifier):
            return self._items == other._items
        if isinstance(other, Mapping):
            return dict(self._items) == dict(other)
        return NotImplemented

    def __repr__(self) -> str:
        return "Identifier(%s)" % ", ".join(f"{k}={v}" for k, v in self._items)

    # FDB-specific helpers ---------------------------------------------------
    def canonical(self) -> str:
        """Canonical string form, usable as a storage-unit name."""
        return ",".join(f"{k}={v}" for k, v in self._items)

    @staticmethod
    def from_canonical(s: str) -> "Identifier":
        if not s:
            return Identifier()
        parts = dict(p.split("=", 1) for p in s.split(","))
        return Identifier(parts)

    def subset(self, keys: Iterable[str]) -> "Identifier":
        return Identifier({k: v for k, v in self._items if k in set(keys)})

    def merged(self, other: Mapping[str, str]) -> "Identifier":
        d = dict(self._items)
        d.update(other)
        return Identifier(d)

    def matches(self, partial: Mapping[str, object]) -> bool:
        """True if this identifier matches a *partial identifier*.

        Partial values may be a plain value or an iterable of allowed values
        (the thesis's multi-object request expressions).
        """
        for k, want in partial.items():
            if k not in self:
                return False
            have = self[k]
            if isinstance(want, (list, tuple, set, frozenset)):
                if have not in {str(w) for w in want}:
                    return False
            elif have != str(want):
                return False
        return True


@dataclasses.dataclass(frozen=True)
class Schema:
    """Defines the valid identifier dimensions and their split into
    dataset / collocation / element keys (thesis §2.7, Listing 2.1)."""

    name: str
    dataset_dims: Tuple[str, ...]
    collocation_dims: Tuple[str, ...]
    element_dims: Tuple[str, ...]

    def __post_init__(self) -> None:
        overlap = (set(self.dataset_dims) & set(self.collocation_dims)) | (
            set(self.dataset_dims) & set(self.element_dims)
        ) | (set(self.collocation_dims) & set(self.element_dims))
        if overlap:
            raise ValueError(f"schema dims appear in multiple keys: {overlap}")

    @property
    def all_dims(self) -> Tuple[str, ...]:
        return self.dataset_dims + self.collocation_dims + self.element_dims

    def validate(self, identifier: Identifier) -> None:
        missing = [d for d in self.all_dims if d not in identifier]
        if missing:
            raise KeyError(
                f"identifier {identifier!r} missing dims {missing} required by "
                f"schema {self.name!r}"
            )
        extra = [k for k in identifier if k not in self.all_dims]
        if extra:
            raise KeyError(
                f"identifier {identifier!r} has dims {extra} not in schema "
                f"{self.name!r}"
            )

    def split(self, identifier: Identifier) -> Tuple[Identifier, Identifier, Identifier]:
        """Split an identifier into (dataset, collocation, element) keys."""
        self.validate(identifier)
        return (
            identifier.subset(self.dataset_dims),
            identifier.subset(self.collocation_dims),
            identifier.subset(self.element_dims),
        )

    def join(self, dataset: Identifier, collocation: Identifier,
             element: Identifier) -> Identifier:
        return Identifier({**dict(dataset), **dict(collocation), **dict(element)})


# ---------------------------------------------------------------------------
# Standard schemas
# ---------------------------------------------------------------------------

#: The operational NWP schema used with the POSIX backends (thesis Listing 2.1):
#: many parallel writers share the same collocation key — fine for per-process
#: files, hostile to shared KV indexes.
NWP_POSIX_SCHEMA = Schema(
    name="nwp-posix",
    dataset_dims=("class", "expver", "stream", "date", "time"),
    collocation_dims=("type", "levtype"),
    element_dims=("step", "number", "levelist", "param"),
)

#: The modified schema used with the object-store backends (thesis §3.1):
#: ``number`` and ``levelist`` are promoted into the collocation key so that
#: concurrent writer processes never contend on the same index KV object.
NWP_OBJECT_SCHEMA = Schema(
    name="nwp-object",
    dataset_dims=("class", "expver", "stream", "date", "time"),
    collocation_dims=("type", "levtype", "number", "levelist"),
    element_dims=("step", "param"),
)

#: Schema for training-framework checkpoints: one dataset per (run, step) —
#: wiping a step is a container destroy; one collocation key per writing host
#: (contention-free index, the paper's C7 lever); element = tensor shard.
CHECKPOINT_SCHEMA = Schema(
    name="ckpt",
    dataset_dims=("run", "kind", "step"),
    collocation_dims=("host",),
    element_dims=("tensor", "shard"),
)

#: Schema for the FDB-backed training-data pipeline.
DATA_SCHEMA = Schema(
    name="data",
    dataset_dims=("corpus", "split"),
    collocation_dims=("producer",),
    element_dims=("shard", "batch"),
)

#: Schema for ``repro.tensorstore`` chunked N-D arrays: one dataset per
#: (store, array) — wiping an array is a container destroy, the Zarr-array ≈
#: DAOS-container mapping; one collocation key per writer process
#: (contention-free chunk index, the paper's C7 lever); element = chunk index
#: within the array (the reserved value ``meta`` holds the array metadata).
TENSOR_SCHEMA = Schema(
    name="tensor",
    dataset_dims=("store", "array"),
    collocation_dims=("writer",),
    element_dims=("chunk",),
)

SCHEMAS: Dict[str, Schema] = {
    s.name: s
    for s in (NWP_POSIX_SCHEMA, NWP_OBJECT_SCHEMA, CHECKPOINT_SCHEMA,
              DATA_SCHEMA, TENSOR_SCHEMA)
}

"""Deterministic fault injection for the Store/Catalogue pair.

The paper's operational claims are about storage that *misbehaves* —
slow OSTs, transient object-store errors, writers that die mid-commit.
This module makes those failure modes reproducible: a seeded
:class:`FaultInjector` wraps any backend's ``Store``/``Catalogue`` pair
(:class:`FaultyStore` / :class:`FaultyCatalogue` mirror the interfaces
one-to-one) and injects, per op class:

* **transient errors** (:class:`~repro.core.retry.TransientStorageError`)
  by probability (``fail(op, rate=...)``) or scripted schedule
  (``fail(op, first=N)`` — the first N calls fail, then heal), the shape
  retry policies are tested against;
* **permanent errors** (:class:`PermanentStorageError` or any exception
  type) that no retry may paper over;
* **latency spikes** (``delay(op, seconds, rate)``) for goodput-under-
  degradation benchmarking;
* **crash points** (``crash_on(op, call=N)``) raising
  :class:`InjectedCrash` — a ``BaseException``, so no retry policy can
  swallow it — which kills a writer *between archive and flush*, leaving
  genuinely torn state (archived-but-unflushed chunks, held leases,
  dirty intents) for ``fdb.recover()`` to find.

Op classes are dotted names mirroring the interface:
``store.archive``, ``store.archive_batch`` (falls back to the
``store.archive`` spec, so one schedule covers both shapes),
``store.retrieve`` (faults at handle-build time), ``store.flush``,
``catalogue.archive``, ``catalogue.archive_batch``, ``catalogue.flush``,
``catalogue.retrieve``.  Placement, listing, lease traffic and close are
deliberately fault-free: they are control-plane, and the retry layer
does not wrap them.

Wiring: ``FDB(config, faults=injector)`` wraps its freshly built
backends; everything above the facade is oblivious.  The injector is
shareable across clients (thread-safe, one seeded RNG) and its
:attr:`injected` / :attr:`counts` feed the bench's ``faults_injected``
column.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Dict, Optional, Tuple, Type

from .retry import TransientStorageError


class PermanentStorageError(RuntimeError):
    """An injected *non-retryable* storage failure: the retry layer must
    propagate it immediately (only ``TransientStorageError`` retries)."""


class InjectedCrash(BaseException):
    """A writer killed at an injected crash point.

    Deliberately a ``BaseException``: it models process death, so no
    retry policy or ``except Exception`` cleanup path may swallow it —
    the torn state it leaves behind (held leases, unflushed archives,
    dirty intents) is exactly what ``fdb.recover()`` exists to mop up.
    """


@dataclasses.dataclass
class FaultSpec:
    """Injection schedule for one op class (see :meth:`FaultInjector.fail`)."""
    rate: float = 0.0                       # P(transient fault) per call
    first: int = 0                          # scripted: fail the first N calls
    error: Type[BaseException] = TransientStorageError
    delay_s: float = 0.0                    # injected latency per spiked call
    delay_rate: float = 0.0                 # P(latency spike) per call
    crash_call: Optional[int] = None        # 1-based call number to crash on


class FaultInjector:
    """Seeded, thread-safe fault source shared by a Store/Catalogue pair.

    All decisions draw from one ``random.Random(seed)`` under a lock, so
    a given (seed, schedule, call order) replays identically — the
    property the fault-matrix tests and the chaos bench column rely on.
    """

    def __init__(self, seed: int = 0):
        self.seed = seed
        self._rng = random.Random(seed)
        self._specs: Dict[str, FaultSpec] = {}
        self._counts: Dict[str, int] = {}
        self._injected: Dict[str, int] = {}
        self._lock = threading.Lock()

    # -- schedule construction (chainable) -----------------------------------
    def fail(self, op: str, rate: float = 0.0, first: int = 0,
             error: Type[BaseException] = TransientStorageError
             ) -> "FaultInjector":
        """Inject ``error`` on ``op``: with probability ``rate`` per call
        and/or unconditionally on the first ``first`` calls."""
        spec = self._specs.setdefault(op, FaultSpec())
        spec.rate, spec.first, spec.error = rate, first, error
        return self

    def delay(self, op: str, seconds: float,
              rate: float = 1.0) -> "FaultInjector":
        """Inject a latency spike of ``seconds`` on ``op`` with
        probability ``rate`` per call (before the op runs)."""
        spec = self._specs.setdefault(op, FaultSpec())
        spec.delay_s, spec.delay_rate = seconds, rate
        return self

    def crash_on(self, op: str, call: int = 1) -> "FaultInjector":
        """Raise :class:`InjectedCrash` on the ``call``-th invocation of
        ``op`` (1-based, counted per op class) — one-shot."""
        self._specs.setdefault(op, FaultSpec()).crash_call = call
        return self

    # -- observation ---------------------------------------------------------
    @property
    def counts(self) -> Dict[str, int]:
        """Calls seen per op class (faulted or not)."""
        with self._lock:
            return dict(self._counts)

    @property
    def injected(self) -> int:
        """Total faults injected (errors + crashes, not latency spikes)."""
        with self._lock:
            return sum(self._injected.values())

    def injected_by_op(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._injected)

    # -- the injection point -------------------------------------------------
    def hit(self, op: str, fallback: Optional[str] = None) -> None:
        """Count one call of ``op`` and raise/delay per its spec (or the
        ``fallback`` op's spec when ``op`` has none — batched variants
        fall back to their per-item op class)."""
        with self._lock:
            spec = self._specs.get(op)
            if spec is None and fallback is not None:
                op, spec = fallback, self._specs.get(fallback)
            n = self._counts.get(op, 0) + 1
            self._counts[op] = n
            if spec is None:
                return
            crash = spec.crash_call is not None and n == spec.crash_call
            fault = (not crash
                     and (n <= spec.first
                          or (spec.rate > 0
                              and self._rng.random() < spec.rate)))
            spike = (spec.delay_s > 0
                     and (spec.delay_rate >= 1.0
                          or self._rng.random() < spec.delay_rate))
            if crash or fault:
                self._injected[op] = self._injected.get(op, 0) + 1
        if spike:
            time.sleep(spec.delay_s)
        if crash:
            raise InjectedCrash(
                f"injected crash at {op!r} call #{n}: writer killed "
                f"between archive and flush")
        if fault:
            raise spec.error(f"injected {spec.error.__name__} on {op!r} "
                             f"call #{n}")

    def wrap(self, store, catalogue) -> Tuple["FaultyStore",
                                              "FaultyCatalogue"]:
        """Wrap a backend pair (what ``FDB(..., faults=...)`` calls)."""
        return FaultyStore(store, self), FaultyCatalogue(catalogue, self)


class FaultyStore:
    """A ``Store`` that consults a :class:`FaultInjector` before each
    data-path op, then delegates to the wrapped backend."""

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.injector = injector

    @property
    def scheme(self) -> str:
        return self.inner.scheme

    def archive(self, data, dataset, collocation):
        self.injector.hit("store.archive")
        return self.inner.archive(data, dataset, collocation)

    def placement(self, dataset, collocation):
        return self.inner.placement(dataset, collocation)

    def archive_batch(self, items):
        self.injector.hit("store.archive_batch", fallback="store.archive")
        return self.inner.archive_batch(items)

    def flush(self) -> None:
        self.injector.hit("store.flush")
        self.inner.flush()

    def retrieve(self, location):
        # faulted at handle-build time: a torn read presents as a failed
        # retrieve, and posix range-handle merging stays intact downstream
        self.injector.hit("store.retrieve")
        return self.inner.retrieve(location)

    def close(self) -> None:
        self.inner.close()

    def wipe(self, dataset) -> None:
        self.inner.wipe(dataset)


class FaultyCatalogue:
    """A ``Catalogue`` twin of :class:`FaultyStore`.  Lease traffic and
    listings pass through un-faulted (control-plane)."""

    def __init__(self, inner, injector: FaultInjector):
        self.inner = inner
        self.injector = injector

    @property
    def scheme(self) -> str:
        return self.inner.scheme

    def archive(self, dataset, collocation, element, location) -> None:
        self.injector.hit("catalogue.archive")
        self.inner.archive(dataset, collocation, element, location)

    def archive_batch(self, entries) -> None:
        self.injector.hit("catalogue.archive_batch",
                          fallback="catalogue.archive")
        self.inner.archive_batch(entries)

    def flush(self) -> None:
        self.injector.hit("catalogue.flush")
        self.inner.flush()

    def close(self) -> None:
        self.inner.close()

    def retrieve(self, dataset, collocation, element):
        self.injector.hit("catalogue.retrieve")
        return self.inner.retrieve(dataset, collocation, element)

    def list(self, dataset, partial):
        return self.inner.list(dataset, partial)

    def axes(self, dataset, collocation, dim):
        return self.inner.axes(dataset, collocation, dim)

    def datasets(self):
        return self.inner.datasets()

    def wipe(self, dataset) -> None:
        self.inner.wipe(dataset)

    # -- leases: pure passthrough (control-plane) ----------------------------
    def acquire_lease(self, dataset, collocation, resource, lo, hi, owner,
                      ttl=None, block=False, timeout=None):
        return self.inner.acquire_lease(dataset, collocation, resource,
                                        lo, hi, owner, ttl=ttl, block=block,
                                        timeout=timeout)

    def release_lease(self, dataset, collocation, resource, lo, hi, owner,
                      exact=False):
        self.inner.release_lease(dataset, collocation, resource, lo, hi,
                                 owner, exact=exact)

    def lease_holders(self, dataset, collocation, resource):
        return self.inner.lease_holders(dataset, collocation, resource)

    def check_lease(self, dataset, collocation, resource, lo, hi, owner,
                    epoch):
        self.inner.check_lease(dataset, collocation, resource, lo, hi,
                               owner, epoch)

    def lease_table(self):
        return self.inner.lease_table()

    def lease_key(self, dataset, collocation, resource):
        return self.inner.lease_key(dataset, collocation, resource)


__all__ = ["FaultInjector", "FaultSpec", "FaultyStore", "FaultyCatalogue",
           "InjectedCrash", "PermanentStorageError"]

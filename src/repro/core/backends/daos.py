"""FDB DAOS backends (thesis §3.1).

Store:   one DAOS array per archived field, OC_S1 by default (no sharding —
         saturation comes from many arrays spread across targets, §3.1),
         OIDs pre-allocated in batches, immediate persistence, no-op flush.
Catalogue: root KV → dataset KV → index KVs + axis KVs (Figs. 3.1/3.2);
         contention on index KVs is avoided by schema choice (the collocation
         key varies across writer processes), not by locking.
"""
from __future__ import annotations

import json
import threading
from typing import Dict, Iterator, Mapping, Optional, Set, Tuple

from ..engine.daos import DaosEngine
from ..handle import DataHandle, FieldLocation, LazyHandle
from ..interfaces import Catalogue, Store
from ..lease import CatalogueLeaseMixin
from ..schema import Identifier, Schema
from ..util import stable_hash
from repro.obs.trace import span as obs_span
from repro.obs.locks import NamedLock

ROOT_KV_OID = 0
#: Index/axis KV OIDs live far above the allocated-array OID space.
_IDX_BASE = 1 << 48


def _index_kv_oid(collocation: Identifier) -> int:
    return _IDX_BASE + stable_hash("idx:" + collocation.canonical())


def _axis_kv_oid(collocation: Identifier, dim: str) -> int:
    return _IDX_BASE + stable_hash(f"axis:{collocation.canonical()}:{dim}")


class DaosStore(Store):
    scheme = "daos"

    def __init__(self, engine: DaosEngine, pool: str = "fdb",
                 oid_batch: int = 256, oclass: str = "OC_S1"):
        self.engine = engine
        self.pool = pool
        self.oclass = oclass
        self.oid_batch = oid_batch
        engine.pool_create(pool)
        engine.pool_connect(pool)
        self._known_conts: Set[str] = set()
        self._oid_cache: Dict[str, Tuple[int, int]] = {}  # label -> (next, left)
        self._lock = NamedLock("store.daos")

    def _ensure_container(self, label: str) -> None:
        if label not in self._known_conts:
            self.engine.cont_create_with_label(self.pool, label)
            self.engine.cont_open(self.pool, label)
            with self._lock:
                self._known_conts.add(label)

    def _next_oid(self, label: str) -> int:
        with self._lock:
            nxt, left = self._oid_cache.get(label, (0, 0))
            if left == 0:
                nxt = self.engine.cont_alloc_oids(self.pool, label,
                                                  self.oid_batch)
                left = self.oid_batch
            self._oid_cache[label] = (nxt + 1, left - 1)
            return nxt

    def archive(self, data: bytes, dataset: Identifier,
                collocation: Identifier) -> FieldLocation:
        # NOTE: the collocation key does not drive placement on DAOS (§3.1.1);
        # all fields of a dataset share one container.
        with obs_span("store.daos.archive", nbytes=len(data)):
            label = dataset.canonical()
            self._ensure_container(label)
            oid = self._next_oid(label)
            self.engine.array_open_with_attr(self.pool, label, oid,
                                             self.oclass)
            self.engine.array_write(self.pool, label, oid, 0, data)
        return FieldLocation(self.scheme, label, str(oid), 0, len(data),
                             pool=self.pool)

    # NOTE on write coalescing: ``placement()`` stays None (the base-class
    # default) — one DAOS array per field is the §3.1 design, and saturation
    # comes from many independent object-granular writes in flight, not from
    # batching them into shared units.  ``archive_batch`` therefore keeps the
    # per-item loop; callers preserve op-level parallelism by submitting one
    # batch (of one object) per executor slot.

    def flush(self) -> None:
        # DAOS persists and publishes on archive(); nothing to do (§3.1.1).
        return

    def retrieve(self, location: FieldLocation) -> DataHandle:
        eng, pool = self.engine, self.pool
        label, oid = location.container, int(location.unit)
        off, length = location.offset, location.length
        # The object length is encoded in the location descriptor, so no
        # daos_array_get_size round-trip is needed (§3.1.1 optimisation).
        return LazyHandle(lambda: eng.array_read(pool, label, oid, off, length),
                          length)

    def wipe(self, dataset: Identifier) -> None:
        label = dataset.canonical()
        self.engine.cont_destroy(self.pool, label)
        with self._lock:
            self._known_conts.discard(label)
            self._oid_cache.pop(label, None)


class DaosCatalogue(CatalogueLeaseMixin, Catalogue):
    scheme = "daos"

    # chunk-range leases live on the shared engine (one table per simulated
    # cluster) — the stand-in for a lease KV beside the index KVs; every
    # client of the deployment sees the same lease state
    def _lease_host(self) -> object:
        return self.engine

    def __init__(self, engine: DaosEngine, schema: Schema, pool: str = "fdb",
                 root_cont: str = "fdb_root"):
        self.engine = engine
        self.schema = schema
        self.pool = pool
        self.root_cont = root_cont
        engine.pool_create(pool)
        engine.pool_connect(pool)
        engine.cont_create_with_label(pool, root_cont)
        self._known_datasets: Set[str] = set()
        self._known_indexes: Set[Tuple[str, str]] = set()
        #: in-memory history of values already inserted into axis KVs (§3.1.2)
        self._axis_seen: Set[Tuple[str, str, str, str]] = set()
        #: pre-loaded axes per (dataset, collocation) (§3.1.2 axis pre-loading)
        self._axes_cache: Dict[Tuple[str, str], Dict[str, frozenset]] = {}
        self._lock = NamedLock("catalogue.daos")

    # -- helpers ---------------------------------------------------------------
    def _ensure_dataset(self, dataset: Identifier) -> str:
        label = dataset.canonical()
        if label in self._known_datasets:
            return label
        existing = self.engine.kv_get(self.pool, self.root_cont, ROOT_KV_OID,
                                      label)
        if existing is None:
            self.engine.cont_create_with_label(self.pool, label)
            self.engine.kv_put(self.pool, label, ROOT_KV_OID, "key",
                               label.encode())
            self.engine.kv_put(self.pool, label, ROOT_KV_OID, "schema",
                               self.schema.name.encode())
            self.engine.kv_put(self.pool, self.root_cont, ROOT_KV_OID, label,
                               json.dumps({"cont": label}).encode())
        with self._lock:
            self._known_datasets.add(label)
        return label

    def _ensure_index(self, label: str, collocation: Identifier) -> int:
        ckey = collocation.canonical()
        oid = _index_kv_oid(collocation)
        if (label, ckey) in self._known_indexes:
            return oid
        if self.engine.kv_get(self.pool, label, ROOT_KV_OID, ckey) is None:
            self.engine.kv_put(self.pool, label, oid, "key", ckey.encode())
            self.engine.kv_put(
                self.pool, label, oid, "axes",
                json.dumps(list(self.schema.element_dims)).encode())
            self.engine.kv_put(self.pool, label, ROOT_KV_OID, ckey,
                               json.dumps({"oid": oid}).encode())
        with self._lock:
            self._known_indexes.add((label, ckey))
        return oid

    # -- Catalogue interface ---------------------------------------------------
    def archive(self, dataset: Identifier, collocation: Identifier,
                element: Identifier, location: FieldLocation) -> None:
        label = self._ensure_dataset(dataset)
        oid = self._ensure_index(label, collocation)
        self.engine.kv_put(self.pool, label, oid, element.canonical(),
                           location.to_bytes())
        ckey = collocation.canonical()
        for dim in self.schema.element_dims:
            val = element[dim]
            seen_key = (label, ckey, dim, val)
            if seen_key in self._axis_seen:
                continue
            self.engine.kv_put(self.pool, label,
                               _axis_kv_oid(collocation, dim), val, b"1")
            with self._lock:
                self._axis_seen.add(seen_key)
                # read-your-writes: drop our own pre-loaded axis summary so a
                # later retrieve by this client sees the new value (other
                # clients' pre-loads stay stale — the §3.1.2 caveat)
                self._axes_cache.pop((label, ckey), None)

    def flush(self) -> None:
        # kv_put is immediately persistent and visible (§3.1.2).
        return

    def close(self) -> None:
        # No full-index finalisation step on DAOS (§3.1.2 close()):
        # consumers use the same structures whether producers live or not.
        return

    def _load_axes(self, label: str, collocation: Identifier,
                   refresh: bool = False) -> Optional[Dict[str, frozenset]]:
        key = (label, collocation.canonical())
        if not refresh and key in self._axes_cache:
            return self._axes_cache[key]
        ptr = self.engine.kv_get(self.pool, label, ROOT_KV_OID,
                                 collocation.canonical())
        if ptr is None:
            return None
        oid = json.loads(ptr.decode())["oid"]
        axes_raw = self.engine.kv_get(self.pool, label, oid, "axes")
        dims = json.loads(axes_raw.decode()) if axes_raw else []
        axes = {dim: frozenset(self.engine.kv_list(
            self.pool, label, _axis_kv_oid(collocation, dim)))
            for dim in dims}
        with self._lock:
            self._axes_cache[key] = axes
        return axes

    def refresh_axes(self) -> None:
        """Drop pre-loaded axis summaries (for long-lived consumers that must
        see objects archived after their first retrieve — §3.1.2 caveat)."""
        with self._lock:
            self._axes_cache.clear()

    def axes(self, dataset: Identifier, collocation: Identifier,
             dim: str) -> frozenset:
        label = dataset.canonical()
        if self.engine.kv_get(self.pool, self.root_cont, ROOT_KV_OID,
                              label) is None:
            return frozenset()
        ax = self._load_axes(label, collocation)
        return ax.get(dim, frozenset()) if ax else frozenset()

    def retrieve(self, dataset: Identifier, collocation: Identifier,
                 element: Identifier) -> Optional[FieldLocation]:
        label = dataset.canonical()
        axes = self._load_axes(label, collocation)
        if axes is None:
            return None
        for dim, val in element.items():
            if dim in axes and val not in axes[dim]:
                return None          # axis summary proves absence (fast path)
        raw = self.engine.kv_get(self.pool, label,
                                 _index_kv_oid(collocation),
                                 element.canonical())
        return None if raw is None else FieldLocation.from_bytes(raw)

    def list(self, dataset: Identifier, partial: Mapping[str, object]
             ) -> Iterator[Tuple[Identifier, FieldLocation]]:
        label = dataset.canonical()
        if self.engine.kv_get(self.pool, self.root_cont, ROOT_KV_OID,
                              label) is None:
            return
        # kv_list + one kv_get per entry: DAOS cannot fetch keys+values in a
        # single op (§3.1.2 list()) — this is the Ceph omap advantage.
        for ckey_str in self.engine.kv_list(self.pool, label, ROOT_KV_OID):
            if ckey_str in ("key", "schema"):
                continue
            collocation = Identifier.from_canonical(ckey_str)
            if not collocation.matches({k: v for k, v in partial.items()
                                        if k in collocation}):
                continue
            oid_raw = self.engine.kv_get(self.pool, label, ROOT_KV_OID,
                                         ckey_str)
            if oid_raw is None:
                continue
            oid = json.loads(oid_raw.decode())["oid"]
            for ekey_str in self.engine.kv_list(self.pool, label, oid):
                if ekey_str in ("key", "axes"):
                    continue
                element = Identifier.from_canonical(ekey_str)
                ident = self.schema.join(dataset, collocation, element)
                if not ident.matches(partial):
                    continue
                raw = self.engine.kv_get(self.pool, label, oid, ekey_str)
                if raw is not None:
                    yield ident, FieldLocation.from_bytes(raw)

    def datasets(self) -> Iterator[Identifier]:
        for label in self.engine.kv_list(self.pool, self.root_cont,
                                         ROOT_KV_OID):
            yield Identifier.from_canonical(label)

    def wipe(self, dataset: Identifier) -> None:
        label = dataset.canonical()
        self.engine.cont_destroy(self.pool, label)
        self.engine.kv_remove(self.pool, self.root_cont, ROOT_KV_OID, label)
        with self._lock:
            self._known_datasets.discard(label)
            self._axes_cache = {k: v for k, v in self._axes_cache.items()
                                if k[0] != label}
            # the index/axis KVs died with the container: forget the memos so
            # re-archiving the same keys rebuilds them
            self._known_indexes = {k for k in self._known_indexes
                                   if k[0] != label}
            self._axis_seen = {k for k in self._axis_seen if k[0] != label}

    # NOTE on wipe(): a dataset container destroy removes data+index in one
    # administrative op — the reason for container-per-dataset (§3.1).

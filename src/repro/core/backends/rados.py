"""FDB Ceph/RADOS backends (thesis §3.2).

Design options evaluated in the thesis (Fig. 3.5) are all implemented and
selectable, with the thesis's winning configuration as the default:

* ``encapsulation``: ``"namespace"`` per dataset (default) or ``"pool"`` per
  dataset (slower: doubles PG count — second test set of Fig. 3.5).
* ``object_mode``: ``"per_field"`` (default, best balance), ``"span"``
  (multi-field objects per process+collocation spanning the 128 MiB limit,
  first test set) or ``"single_large"`` (one object per process+collocation,
  requires a raised ``max_object_size``; best reads, halved writes).
* ``persistence``: ``"immediate"`` (default; blocking ops, §3.2 consistency
  requirement) or ``"on_flush"`` (async writes persisted at flush; the thesis
  found librados misbehaving in one combination — our implementation keeps the
  FDB contract: data invisible until flush, then fully visible).

Field object names are MD5 hashes of unique strings so that name prefixes do
not skew placement (§3.2.1).
"""
from __future__ import annotations

import hashlib
import itertools
import json
import os
import socket
import threading
import time
from typing import Dict, Iterator, List, Mapping, Optional, Set, Tuple

from ..engine.rados import RadosEngine
from ..handle import DataHandle, FieldLocation, LazyHandle
from ..interfaces import Catalogue, Store
from ..lease import CatalogueLeaseMixin
from ..schema import Identifier, Schema
from repro.obs.trace import span as obs_span
from repro.obs.locks import NamedLock

MiB = 1024 ** 2
_uniq_counter = itertools.count()


def _unique_name(tag: str) -> str:
    raw = f"{tag}.{time.time_ns()}.{socket.gethostname()}.{os.getpid()}." \
          f"{next(_uniq_counter)}"
    return hashlib.md5(raw.encode()).hexdigest()


class RadosStore(Store):
    scheme = "rados"

    def __init__(self, engine: RadosEngine, pool: str = "fdb",
                 encapsulation: str = "namespace",
                 object_mode: str = "per_field",
                 persistence: str = "immediate",
                 pg_count: int = 512,
                 replication: int = 1,
                 ec: Optional[Tuple[int, int]] = None):
        assert encapsulation in ("namespace", "pool")
        assert object_mode in ("per_field", "span", "single_large")
        assert persistence in ("immediate", "on_flush")
        self.engine = engine
        self.base_pool = pool
        self.encapsulation = encapsulation
        self.object_mode = object_mode
        self.persistence = persistence
        self.pg_count = pg_count
        self.replication = replication
        self.ec = ec
        engine.pool_create(pool, pg_count=pg_count, replication=replication,
                           ec=ec)
        self._known_pools: Set[str] = {pool}
        # span/single_large state: (ns, ckey) -> (object name, next offset, part)
        self._spans: Dict[Tuple[str, str], Tuple[str, int, int]] = {}
        self._pending: List[Tuple[str, str, str, int, bytes]] = []
        self._lock = NamedLock("store.rados")

    # -- placement of datasets --------------------------------------------------
    def _locate(self, dataset: Identifier) -> Tuple[str, str]:
        """Returns (pool, namespace) for a dataset key."""
        label = dataset.canonical()
        if self.encapsulation == "namespace":
            return self.base_pool, label
        pool = "fdb." + hashlib.md5(label.encode()).hexdigest()[:8]
        if pool not in self._known_pools:
            self.engine.pool_create(pool, pg_count=self.pg_count,
                                    replication=self.replication, ec=self.ec)
            with self._lock:
                self._known_pools.add(pool)
        return pool, label

    # -- Store interface -----------------------------------------------------------
    def archive(self, data: bytes, dataset: Identifier,
                collocation: Identifier) -> FieldLocation:
        with obs_span("store.rados.archive", nbytes=len(data)):
            return self._archive(data, dataset, collocation)

    def _archive(self, data: bytes, dataset: Identifier,
                 collocation: Identifier) -> FieldLocation:
        pool, ns = self._locate(dataset)
        if self.object_mode == "per_field":
            name = _unique_name(collocation.canonical())
            if self.persistence == "immediate":
                self.engine.write_full(pool, ns, name, data)
            else:
                with self._lock:
                    self._pending.append((pool, ns, name, 0, bytes(data)))
            return FieldLocation(self.scheme, ns, name, 0, len(data),
                                 pool=pool)
        # span / single_large: append into a shared per-(proc, ckey) object
        limit = (self.engine.max_object_size if self.object_mode == "span"
                 else (1 << 62))
        key = (ns, collocation.canonical())
        with self._lock:
            name, off, part = self._spans.get(key, (None, 0, 0))
            if name is None or off + len(data) > limit:
                part = part + 1 if name is not None else 0
                name = _unique_name(f"{collocation.canonical()}.part{part}")
                off = 0
            self._spans[key] = (name, off + len(data), part)
            # append (or enqueue) under the reservation lock: with parallel
            # archives the physical append order must match the reserved
            # offsets or locations would point at other items' bytes
            if self.persistence == "immediate":
                self.engine.append(pool, ns, name, data)
            else:
                self._pending.append((pool, ns, name, off, bytes(data)))
        return FieldLocation(self.scheme, ns, name, off, len(data), pool=pool)

    # NOTE on write coalescing: ``placement()`` stays None even in span /
    # single_large modes.  Span objects are an *offset-reservation* shared
    # unit — appends interleave per-op under the reservation lock so many
    # archives stay in flight (§3.2.1); collapsing them into one batched
    # write would serialize exactly the op-level parallelism object stores
    # are won by.  Coalescing is the POSIX backend's lever, not RADOS's.

    def flush(self) -> None:
        if self.persistence != "on_flush":
            return
        with self._lock:
            pending, self._pending = self._pending, []
        for pool, ns, name, off, data in pending:
            if self.object_mode == "per_field":
                self.engine.write_full(pool, ns, name, data)
            else:
                self.engine.append(pool, ns, name, data)

    def retrieve(self, location: FieldLocation) -> DataHandle:
        eng = self.engine
        pool, ns, name = location.pool, location.container, location.unit
        off, length = location.offset, location.length
        return LazyHandle(lambda: eng.read(pool, ns, name, off, length),
                          length)

    def wipe(self, dataset: Identifier) -> None:
        pool, ns = self._locate(dataset)
        if self.encapsulation == "pool":
            self.engine.pool_delete(pool)
        else:
            for name in self.engine.list_objects(pool, ns):
                self.engine.remove(pool, ns, name)


def _idx_name(collocation: Identifier) -> str:
    return "idx." + hashlib.md5(collocation.canonical().encode()).hexdigest()


def _axis_name(collocation: Identifier, dim: str) -> str:
    raw = f"{collocation.canonical()}:{dim}"
    return "axis." + hashlib.md5(raw.encode()).hexdigest()


class RadosCatalogue(CatalogueLeaseMixin, Catalogue):
    """Omap-based catalogue, mirroring the DAOS KV design (§3.2.1), with the
    one structural improvement RADOS allows: ``list()`` fetches whole omaps
    (keys *and* values) in single RPCs."""

    scheme = "rados"

    # chunk-range leases hang off the shared engine (the stand-in for a
    # lease omap beside the index omaps — same cross-client visibility)
    def _lease_host(self) -> object:
        return self.engine
    ROOT_NS = "_fdb_root"
    ROOT_OBJ = "root_kv"
    DATASET_OBJ = "dataset_kv"

    def __init__(self, engine: RadosEngine, schema: Schema, pool: str = "fdb",
                 persistence: str = "immediate"):
        assert persistence in ("immediate", "on_flush")
        self.engine = engine
        self.schema = schema
        self.pool = pool
        self.persistence = persistence
        engine.pool_create(pool)
        engine.omap_create(pool, self.ROOT_NS, self.ROOT_OBJ)
        self._known_datasets: Set[str] = set()
        self._known_indexes: Set[Tuple[str, str]] = set()
        self._axis_seen: Set[Tuple[str, str, str, str]] = set()
        self._axes_cache: Dict[Tuple[str, str], Dict[str, frozenset]] = {}
        self._pending: List[Tuple[str, str, Dict[str, bytes]]] = []
        self._lock = NamedLock("catalogue.rados")

    def _omap_set(self, ns: str, obj: str, kvs: Dict[str, bytes],
                  defer: bool = True) -> None:
        if self.persistence == "on_flush" and defer:
            with self._lock:
                self._pending.append((ns, obj, kvs))
        else:
            self.engine.omap_set(self.pool, ns, obj, kvs)

    def _ensure_dataset(self, dataset: Identifier) -> str:
        label = dataset.canonical()
        if label in self._known_datasets:
            return label
        root = self.engine.omap_get_vals_by_keys(
            self.pool, self.ROOT_NS, self.ROOT_OBJ, [label])
        if label not in root:
            self._omap_set(label, self.DATASET_OBJ,
                           {"key": label.encode(),
                            "schema": self.schema.name.encode()}, defer=False)
            self._omap_set(self.ROOT_NS, self.ROOT_OBJ,
                           {label: json.dumps({"ns": label}).encode()},
                           defer=False)
        with self._lock:
            self._known_datasets.add(label)
        return label

    def _ensure_index(self, label: str, collocation: Identifier) -> str:
        ckey = collocation.canonical()
        name = _idx_name(collocation)
        if (label, ckey) in self._known_indexes:
            return name
        have = self.engine.omap_get_vals_by_keys(self.pool, label,
                                                 self.DATASET_OBJ, [ckey])
        if ckey not in have:
            self._omap_set(label, name,
                           {"key": ckey.encode(),
                            "axes": json.dumps(
                                list(self.schema.element_dims)).encode()},
                           defer=False)
            self._omap_set(label, self.DATASET_OBJ,
                           {ckey: json.dumps({"obj": name}).encode()},
                           defer=False)
        with self._lock:
            self._known_indexes.add((label, ckey))
        return name

    def archive(self, dataset: Identifier, collocation: Identifier,
                element: Identifier, location: FieldLocation) -> None:
        label = self._ensure_dataset(dataset)
        idx = self._ensure_index(label, collocation)
        self._omap_set(label, idx, {element.canonical(): location.to_bytes()})
        ckey = collocation.canonical()
        axis_updates: Dict[str, Dict[str, bytes]] = {}
        for dim in self.schema.element_dims:
            val = element[dim]
            seen = (label, ckey, dim, val)
            if seen in self._axis_seen:
                continue
            axis_updates.setdefault(_axis_name(collocation, dim), {})[val] = b"1"
            with self._lock:
                self._axis_seen.add(seen)
                # read-your-writes: invalidate our own axis summary cache
                self._axes_cache.pop((label, ckey), None)
        for obj, kvs in axis_updates.items():
            self._omap_set(label, obj, kvs)

    def flush(self) -> None:
        if self.persistence != "on_flush":
            return
        with self._lock:
            pending, self._pending = self._pending, []
        for ns, obj, kvs in pending:
            self.engine.omap_set(self.pool, ns, obj, kvs)

    def close(self) -> None:
        self.flush()

    def _load_axes(self, label: str, collocation: Identifier
                   ) -> Optional[Dict[str, frozenset]]:
        key = (label, collocation.canonical())
        if key in self._axes_cache:
            return self._axes_cache[key]
        ptr = self.engine.omap_get_vals_by_keys(
            self.pool, label, self.DATASET_OBJ, [collocation.canonical()])
        if collocation.canonical() not in ptr:
            return None
        idx = json.loads(ptr[collocation.canonical()].decode())["obj"]
        meta = self.engine.omap_get_vals_by_keys(self.pool, label, idx,
                                                 ["axes"])
        dims = json.loads(meta["axes"].decode()) if "axes" in meta else []
        axes = {d: frozenset(self.engine.omap_list_keys(
            self.pool, label, _axis_name(collocation, d))) for d in dims}
        with self._lock:
            self._axes_cache[key] = axes
        return axes

    def refresh_axes(self) -> None:
        with self._lock:
            self._axes_cache.clear()

    def axes(self, dataset: Identifier, collocation: Identifier,
             dim: str) -> frozenset:
        ax = self._load_axes(dataset.canonical(), collocation)
        return ax.get(dim, frozenset()) if ax else frozenset()

    def retrieve(self, dataset: Identifier, collocation: Identifier,
                 element: Identifier) -> Optional[FieldLocation]:
        label = dataset.canonical()
        axes = self._load_axes(label, collocation)
        if axes is None:
            return None
        for dim, val in element.items():
            if dim in axes and val not in axes[dim]:
                return None
        got = self.engine.omap_get_vals_by_keys(
            self.pool, label, _idx_name(collocation), [element.canonical()])
        raw = got.get(element.canonical())
        return None if raw is None else FieldLocation.from_bytes(raw)

    def list(self, dataset: Identifier, partial: Mapping[str, object]
             ) -> Iterator[Tuple[Identifier, FieldLocation]]:
        label = dataset.canonical()
        root = self.engine.omap_get_vals_by_keys(
            self.pool, self.ROOT_NS, self.ROOT_OBJ, [label])
        if label not in root:
            return
        # One RPC for the whole dataset omap, one per matching index omap
        # (rados_read_op_omap_get_vals_by_keys2 advantage — §3.2.1).
        dataset_kv = self.engine.omap_get_all(self.pool, label,
                                              self.DATASET_OBJ)
        for ckey_str, ptr in dataset_kv.items():
            if ckey_str in ("key", "schema"):
                continue
            collocation = Identifier.from_canonical(ckey_str)
            if not collocation.matches({k: v for k, v in partial.items()
                                        if k in collocation}):
                continue
            idx = json.loads(ptr.decode())["obj"]
            entries = self.engine.omap_get_all(self.pool, label, idx)
            for ekey_str, raw in entries.items():
                if ekey_str in ("key", "axes"):
                    continue
                element = Identifier.from_canonical(ekey_str)
                ident = self.schema.join(dataset, collocation, element)
                if ident.matches(partial):
                    yield ident, FieldLocation.from_bytes(raw)

    def datasets(self) -> Iterator[Identifier]:
        for label in self.engine.omap_list_keys(self.pool, self.ROOT_NS,
                                                self.ROOT_OBJ):
            yield Identifier.from_canonical(label)

    def wipe(self, dataset: Identifier) -> None:
        label = dataset.canonical()
        for name in self.engine.list_objects(self.pool, label):
            self.engine.remove(self.pool, label, name)
        # remove from root omap by re-publishing without the key
        root = self.engine.omap_get_all(self.pool, self.ROOT_NS, self.ROOT_OBJ)
        root.pop(label, None)
        p = self.engine._pool(self.pool)
        with p.lock:
            p.omaps[(self.ROOT_NS, self.ROOT_OBJ)] = root
        with self._lock:
            self._known_datasets.discard(label)
            self._axes_cache = {k: v for k, v in self._axes_cache.items()
                                if k[0] != label}
            # the index/axis omaps died with the namespace: forget the memos
            # so re-archiving the same keys rebuilds them
            self._known_indexes = {k for k in self._known_indexes
                                   if k[0] != label}
            self._axis_seen = {k for k in self._axis_seen if k[0] != label}

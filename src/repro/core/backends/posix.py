"""FDB POSIX I/O backends on a real filesystem (thesis §2.7.2).

Faithful implementation of the Lustre-era design:

* one **directory per dataset key**; atomic ``mkdir`` initialisation;
* per-(process, collocation-key) **data file**, opened in append mode and
  *buffered* — data is only guaranteed persistent on ``flush()`` (fflush +
  fdatasync);
* per-(process, collocation-key) **partial index file** (one serialized index
  blob appended per flush) and **full index file** (single blob at close);
* per-process **sub-TOC file** carrying axes + URI stores + index locations;
* a shared **TOC file**, appended with O_APPEND single-write records (atomic
  under the POSIX small-write guarantee), including ``TOC_MASK`` entries that
  obsolete sub-TOCs once full indexes land at ``close()``;
* **TOC pre-loading**: first retrieve/list reads the whole TOC + all unmasked
  sub-TOCs, rebuilding axes and URI stores in memory;
* URI stores: data-file URIs interned to integers inside index entries.

A shared :class:`LustreSim` meters every filesystem touch onto simulated
OSTs/MDS (striping: default 8 × 8 MiB) and distributed-lock traffic under
write+read contention, feeding the cost model.
"""
from __future__ import annotations

import dataclasses
import io
import itertools
import os
import socket
import struct
import threading
import time
from typing import (Dict, Iterator, List, Mapping, Optional, Sequence, Set,
                    Tuple)

import msgpack

from ..engine.meter import GLOBAL_METER, Meter
from ..handle import DataHandle, FieldLocation, FileRangeHandle
from ..interfaces import Catalogue, Store
from repro.obs.trace import span as obs_span
from repro.obs.locks import NamedLock
from ..lease import CatalogueLeaseMixin
from ..schema import Identifier, Schema
from ..util import stable_hash

MiB = 1024 ** 2
_uniq = itertools.count()

TOC_FILE = "toc"
SCHEMA_FILE = "schema"


def _unique_stem(tag: str) -> str:
    return (f"{stable_hash(tag):08x}.{time.time_ns()}."
            f"{socket.gethostname()}.{os.getpid()}.{next(_uniq)}")


class LustreSim:
    """Shared metering context mapping file ops onto a simulated Lustre
    deployment (OSTs + MDS + LDLM lock traffic)."""

    def __init__(self, root: str, n_osts: int = 16, stripe_count: int = 8,
                 stripe_size: int = 8 * MiB,
                 meter: Optional[Meter] = None):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self.n_osts = n_osts
        self.stripe_count = stripe_count
        self.stripe_size = stripe_size
        self.meter = meter or GLOBAL_METER
        self._write_open: Set[str] = set()   # files open by active writers
        self._lock = NamedLock("engine.lustre")

    # -- op metering --------------------------------------------------------
    def meta(self, nops: int = 1) -> None:
        for _ in range(nops):
            self.meter.record("mds", "meta", 0)

    def _ost(self, path: str, stripe: int) -> str:
        return f"ost:{(stable_hash(path) + stripe) % self.n_osts}"

    def data_io(self, path: str, nbytes: int, kind: str,
                unit: str = "") -> None:
        """Meter a bulk read/write split across the file's stripes."""
        stripes = min(self.stripe_count, self.n_osts)
        per = (nbytes + stripes - 1) // stripes if nbytes else 0
        done = 0
        for s in range(stripes):
            part = min(per, nbytes - done)
            if part <= 0 and s > 0:
                break
            self.meter.record(self._ost(path, s), kind, max(part, 0),
                              unit=unit)
            done += part

    def fsync(self, path: str) -> None:
        self.meter.record(self._ost(path, 0), "fsync", 0)

    # -- write-read contention tracking --------------------------------------
    def writer_opens(self, path: str) -> None:
        with self._lock:
            self._write_open.add(path)

    def writer_closes(self, path: str) -> None:
        with self._lock:
            self._write_open.discard(path)

    def read_with_locks(self, path: str, nbytes: int) -> None:
        """A read conflicting with an active writer costs LDLM round-trips
        (§2.2: distributed locking under write+read contention)."""
        with self._lock:
            contended = path in self._write_open
        if contended:
            self.meter.record("ldlm", "lock", 0, unit=path)
        self.data_io(path, nbytes, "read")


def _append_record(path: str, payload: dict, sim: LustreSim,
                   unit: str = "") -> None:
    """Atomic O_APPEND record append (length-prefixed msgpack)."""
    blob = msgpack.packb(payload, use_bin_type=True)
    rec = struct.pack("<I", len(blob)) + blob
    fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
    try:
        os.write(fd, rec)           # single write → POSIX-atomic append
    finally:
        os.close(fd)
    sim.meter.record(sim._ost(path, 0), "append", len(rec),
                     unit=unit or path)


def _read_records(path: str, sim: Optional[LustreSim] = None) -> List[dict]:
    """Read a whole record file with one read call (TOC pre-loading)."""
    if not os.path.exists(path):
        return []
    with open(path, "rb") as f:
        blob = f.read()
    if sim is not None:
        sim.read_with_locks(path, len(blob))
    out, pos = [], 0
    while pos + 4 <= len(blob):
        (n,) = struct.unpack_from("<I", blob, pos)
        pos += 4
        if pos + n > len(blob):
            break                   # torn tail record (crash mid-append)
        out.append(msgpack.unpackb(blob[pos:pos + n], raw=False))
        pos += n
    return out


class PosixStore(Store):
    scheme = "posix"

    def __init__(self, sim: LustreSim, buffer_size: int = 4 * MiB):
        self.sim = sim
        self.buffer_size = buffer_size
        # (dataset, ckey) -> (path, fileobj, offset, unsynced_bytes)
        self._files: Dict[Tuple[str, str], List] = {}
        self._lock = NamedLock("store.posix")

    def _dataset_dir(self, dataset: Identifier) -> str:
        d = os.path.join(self.sim.root, dataset.canonical())
        try:
            os.mkdir(d)             # atomic even under contention (§2.7.2)
            self.sim.meta()
        except FileExistsError:
            pass
        return d

    def _entry(self, dataset: Identifier, collocation: Identifier) -> List:
        """Resolve (reserving on first use) the per-(process, collocation)
        data-file entry ``[path, fileobj_or_None, offset, unsynced]``.
        Reservation only names the path — no directory, file, or metered op
        is created until the first real write (:meth:`_open_entry`), so
        planning-time placement stays free of side effects.  Caller must
        hold ``self._lock``."""
        key = (dataset.canonical(), collocation.canonical())
        ent = self._files.get(key)
        if ent is None:
            stem = _unique_stem(collocation.canonical())
            path = os.path.join(self.sim.root, dataset.canonical(),
                                stem + ".data")
            ent = [path, None, 0, 0]
            self._files[key] = ent
        return ent

    def _open_entry(self, ent: List, dataset: Identifier):
        """Open the entry's data file on first write (mkdir + create +
        contention tracking are charged here, not at placement time).
        Caller must hold ``self._lock``."""
        if ent[1] is None:
            self._dataset_dir(dataset)
            ent[1] = open(ent[0], "ab", buffering=self.buffer_size)
            self.sim.meta()                      # file create
            self.sim.writer_opens(ent[0])
        return ent[1]

    def archive(self, data: bytes, dataset: Identifier,
                collocation: Identifier) -> FieldLocation:
        with obs_span("store.posix.archive", nbytes=len(data)), self._lock:
            ent = self._entry(dataset, collocation)
            f = self._open_entry(ent, dataset)
            path, _f, offset, unsynced = ent
            # lint: disable=L003 -- by design: the lock serialises the
            # shared append cursor; the write IS the protected operation
            f.write(data)
            ent[2] = offset + len(data)
            ent[3] = unsynced + len(data)
        return FieldLocation(self.scheme, dataset.canonical(), path,
                             offset, len(data))

    def placement(self, dataset: Identifier,
                  collocation: Identifier) -> Optional[str]:
        """The data file archives to this (dataset, collocation) append
        into — the write-side merge unit, resolved without touching disk or
        the op meter (the file itself opens lazily on first write).
        Archives resolving to one path coalesce into a single buffered
        append (``archive_batch``), the write-op mirror of
        ``FileRangeHandle`` read merging."""
        with self._lock:
            return self._entry(dataset, collocation)[0]

    def archive_batch(self, items: Sequence[Tuple[bytes, Identifier,
                                                  Identifier]]
                      ) -> List[FieldLocation]:
        """One buffered append per destination data file for the whole
        batch: payloads bound for the same file concatenate into a single
        ``write()`` under one lock round-trip — the store-level write-op
        reduction the paper's POSIX scaling numbers call for.  Offsets are
        reserved in input order, so per-item locations stay exact."""
        locs: List[Optional[FieldLocation]] = [None] * len(items)
        with obs_span("store.posix.archive_batch", items=len(items),
                      nbytes=sum(len(d) for d, _ds, _c in items)), self._lock:
            per_file: Dict[int, Tuple[List, str, List[Tuple[int, bytes]]]] = {}
            for pos, (data, dataset, collocation) in enumerate(items):
                ent = self._entry(dataset, collocation)
                self._open_entry(ent, dataset)
                per_file.setdefault(
                    id(ent), (ent, dataset.canonical(), []))[2].append(
                        (pos, data))
            for ent, dlabel, parts in per_file.values():
                path, f = ent[0], ent[1]
                buf = b"".join(d for _pos, d in parts)
                # ONE append for this file's whole batch
                # lint: disable=L003 -- by-design coalescing: batch append
                # under the cursor lock is the point of archive_batch
                f.write(buf)
                offset = ent[2]
                for pos, d in parts:
                    locs[pos] = FieldLocation(self.scheme, dlabel, path,
                                              offset, len(d))
                    offset += len(d)
                ent[2] = offset
                ent[3] += len(buf)
        return locs                  # type: ignore[return-value]

    def flush(self) -> None:
        with self._lock:
            items = list(self._files.values())
        for ent in items:
            # snapshot-and-reset the unsynced counter under the lock: a
            # concurrent archive() incrementing it between our read and the
            # reset would have its bytes dropped from the metering
            with self._lock:
                path, f, unsynced = ent[0], ent[1], ent[3]
                ent[3] = 0
            if f is None:
                continue            # placement-reserved, never written
            f.flush()
            os.fsync(f.fileno())
            if unsynced:
                self.sim.data_io(path, unsynced, "write")
            self.sim.fsync(path)

    def retrieve(self, location: FieldLocation) -> DataHandle:
        """Return a :class:`FileRangeHandle` — no I/O until it is read.

        Handles over the same data file merge, so multi-chunk retrieves
        (``MultiHandle`` / the tensorstore ``ReadPlan``) coalesce adjacent
        ranges into one large read + one open, the POSIX read optimisation
        the paper's Lustre numbers hinge on.  A short read (range past EOF —
        e.g. another writer's data not yet flushed, rule 3) surfaces as
        :class:`repro.core.ShortReadError` at read time.
        """
        sim = self.sim

        def reader(unit: str, offset: int, length: int) -> bytes:
            with open(unit, "rb") as f:
                f.seek(offset)
                data = f.read(length)
            sim.read_with_locks(unit, len(data))
            sim.meta()              # open
            return data

        return FileRangeHandle.single(reader, location.unit,
                                      location.offset, location.length)

    def close(self) -> None:
        with self._lock:
            items = list(self._files.items())
            self._files.clear()
        for _key, (path, f, _off, unsynced) in items:
            if f is None:
                continue            # placement-reserved, never written
            f.flush()
            os.fsync(f.fileno())
            f.close()
            if unsynced:
                self.sim.data_io(path, unsynced, "write")
            self.sim.writer_closes(path)

    def wipe(self, dataset: Identifier) -> None:
        d = os.path.join(self.sim.root, dataset.canonical())
        if os.path.isdir(d):
            for name in os.listdir(d):
                os.unlink(os.path.join(d, name))
                self.sim.meta()
            os.rmdir(d)
            self.sim.meta()


@dataclasses.dataclass
class _PerKeyIndex:
    """In-memory indexing state for one (dataset, collocation) pair
    (thesis Fig. 2.6): partial + full B*-tree stand-ins, URI store, axes."""
    partial: Dict[str, Tuple[int, int, int]]
    full: Dict[str, Tuple[int, int, int]]
    uris: List[str]
    uri_ids: Dict[str, int]
    axes: Dict[str, Set[str]]
    pindex_path: str
    findex_path: str

    def intern(self, uri: str) -> int:
        i = self.uri_ids.get(uri)
        if i is None:
            i = len(self.uris)
            self.uris.append(uri)
            self.uri_ids[uri] = i
        return i


class PosixCatalogue(CatalogueLeaseMixin, Catalogue):
    scheme = "posix"

    # chunk-range leases live on the shared LustreSim (one table per
    # simulated filesystem) — the stand-in for an LDLM-style range-lock
    # service; every client on the same root/geometry shares lease state
    def _lease_host(self) -> object:
        return self.sim

    def __init__(self, sim: LustreSim, schema: Schema):
        self.sim = sim
        self.schema = schema
        self._mem: Dict[Tuple[str, str], _PerKeyIndex] = {}
        self._subtoc_path: Dict[str, str] = {}       # dataset -> sub-TOC file
        self._preloaded: Dict[str, List[dict]] = {}  # dataset -> index entries
        self._index_cache: Dict[Tuple[str, int, int], Dict] = {}
        self._lock = NamedLock("catalogue.posix")
        self._closed = False

    # -- write path --------------------------------------------------------------
    def _dataset_dir(self, dataset: Identifier, create: bool = True) -> str:
        d = os.path.join(self.sim.root, dataset.canonical())
        if create and not os.path.isdir(d):
            try:
                os.mkdir(d)
                self.sim.meta()
            except FileExistsError:
                pass
        if create:
            toc = os.path.join(d, TOC_FILE)
            if not os.path.exists(toc):
                _append_record(toc, {"type": "TOC_INIT",
                                     "schema": self.schema.name}, self.sim,
                               unit=toc)
                with open(os.path.join(d, SCHEMA_FILE), "w") as f:
                    f.write(self.schema.name)
                self.sim.meta(2)
        return d

    def _mem_index(self, dataset: Identifier, collocation: Identifier
                   ) -> _PerKeyIndex:
        key = (dataset.canonical(), collocation.canonical())
        with self._lock:
            mi = self._mem.get(key)
            if mi is None:
                d = self._dataset_dir(dataset)
                stem = _unique_stem(collocation.canonical())
                mi = _PerKeyIndex(
                    partial={}, full={}, uris=[], uri_ids={},
                    axes={dim: set() for dim in self.schema.element_dims},
                    pindex_path=os.path.join(d, stem + ".pindex"),
                    findex_path=os.path.join(d, stem + ".findex"))
                self.sim.meta(2)     # two index file creates
                self._mem[key] = mi
            return mi

    def archive(self, dataset: Identifier, collocation: Identifier,
                element: Identifier, location: FieldLocation) -> None:
        mi = self._mem_index(dataset, collocation)
        ekey = element.canonical()
        with self._lock:
            self._index_one(mi, element, ekey, location)
        # purely in-memory: no I/O until flush() (§2.7.2)

    def _index_one(self, mi: "_PerKeyIndex", element: Identifier, ekey: str,
                   location: FieldLocation) -> None:
        """Insert one entry; caller must hold ``self._lock``."""
        uri_id = mi.intern(location.unit)
        entry = (uri_id, location.offset, location.length)
        mi.partial[ekey] = entry
        mi.full[ekey] = entry
        for dim in self.schema.element_dims:
            mi.axes[dim].add(element[dim])

    def archive_batch(self, entries) -> None:
        """Index a whole batch with one index resolution + one lock
        round-trip per (dataset, collocation) key — the catalogue half of a
        coalesced store write (still in-memory only until flush)."""
        by_key: Dict[Tuple[str, str], List] = {}
        for dataset, collocation, element, location in entries:
            by_key.setdefault(
                (dataset.canonical(), collocation.canonical()),
                []).append((dataset, collocation, element, location))
        for batch in by_key.values():
            mi = self._mem_index(batch[0][0], batch[0][1])
            with self._lock:
                for _d, _c, element, location in batch:
                    self._index_one(mi, element, element.canonical(),
                                    location)

    def _subtoc_for(self, dataset_dir: str, dataset_label: str) -> str:
        with self._lock:
            st = self._subtoc_path.get(dataset_label)
        if st is None:
            st = os.path.join(dataset_dir,
                              _unique_stem(dataset_label) + ".subtoc")
            # creation registers a pointer in the shared TOC (§2.7.2 flush)
            toc = os.path.join(dataset_dir, TOC_FILE)
            _append_record(toc, {"type": "TOC_SUBTOC", "path": st}, self.sim,
                           unit=toc)
            self.sim.meta()
            with self._lock:
                self._subtoc_path[dataset_label] = st
        return st

    def flush(self) -> None:
        with self._lock:
            items = list(self._mem.items())
        for (dlabel, clabel), mi in items:
            with self._lock:
                if not mi.partial:
                    continue
                partial = dict(mi.partial)
                mi.partial.clear()
                uris = list(mi.uris)
                axes = {d: sorted(v) for d, v in mi.axes.items()}
            blob = msgpack.packb({"entries": partial}, use_bin_type=True)
            offset = (os.path.getsize(mi.pindex_path)
                      if os.path.exists(mi.pindex_path) else 0)
            with open(mi.pindex_path, "ab") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            self.sim.data_io(mi.pindex_path, len(blob), "write")
            self.sim.fsync(mi.pindex_path)
            ddir = os.path.dirname(mi.pindex_path)
            st = self._subtoc_for(ddir, dlabel)
            _append_record(st, {
                "type": "INDEX", "ckey": clabel,
                "index": {"path": mi.pindex_path, "offset": offset,
                          "length": len(blob)},
                "uris": uris, "axes": axes}, self.sim, unit=st)
            with self._lock:
                # read-your-writes: our own pre-loaded TOC is now stale
                self._preloaded.pop(dlabel, None)

    def close(self) -> None:
        """Write full indexes, point the TOC at them, mask our sub-TOCs."""
        if self._closed:
            return
        with self._lock:
            items = list(self._mem.items())
        masked_datasets: Set[str] = set()
        for (dlabel, clabel), mi in items:
            with self._lock:
                full = dict(mi.full)
                uris = list(mi.uris)
                axes = {d: sorted(v) for d, v in mi.axes.items()}
            if not full:
                continue
            blob = msgpack.packb({"entries": full}, use_bin_type=True)
            with open(mi.findex_path, "wb") as f:
                f.write(blob)
                f.flush()
                os.fsync(f.fileno())
            self.sim.data_io(mi.findex_path, len(blob), "write")
            self.sim.fsync(mi.findex_path)
            ddir = os.path.dirname(mi.findex_path)
            toc = os.path.join(ddir, TOC_FILE)
            _append_record(toc, {
                "type": "TOC_INDEX", "ckey": clabel,
                "index": {"path": mi.findex_path, "offset": 0,
                          "length": len(blob)},
                "uris": uris, "axes": axes}, self.sim, unit=toc)
            masked_datasets.add(dlabel)
        for dlabel in masked_datasets:
            st = self._subtoc_path.get(dlabel)
            if st:
                toc = os.path.join(os.path.dirname(st), TOC_FILE)
                _append_record(toc, {"type": "TOC_MASK", "path": st},
                               self.sim, unit=toc)
        self._closed = True

    # -- read path -----------------------------------------------------------------
    def _preload(self, dataset: Identifier, force: bool = False) -> List[dict]:
        """TOC pre-loading (§2.7.2): read TOC + unmasked sub-TOCs entirely."""
        label = dataset.canonical()
        if not force and label in self._preloaded:
            return self._preloaded[label]
        d = os.path.join(self.sim.root, label)
        toc = os.path.join(d, TOC_FILE)
        records = _read_records(toc, self.sim)
        self.sim.meta()             # TOC open
        masked: Set[str] = set()
        entries: List[dict] = []
        for rec in reversed(records):           # reverse scan: masks first
            if rec.get("type") == "TOC_MASK":
                masked.add(rec["path"])
            elif rec.get("type") == "TOC_INDEX":
                entries.append(rec)
            elif rec.get("type") == "TOC_SUBTOC":
                if rec["path"] in masked:
                    continue
                sub = _read_records(rec["path"], self.sim)
                self.sim.meta()
                entries.extend(reversed(sub))   # newest flush first
        with self._lock:
            self._preloaded[label] = entries
        return entries

    def refresh(self) -> None:
        with self._lock:
            self._preloaded.clear()
            self._index_cache.clear()

    def _load_index(self, ref: dict) -> Dict[str, Tuple[int, int, int]]:
        key = (ref["path"], ref["offset"], ref["length"])
        with self._lock:
            cached = self._index_cache.get(key)
        if cached is not None:
            return cached
        with open(ref["path"], "rb") as f:
            f.seek(ref["offset"])
            blob = f.read(ref["length"])
        # B*-tree loads issue several reads (§2.7.2 retrieve())
        chunk = 64 * 1024
        for off in range(0, max(len(blob), 1), chunk):
            self.sim.read_with_locks(ref["path"],
                                     min(chunk, len(blob) - off))
        idx = {k: tuple(v) for k, v in
               msgpack.unpackb(blob, raw=False)["entries"].items()}
        with self._lock:
            self._index_cache[key] = idx
        return idx

    def axes(self, dataset: Identifier, collocation: Identifier,
             dim: str) -> frozenset:
        out: Set[str] = set()
        for e in self._preload(dataset):
            if e.get("ckey") == collocation.canonical():
                out.update(e.get("axes", {}).get(dim, []))
        return frozenset(out)

    def retrieve(self, dataset: Identifier, collocation: Identifier,
                 element: Identifier) -> Optional[FieldLocation]:
        ckey = collocation.canonical()
        ekey = element.canonical()
        for e in self._preload(dataset):        # newest-first ⇒ replace wins
            if e.get("ckey") != ckey:
                continue
            ax = e.get("axes", {})
            if any(dim in ax and element[dim] not in ax[dim]
                   for dim in element):
                continue                        # axis summary: skip index
            idx = self._load_index(e["index"])
            hit = idx.get(ekey)
            if hit is not None:
                uri_id, off, length = hit
                return FieldLocation("posix", dataset.canonical(),
                                     e["uris"][uri_id], off, length)
        return None

    def list(self, dataset: Identifier, partial: Mapping[str, object]
             ) -> Iterator[Tuple[Identifier, FieldLocation]]:
        seen: Set[str] = set()
        for e in self._preload(dataset):
            ckey = e.get("ckey")
            if ckey is None:
                continue
            collocation = Identifier.from_canonical(ckey)
            if not collocation.matches({k: v for k, v in partial.items()
                                        if k in collocation}):
                continue
            idx = self._load_index(e["index"])
            for ekey, (uri_id, off, length) in idx.items():
                full_key = ckey + "|" + ekey
                if full_key in seen:
                    continue        # an older (masked/partial) duplicate
                seen.add(full_key)
                element = Identifier.from_canonical(ekey)
                ident = self.schema.join(dataset, collocation, element)
                if ident.matches(partial):
                    yield ident, FieldLocation(
                        "posix", dataset.canonical(), e["uris"][uri_id],
                        off, length)

    def datasets(self) -> Iterator[Identifier]:
        if not os.path.isdir(self.sim.root):
            return
        for name in sorted(os.listdir(self.sim.root)):
            if os.path.isdir(os.path.join(self.sim.root, name)):
                yield Identifier.from_canonical(name)

    def wipe(self, dataset: Identifier) -> None:
        label = dataset.canonical()
        with self._lock:
            self._preloaded.pop(label, None)
            self._mem = {k: v for k, v in self._mem.items() if k[0] != label}
            self._subtoc_path.pop(label, None)

"""FDB S3 Store backend (thesis §3.3).

Store-only: S3 lacks atomic append and KV primitives, so no conforming
Catalogue is implementable (the thesis drafts and rejects one); an S3 Store
pairs with any conforming Catalogue (we default to the DAOS catalogue).
Chunk-range leases (multi-writer tensorstore) ride the paired catalogue
too — S3 offers no compare-and-swap to build a lease table on, which is
one more reason the catalogue half lives elsewhere.

Design choices follow the thesis: bucket-per-dataset (cleaner wipes), object
per field keyed by a unique time/host/pid string, persist-on-PUT (flush is a
no-op).  The multipart-upload span mode is drafted in the engine and can be
enabled with ``object_mode="multipart"``.
"""
from __future__ import annotations

import hashlib
import itertools
import os
import socket
import threading
import time
from typing import Dict, Optional, Set, Tuple

from ..engine.s3 import S3Engine
from ..handle import DataHandle, FieldLocation, LazyHandle
from ..interfaces import Store
from ..schema import Identifier
from repro.obs.trace import span as obs_span
from repro.obs.locks import NamedLock

_uniq = itertools.count()


def _bucket_name(dataset: Identifier) -> str:
    return "fdb-" + hashlib.md5(dataset.canonical().encode()).hexdigest()[:16]


class S3Store(Store):
    scheme = "s3"

    def __init__(self, engine: S3Engine, object_mode: str = "per_field",
                 part_size: int = 8 * 1024 * 1024):
        assert object_mode in ("per_field", "multipart")
        self.engine = engine
        self.object_mode = object_mode
        self.part_size = part_size
        self._known_buckets: Set[str] = set()
        # multipart state: (bucket, ckey) -> (upload_id, key, offset, part_no)
        self._mpu: Dict[Tuple[str, str], list] = {}
        self._lock = NamedLock("store.s3")

    def _bucket(self, dataset: Identifier) -> str:
        b = _bucket_name(dataset)
        if b not in self._known_buckets:
            self.engine.create_bucket(b)
            with self._lock:
                self._known_buckets.add(b)
        return b

    def archive(self, data: bytes, dataset: Identifier,
                collocation: Identifier) -> FieldLocation:
        with obs_span("store.s3.archive", nbytes=len(data)):
            return self._archive(data, dataset, collocation)

    def _archive(self, data: bytes, dataset: Identifier,
                 collocation: Identifier) -> FieldLocation:
        bucket = self._bucket(dataset)
        if self.object_mode == "per_field":
            key = (f"{collocation.canonical()}/"
                   f"{time.time_ns()}.{socket.gethostname()}.{os.getpid()}."
                   f"{next(_uniq)}")
            self.engine.put_object(bucket, key, data)   # visible on return
            return FieldLocation(self.scheme, bucket, key, 0, len(data))
        # multipart span mode: parts accumulate, object visible on flush()
        ckey = collocation.canonical()
        with self._lock:
            st = self._mpu.get((bucket, ckey))
            if st is None:
                key = f"{ckey}/span.{time.time_ns()}.{os.getpid()}"
                upload = self.engine.create_multipart_upload(bucket, key)
                st = [upload, key, 0, 0]
                self._mpu[(bucket, ckey)] = st
            upload, key, offset, part_no = st
            st[2] = offset + len(data)
            st[3] = part_no + 1
        self.engine.upload_part(upload, part_no + 1, data)
        return FieldLocation(self.scheme, bucket, key, offset, len(data))

    # NOTE on write coalescing: ``placement()`` stays None — a PUT per field
    # is the §3.3 design (multipart spans reserve offsets per-part, like the
    # RADOS span mode), so batching archives into one request would trade
    # away the request-level parallelism S3 throughput depends on.

    def flush(self) -> None:
        if self.object_mode != "multipart":
            return
        with self._lock:
            mpu, self._mpu = self._mpu, {}
        for upload, _key, _off, _parts in mpu.values():
            self.engine.complete_multipart_upload(upload)

    def retrieve(self, location: FieldLocation) -> DataHandle:
        eng = self.engine
        bucket, key = location.container, location.unit
        off, length = location.offset, location.length
        return LazyHandle(
            lambda: eng.get_object(bucket, key, (off, off + length - 1)),
            length)

    def wipe(self, dataset: Identifier) -> None:
        bucket = _bucket_name(dataset)
        if bucket in self.engine.buckets:
            self.engine.delete_bucket(bucket)
        with self._lock:
            self._known_buckets.discard(bucket)

"""Store and Catalogue backend interfaces (thesis §2.7.1).

Any conforming Catalogue can be paired with any conforming Store; the FDB
facade guarantees the external API semantics if the backends honour these
contracts:

Store
  * ``archive`` takes control of the data and returns a unique, collision-free
    :class:`FieldLocation`; data need not be persistent yet.
  * ``placement`` resolves the destination storage unit an archive would
    append into — without writing — so callers can group archives per unit
    (write coalescing); ``None`` = every archive is its own object.
  * ``archive_batch`` archives several objects in one store-level submission;
    backends whose archives share a storage unit coalesce the batch into a
    single write to that unit.
  * ``flush`` blocks until all data archived by this process is persistent and
    readable by external processes.
  * ``retrieve`` builds a :class:`DataHandle` without performing I/O.

Catalogue
  * ``archive`` indexes element-key → location; may be in-memory only.
  * ``archive_batch`` indexes several entries in one submission.
  * ``flush`` blocks until all indexed entries are persistent & visible.
  * ``close`` finalises process-lifetime structures (e.g. full indexes).
  * ``retrieve`` returns the location for an exact key triple (None = absent —
    not an error: the FDB may be a cache in a larger infrastructure).
  * ``list`` yields (identifier, location) for all indexed objects matching a
    partial identifier.
  * ``axes`` returns all values indexed along one element dimension for a
    (dataset, collocation) pair, served from summaries, not index scans.
  * ``acquire_lease`` / ``release_lease`` / ``lease_holders`` /
    ``check_lease`` — the catalogue-level **chunk-range lease table**
    (see :mod:`repro.core.lease`): exclusive, epoch-fenced leases on
    half-open ranges of linearised chunk ids, shared by every client of
    one deployment.  Lease traffic is control-plane (not metered as
    data-path ops); overlap raises ``LeaseConflictError`` and a fenced
    stale epoch raises ``StaleLeaseError``.
"""
from __future__ import annotations

from typing import Iterator, List, Mapping, Optional, Sequence, Tuple

from .handle import DataHandle, FieldLocation
from .lease import Lease
from .schema import Identifier


class Store:
    scheme: str = "?"

    def archive(self, data: bytes, dataset: Identifier,
                collocation: Identifier) -> FieldLocation:
        raise NotImplementedError

    def placement(self, dataset: Identifier,
                  collocation: Identifier) -> Optional[str]:
        """Destination storage unit an ``archive(dataset, collocation)``
        would append into, resolved without writing — the write-side
        counterpart of ``retrieve``'s no-I/O handle.  ``None`` (the object
        backends) means archives are independent objects with no shared
        unit, so there is nothing to coalesce."""
        return None

    def archive_batch(self, items: Sequence[Tuple[bytes, Identifier,
                                                  Identifier]]
                      ) -> List[FieldLocation]:
        """Archive several objects in one store-level submission, returning
        locations in input order.  The default loops ``archive`` (object
        backends: one op per object is the point); backends with shared
        storage units override to issue one write per unit."""
        return [self.archive(data, dataset, collocation)
                for data, dataset, collocation in items]

    def flush(self) -> None:
        raise NotImplementedError

    def retrieve(self, location: FieldLocation) -> DataHandle:
        raise NotImplementedError

    def close(self) -> None:  # release process-lifetime resources
        pass

    def wipe(self, dataset: Identifier) -> None:
        raise NotImplementedError


class Catalogue:
    scheme: str = "?"

    def archive(self, dataset: Identifier, collocation: Identifier,
                element: Identifier, location: FieldLocation) -> None:
        raise NotImplementedError

    def archive_batch(self, entries: Sequence[Tuple[Identifier, Identifier,
                                                    Identifier,
                                                    FieldLocation]]) -> None:
        """Index several entries in one submission (the catalogue half of a
        batched archive).  Default loops ``archive``; backends with per-key
        in-memory indexes override to take their locks once per key."""
        for dataset, collocation, element, location in entries:
            self.archive(dataset, collocation, element, location)

    def flush(self) -> None:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    def retrieve(self, dataset: Identifier, collocation: Identifier,
                 element: Identifier) -> Optional[FieldLocation]:
        raise NotImplementedError

    def list(self, dataset: Identifier, partial: Mapping[str, object]
             ) -> Iterator[Tuple[Identifier, FieldLocation]]:
        raise NotImplementedError

    def axes(self, dataset: Identifier, collocation: Identifier,
             dim: str) -> frozenset:
        raise NotImplementedError

    # -- chunk-range leases (multi-writer concurrency control) --------------
    def acquire_lease(self, dataset: Identifier, collocation: Identifier,
                      resource: str, lo: int, hi: int, owner: str,
                      ttl: Optional[float] = None, block: bool = False,
                      timeout: Optional[float] = None) -> int:
        """Acquire an exclusive lease on the half-open chunk-id range
        ``[lo, hi)`` of ``resource`` for ``owner``; returns the lease
        *epoch* (monotonic per (dataset, collocation, resource)).  Raises
        ``LeaseConflictError`` when the range overlaps another owner's
        active lease; an exact same-owner re-acquire is idempotent.
        ``ttl`` bounds the lease's life between heartbeat renewals
        (expiry behaves like a release, on the deployment's shared lease
        clock); ``block=True`` queues on a conflicting range until it
        frees or ``timeout`` seconds pass (then ``LeaseConflictError``)."""
        raise NotImplementedError

    def release_lease(self, dataset: Identifier, collocation: Identifier,
                      resource: str, lo: int, hi: int, owner: str,
                      exact: bool = False) -> None:
        """Release ``owner``'s leases overlapping ``[lo, hi)``.  Any caller
        may release any owner's lease (the coordinator escape hatch for
        presumed-dead writers — epoch fencing keeps it safe).
        ``exact=True`` releases only a lease on exactly ``[lo, hi)`` — the
        holder-side form, which cannot sweep away the owner's own
        overlapping sibling leases."""
        raise NotImplementedError

    def lease_holders(self, dataset: Identifier, collocation: Identifier,
                      resource: str) -> List[Lease]:
        """All active leases under (dataset, collocation, resource)."""
        raise NotImplementedError

    def check_lease(self, dataset: Identifier, collocation: Identifier,
                    resource: str, lo: int, hi: int, owner: str,
                    epoch: int) -> None:
        """Commit-time fencing gate: raise ``StaleLeaseError`` unless
        ``owner`` still holds a covering lease at exactly ``epoch``."""
        raise NotImplementedError

    def lease_table(self):
        """The deployment's shared :class:`repro.core.lease.LeaseTable`
        — the facade's direct line for TTL renewal, expiry sweeps and
        the crash-recovery dirty-intent journal."""
        raise NotImplementedError

    def lease_key(self, dataset: Identifier, collocation: Identifier,
                  resource: str):
        """The lease-table key triple for (dataset, collocation,
        resource)."""
        raise NotImplementedError

    def datasets(self) -> Iterator[Identifier]:
        """All dataset keys known to this catalogue (the thesis's registry)."""
        raise NotImplementedError

    def wipe(self, dataset: Identifier) -> None:
        raise NotImplementedError

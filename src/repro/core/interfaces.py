"""Store and Catalogue backend interfaces (thesis §2.7.1).

Any conforming Catalogue can be paired with any conforming Store; the FDB
facade guarantees the external API semantics if the backends honour these
contracts:

Store
  * ``archive`` takes control of the data and returns a unique, collision-free
    :class:`FieldLocation`; data need not be persistent yet.
  * ``flush`` blocks until all data archived by this process is persistent and
    readable by external processes.
  * ``retrieve`` builds a :class:`DataHandle` without performing I/O.

Catalogue
  * ``archive`` indexes element-key → location; may be in-memory only.
  * ``flush`` blocks until all indexed entries are persistent & visible.
  * ``close`` finalises process-lifetime structures (e.g. full indexes).
  * ``retrieve`` returns the location for an exact key triple (None = absent —
    not an error: the FDB may be a cache in a larger infrastructure).
  * ``list`` yields (identifier, location) for all indexed objects matching a
    partial identifier.
  * ``axes`` returns all values indexed along one element dimension for a
    (dataset, collocation) pair, served from summaries, not index scans.
"""
from __future__ import annotations

from typing import Iterator, Mapping, Optional, Tuple

from .handle import DataHandle, FieldLocation
from .schema import Identifier


class Store:
    scheme: str = "?"

    def archive(self, data: bytes, dataset: Identifier,
                collocation: Identifier) -> FieldLocation:
        raise NotImplementedError

    def flush(self) -> None:
        raise NotImplementedError

    def retrieve(self, location: FieldLocation) -> DataHandle:
        raise NotImplementedError

    def close(self) -> None:  # release process-lifetime resources
        pass

    def wipe(self, dataset: Identifier) -> None:
        raise NotImplementedError


class Catalogue:
    scheme: str = "?"

    def archive(self, dataset: Identifier, collocation: Identifier,
                element: Identifier, location: FieldLocation) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    def retrieve(self, dataset: Identifier, collocation: Identifier,
                 element: Identifier) -> Optional[FieldLocation]:
        raise NotImplementedError

    def list(self, dataset: Identifier, partial: Mapping[str, object]
             ) -> Iterator[Tuple[Identifier, FieldLocation]]:
        raise NotImplementedError

    def axes(self, dataset: Identifier, collocation: Identifier,
             dim: str) -> frozenset:
        raise NotImplementedError

    def datasets(self) -> Iterator[Identifier]:
        """All dataset keys known to this catalogue (the thesis's registry)."""
        raise NotImplementedError

    def wipe(self, dataset: Identifier) -> None:
        raise NotImplementedError

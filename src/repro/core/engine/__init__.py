from .meter import Meter, Op, client_context, current_client
from .costmodel import HardwareProfile, PROFILES, model_run
from .daos import DaosEngine
from .rados import RadosEngine
from .s3 import S3Engine

__all__ = [
    "Meter", "Op", "client_context", "current_client",
    "HardwareProfile", "PROFILES", "model_run",
    "DaosEngine", "RadosEngine", "S3Engine",
]

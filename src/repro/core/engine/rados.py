"""In-process Ceph/RADOS-like object engine (thesis §2.4).

Implements the librados surface the FDB Ceph backends need:

* **Pools** with configurable **placement-group** counts, replication factor
  or 2+1 erasure coding (redundancy is a *pool* property, unlike DAOS);
* **Namespaces** inside pools (lightweight, no create/open RPC — §3.2.1);
* regular objects (``write_full``/``read``/``stat``) with the RADOS
  **object-size limit** (128 MiB default) enforced;
* **Omap** key-value objects, including the single-RPC full read
  (``omap_get_all`` ≈ rados_read_op_omap_get_vals_by_keys2) that makes the
  Ceph ``list()`` implementation more efficient than DAOS's (§3.2.1);
* algorithmic placement: pg = stable_hash(name) % pg_count, osd = pg % n_osds
  — *PG count caps effective parallelism*, reproducing the PG sensitivity of
  §3.2 (Fig. 3.5, second test set).

MVCC-style consistency: the primary OSD persists, replicas follow, and the
index (our dict slot) is published last — readers always see complete
versions (§2.4).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

from .meter import GLOBAL_METER, Meter
from ..util import stable_hash

MiB = 1024 ** 2


class RadosApiError(RuntimeError):
    pass


class _Pool:
    def __init__(self, name: str, pg_count: int, replication: int = 1,
                 ec: Optional[Tuple[int, int]] = None):
        self.name = name
        self.pg_count = pg_count
        self.replication = replication      # 1 = none
        self.ec = ec                        # (k, m) e.g. (2, 1)
        self.objects: Dict[Tuple[str, str], bytes] = {}
        self.omaps: Dict[Tuple[str, str], Dict[str, bytes]] = {}
        self.lock = threading.Lock()


class RadosEngine:
    def __init__(self, n_osds: int = 16, max_object_size: int = 128 * MiB,
                 meter: Optional[Meter] = None):
        self.n_osds = n_osds
        self.max_object_size = max_object_size
        self.meter = meter or GLOBAL_METER
        self.pools: Dict[str, _Pool] = {}
        self._lock = threading.Lock()

    # -- placement -------------------------------------------------------------
    def _osd(self, pool: _Pool, name: str, shift: int = 0) -> str:
        pg = stable_hash(name) % pool.pg_count
        return f"osd:{(pg + shift) % self.n_osds}|pg:{pg % pool.pg_count}"

    # -- pool management ---------------------------------------------------------
    def pool_create(self, name: str, pg_count: int = 512, replication: int = 1,
                    ec: Optional[Tuple[int, int]] = None) -> None:
        with self._lock:
            if name not in self.pools:
                self.pools[name] = _Pool(name, pg_count, replication, ec)
        self.meter.record("mon", "meta", 0)

    def pool_delete(self, name: str) -> None:
        with self._lock:
            self.pools.pop(name, None)
        self.meter.record("mon", "meta", 0)

    def _pool(self, name: str) -> _Pool:
        p = self.pools.get(name)
        if p is None:
            raise RadosApiError(f"no such pool {name!r}")
        return p

    # -- regular objects -----------------------------------------------------------
    def _redundancy_meter(self, pool: _Pool, name: str, nbytes: int) -> None:
        for r in range(pool.replication - 1):
            self.meter.record(self._osd(pool, name, shift=1 + r),
                              "repl_write", nbytes)
        if pool.ec:
            k, m = pool.ec
            for j in range(m):
                self.meter.record(self._osd(pool, name, shift=1 + j),
                                  "repl_write", nbytes * m // k)

    def write_full(self, pool: str, ns: str, name: str, data: bytes) -> None:
        p = self._pool(pool)
        if len(data) > self.max_object_size:
            raise RadosApiError(
                f"object {name!r} size {len(data)} exceeds RADOS limit "
                f"{self.max_object_size} (thesis §2.4: split large elements)")
        p.objects[(ns, name)] = bytes(data)   # publish atomically
        self.meter.record(self._osd(p, name), "write", len(data))
        self._redundancy_meter(p, name, len(data))

    def append(self, pool: str, ns: str, name: str, data: bytes) -> int:
        """RADOS append (used by the multi-field-object store mode)."""
        p = self._pool(pool)
        with p.lock:
            cur = p.objects.get((ns, name), b"")
            if len(cur) + len(data) > self.max_object_size:
                raise RadosApiError("append exceeds object size limit")
            p.objects[(ns, name)] = cur + bytes(data)
            off = len(cur)
        self.meter.record(self._osd(p, name), "write", len(data))
        self._redundancy_meter(p, name, len(data))
        return off

    def read(self, pool: str, ns: str, name: str, offset: int = 0,
             length: int = -1) -> bytes:
        p = self._pool(pool)
        data = p.objects.get((ns, name))
        if data is None:
            self.meter.record(self._osd(p, name), "read", 0)
            return b""
        if p.ec:
            # EC pools fetch the full object extent even for partial reads (§2.5)
            fetched = len(data)
        else:
            fetched = len(data[offset:offset + length if length >= 0 else None])
        out = data[offset:] if length < 0 else data[offset:offset + length]
        self.meter.record(self._osd(p, name), "read", fetched)
        return out

    def stat(self, pool: str, ns: str, name: str) -> Optional[int]:
        p = self._pool(pool)
        data = p.objects.get((ns, name))
        self.meter.record(self._osd(p, name), "meta", 0)
        return None if data is None else len(data)

    def remove(self, pool: str, ns: str, name: str) -> None:
        p = self._pool(pool)
        with p.lock:
            p.objects.pop((ns, name), None)
            p.omaps.pop((ns, name), None)
        self.meter.record(self._osd(p, name), "meta", 0)

    def list_objects(self, pool: str, ns: str) -> List[str]:
        p = self._pool(pool)
        names = [n for (s, n) in list(p.objects) if s == ns] + \
                [n for (s, n) in list(p.omaps) if s == ns and (s, n) not in p.objects]
        self.meter.record("mon", "meta", 0)
        return names

    # -- omaps ---------------------------------------------------------------------
    def omap_create(self, pool: str, ns: str, name: str) -> None:
        p = self._pool(pool)
        with p.lock:
            p.omaps.setdefault((ns, name), {})
        self.meter.record(self._osd(p, name), "meta", 0)

    def omap_set(self, pool: str, ns: str, name: str,
                 kvs: Dict[str, bytes]) -> None:
        p = self._pool(pool)
        with p.lock:
            omap = p.omaps.setdefault((ns, name), {})
            new = dict(omap)
            for k, v in kvs.items():
                new[k] = bytes(v)
            p.omaps[(ns, name)] = new          # publish atomically
        nbytes = sum(len(k) + len(v) for k, v in kvs.items())
        self.meter.record(self._osd(p, name), "omap_set", nbytes,
                          unit=f"{ns}/{name}")
        self._redundancy_meter(p, name, nbytes)

    def omap_get_vals_by_keys(self, pool: str, ns: str, name: str,
                              keys: List[str]) -> Dict[str, bytes]:
        p = self._pool(pool)
        omap = p.omaps.get((ns, name), {})
        out = {k: omap[k] for k in keys if k in omap}
        self.meter.record(self._osd(p, name), "omap_get",
                          sum(len(v) for v in out.values()),
                          unit=f"{ns}/{name}")
        return out

    def omap_get_all(self, pool: str, ns: str, name: str) -> Dict[str, bytes]:
        """Full keys+values in a single RPC (unavailable in DAOS — §3.2.1)."""
        p = self._pool(pool)
        omap = dict(p.omaps.get((ns, name), {}))
        self.meter.record(self._osd(p, name), "omap_get",
                          sum(len(k) + len(v) for k, v in omap.items()),
                          unit=f"{ns}/{name}")
        return omap

    def omap_list_keys(self, pool: str, ns: str, name: str) -> List[str]:
        p = self._pool(pool)
        keys = list(p.omaps.get((ns, name), {}).keys())
        self.meter.record(self._osd(p, name), "omap_list",
                          sum(len(k) for k in keys), unit=f"{ns}/{name}")
        return keys

"""Analytic cluster cost model (DESIGN.md §3.2).

Converts an in-process op trace (:class:`..meter.Op`) into a modeled
wall-time / aggregate bandwidth for a *target cluster* described by a
:class:`HardwareProfile`.  The model is a bottleneck-max over five terms:

  T = max( T_latency,      per-client serial op latency (clients parallel)
           T_client_net,   per-client-node NIC bytes / bandwidth
           T_server,       per-server-resource storage bytes (w + r serialized)
           T_server_net,   per-server-node NIC bytes / bandwidth
           T_op_rate,      per-resource op-count / service rate
           T_meta,         centralized metadata service (Lustre MDS, Ceph Mon)
           T_hotspot )     per-unit serialized commits (contended KV keys)

This reproduces the paper's qualitative scaling behaviour: DAOS is
network/storage-bound and scales with targets (C1); Ceph pays TCP latency and
per-op CPU (C2, C6); Lustre pays MDS + distributed-lock terms under
contention (C1); redundancy multiplies server-side bytes (C5); hot-spotted
index keys serialize (C7).

Latency/bandwidth parameters are calibrated from the thesis's own hardware
sections (§4.2.2 NEXTGenIO ideal-node figures, §4.3.2 GCP ideal-node figures,
Table 4.1 PSM2/TCP rates) — see PROFILES.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, defaultdict
from typing import Dict, Iterable, List, Mapping, Optional

from .meter import Op
from ..util import stable_hash

MiB = 1024 ** 2
GiB = 1024 ** 3

#: op kinds that move payload bytes to/from storage
WRITE_KINDS = frozenset({"array_write", "write", "append", "http_put",
                         "repl_write", "omap_set", "kv_put"})
READ_KINDS = frozenset({"array_read", "read", "http_get", "kv_get",
                        "omap_get", "kv_list", "omap_list"})
#: kinds counted as *payload* for bandwidth reporting (index/meta excluded)
PAYLOAD_W = frozenset({"array_write", "write", "append", "http_put"})
PAYLOAD_R = frozenset({"array_read", "read", "http_get"})


@dataclasses.dataclass(frozen=True)
class BackendParams:
    """Per-backend service parameters."""
    lat: Mapping[str, float]        # client-observed latency per op kind (s)
    default_lat: float              # fallback latency
    op_rate: float                  # ops/s per data resource (target/OSD/OST)
    meta_rate: float                # ops/s of centralized meta service
    key_serial_rate: float          # serialized commits/s on one hot unit
    lock_latency: float             # distributed-lock round trip (s)


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    client_link_bw: float           # B/s per client node NIC
    server_link_bw: float           # B/s per server node NIC
    storage_w: float                # B/s per server node, writes
    storage_r: float                # B/s per server node, reads
    backends: Mapping[str, BackendParams]

    def backend_for(self, resource: str) -> str:
        if resource.startswith("target"):
            return "daos"
        if resource.startswith(("osd", "mon", "pg")):
            return "rados"
        if resource.startswith(("ost", "mds", "ldlm")):
            return "posix"
        return "s3"


def _daos_params(rpc: float) -> BackendParams:
    return BackendParams(
        lat={"kv_put": rpc, "kv_get": rpc, "kv_list": 2 * rpc,
             "array_write": rpc, "array_read": rpc, "meta": 8 * rpc,
             "oid_alloc": rpc, "repl_write": rpc},
        default_lat=rpc, op_rate=200_000.0, meta_rate=float("inf"),
        key_serial_rate=30_000.0, lock_latency=0.0)


def _rados_params(rpc: float) -> BackendParams:
    return BackendParams(
        lat={"omap_set": rpc, "omap_get": rpc, "omap_list": rpc,
             "write": rpc, "read": rpc, "meta": 4 * rpc,
             "repl_write": rpc},
        default_lat=rpc, op_rate=15_000.0, meta_rate=30_000.0,
        key_serial_rate=8_000.0, lock_latency=0.0)


def _posix_params(rpc: float, lock: float) -> BackendParams:
    return BackendParams(
        lat={"write": rpc, "read": rpc, "append": rpc, "meta": 4 * rpc,
             "fsync": 20 * rpc, "lock": lock},
        default_lat=rpc, op_rate=50_000.0, meta_rate=40_000.0,
        key_serial_rate=20_000.0, lock_latency=lock)


def _s3_params() -> BackendParams:
    http = 800e-6
    return BackendParams(
        lat={"http_put": http, "http_get": http, "http_list": 2 * http,
             "meta": 2 * http},
        default_lat=http, op_rate=8_000.0, meta_rate=10_000.0,
        key_serial_rate=5_000.0, lock_latency=0.0)


PROFILES: Dict[str, HardwareProfile] = {
    # NEXTGenIO: 3D XPoint SCM servers, 100 Gb/s Omni-Path (PSM2), §4.2.
    "nextgenio": HardwareProfile(
        name="nextgenio",
        client_link_bw=11.0 * GiB, server_link_bw=11.0 * GiB,
        storage_w=8.0 * GiB, storage_r=10.0 * GiB,
        backends={
            "daos": _daos_params(rpc=25e-6),
            "rados": _rados_params(rpc=300e-6),   # Ceph = TCP even here
            "posix": _posix_params(rpc=80e-6, lock=700e-6),
            "s3": _s3_params(),
        }),
    # GCP: n2-custom-36 VMs, 6 TiB local NVMe, 32 Gb/s VM NICs, §4.3.
    "gcp": HardwareProfile(
        name="gcp",
        client_link_bw=4.0 * GiB, server_link_bw=4.0 * GiB,
        storage_w=3.2 * GiB, storage_r=6.6 * GiB,
        backends={
            "daos": _daos_params(rpc=60e-6),      # OFI/tcp provider on GCP
            "rados": _rados_params(rpc=350e-6),
            "posix": _posix_params(rpc=120e-6, lock=900e-6),
            "s3": _s3_params(),
        }),
}


@dataclasses.dataclass
class ModelResult:
    wall_time: float
    write_bw: float                 # modeled aggregate payload write B/s
    read_bw: float
    terms: Dict[str, float]
    dominant: str
    payload_w: int
    payload_r: int

    def as_dict(self) -> Dict[str, object]:
        return {
            "wall_time_s": self.wall_time,
            "write_bw_GiBps": self.write_bw / GiB,
            "read_bw_GiBps": self.read_bw / GiB,
            "dominant": self.dominant,
            **{f"T_{k}": v for k, v in self.terms.items()},
        }


def _node_of(client: str) -> str:
    return client.split("@", 1)[1] if "@" in client else "node0"


def model_run(ops: Iterable[Op], profile: HardwareProfile,
              server_nodes: int, targets_per_node: int = 1,
              op_scale: float = 1.0) -> ModelResult:
    """Model the wall time of the traced run on ``profile`` hardware.

    ``server_nodes`` — number of storage server nodes in the modeled cluster;
    engine resources are folded onto them round-robin.
    ``op_scale`` — multiply op counts/bytes (to extrapolate a short in-process
    run to the paper's per-process field counts; bandwidth is steady-state so
    this only matters for fixed-cost amortisation).
    """
    ops = list(ops)
    lat_per_client: Counter = Counter()
    bytes_per_cnode: Counter = Counter()
    storage_w_per_snode: Counter = Counter()
    storage_r_per_snode: Counter = Counter()
    net_per_snode: Counter = Counter()
    ops_per_resource: Counter = Counter()
    resource_backend: Dict[str, str] = {}
    meta_ops: Counter = Counter()           # backend -> centralized meta ops
    hot_puts: Counter = Counter()           # (backend, unit) -> serialized ops
    payload_w = payload_r = 0

    for op in ops:
        be = profile.backend_for(op.resource)
        params = profile.backends[be]
        lat = params.lat.get(op.kind, params.default_lat)
        lat_per_client[op.client] += lat
        if op.resource in ("mds", "mon"):
            meta_ops[be] += 1
            continue
        resource_backend[op.resource] = be
        ops_per_resource[op.resource] += 1
        snode = f"snode{stable_hash(op.resource) % max(server_nodes, 1)}"
        if op.kind in WRITE_KINDS:
            storage_w_per_snode[snode] += op.nbytes
            net_per_snode[snode] += op.nbytes
            bytes_per_cnode[_node_of(op.client)] += op.nbytes
        elif op.kind in READ_KINDS:
            storage_r_per_snode[snode] += op.nbytes
            net_per_snode[snode] += op.nbytes
            bytes_per_cnode[_node_of(op.client)] += op.nbytes
        if op.kind in PAYLOAD_W:
            payload_w += op.nbytes
        elif op.kind in PAYLOAD_R:
            payload_r += op.nbytes
        if op.unit and op.kind in ("kv_put", "omap_set", "append"):
            hot_puts[(be, op.unit)] += 1

    s = op_scale
    terms: Dict[str, float] = {}
    terms["latency"] = max(lat_per_client.values(), default=0.0) * s
    terms["client_net"] = max(
        (b / profile.client_link_bw for b in bytes_per_cnode.values()),
        default=0.0) * s
    terms["server_storage"] = max(
        ((storage_w_per_snode[n] / profile.storage_w)
         + (storage_r_per_snode[n] / profile.storage_r)
         for n in set(storage_w_per_snode) | set(storage_r_per_snode)),
        default=0.0) * s
    terms["server_net"] = max(
        (b / profile.server_link_bw for b in net_per_snode.values()),
        default=0.0) * s
    terms["op_rate"] = max(
        (c / profile.backends[resource_backend[r]].op_rate
         for r, c in ops_per_resource.items()),
        default=0.0) * s
    terms["meta"] = max(
        (c / profile.backends[be].meta_rate for be, c in meta_ops.items()),
        default=0.0) * s
    terms["hotspot"] = max(
        (c / profile.backends[be].key_serial_rate
         for (be, _u), c in hot_puts.items()),
        default=0.0) * s

    wall = max(terms.values()) if terms else 0.0
    dominant = max(terms, key=lambda k: terms[k]) if terms else "none"
    pw, pr = int(payload_w * s), int(payload_r * s)
    return ModelResult(
        wall_time=wall,
        write_bw=(pw / wall) if wall > 0 and pw else 0.0,
        read_bw=(pr / wall) if wall > 0 and pr else 0.0,
        terms=terms, dominant=dominant, payload_w=pw, payload_r=pr)
